"""Serve-layer benchmark: coalesced multi-query throughput + result cache.

The serving tier's reason to exist is amortization: Q compatible
concurrent queries ride ⌈shards/wave⌉ ``run_wave_fused_multi`` device
dispatches *total* instead of Q×⌈shards/wave⌉ single-query dispatches.
The report shows

  * p50/p99 latency and QPS for N ∈ {1, 8, 64} concurrent trip queries
    served through the coalescing scheduler,
  * the same pool served strictly one query at a time (the N=1
    sequential baseline) and the resulting **coalesce speedup** — the
    acceptance gate is coalesced N=8 QPS > 2× the sequential baseline,
  * launch evidence: one coalesced batch of Q compatible queries costs
    exactly ⌈shards/wave⌉ ``run_wave_fused_multi`` dispatches,
  * cold vs warm TTL-cache service of an identical pool (warm must be
    pure cache hits), and
  * a byte-parity verdict: every coalesced result equals the
    single-query numpy-oracle rows for the same flow.
"""
from __future__ import annotations

import math
import time

import numpy as np

from repro.core import fdb
from repro.data.synthetic import generate_world
from repro.exec import AdHocEngine, Catalog
from repro.exec.batched import fused_enabled
from repro.fdb import build_fdb
from repro.kernels import ops
from repro.serve import QueryServer

from .queries import TRIP_QUERIES, tesseract_for

__all__ = ["run"]


def _pool(n: int):
    """``n`` compatible-but-distinct trip flows: the Q6/Q7 legs with the
    hour windows jittered, so the pool shares one coalescing key (same
    FDb, same shards, same refine path) while every query keeps its own
    constraints and its own cache key.  No per-record lambdas — the
    flows stay hashable for the result cache."""
    base = list(TRIP_QUERIES.values())
    flows = []
    for k in range(n):
        legs = base[k % len(base)]
        jit = 0.25 * ((k // len(base)) % 8)
        legs = tuple((cities, h0 + jit, h1 + jit)
                     for cities, h0, h1 in legs)
        flows.append(fdb("Trips").tesseract(tesseract_for(legs)))
    return flows


def _serve_once(srv: QueryServer, flows, coalesced: bool = True):
    """Submit the pool, drain it, return (wall_s, sorted per-query
    latencies).  ``coalesced=False`` drains after every submit — the
    strictly-sequential baseline on the same machinery."""
    lat: list = []
    futs = []
    t0 = time.perf_counter()
    for f in flows:
        ts = time.perf_counter()
        fut = srv.submit(f)
        fut.add_done_callback(
            lambda _f, ts=ts: lat.append(time.perf_counter() - ts))
        futs.append(fut)
        if not coalesced:
            srv.run_pending()
    if coalesced:
        srv.run_pending()
    for f in futs:
        f.result(300)
    return time.perf_counter() - t0, sorted(lat)


def _pcts(lat):
    p50 = lat[int(0.50 * (len(lat) - 1))]
    p99 = lat[int(0.99 * (len(lat) - 1))]
    return p50 * 1e3, p99 * 1e3


def run(scale: float = 0.5, print_fn=print, raise_on_mismatch: bool = True):
    rows: list = []
    # same floor as bench_tesseract: below ~0.2 the synthetic week holds
    # so few trips that the queries select nothing and parity is vacuous
    scale = max(scale, 0.2)
    world = generate_world(scale=scale)
    cat = Catalog(server_slots=64)
    cat.register(build_fdb("Trips", world["trips_schema"], world["trips"],
                           num_shards=10))
    db = cat.get("Trips")

    def server(**kw):
        kw.setdefault("cache", False)
        srv = QueryServer(catalog=cat, backend="jax", start=False,
                          max_pending=256, **kw)
        return srv

    # ---- correctness: coalesced rows ≡ single-query numpy oracle rows
    pool8 = _pool(8)
    np_eng = AdHocEngine(cat, backend="numpy")
    oracle = [np.sort(np_eng.collect(f).batch["id"].values) for f in pool8]
    srv = server()
    futs = [srv.submit(f) for f in pool8]
    srv.run_pending()
    parity = all(
        np.array_equal(np.sort(f.result(300).batch["id"].values), o)
        for f, o in zip(futs, oracle))
    if srv.stats()["coalesced_queries"] != len(pool8):
        parity = False

    # ---- launch evidence: Q coalesced queries ⇒ ⌈shards/wave⌉ multi
    #      dispatches total (REPRO_EXEC_FUSED=0 falls back to per-query
    #      per-primitive launches — still served, evidence informational)
    for f in pool8:
        srv.submit(f)
    srv.run_pending()                          # warm: prime + jit
    for f in pool8:
        srv.submit(f)
    ops.reset_launch_counts()
    srv.run_pending()
    lc = dict(ops.launch_counts())
    waves = math.ceil(db.num_shards / srv.engine.wave)
    if fused_enabled():
        launches_ok = lc == {"run_wave_fused_multi": waves}
    else:
        launches_ok = lc.get("run_wave_fused", 0) == 0 \
            and lc.get("run_wave_fused_multi", 0) == 0
    parity &= launches_ok
    rows.append({"name": "serve_launch_evidence", "us_per_call": "",
                 "parity": 1 if launches_ok else 0,
                 "derived": (f"launches={lc} waves={waves} "
                             f"q={len(pool8)} "
                             f"fused={1 if fused_enabled() else 0}")})
    print_fn(f"  launch evidence: {rows[-1]['derived']}")

    # ---- throughput: coalesced N ∈ {1, 8, 64}
    qps = {}
    for n in (1, 8, 64):
        flows = _pool(n)
        srv = server()
        _serve_once(srv, flows)                # warm (jit per batch shape)
        best = None
        for _ in range(2):
            wall, lat = _serve_once(srv, flows)
            if best is None or wall < best[0]:
                best = (wall, lat)
        wall, lat = best
        p50, p99 = _pcts(lat)
        qps[n] = n / wall
        st = srv.stats()
        rows.append({
            "name": f"serve_coalesced_n{n}",
            "us_per_call": round(wall / n * 1e6, 1),
            "parity": 1,
            "derived": (f"qps={qps[n]:.1f} p50_ms={p50:.1f} "
                        f"p99_ms={p99:.1f} "
                        f"coalesced={st['coalesced_queries']} "
                        f"fallback={st['fallback_queries']}")})
        print_fn(f"  coalesced n={n}: {rows[-1]['derived']}")

    # ---- sequential baseline (one query per drain) + speedup gate
    flows = _pool(8)
    srv = server(max_coalesce=1)
    _serve_once(srv, flows, coalesced=False)   # warm
    wall_seq = min(_serve_once(srv, flows, coalesced=False)[0]
                   for _ in range(2))
    qps_seq = len(flows) / wall_seq
    speedup = qps[8] / max(qps_seq, 1e-9)
    gate = speedup > 2.0
    parity &= gate
    rows.append({"name": "serve_sequential_n1",
                 "us_per_call": round(wall_seq / len(flows) * 1e6, 1),
                 "parity": 1,
                 "derived": f"qps={qps_seq:.1f}"})
    rows.append({"name": "serve_coalesce_speedup", "us_per_call": "",
                 "parity": 1 if gate else 0,
                 "derived": (f"speedup={speedup:.2f}x "
                             f"coalesced_qps={qps[8]:.1f} "
                             f"sequential_qps={qps_seq:.1f} "
                             f"gate={'OK' if gate else 'MISS(<2x)'}")})
    print_fn(f"  sequential: qps={qps_seq:.1f}; "
             f"coalesce speedup: {rows[-1]['derived']}")

    # ---- cache: cold serve, then the identical pool warm (pure hits)
    flows = _pool(8)
    srv = server(cache=None)                   # default TTL ResultCache
    _serve_once(srv, flows)                    # jit warm (cache cleared)
    srv.cache.clear()
    wall_cold, _ = _serve_once(srv, flows)
    wall_warm, _ = _serve_once(srv, flows)
    st = srv.stats()
    warm_hits = st["cache_hits"] >= len(flows)
    parity &= warm_hits
    rows.append({"name": "serve_cache_cold",
                 "us_per_call": round(wall_cold / len(flows) * 1e6, 1),
                 "parity": 1,
                 "derived": f"qps={len(flows) / wall_cold:.1f}"})
    rows.append({"name": "serve_cache_warm",
                 "us_per_call": round(wall_warm / len(flows) * 1e6, 1),
                 "parity": 1 if warm_hits else 0,
                 "derived": (f"qps={len(flows) / wall_warm:.1f} "
                             f"hits={st['cache_hits']} "
                             f"errors={st['cache_errors']} "
                             f"speedup={wall_cold / max(wall_warm, 1e-9):.1f}x")})
    print_fn(f"  cache: cold {wall_cold * 1e3:.1f}ms → warm "
             f"{wall_warm * 1e3:.1f}ms ({rows[-1]['derived']})")

    rows.append({"name": "serve_parity_all", "us_per_call": "",
                 "parity": 1 if parity else 0,
                 "derived": "OK" if parity else "MISMATCH"})
    print_fn(f"  serve parity + gates: {'OK' if parity else 'MISMATCH'}")
    if not parity and raise_on_mismatch:
        raise AssertionError("serve coalescing parity/gate violated")
    return rows
