"""Per-kernel microbenchmarks.

Wall-clock on CPU measures the *reference* jnp path (interpret mode
executes kernel bodies in Python — not a timing proxy); the Pallas kernels
target TPU, so their perf claim lives in §Roofline, not here.  What this
bench adds: per-call µs of the reference math (the dry-run's compute) and
derived throughput figures.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

__all__ = ["run"]


def _time(fn, *args, repeats=5, **kw):
    fn(*args, **kw)                      # compile+warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6                    # µs


def run(print_fn=print):
    rng = np.random.default_rng(0)
    rows = []

    w = 1 << 20
    stack = jnp.asarray(rng.integers(0, 2**32, (4, w), dtype=np.uint32))
    us = _time(lambda s: ops.bitmap_intersect(s, impl="reference")[0],
               stack)
    rows.append({"name": "kernel_bitmap_intersect_4x1Mwords",
                 "us_per_call": round(us, 1),
                 "derived": f"{4 * w * 4 / us / 1e3:.2f} GB/s"})

    n = 1 << 20
    mask = jnp.asarray(rng.random(n) < 0.3)
    us = _time(lambda m: ops.compact(m, impl="reference")[0], mask)
    rows.append({"name": "kernel_compact_1M",
                 "us_per_call": round(us, 1),
                 "derived": f"{n / us:.1f} Melem/s"})

    gid = jnp.asarray(rng.integers(0, 1024, n, dtype=np.int32))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    us = _time(lambda g, v: ops.segment_agg(g, v, 1024,
                                            impl="reference")[1],
               gid, vals)
    rows.append({"name": "kernel_segment_agg_1M_1024g",
                 "us_per_call": round(us, 1),
                 "derived": f"{n / us:.1f} Melem/s"})

    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 1024, 64)).astype(np.float32))
    flops = 4 * 8 * 1024 * 1024 * 64 / 2     # causal ≈ half
    us = _time(lambda a, b: ops.flash_attention(a, b, b,
                                                impl="reference"), q, k)
    rows.append({"name": "kernel_flash_attention_1k_gqa",
                 "us_per_call": round(us, 1),
                 "derived": f"{flops / us / 1e3:.2f} GFLOP/s"})

    a = jnp.asarray(rng.uniform(0.8, 1.0, (4, 2048, 256)
                                ).astype(np.float32))
    bx = jnp.asarray(rng.normal(size=(4, 2048, 256)).astype(np.float32))
    us = _time(lambda x, y: ops.ssm_scan(x, y, impl="reference")[0], a, bx)
    rows.append({"name": "kernel_ssm_scan_4x2048x256",
                 "us_per_call": round(us, 1),
                 "derived": f"{4 * 2048 * 256 / us:.1f} Melem/s"})

    for r in rows:
        print_fn(f"  {r['name']:42s} {r['us_per_call']:10.1f} µs  "
                 f"{r['derived']}")
    return rows
