"""Paper Figure 12: data scan size per query (Q1–Q5)."""
from __future__ import annotations

from repro.exec import AdHocEngine

from .queries import QUERIES, build_catalog, q_variability

__all__ = ["run"]


def run(scale: float = 1.0, num_shards: int = 40, print_fn=print):
    cat = build_catalog(scale=scale, num_shards=num_shards)
    engine = AdHocEngine(cat, num_servers=16)
    total_bytes = cat.get("SpeedObservations").nbytes()
    rows = []
    for qname, (cities, months) in QUERIES.items():
        res = engine.collect(q_variability(cities, months,
                                           mode="multi_index"))
        p = res.profile
        rows.append({
            "name": f"fig12_{qname}",
            "bytes_read": p.bytes_read,
            "dataset_bytes": total_bytes,
            "scan_fraction_pct": round(100 * p.bytes_read
                                       / max(total_bytes, 1), 3),
            "rows_selected": p.rows_selected,
        })
        print_fn(f"  {qname}: read {p.bytes_read:>10d} B "
                 f"({100 * p.bytes_read / max(total_bytes, 1):6.2f}% of "
                 f"{total_bytes} B)")
    return rows
