"""Single registry of benchmark suites (stdlib-only).

``benchmarks/run.py --only``, ``benchmarks/check_regression.py --suite``,
and the Makefile ``ci-bench``/``bench-regression`` targets all derive
their suite lists from this table, so the three can't drift: a suite
added here is immediately runnable, and marking it ``regression=True``
puts it in the blocking baseline-gate set (commit its
``benchmarks/baselines/BENCH_<name>.json`` alongside).

Fields per suite:
  * ``module``      — module under ``benchmarks/`` exposing ``run()``
  * ``scale``       — ``run()`` takes ``scale=`` (grows the world)
  * ``parity``      — ``run()`` takes ``raise_on_mismatch=`` (the harness
                      owns the exit code; parity bits flow into rows)
  * ``regression``  — in the blocking ``check_regression.py`` gate set

Print helpers for shell use::

    python -m benchmarks.suites --regression   # csv of the gate set
    python -m benchmarks.suites --all          # csv of every suite
"""
from __future__ import annotations

SUITES = {
    "table2": dict(module="bench_table2", scale=True, parity=False,
                   regression=False),
    "fig11": dict(module="bench_fig11", scale=True, parity=False,
                  regression=False),
    "fig12": dict(module="bench_fig12", scale=True, parity=False,
                  regression=False),
    "flume": dict(module="bench_flume_overhead", scale=True, parity=False,
                  regression=False),
    "kernels": dict(module="bench_kernels", scale=False, parity=False,
                    regression=False),
    "backends": dict(module="bench_backends", scale=True, parity=True,
                     regression=True),
    "tesseract": dict(module="bench_tesseract", scale=True, parity=True,
                      regression=True),
    "serve": dict(module="bench_serve", scale=True, parity=True,
                  regression=True),
    "streaming": dict(module="bench_streaming", scale=True, parity=True,
                      regression=True),
    "partition": dict(module="bench_partition", scale=True, parity=True,
                      regression=True),
    "analytics": dict(module="bench_analytics", scale=True, parity=True,
                      regression=True),
    "roofline": dict(module="roofline", scale=False, parity=False,
                     regression=False),
}

REGRESSION_SUITES = [n for n, s in SUITES.items() if s["regression"]]


def suite_names() -> list:
    return list(SUITES)


def regression_csv() -> str:
    return ",".join(REGRESSION_SUITES)


if __name__ == "__main__":
    import sys
    if "--regression" in sys.argv:
        print(regression_csv())
    else:
        print(",".join(SUITES))
