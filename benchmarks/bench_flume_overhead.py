"""Paper §4.3.6: Warp:Flume vs Warp:AdHoc overhead.

The paper reports ~25% runtime penalty for the auto-translated batch
pipeline versus a hand-written one, bought back by 5–10× faster
development.  Our analog: the same logical plan run through the
checkpointed batch engine (stage materialization + DONE markers) vs the
in-memory interactive engine; overhead = Flume's durability tax.
"""
from __future__ import annotations

import tempfile
import time

from repro.exec import AdHocEngine, FlumeEngine

from .queries import QUERIES, build_catalog, q_variability

__all__ = ["run"]


def run(scale: float = 1.0, num_shards: int = 40, print_fn=print):
    cat = build_catalog(scale=scale, num_shards=num_shards)
    adhoc = AdHocEngine(cat, num_servers=8)
    rows = []
    for qname in ("Q1", "Q4"):
        cities, months = QUERIES[qname]
        q = q_variability(cities, months, mode="multi_index")
        adhoc.collect(q)                                   # warm caches
        t0 = time.perf_counter()
        a = adhoc.collect(q)
        t_adhoc = time.perf_counter() - t0
        flume = FlumeEngine(cat, ckpt_dir=tempfile.mkdtemp(),
                            max_workers=8)
        t0 = time.perf_counter()
        f = flume.collect(q)
        t_flume = time.perf_counter() - t0
        assert a.to_records() == f.to_records()
        over = 100.0 * (t_flume - t_adhoc) / max(t_adhoc, 1e-9)
        rows.append({
            "name": f"flume_overhead_{qname}",
            "adhoc_ms": round(t_adhoc * 1e3, 2),
            "flume_ms": round(t_flume * 1e3, 2),
            "overhead_pct": round(over, 1),
        })
        print_fn(f"  {qname}: adhoc={t_adhoc*1e3:8.1f}ms "
                 f"flume={t_flume*1e3:8.1f}ms overhead={over:+6.1f}%")
    return rows
