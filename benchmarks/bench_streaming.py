"""Streaming-ingestion benchmark: ingest → index → prime → queryable.

Live ingestion is only worth its complexity if new data becomes
queryable fast and *without* reprocessing old data.  The report shows

  * ingest + incremental index throughput: time-sorted trips appended
    through the memtable → delta-shard flushes, each flush building only
    its own ``spacetime`` postings,
  * time-partition pruning evidence: a Q6 morning-commute window plans
    a strict subset of the delta shards and the fused launch count
    shrinks to ⌈kept/wave⌉ (< ⌈total/wave⌉),
  * byte parity of the live view: Q6 ids on the streaming catalog source
    match the numpy oracle on the same pinned snapshot,
  * **ingest-to-queryable latency** — append one crafted probe trip,
    flush, re-prime (only the new delta buffers upload), and run the
    first Tesseract query that must contain it; per-stage breakdown,
  * compaction equivalence: merging the deltas into one sealed shard
    leaves the Q6 answer byte-identical,
  * cache invalidation: a live ``QueryServer`` serves Q6 from its
    ResultCache, an append fires the bound invalidation hook, and the
    next submit recomputes — the probe id appears, a stale hit would
    miss it.
"""
from __future__ import annotations

import math
import time

import numpy as np

from repro.core import fdb
from repro.core.planner import plan_flow
from repro.data.synthetic import CITIES, generate_world
from repro.exec import AdHocEngine, Catalog
from repro.exec.batched import fused_enabled
from repro.fdb.streaming import StreamingFDb
from repro.kernels import ops
from repro.serve import QueryServer, ResultCache
from repro.tess import Tesseract

from .queries import TRIP_DAY, TRIP_QUERIES, tesseract_for

__all__ = ["run"]


def _probe_trip(trip_id: int, minute: int = 0) -> dict:
    """A trip Q6 must select: through SF-center 7:00–7:10 then
    Berkeley-center 7:15–7:25 on TRIP_DAY (windows 6–12 / 6–14)."""
    def center(city):
        lat0, lng0, dlat, dlng = CITIES[city]
        return lat0 + dlat / 2.0, lng0 + dlng / 2.0
    t0 = TRIP_DAY * 86400.0 + 7 * 3600.0 + minute * 60.0
    lats, lngs = [], []
    for city in ("SF", "SF", "SF", "Berkeley", "Berkeley", "Berkeley"):
        lat, lng = center(city)
        lats.append(lat)
        lngs.append(lng)
    ts = [t0 + k * 300.0 for k in range(6)]
    return {"id": trip_id, "vehicle": 0, "day": TRIP_DAY, "start_hour": 7,
            "track": {"lat": lats, "lng": lngs, "t": ts},
            "duration_s": ts[-1] - ts[0]}


def _q6_flow():
    return fdb("Trips").tesseract(tesseract_for(TRIP_QUERIES["Q6"]))


def _ids(res) -> list:
    return sorted(int(v) for v in res.batch["id"].values)


def run(scale: float = 0.5, print_fn=print, raise_on_mismatch: bool = True):
    rows: list = []
    # same floor as bench_tesseract/bench_serve: below ~0.2 the synthetic
    # week holds so few trips that Q6 selects nothing and parity is vacuous
    scale = max(scale, 0.2)
    world = generate_world(scale=scale)
    trips = sorted(world["trips"],
                   key=lambda r: (r["track"]["t"][0]
                                  if r["track"]["t"] else 0.0))
    next_id = max(r["id"] for r in trips) + 1

    # time-sorted ingestion into ~12 delta shards ⇒ each delta covers a
    # disjoint time band (auto-compaction off so the bands survive)
    flush = max(64, math.ceil(len(trips) / 12))
    live = StreamingFDb("Trips", world["trips_schema"],
                        flush_threshold=flush, compact_threshold=0)
    t0 = time.perf_counter()
    live.extend(trips)
    live.flush()
    ingest_s = time.perf_counter() - t0
    st = live.stats()
    rows.append({
        "name": "streaming_ingest_index",
        "us_per_call": round(ingest_s / max(len(trips), 1) * 1e6, 2),
        "parity": 1,
        "derived": (f"docs={st['docs']} delta_shards={st['delta_shards']} "
                    f"ingest_ms={ingest_s * 1e3:.1f} "
                    f"flush_threshold={flush}")})
    print_fn(f"  ingest+index: {len(trips)} trips in {ingest_s * 1e3:.1f}ms "
             f"→ {st['delta_shards']} delta shards")

    cat = Catalog(server_slots=64)
    cat.register(live)
    wave = 4
    np_eng = AdHocEngine(cat, backend="numpy", wave=wave)
    jx_eng = AdHocEngine(cat, backend="jax", wave=wave)
    flow = _q6_flow()

    # ---- parity: live catalog view, numpy oracle vs jax batched path
    want = _ids(np_eng.collect(flow))
    got = _ids(jx_eng.collect(flow))
    parity = want == got and len(want) > 0
    rows.append({"name": "streaming_parity", "us_per_call": "",
                 "parity": 1 if parity else 0,
                 "derived": f"q6_rows={len(want)} "
                            f"{'OK' if parity else 'MISMATCH'}"})
    print_fn(f"  live-view parity: q6_rows={len(want)} "
             f"{'OK' if parity else 'MISMATCH'}")

    # ---- pruning: Q6's day-2 window plans a subset of the time bands
    plan = plan_flow(flow, cat)
    total = cat.get("Trips").num_shards
    kept = len(plan.shard_ids)
    pruned_ok = 0 < kept < total
    ops.reset_launch_counts()
    jx_eng.collect(flow)
    lc = dict(ops.launch_counts())
    if fused_enabled():
        launches_ok = lc.get("run_wave_fused") == math.ceil(kept / wave)
    else:
        launches_ok = lc.get("refine_tracks_batched") == \
            math.ceil(kept / wave)
    prune_ok = pruned_ok and launches_ok
    parity &= prune_ok
    rows.append({"name": "streaming_prune_launches", "us_per_call": "",
                 "parity": 1 if prune_ok else 0,
                 "derived": (f"kept={kept}/{total} "
                             f"waves={math.ceil(kept / wave)} "
                             f"full_waves={math.ceil(total / wave)} "
                             f"launches={lc} "
                             f"fused={1 if fused_enabled() else 0}")})
    print_fn(f"  pruning: {rows[-1]['derived']}")

    # ---- ingest-to-queryable: append probe → flush → prime → first
    #      correct answer (the PR's headline row)
    probe_id = next_id
    stages = {}
    t = time.perf_counter()
    live.append(_probe_trip(probe_id))
    stages["append_ms"] = (time.perf_counter() - t) * 1e3
    t = time.perf_counter()
    live.flush()                        # freeze + index the delta shard
    stages["flush_index_ms"] = (time.perf_counter() - t) * 1e3
    t = time.perf_counter()
    snap = live.snapshot()
    new_buffers = jx_eng.backend.prime_fdb(snap)
    stages["prime_ms"] = (time.perf_counter() - t) * 1e3
    t = time.perf_counter()
    res = jx_eng.collect(flow)
    stages["query_ms"] = (time.perf_counter() - t) * 1e3
    total_ms = sum(stages.values())
    found = probe_id in set(_ids(res))
    oracle_found = probe_id in set(_ids(np_eng.collect(flow)))
    i2q_ok = found and oracle_found
    parity &= i2q_ok
    rows.append({
        "name": "streaming_ingest_to_queryable",
        "us_per_call": round(total_ms * 1e3, 1),
        "parity": 1 if i2q_ok else 0,
        "stages": {k: round(v, 2) for k, v in stages.items()},
        "derived": (f"total_ms={total_ms:.1f} "
                    + " ".join(f"{k}={v:.1f}" for k, v in stages.items())
                    + f" new_buffers={new_buffers} "
                    f"probe={'HIT' if i2q_ok else 'MISS'}")})
    print_fn(f"  ingest→queryable: {rows[-1]['derived']}")

    # ---- compaction equivalence: merged sealed view answers identically
    before = _ids(np_eng.collect(flow))
    t = time.perf_counter()
    compacted = live.compact()
    compact_ms = (time.perf_counter() - t) * 1e3
    after_np = _ids(np_eng.collect(flow))
    after_jx = _ids(jx_eng.collect(flow))
    comp_ok = compacted and before == after_np == after_jx
    parity &= comp_ok
    st = live.stats()
    rows.append({"name": "streaming_compaction", "us_per_call": "",
                 "parity": 1 if comp_ok else 0,
                 "derived": (f"compact_ms={compact_ms:.1f} "
                             f"sealed={st['sealed_shards']} "
                             f"delta={st['delta_shards']} "
                             f"{'OK' if comp_ok else 'MISMATCH'}")})
    print_fn(f"  compaction: {rows[-1]['derived']}")

    # ---- cache invalidation on a live server: append between submits
    cache = ResultCache()
    srv = QueryServer(catalog=cat, backend="jax", cache=cache,
                      start=False, max_pending=64)
    srv.engine.wave = wave
    try:
        f1 = srv.submit(_q6_flow()); srv.run_pending()
        r1 = f1.result(300)
        f2 = srv.submit(_q6_flow()); srv.run_pending()
        hit = f2.result(300) is r1
        probe2 = next_id + 1
        live.append(_probe_trip(probe2, minute=30))
        live.flush()
        f3 = srv.submit(_q6_flow()); srv.run_pending()
        r3 = f3.result(300)
        inval_ok = (hit and r3 is not r1
                    and probe2 in set(_ids(r3))
                    and cache.stats()["invalidations"] >= 1)
    finally:
        srv.close()
    parity &= inval_ok
    rows.append({"name": "streaming_cache_invalidation", "us_per_call": "",
                 "parity": 1 if inval_ok else 0,
                 "derived": (f"warm_hit={1 if hit else 0} "
                             f"invalidations={cache.stats()['invalidations']} "
                             f"{'OK' if inval_ok else 'STALE'}")})
    print_fn(f"  cache invalidation: {rows[-1]['derived']}")

    rows.append({"name": "streaming_parity_all", "us_per_call": "",
                 "parity": 1 if parity else 0,
                 "derived": "OK" if parity else "MISMATCH"})
    print_fn(f"  streaming parity + gates: {'OK' if parity else 'MISMATCH'}")
    if not parity and raise_on_mismatch:
        raise AssertionError("streaming ingest parity/gate violated")
    return rows
