"""Partitioned-execution benchmark: P=1 vs P=2 wall time + merge evidence.

The partition layer claims scale-out comes (almost) for free: splitting
a query's pruned shard list across P partitions changes the launch
shape — Σ_p ⌈shards_p/wave⌉ fused dispatches plus one ``merge_partials``
combine — but not one result bit.  The report shows

  * **partition invariance**: a rush-hour group-by carrying every fused
    aggregate kind (count/sum/avg/std_dev/min/max) and a Tesseract trip
    selection return identical results at P=1/2/4 on the jax backend,
    and the numpy loop-over-partitions oracle agrees,
  * **launch evidence**: counted launches at each P match the
    ``PartitionPlan`` arithmetic exactly (dispatches + the single merge
    combine at P>1, none at P=1),
  * **P=1 vs P=2 wall time** per query — on one CPU device the mesh is
    emulated, so this row tracks the partition layer's *overhead* (the
    extra dispatch + host align/merge), which the regression gate keeps
    honest; on a real multi-device mesh the same code path is the
    speedup path.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import BETWEEN, P, fdb, group
from repro.core.planner import partition_shards
from repro.data.synthetic import generate_world
from repro.exec import AdHocEngine, Catalog
from repro.fdb import build_fdb
from repro.kernels import ops

from .queries import TRIP_QUERIES, tesseract_for

__all__ = ["run"]

NUM_SHARDS = 8
WAVE = 3


def _batch_equal(a, b) -> bool:
    if a.n != b.n or a.paths() != b.paths():
        return False
    return all(a[p].values.dtype == b[p].values.dtype
               and np.array_equal(a[p].values, b[p].values)
               for p in a.paths())


def _time(engine, flow, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        engine.collect(flow)
        best = min(best, time.perf_counter() - t0)
    return best


def run(scale: float = 0.5, print_fn=print, raise_on_mismatch: bool = True):
    rows: list = []
    # same floor as bench_tesseract/bench_serve: below ~0.2 the synthetic
    # week holds so few trips that Q6 selects nothing and the selection
    # half of the invariance row is vacuous
    scale = max(scale, 0.2)
    world = generate_world(scale=scale)
    cat = Catalog(server_slots=64)
    cat.register(build_fdb("Obs", world["observations_schema"],
                           world["observations"], num_shards=NUM_SHARDS))
    cat.register(build_fdb("Trips", world["trips_schema"], world["trips"],
                           num_shards=NUM_SHARDS))

    agg = (fdb("Obs").find(BETWEEN(P.hour, 7, 9))
           .aggregate(group(P.road_id).count("n").avg(mean=P.speed)
                      .std_dev(sd=P.speed).min(lo=P.speed)
                      .max(hi=P.speed)))
    sel = fdb("Trips").tesseract(tesseract_for(TRIP_QUERIES["Q6"]))

    engines = {p: AdHocEngine(cat, backend="jax", wave=WAVE, partitions=p)
               for p in (1, 2, 4)}
    for eng in engines.values():                   # warm: prime + jit
        eng.collect(agg)
        eng.collect(sel)

    # ---- invariance: P=2/4 ≡ P=1, and the numpy oracle agrees
    ref_agg = engines[1].collect(agg).batch
    ref_sel = engines[1].collect(sel).batch
    np_agg = AdHocEngine(cat, backend="numpy", wave=WAVE,
                         partitions=2).collect(agg).batch
    inv_ok = _batch_equal(ref_agg, np_agg) and ref_agg.n > 0
    detail = []
    for p in (2, 4):
        a_ok = _batch_equal(ref_agg, engines[p].collect(agg).batch)
        s_ok = _batch_equal(ref_sel, engines[p].collect(sel).batch)
        inv_ok &= a_ok and s_ok
        detail.append(f"P{p}:agg={'OK' if a_ok else 'MISMATCH'}"
                      f",sel={'OK' if s_ok else 'MISMATCH'}")
    rows.append({"name": "partition_invariance", "us_per_call": "",
                 "parity": 1 if inv_ok else 0,
                 "derived": (f"groups={ref_agg.n} sel_rows={ref_sel.n} "
                             + " ".join(detail)
                             + " oracle=" + ("OK" if inv_ok else "CHECK"))})
    print_fn(f"  invariance: {rows[-1]['derived']}")
    if raise_on_mismatch and not inv_ok:
        raise AssertionError("partition invariance violated")

    # ---- launch evidence: counts match the PartitionPlan arithmetic
    ev_ok = True
    ev = []
    for p in (1, 2, 4):
        ops.reset_launch_counts()
        engines[p].collect(agg)
        lc = dict(ops.launch_counts())
        pp = partition_shards(range(NUM_SHARDS), p)
        want = {"run_wave_fused": pp.wave_dispatches(WAVE)}
        if pp.merge_combines():
            want["merge_partials"] = pp.merge_combines()
        ev_ok &= lc == want
        ev.append(f"P{p}:{lc}{'' if lc == want else f'!=want{want}'}")
    rows.append({"name": "partition_launch_evidence", "us_per_call": "",
                 "parity": 1 if ev_ok else 0,
                 "derived": f"wave={WAVE} shards={NUM_SHARDS} "
                            + " ".join(ev)})
    print_fn(f"  launches: {rows[-1]['derived']}")

    # ---- P=1 vs P=2 wall time (emulated mesh: overhead tracking)
    for name, flow in (("agg", agg), ("tesseract_q6", sel)):
        t1 = _time(engines[1], flow)
        t2 = _time(engines[2], flow)
        rows.append({
            "name": f"partition_wall_{name}_p2",
            "us_per_call": round(t2 * 1e6, 1),
            "parity": 1,
            "derived": (f"p1_ms={t1 * 1e3:.2f} p2_ms={t2 * 1e3:.2f} "
                        f"p2_over_p1={t2 / max(t1, 1e-9):.2f}x "
                        f"(emulated one-device mesh)")})
        print_fn(f"  wall {name}: {rows[-1]['derived']}")

    return rows
