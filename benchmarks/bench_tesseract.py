"""Tesseract trip-query benchmark (Q6–Q9): pruning ratio + backend parity.

For each trip query the report shows

  * wall time per backend (numpy oracle vs jax kernel dispatch),
  * **index-probe candidate counts vs. exact-refine counts** — how many
    trips the per-shard ``spacetime`` postings admit at (cell × bucket)
    granularity vs. how many survive the exact point-in-cover ×
    time-window pass — and the resulting pruning ratio,
  * a byte-level parity verdict between the backends' trip-id sets *and*
    between their per-shard candidate/refined counts (the
    ``refine_tracks`` op parity gate), and
  * the launch count on the jax path: the whole selection (probe →
    exact refine → compact) is ⌈shards/wave⌉ fused ``run_wave_fused``
    device dispatches per query — the per-shard host refine and the
    per-primitive launches are gone from the hot loop (with
    ``REPRO_EXEC_FUSED=0`` the evidence reverts to ⌈shards/wave⌉
    ``refine_tracks_batched`` launches, still zero per-shard ops).

Q8–Q9 are the *ordered* (A-then-B) variants of Q6–Q7: the same legs
sequenced with ``Tesseract.then()``.  Their parity verdict additionally
compares the per-(doc × constraint) **first-hit timestamp tables** across
backends byte-for-byte (the table the ordering DAG is resolved against),
and their launch evidence shows ordering rides the same fused wave
dispatches — no extra launches.

The pruning ratio is the subsystem's reason to exist: for selective
regions the index must prune ≥ 90 % of trips before the exact pass.
"""
from __future__ import annotations

import math
import time

import numpy as np

from repro.data.synthetic import generate_world
from repro.exec import AdHocEngine, Catalog, get_backend
from repro.exec.batched import fused_enabled
from repro.fdb import build_fdb
from repro.kernels import ops
from repro.tess import tesseract_stats

from .queries import (ORDERED_TRIP_QUERIES, TRIP_QUERIES, q_tesseract,
                      tesseract_for)

__all__ = ["run"]


def _first_hit_parity(db, tess) -> bool:
    """Byte parity of the per-shard first-hit tables across backends."""
    cons = list(tess.constraints)
    batches = [sh.batch for sh in db.shards]
    _, tab_n = get_backend("numpy").refine_tracks_batched(
        batches, tess.field, cons, with_first_hits=True)
    _, tab_j = get_backend("jax").refine_tracks_batched(
        batches, tess.field, cons, with_first_hits=True)
    return all(np.array_equal(a, b) for a, b in zip(tab_n, tab_j))


def _sync(out):
    """jax dispatch is async: block on any device values reachable from
    ``out`` so the clock stops at completion, not at enqueue."""
    try:
        import jax
        jax.block_until_ready(out)
    except Exception:
        pass
    return out


def _time(fn, repeats=3):
    _sync(fn())                              # warm (jit compile etc.)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = _sync(fn())
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e3                   # ms


def run(scale: float = 0.5, print_fn=print, raise_on_mismatch: bool = True):
    rows: list = []
    # floor the world size: below ~0.2 the synthetic week holds so few
    # trips that Q6–Q9 select nothing, which would turn the parity and
    # pruning evidence vacuous (the CI smoke runs --scale 0.05)
    scale = max(scale, 0.2)
    # trips-only catalog: skip the (dominant) ingest/index cost of the
    # road/observation datasets the trip queries never touch
    world = generate_world(scale=scale)
    cat = Catalog(server_slots=64)
    cat.register(build_fdb("Trips", world["trips_schema"], world["trips"],
                           num_shards=10))
    db = cat.get("Trips")
    engines = {b: AdHocEngine(cat, backend=b) for b in ("numpy", "jax")}
    all_parity = True
    all_queries = {**{q: (legs, False) for q, legs in TRIP_QUERIES.items()},
                   **{q: (legs, True)
                      for q, legs in ORDERED_TRIP_QUERIES.items()}}
    for qname, (legs, ordered) in all_queries.items():
        flow = q_tesseract(legs, ordered=ordered)
        tess = tesseract_for(legs, ordered=ordered)
        results, times = {}, {}
        for bname, eng in engines.items():
            res, ms = _time(lambda e=eng: e.collect(flow), repeats=2)
            results[bname], times[bname] = res, ms
        ids = {b: np.sort(r.batch["id"].values)
               for b, r in results.items()}
        # refine-op byte parity: identical per-shard candidate/refined
        # counts across backends (kernel mask ≡ numpy oracle mask); for
        # ordered queries also the first-hit tables byte-for-byte
        stats = tesseract_stats(db, tess, backend="numpy")
        stats_j = tesseract_stats(db, tess, backend="jax")
        refine_parity = stats["per_shard"] == stats_j["per_shard"]
        if ordered:
            refine_parity &= _first_hit_parity(db, tess)
        # launch evidence: the whole selection (probe → refine → compact)
        # is ⌈shards/wave⌉ ``run_wave_fused`` dispatches per query — no
        # per-primitive or per-shard launches remain.  REPRO_EXEC_FUSED=0
        # restores the legacy contract: ⌈shards/wave⌉ batched refine
        # launches, still zero per-shard host refines.
        ops.reset_launch_counts()
        engines["jax"].collect(flow)
        lc = ops.launch_counts()
        waves = math.ceil(db.num_shards / engines["jax"].wave)
        if fused_enabled():
            refine_launches = lc.get("run_wave_fused", 0)
            fused = (refine_launches == waves
                     and lc.get("refine_tracks_batched", 0) == 0
                     and lc.get("refine_tracks", 0) == 0)
        else:
            refine_launches = lc.get("refine_tracks_batched", 0)
            fused = (refine_launches == waves
                     and lc.get("refine_tracks", 0) == 0)
        parity = bool(np.array_equal(ids["numpy"], ids["jax"])) \
            and results["numpy"].profile.rows_selected \
            == results["jax"].profile.rows_selected \
            and refine_parity and fused
        all_parity &= parity
        speedup = times["numpy"] / max(times["jax"], 1e-9)
        rows.append({
            "name": f"tesseract_{qname}",
            "us_per_call": round(times["jax"] * 1e3, 1),
            "parity": 1 if parity else 0,
            "derived": (f"numpy={times['numpy']:.1f}ms "
                        f"jax={times['jax']:.1f}ms "
                        f"speedup={speedup:.2f}x "
                        f"docs={stats['docs']} "
                        f"candidates={stats['candidates']} "
                        f"refined={stats['refined']} "
                        f"pruning={stats['pruning']:.3f} "
                        f"ordered={1 if ordered else 0} "
                        + ("fused_launches" if fused_enabled()
                           else "refine_launches")
                        + f"={refine_launches}/{waves}waves "
                        f"parity={'OK' if parity else 'MISMATCH'}")})
        print_fn(f"  {qname}: {rows[-1]['derived']}")
        if stats["pruning"] < 0.9:
            print_fn(f"  WARNING: {qname} pruning "
                     f"{stats['pruning']:.3f} < 0.90")
    rows.append({"name": "tesseract_parity_all",
                 "us_per_call": "",
                 "parity": 1 if all_parity else 0,
                 "derived": "OK" if all_parity else "MISMATCH"})
    print_fn(f"  parity across trip queries: "
             f"{'OK' if all_parity else 'MISMATCH'}")
    if not all_parity and raise_on_mismatch:
        raise AssertionError("tesseract backend parity violated")
    return rows
