"""Analytics benchmark: dwell/count trip queries + time-to-trained-model.

Q10–Q11 extend the Q6–Q9 trip-query family with the refine kernel's
reduction outputs — computed in the *same* one-hot compare pass, at zero
extra launches:

  * **Q10 (count)** — trips with ≥ 2 distinct SF window hits and a
    Berkeley hit (``Tesseract.at_least(2)``): the per-constraint hit
    *count* reduction,
  * **Q11 (dwell)** — trips that stayed inside the SF window at least 10
    simulated minutes (``Tesseract.dwell(600)``): the last-hit − first-hit
    span reduction.

Each row carries the same evidence as the Q6–Q9 suite: numpy-vs-jax trip
id parity, per-shard candidate/refined count parity, and the launch
contract — the reductions ride the existing ⌈shards/wave⌉ fused
dispatches (``REPRO_EXEC_FUSED=0`` reverts to ⌈shards/wave⌉ batched
refine launches, still zero per-shard ops).

The **time-to-trained-model** row closes the paper's §5 loop as a gated
number: ``Flow.to_dataset(features=..., target=...)`` streams
query-selected rows into an ``MLPRegressor`` and the row's wall time is
selection + training end to end, so a regression in either the query
path or the training hand-off trips the gate.
"""
from __future__ import annotations

import math
import time

import numpy as np

from repro.core import P, BETWEEN, fdb, proto
from repro.exec import AdHocEngine, Catalog
from repro.exec.batched import fused_enabled
from repro.fdb import build_fdb
from repro.data.synthetic import generate_world
from repro.kernels import ops
from repro.tess import Tesseract, tesseract_stats

from .queries import TRIP_DAY, build_catalog, region_for

__all__ = ["run"]


def _win(h0: float, h1: float, day: int = TRIP_DAY):
    return day * 86400.0 + h0 * 3600.0, day * 86400.0 + h1 * 3600.0


def analytics_tesseracts():
    """Q10 (count) / Q11 (dwell) — the Q6 commute legs with reductions."""
    sf, bk = region_for(("SF",)), region_for(("Berkeley",))
    return {
        "Q10": (Tesseract(sf, *_win(6, 12), label="sf").at_least(2)
                .also(bk, *_win(6, 14), label="berkeley")),
        "Q11": (Tesseract(sf, *_win(6, 12), label="sf").dwell(600.0)
                .also(bk, *_win(6, 14), label="berkeley")),
    }


def _sync(out):
    try:
        import jax
        jax.block_until_ready(out)
    except Exception:
        pass
    return out


def _time(fn, repeats=2):
    _sync(fn())                              # warm (jit compile etc.)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = _sync(fn())
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e3                   # ms


def run(scale: float = 0.5, print_fn=print, raise_on_mismatch: bool = True):
    rows: list = []
    # same floor as the tesseract suite: below ~0.2 the synthetic week is
    # too sparse for the reductions to select anything (vacuous evidence)
    trip_scale = max(scale, 0.2)
    world = generate_world(scale=trip_scale)
    cat = Catalog(server_slots=64)
    cat.register(build_fdb("Trips", world["trips_schema"], world["trips"],
                           num_shards=10))
    db = cat.get("Trips")
    engines = {b: AdHocEngine(cat, backend=b) for b in ("numpy", "jax")}
    all_parity = True

    for qname, tess in analytics_tesseracts().items():
        flow = fdb("Trips").tesseract(tess).map(lambda p: proto(id=p.id))
        results, times = {}, {}
        for bname, eng in engines.items():
            res, ms = _time(lambda e=eng: e.collect(flow))
            results[bname], times[bname] = res, ms
        ids = {b: np.sort(r.batch["id"].values)
               for b, r in results.items()}
        stats = tesseract_stats(db, tess, backend="numpy")
        stats_j = tesseract_stats(db, tess, backend="jax")
        refine_parity = stats["per_shard"] == stats_j["per_shard"]
        # launch contract: the count/dwell reductions ride the existing
        # fused wave dispatches — same counts as a plain trip query
        ops.reset_launch_counts()
        engines["jax"].collect(flow)
        lc = ops.launch_counts()
        waves = math.ceil(db.num_shards / engines["jax"].wave)
        if fused_enabled():
            launches = lc.get("run_wave_fused", 0)
            contract = (launches == waves
                        and lc.get("refine_tracks_batched", 0) == 0
                        and lc.get("refine_tracks", 0) == 0)
        else:
            launches = lc.get("refine_tracks_batched", 0)
            contract = (launches == waves
                        and lc.get("refine_tracks", 0) == 0)
        parity = bool(np.array_equal(ids["numpy"], ids["jax"])) \
            and refine_parity and contract
        all_parity &= parity
        rows.append({
            "name": f"analytics_{qname}",
            "us_per_call": round(times["jax"] * 1e3, 1),
            "parity": 1 if parity else 0,
            "derived": (f"numpy={times['numpy']:.1f}ms "
                        f"jax={times['jax']:.1f}ms "
                        f"selected={ids['jax'].size} "
                        f"candidates={stats['candidates']} "
                        f"refined={stats['refined']} "
                        + ("fused_launches" if fused_enabled()
                           else "refine_launches")
                        + f"={launches}/{waves}waves "
                        f"parity={'OK' if parity else 'MISMATCH'}")})
        print_fn(f"  {qname}: {rows[-1]['derived']}")
        if ids["jax"].size == 0:
            print_fn(f"  WARNING: {qname} selected nothing — reduction "
                     f"evidence vacuous at scale {trip_scale}")

    # ---- time-to-trained-model (§5): query-selected rows → MLP train ----
    ttm_cat = build_catalog(scale=max(scale, 0.1), num_shards=12)
    roads_tbl = (fdb("Roads").collect(AdHocEngine(ttm_cat, backend="jax"))
                 .to_dict("id"))
    eng = AdHocEngine(ttm_cat, backend="jax")

    def ttm():
        ds = (fdb("SpeedObservations")
              .find(BETWEEN(P.month, 1, 4))
              .to_dataset(features={"hour": P.hour * 1.0,
                                    "dow": P.dow * 1.0,
                                    "sl": roads_tbl[P.road_id].speed_limit},
                          target=P.speed, engine=eng))
        model, losses = ds.fit(steps=60, lr=2e-3, batch=256)
        return ds, losses

    (ds, losses), ms = _time(ttm)
    trained = bool(len(ds) > 0 and losses[-1] < losses[0])
    all_parity &= trained
    rows.append({
        "name": "analytics_time_to_trained_model",
        "us_per_call": round(ms * 1e3, 1),
        "parity": 1 if trained else 0,
        "derived": (f"rows={len(ds)} steps=60 "
                    f"loss={losses[0]:.2f}->{losses[-1]:.2f} "
                    f"trained={'OK' if trained else 'FAILED'}")})
    print_fn(f"  time_to_trained_model: {rows[-1]['derived']} "
             f"({ms:.0f}ms end-to-end)")

    rows.append({"name": "analytics_parity_all",
                 "us_per_call": "",
                 "parity": 1 if all_parity else 0,
                 "derived": "OK" if all_parity else "MISMATCH"})
    print_fn(f"  analytics parity: {'OK' if all_parity else 'MISMATCH'}")
    if not all_parity and raise_on_mismatch:
        raise AssertionError("analytics backend parity violated")
    return rows
