"""Paper Figure 11: Q1–Q5 performance on two micro-cluster sizes.

Cluster 1 = 16 server slots, Cluster 2 = 2 slots (the paper's 965-core vs
118-core clusters, scaled to a laptop).  The paper's headline: the small
cluster is only modestly slower because indexed scans make work ∝ result
size — CPU/IO totals stay flat while only parallelism changes.
"""
from __future__ import annotations

import time

from repro.exec import AdHocEngine

from .queries import QUERIES, build_catalog, q_variability

__all__ = ["run"]


def run(scale: float = 1.0, num_shards: int = 40, print_fn=print):
    cat = build_catalog(scale=scale, num_shards=num_shards)
    clusters = {"cluster1": 16, "cluster2": 2}
    rows = []
    for cname, slots in clusters.items():
        engine = AdHocEngine(cat, num_servers=slots)
        for qname, (cities, months) in QUERIES.items():
            q = q_variability(cities, months, mode="multi_index")
            engine.collect(q)                       # warm
            t0 = time.perf_counter()
            res = engine.collect(q)
            exec_ms = (time.perf_counter() - t0) * 1e3
            p = res.profile
            rows.append({
                "name": f"fig11_{qname}_{cname}",
                "exec_ms": round(exec_ms, 2),
                "cpu_ms": round(p.cpu_ms, 2),
                "io_ms": round(p.io_ms, 2),
                "rows_selected": p.rows_selected,
                "bytes_read": p.bytes_read,
                "result_rows": res.n,
            })
            print_fn(f"  {qname} {cname:9s} exec={exec_ms:8.1f}ms "
                     f"cpu={p.cpu_ms:8.1f}ms io={p.io_ms:6.1f}ms "
                     f"sel={p.rows_selected:7d}")
    return rows
