"""Paper Table 2: Q1 under different selection criteria.

Reproduces the qualitative structure the paper reports on Cluster 1:
  * full scan ≫ geospatial index ≫ multiple indices (CPU time),
  * 10% / 1% samples trade accuracy for time, with the 1% sample barely
    faster than 10% ("we gain little from parallelism when using only 1%
    of the data shards").
"""
from __future__ import annotations

import time

from repro.exec import AdHocEngine

from .queries import QUERIES, build_catalog, q_variability

__all__ = ["run"]


def _run_query(engine, q, repeats=3):
    best = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = engine.collect(q)
        dt = (time.perf_counter() - t0) * 1e3
        if best is None or dt < best[0]:
            best = (dt, res)
    return best[1], best[0]


def run(scale: float = 1.0, num_shards: int = 100, print_fn=print):
    cat = build_catalog(scale=scale, num_shards=num_shards)
    engine = AdHocEngine(cat, num_servers=16)
    cities, months = QUERIES["Q1"]

    rows = []
    # exact CoV ground truth for sample-error measurement
    res_exact, _ = _run_query(
        engine, q_variability(cities, months, mode="multi_index"))
    exact = {r["road_id"]: r["cov"] for r in res_exact.to_records()
             if r["n"] >= 2}

    cases = [
        ("full_scan", dict(mode="full_scan")),
        ("geospatial_index", dict(mode="geo_index")),
        ("multiple_indices", dict(mode="multi_index")),
        ("sample_10pct", dict(mode="multi_index", sample=0.10)),
        ("sample_1pct", dict(mode="multi_index", sample=0.01)),
    ]
    for name, kw in cases:
        res, exec_ms = _run_query(engine, q_variability(cities, months,
                                                        **kw))
        p = res.profile
        got = {r["road_id"]: r["cov"] for r in res.to_records()
               if r["n"] >= 2}
        common = set(got) & set(exact)
        err = (sum(abs(got[k] - exact[k]) / max(abs(exact[k]), 1e-9)
                   for k in common) / len(common) * 100) if common else 0.0
        rows.append({
            "name": f"table2_{name}",
            "exec_ms": round(exec_ms, 2),
            "cpu_ms": round(p.cpu_ms, 2),
            "io_ms": round(p.io_ms, 2),
            "rows_scanned": p.rows_scanned,
            "rows_selected": p.rows_selected,
            "bytes_read": p.bytes_read,
            "sample_err_pct": round(err, 2),
        })
        print_fn(f"  {name:18s} exec={exec_ms:8.1f}ms cpu={p.cpu_ms:8.1f}ms"
                 f" scanned={p.rows_scanned:8d} read={p.bytes_read:10d}B"
                 f" err={err:5.1f}%")
    return rows
