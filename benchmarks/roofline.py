"""§Roofline: three-term analysis from the dry-run artifacts.

For every (arch × shape × mesh) JSON under runs/dryrun/:

  compute_s    = HLO_FLOPs(global)       / (chips · 197 TFLOP/s)
  memory_s     = HLO_bytes(global)       / (chips · 819 GB/s)
  collective_s = collective_bytes(global)/ (chips · 50 GB/s/link)

cost_analysis() reports the per-device SPMD module, so global = per-device
× chips and the formulas above reduce to per-device quantities over
per-chip rates.  MODEL_FLOPS = 6·N(_active)·D with D = tokens (decode: B·1
token); the useful-fraction column MODEL/HLO exposes remat & redundancy
(full remat ⇒ ≈ 0.7–0.75 by construction: 8·N·D recomputed vs 6·N·D
useful).  ``mfu_bound`` = MODEL_FLOPS/(chips·peak) ÷ dominant term — the
roofline-implied ceiling on MFU for this program.

CPU-lowering caveat: XLA:CPU upconverts most bf16 math to f32, inflating
HLO bytes (and memory_analysis) by up to 2× versus the TPU target; FLOPs
and collective bytes are dtype-honest.  Recorded per EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link

__all__ = ["load_records", "roofline_terms", "table", "run"]


def load_records(out_dir: str = "runs/dryrun", tag: Optional[str] = None
                 ) -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as fh:
            r = json.load(fh)
        if (r.get("tag") or "") != (tag or ""):
            continue
        recs.append(r)
    return recs


def roofline_terms(rec: dict, *, flash_adjust: bool = False) -> Dict:
    """Three terms from the trip-count-aware HLO analysis.

    ``flash_adjust`` subtracts the flash-interior fusion traffic (softmax
    temporaries that the Pallas kernel keeps in VMEM) from the memory
    term — the HLO-quantified effect of the flash_attention kernel.
    """
    chips = rec["chips"]
    a = rec.get("analyzed") or {}
    flops_dev = a.get("flops_per_device") or \
        rec["cost"]["flops_per_device"] or 0.0
    bytes_dev = a.get("bytes_per_device") or \
        rec["cost"]["bytes_per_device"] or 0.0
    if flash_adjust:
        bytes_dev = bytes_dev - a.get("bytes_flash_interior", 0)
    coll_dev = a.get("collective_bytes",
                     rec["collectives"]["total_bytes"])
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    # MODEL_FLOPS: 6·N_active·D for training (fwd+bwd); 2·N_active·D for
    # inference kinds (forward only) — the dry-run artifact stores 6×.
    model_flops = rec["model_flops_active"]
    if rec["kind"] in ("prefill", "decode"):
        model_flops /= 3.0
    hlo_flops_global = flops_dev * chips
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0
    ideal_s = model_flops / (chips * PEAK_FLOPS)
    bound = ideal_s / max(terms[dominant], 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "useful_flops_frac": useful, "mfu_bound": bound,
        "mem_gib": (rec["memory"]["peak_bytes"] or 0) / 2**30,
        "kind": rec["kind"],
        "flash_interior_frac": (a.get("bytes_flash_interior", 0)
                                / max(a.get("bytes_per_device", 1), 1)),
    }


def table(out_dir: str = "runs/dryrun", tag: Optional[str] = None,
          mesh_filter: str = "16x16", flash_adjust: bool = False
          ) -> List[dict]:
    rows = [roofline_terms(r, flash_adjust=flash_adjust)
            for r in load_records(out_dir, tag)
            if r["mesh"] == mesh_filter]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def fmt_row(t: dict) -> str:
    return (f"| {t['arch']:23s} | {t['shape']:11s} "
            f"| {t['compute_s']*1e3:9.2f} | {t['memory_s']*1e3:9.2f} "
            f"| {t['collective_s']*1e3:9.2f} | {t['dominant'][:4]:4s} "
            f"| {t['useful_flops_frac']:5.2f} | {t['mfu_bound']:6.3f} "
            f"| {t['mem_gib']:6.1f} |")


HEADER = ("| arch                    | shape       | compute ms | "
          "memory ms | collect ms | dom  | MF/H  | bound  | GiB/dev |")


def run(print_fn=print, out_dir: str = "runs/dryrun"):
    rows = table(out_dir)
    if not rows:
        print_fn("  (no dry-run artifacts; run repro.launch.dryrun first)")
        return []
    print_fn(HEADER)
    for t in rows:
        print_fn(fmt_row(t))
    out = [{"name": f"roofline_{t['arch']}_{t['shape']}",
            "compute_ms": round(t["compute_s"] * 1e3, 3),
            "memory_ms": round(t["memory_s"] * 1e3, 3),
            "collective_ms": round(t["collective_s"] * 1e3, 3),
            "dominant": t["dominant"],
            "mfu_bound": round(t["mfu_bound"], 4)} for t in rows]
    return out
