"""Generate EXPERIMENTS.md from dry-run artifacts + benchmark results.

Usage: PYTHONPATH=src python -m benchmarks.make_experiments
Reads runs/dryrun (optimized sweep), runs/dryrun_baseline (baseline sweep)
and re-runs the paper-table benchmarks at --scale.
"""
from __future__ import annotations

import io
import json
import sys

from . import (bench_fig11, bench_fig12, bench_flume_overhead,
               bench_kernels, bench_table2)
from .roofline import (HBM_BW, LINK_BW, PEAK_FLOPS, load_records,
                       roofline_terms)

OUT = "EXPERIMENTS.md"


def _cap(rows_fn, *a, **kw):
    buf = io.StringIO()
    rows = rows_fn(*a, print_fn=lambda *s: buf.write(" ".join(map(str, s))
                                                     + "\n"), **kw)
    return rows, buf.getvalue()


def roofline_table_md(recs, flash_adjust=False):
    lines = ["| arch | shape | compute ms | memory ms | kernel-adj mem ms |"
             " collective ms | dominant | 6ND/HLO | bound | GiB/dev |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        t = roofline_terms(r)
        ta = roofline_terms(r, flash_adjust=True)
        lines.append(
            f"| {t['arch']} | {t['shape']} | {t['compute_s']*1e3:.1f} "
            f"| {t['memory_s']*1e3:.1f} | {ta['memory_s']*1e3:.1f} "
            f"| {t['collective_s']*1e3:.1f} | {t['dominant']} "
            f"| {t['useful_flops_frac']:.2f} | {max(t['mfu_bound'], ta['mfu_bound']):.3f} "
            f"| {t['mem_gib']:.1f} |")
    return "\n".join(lines)


def sweep_summary_md(log_path):
    rows = []
    import re
    for line in open(log_path):
        m = re.match(r"\[OK\]\s+(\S+) × (\S+) × (\S+)\s+compile=\s*([\d.]+)s"
                     r" mem/dev=\s*([\d.]+)GiB coll=\s*([\d.]+)MiB", line)
        if m:
            rows.append(m.groups())
    return rows


def main():
    scale = 1.0
    single = [r for r in load_records("runs/dryrun")
              if r["mesh"] == "16x16" and not r.get("tag")]
    multi = [r for r in load_records("runs/dryrun")
             if r["mesh"] == "2x16x16"]
    base = {(r["arch"], r["shape"]): r
            for r in load_records("runs/dryrun_baseline")
            if r["mesh"] == "16x16"}

    t2_rows, t2_txt = _cap(bench_table2.run, scale=scale)
    f11_rows, f11_txt = _cap(bench_fig11.run, scale=scale)
    f12_rows, f12_txt = _cap(bench_fig12.run, scale=scale)
    fl_rows, fl_txt = _cap(bench_flume_overhead.run, scale=scale)
    kn_rows, kn_txt = _cap(bench_kernels.run)

    # baseline-vs-optimized deltas on analyzer-stable metrics
    deltas = []
    for r in single:
        b = base.get((r["arch"], r["shape"]))
        if b is None:
            continue
        tb, tn = roofline_terms(b), roofline_terms(r)
        mb = (b["memory"]["peak_bytes"] or 0) / 2**30
        mn = (r["memory"]["peak_bytes"] or 0) / 2**30
        deltas.append((r["arch"], r["shape"], tb["collective_s"],
                       tn["collective_s"], mb, mn))

    md = []
    md.append(open("EXPERIMENTS.header.md").read())

    md.append("\n## §Paper-validation\n")
    md.append(open("EXPERIMENTS.paper.md").read())
    md.append("\n### Table 2 analog (Q1 selection criteria, scale=1.0, "
              "100 shards)\n```\n" + t2_txt + "```\n")
    md.append("### Figure 11 analog (Q1–Q5 × two cluster sizes)\n```\n"
              + f11_txt + "```\n")
    md.append("### Figure 12 analog (data scan size)\n```\n" + f12_txt
              + "```\n")
    md.append("### §4.3.6 analog (Warp:Flume overhead)\n```\n" + fl_txt
              + "```\n")
    md.append("### Kernel microbenches (CPU reference path)\n```\n"
              + kn_txt + "```\n")

    md.append("\n## §Dry-run\n")
    md.append(open("EXPERIMENTS.dryrun.md").read())
    md.append("\n### Optimized single-pod sweep (16×16, per-cell)\n")
    md.append("| arch | shape | compile s | GiB/dev | collective MiB/dev |")
    md.append("|---|---|---|---|---|")
    for g in sweep_summary_md("runs/dryrun_sweep_opt.log"):
        arch, shape, mesh, comp, mem, coll = g
        if mesh == "16x16":
            md.append(f"| {arch} | {shape} | {comp} | {mem} | {coll} |")
    md.append("\nMulti-pod (2×16×16) spot-checks of the optimized code "
              "(all compile):\n")
    for g in sweep_summary_md("runs/dryrun_sweep_opt.log"):
        arch, shape, mesh, comp, mem, coll = g
        if mesh == "2x16x16":
            md.append(f"* {arch} × {shape}: compile {comp}s, {mem} GiB/dev,"
                      f" {coll} MiB collectives")

    md.append("\n## §Roofline (single-pod 16×16, optimized code)\n")
    md.append(open("EXPERIMENTS.roofline.md").read())
    md.append(roofline_table_md(single))

    md.append("\n### Baseline → optimized (analyzer-stable metrics)\n")
    md.append("| arch | shape | collective s (base→opt) | peak GiB/dev "
              "(base→opt) |")
    md.append("|---|---|---|---|")
    for arch, shape, cb, cn, mb, mn in sorted(deltas):
        md.append(f"| {arch} | {shape} | {cb:.2f} → {cn:.2f} "
                  f"| {mb:.1f} → {mn:.1f} |")

    md.append("\n## §Perf\n")
    md.append(open("EXPERIMENTS.perf.md").read())

    with open(OUT, "w") as fh:
        fh.write("\n".join(md))
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
