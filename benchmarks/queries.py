"""Shared query library for the paper-table benchmarks.

Builds the §6 world (roads + speed observations) and the Q1–Q5 traffic
speed-variability queries: "accumulate all the speed observations per road
segment during the morning rush hours (8−9 am on weekdays), and compute
the standard deviation of the speeds, normalized with respect to its mean
— the *coefficient of variation*."
"""
from __future__ import annotations

import numpy as np

from repro.core import P, proto, IN, BETWEEN, group, fdb
from repro.data.synthetic import CITIES, BAY_AREA, generate_world
from repro.exec import AdHocEngine, Catalog
from repro.fdb import build_fdb
from repro.geo import AreaTree, mercator as M

__all__ = ["build_catalog", "region_for", "q_variability", "QUERIES"]


def build_catalog(scale: float = 1.0, num_shards: int = 20,
                  seed: int = 0) -> Catalog:
    world = generate_world(scale=scale, seed=seed)
    cat = Catalog(server_slots=64)
    cat.register(build_fdb("Roads", world["roads_schema"],
                           world["roads"], num_shards=max(4, num_shards // 4)))
    cat.register(build_fdb("SpeedObservations",
                           world["observations_schema"],
                           world["observations"], num_shards=num_shards))
    cat.register(build_fdb("RouteRequests",
                           world["route_requests_schema"],
                           world["route_requests"],
                           num_shards=max(4, num_shards // 4)))
    return cat


def region_for(cities) -> AreaTree:
    """Union of city bounding boxes → selection region."""
    area = AreaTree.empty()
    for c in cities:
        lat0, lng0, dlat, dlng = CITIES[c]
        ix, iy = M.latlng_to_xy(np.array([lat0, lat0 + dlat]),
                                np.array([lng0, lng0 + dlng]))
        # level 6 ≈ 150 m cells: city-scale selection with ~100× fewer
        # Morton ranges than level 7 (probe cost ∝ ranges)
        area = area | AreaTree.from_box(int(ix[0]), int(iy[1]),
                                        int(ix[1]), int(iy[0]),
                                        max_level=6)
    return area


def q_variability(cities, months: int, *, mode: str = "multi_index",
                  sample: float | None = None):
    """Coefficient-of-variation per road (Q1–Q5) under a selection mode.

    mode = 'multi_index'  — geospatial + hour + dow + month indices
           'geo_index'    — geospatial index only; time filtered post-read
           'full_scan'    — no index use at all (filter everything)
    """
    region = region_for(cities)
    flow = fdb("SpeedObservations")
    time_pred = (BETWEEN(P.hour, 8, 9) & BETWEEN(P.dow, 0, 4)
                 & BETWEEN(P.month, 1, months))
    if mode == "multi_index":
        flow = flow.find(IN(P.loc, region) & time_pred)
    elif mode == "geo_index":
        flow = flow.find(IN(P.loc, region)).filter(time_pred)
    elif mode == "full_scan":
        # obscure the predicates so the planner cannot use any index:
        # (x + 0) is no longer a bare FieldRef
        flow = flow.filter(
            IN(P.loc, region) if False else (
                ((P.hour + 0) >= 8) & ((P.hour + 0) <= 9)
                & ((P.dow + 0) <= 4) & ((P.month + 0) <= months)))
        # geospatial containment without the index:
        flow = flow.filter(IN_region_residual(region))
    else:
        raise ValueError(mode)
    if sample:
        flow = flow.sample(sample)
    return (flow.aggregate(group(P.road_id)
                           .avg(mean_speed=P.speed)
                           .std_dev(std_speed=P.speed)
                           .count("n"))
            .map(lambda p: proto(road_id=p.road_id, n=p.n,
                                 cov=p.std_speed / p.mean_speed)))


def IN_region_residual(region):
    """Point-in-region as a plain expression (no index use)."""
    from repro.core.exprs import InRegion, FieldRef, ExprProxy, BinOp, Lit
    # InRegion on a synthetic FieldRef copy — identical math, but applied
    # via filter() so the planner never sees it in find()
    return ExprProxy(InRegion(FieldRef("loc"), region))


#: paper §6 query list
QUERIES = {
    "Q1": (("SF",), 1),
    "Q2": (("SF",), 6),
    "Q3": (BAY_AREA, 1),
    "Q4": (BAY_AREA, 6),
    "Q5": (tuple(CITIES), 1),       # "California" = every city
}
