"""Shared query library for the paper-table benchmarks.

Builds the §6 world (roads + speed observations + trips) and two query
families:

  * Q1–Q5 — traffic speed variability: "accumulate all the speed
    observations per road segment during the morning rush hours (8−9 am on
    weekdays), and compute the standard deviation of the speeds, normalized
    with respect to its mean — the *coefficient of variation*",
  * Q6–Q7 — Tesseract trip queries (§2): "all trips passing through region
    A during time window T1 and region B during T2", served by the
    per-shard ``spacetime`` index (:mod:`repro.tess`),
  * Q8–Q9 — *ordered* Tesseract trip queries: the same legs sequenced with
    ``Tesseract.then()`` ("through A during T1 **and then** B during T2"),
    resolved by the refine kernel's per-constraint first-hit timestamps.
"""
from __future__ import annotations

from repro.core import P, proto, IN, BETWEEN, group, fdb
from repro.data.synthetic import (CITIES, BAY_AREA, city_region,
                                  generate_world)
from repro.exec import AdHocEngine, Catalog
from repro.fdb import build_fdb
from repro.geo import AreaTree
from repro.tess import Tesseract

__all__ = ["build_catalog", "region_for", "q_variability", "QUERIES",
           "tesseract_for", "q_tesseract", "TRIP_QUERIES", "TRIP_DAY",
           "ORDERED_TRIP_QUERIES"]


def build_catalog(scale: float = 1.0, num_shards: int = 20,
                  seed: int = 0) -> Catalog:
    world = generate_world(scale=scale, seed=seed)
    cat = Catalog(server_slots=64)
    cat.register(build_fdb("Roads", world["roads_schema"],
                           world["roads"], num_shards=max(4, num_shards // 4)))
    cat.register(build_fdb("SpeedObservations",
                           world["observations_schema"],
                           world["observations"], num_shards=num_shards))
    cat.register(build_fdb("RouteRequests",
                           world["route_requests_schema"],
                           world["route_requests"],
                           num_shards=max(4, num_shards // 4)))
    cat.register(build_fdb("Trips", world["trips_schema"], world["trips"],
                           num_shards=max(10, num_shards // 2)))
    return cat


def region_for(cities) -> AreaTree:
    """Union of city bounding boxes → selection region."""
    return city_region(*cities)


def q_variability(cities, months: int, *, mode: str = "multi_index",
                  sample: float | None = None):
    """Coefficient-of-variation per road (Q1–Q5) under a selection mode.

    mode = 'multi_index'  — geospatial + hour + dow + month indices
           'geo_index'    — geospatial index only; time filtered post-read
           'full_scan'    — no index use at all (filter everything)
    """
    region = region_for(cities)
    flow = fdb("SpeedObservations")
    time_pred = (BETWEEN(P.hour, 8, 9) & BETWEEN(P.dow, 0, 4)
                 & BETWEEN(P.month, 1, months))
    if mode == "multi_index":
        flow = flow.find(IN(P.loc, region) & time_pred)
    elif mode == "geo_index":
        flow = flow.find(IN(P.loc, region)).filter(time_pred)
    elif mode == "full_scan":
        # obscure the predicates so the planner cannot use any index:
        # (x + 0) is no longer a bare FieldRef
        flow = flow.filter(
            IN(P.loc, region) if False else (
                ((P.hour + 0) >= 8) & ((P.hour + 0) <= 9)
                & ((P.dow + 0) <= 4) & ((P.month + 0) <= months)))
        # geospatial containment without the index:
        flow = flow.filter(IN_region_residual(region))
    else:
        raise ValueError(mode)
    if sample:
        flow = flow.sample(sample)
    return (flow.aggregate(group(P.road_id)
                           .avg(mean_speed=P.speed)
                           .std_dev(std_speed=P.speed)
                           .count("n"))
            .map(lambda p: proto(road_id=p.road_id, n=p.n,
                                 cov=p.std_speed / p.mean_speed)))


def IN_region_residual(region):
    """Point-in-region as a plain expression (no index use)."""
    from repro.core.exprs import InRegion, FieldRef, ExprProxy, BinOp, Lit
    # InRegion on a synthetic FieldRef copy — identical math, but applied
    # via filter() so the planner never sees it in find()
    return ExprProxy(InRegion(FieldRef("loc"), region))


#: paper §6 query list
QUERIES = {
    "Q1": (("SF",), 1),
    "Q2": (("SF",), 6),
    "Q3": (BAY_AREA, 1),
    "Q4": (BAY_AREA, 6),
    "Q5": (tuple(CITIES), 1),       # "California" = every city
}


# --------------------------------------------------------------------------
# Tesseract trip queries (Q6–Q7)
# --------------------------------------------------------------------------

#: synthetic-week day the trip queries pin their windows to (0=Mon … 6=Sun)
TRIP_DAY = 2


def tesseract_for(legs, day: int = TRIP_DAY,
                  ordered: bool = False) -> Tesseract:
    """``legs``: sequence of ``(cities, hour0, hour1)`` constraints — the
    trip must pass through ``region_for(cities)`` during ``[hour0, hour1]``
    of ``day`` (track ``t`` is seconds since the week's epoch).
    ``ordered`` sequences the legs with ``then()``: each leg's first hit
    must come strictly before the next leg's (A-then-B trip queries)."""
    tess = None
    for cities, h0, h1 in legs:
        region = region_for(cities)
        t0 = day * 86400.0 + h0 * 3600.0
        t1 = day * 86400.0 + h1 * 3600.0
        tess = Tesseract(region, t0, t1) if tess is None \
            else (tess.then(region, t0, t1) if ordered
                  else tess.also(region, t0, t1))
    return tess


def q_tesseract(legs, day: int = TRIP_DAY, ordered: bool = False):
    """Trip ids + durations matching a multi-constraint Tesseract query."""
    return (fdb("Trips").tesseract(tesseract_for(legs, day,
                                                 ordered=ordered))
            .map(lambda p: proto(id=p.id, day=p.day,
                                 duration_s=p.duration_s)))


#: Q6: morning SF → Berkeley commute; Q7: Bay Area → LA long-haul
TRIP_QUERIES = {
    "Q6": ((("SF",), 6, 12), (("Berkeley",), 6, 14)),
    "Q7": ((BAY_AREA, 6, 12), (("LA",), 6, 18)),
}

#: ordered (A-then-B) variants: Q8 sequences Q6's commute (SF first, then
#: Berkeley), Q9 sequences Q7's long-haul (Bay Area first, then LA) — the
#: synthetic inter-city trips run origin-city-first, so ordering keeps the
#: true A→B trips and drops the B→A ones Q6/Q7 also admit
ORDERED_TRIP_QUERIES = {
    "Q8": TRIP_QUERIES["Q6"],
    "Q9": TRIP_QUERIES["Q7"],
}
