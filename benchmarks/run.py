"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus per-bench extra
columns) and a human-readable transcript.  ``--scale`` grows the synthetic
world; default sizes finish on a laptop CPU in a few minutes.

``--json`` additionally writes one machine-readable ``BENCH_<suite>.json``
per suite (per-query wall time + parity bit where the suite checks
parity), so the perf trajectory can be tracked across PRs
(``benchmarks/check_regression.py`` compares against a committed
baseline).

Exit status is the CI contract: **non-zero whenever any suite reports a
false parity bit** (numpy oracle ≠ jax batched path), and — under
``--json`` — whenever a suite errored outright, so the bench smoke job
cannot go green on broken output.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _write_json(suite: str, rows: list, scale: float, out_dir: str) -> str:
    """One BENCH_<suite>.json: rows with wall time + parity bit."""
    payload = {
        "suite": suite,
        "scale": scale,
        "rows": [
            {"name": r.get("name"),
             "us_per_call": r.get("us_per_call",
                                  r.get("exec_ms", r.get("compute_ms"))),
             **({"parity": r["parity"]} if "parity" in r else {}),
             **({"stages": r["stages"]} if "stages" in r else {}),
             **({"error": r["error"]} if "error" in r else {}),
             "derived": r.get("derived") or ",".join(
                 f"{k}={v}" for k, v in r.items()
                 if k not in ("name", "us_per_call", "derived"))}
            for r in rows
        ],
    }
    path = os.path.join(out_dir, f"BENCH_{suite}.json")
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1)
    return path


def main() -> None:
    from .suites import SUITES

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of "
                         + "|".join(SUITES))
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<suite>.json per suite "
                         "(wall time + parity bit)")
    ap.add_argument("--json-dir", default=".",
                    help="directory for --json output files")
    ap.add_argument("--profile", action="store_true",
                    help="run the fused wave pipeline stage-by-stage with "
                         "per-stage device sync and add a per-stage "
                         "(upload/probe/refine/compact/agg) ms breakdown "
                         "to each backend query row (diagnostic: stages "
                         "run eagerly, so wall times are not the fused "
                         "single-dispatch numbers)")
    args = ap.parse_args()
    if args.profile:
        os.environ["REPRO_EXEC_PROFILE"] = "1"

    # one bench per registry entry (benchmarks/suites.py): --only here,
    # check_regression.py --suite, and the Makefile all read the same table
    import importlib

    def _bench(spec):
        mod = importlib.import_module(f".{spec['module']}", __package__)
        kw = {}
        if spec["scale"]:
            kw["scale"] = args.scale
        if spec["parity"]:
            # parity verdicts flow into rows; this harness owns the exit
            # code
            kw["raise_on_mismatch"] = False
        return lambda: mod.run(**kw)

    benches = {name: _bench(spec) for name, spec in SUITES.items()}
    only = {s for s in (args.only or "").split(",") if s}
    unknown = only - set(benches)
    if unknown:
        raise SystemExit(f"unknown --only suite(s): {sorted(unknown)}; "
                         f"known: {sorted(benches)}")
    all_rows = []
    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"== {name} ==", flush=True)
        try:
            suite_rows = fn() or []
        except Exception as e:  # keep the harness going; report at end
            print(f"  BENCH FAILED: {name}: {e!r}", file=sys.stderr)
            suite_rows = [{"name": f"{name}_FAILED", "error": repr(e)}]
        all_rows.extend(suite_rows)
        if args.json:
            path = _write_json(name, suite_rows, args.scale, args.json_dir)
            print(f"  wrote {path}")

    print("\nname,us_per_call,derived")
    for r in all_rows:
        us = r.get("us_per_call", r.get("exec_ms", r.get("compute_ms", "")))
        derived = r.get("derived") or ",".join(
            f"{k}={v}" for k, v in r.items()
            if k not in ("name", "us_per_call", "derived"))
        print(f"{r['name']},{us},\"{derived}\"")

    parity_bad = [r["name"] for r in all_rows
                  if "parity" in r and not r["parity"]]
    errors = [r["name"] for r in all_rows if "error" in r]
    if parity_bad:
        print(f"\nPARITY FAILURE: {parity_bad}", file=sys.stderr)
        sys.exit(1)
    if errors and args.json:
        print(f"\nSUITE ERRORS: {errors}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
