#!/usr/bin/env python
"""Compare a fresh ``BENCH_<suite>.json`` against a committed baseline.

Fails (exit 1) when any query's wall time regressed by more than
``--threshold`` (default 1.5×) versus the baseline.  Rows are matched by
name; rows missing from either side, non-numeric rows (parity summaries),
and rows faster than ``--min-us`` (dispatch noise on shared CI runners)
are reported but never fail the check.

CI wires this as a *non-blocking* report step to start (the baselines are
laptop-class numbers; absolute CI-runner variance is still being learned)
— flip ``continue-on-error`` off in ``.github/workflows/ci.yml`` once the
numbers settle.  Runs on stdlib only, no repo imports:

    python benchmarks/check_regression.py \
        --current BENCH_backends.json \
        --baseline benchmarks/baselines/BENCH_backends.json
"""
from __future__ import annotations

import argparse
import json
import sys


def _rows_by_name(path: str) -> dict:
    with open(path) as fh:
        payload = json.load(fh)
    out = {}
    for row in payload.get("rows", []):
        us = row.get("us_per_call")
        if isinstance(us, (int, float)) and row.get("name"):
            out[row["name"]] = float(us)
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--current", required=True,
                    help="fresh BENCH_<suite>.json")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline BENCH_<suite>.json")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="fail when current > threshold × baseline")
    ap.add_argument("--min-us", type=float, default=500.0,
                    help="ignore rows faster than this (dispatch noise)")
    args = ap.parse_args()

    cur = _rows_by_name(args.current)
    base = _rows_by_name(args.baseline)
    regressions, skipped = [], []
    print(f"{'query':44s} {'baseline':>12s} {'current':>12s} {'ratio':>7s}")
    for name in sorted(base):
        if name not in cur:
            skipped.append(f"{name} (missing from current)")
            continue
        b, c = base[name], cur[name]
        ratio = c / b if b > 0 else float("inf")
        flag = ""
        if max(b, c) < args.min_us:
            flag = "  (below --min-us, informational)"
        elif ratio > args.threshold:
            flag = "  REGRESSION"
            regressions.append((name, b, c, ratio))
        print(f"{name:44s} {b:10.1f}µs {c:10.1f}µs {ratio:6.2f}x{flag}")
    for name in sorted(set(cur) - set(base)):
        skipped.append(f"{name} (new, no baseline)")
    for s in skipped:
        print(f"  note: {s}")
    if regressions:
        print(f"\n{len(regressions)} regression(s) past "
              f"{args.threshold:.2f}x:", file=sys.stderr)
        for name, b, c, ratio in regressions:
            print(f"  {name}: {b:.1f}µs → {c:.1f}µs ({ratio:.2f}x)",
                  file=sys.stderr)
        return 1
    print("\nno wall-time regressions past "
          f"{args.threshold:.2f}x ({len(base)} baseline rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
