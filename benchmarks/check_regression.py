#!/usr/bin/env python
"""Compare fresh ``BENCH_<suite>.json`` runs against committed baselines.

Fails (exit 1) when

  * any query's wall time regressed by more than ``--threshold`` (default
    1.5×) versus the baseline, or
  * the two sides disagree about which queries exist — a query in the
    baseline but missing from the current run, or vice versa, is printed
    as a readable two-column diff and fails the check (an out-of-date
    baseline must be regenerated and committed alongside the change).

Rows are matched by name; non-numeric rows (parity summaries) and rows
faster than ``--min-us`` (dispatch noise on shared CI runners) are
reported but never fail the check.

CI wires this as a **blocking** PR gate (the ``bench-smoke`` job): pass
the ``bench-skip`` PR label or put ``[bench-skip]`` in the head commit
message to skip it for an intentional perf trade.  Runs on stdlib only,
no repo imports:

    # one suite, explicit files
    python benchmarks/check_regression.py \
        --current BENCH_backends.json \
        --baseline benchmarks/baselines/BENCH_backends.json

    # several suites, conventional paths (BENCH_<s>.json in --current-dir
    # vs benchmarks/baselines/BENCH_<s>.json)
    python benchmarks/check_regression.py --suite backends,tesseract
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _registry_suites() -> str:
    """Blocking suite set from benchmarks/suites.py (stdlib-only import;
    works both as a script and as the ``benchmarks.check_regression``
    module)."""
    try:
        from .suites import regression_csv        # type: ignore
    except ImportError:
        sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
        from suites import regression_csv          # type: ignore
    return regression_csv()


def _rows_by_name(path: str) -> dict:
    with open(path) as fh:
        payload = json.load(fh)
    out = {}
    for row in payload.get("rows", []):
        us = row.get("us_per_call")
        if isinstance(us, (int, float)) and row.get("name"):
            out[row["name"]] = float(us)
    return out


def check_pair(current: str, baseline: str, threshold: float,
               min_us: float) -> int:
    """Compare one (current, baseline) file pair; returns the number of
    failures (regressions + row-set mismatches)."""
    cur = _rows_by_name(current)
    base = _rows_by_name(baseline)
    regressions = []
    print(f"{'query':44s} {'baseline':>12s} {'current':>12s} {'ratio':>7s}")
    for name in sorted(set(base) & set(cur)):
        b, c = base[name], cur[name]
        ratio = c / b if b > 0 else float("inf")
        flag = ""
        if max(b, c) < min_us:
            flag = "  (below --min-us, informational)"
        elif ratio > threshold:
            flag = "  REGRESSION"
            regressions.append((name, b, c, ratio))
        print(f"{name:44s} {b:10.1f}µs {c:10.1f}µs {ratio:6.2f}x{flag}")
    # row-set mismatch: fail with a readable diff instead of silently
    # skipping (or KeyError-ing) — the baseline must track the suite
    missing_cur = sorted(set(base) - set(cur))
    missing_base = sorted(set(cur) - set(base))
    if missing_cur or missing_base:
        print(f"\nrow-set mismatch between {current} and {baseline}:",
              file=sys.stderr)
        for name in missing_cur:
            print(f"  - {name:42s} in baseline, missing from current run",
                  file=sys.stderr)
        for name in missing_base:
            print(f"  + {name:42s} in current run, missing from baseline "
                  f"(regenerate + commit the baseline)", file=sys.stderr)
    if regressions:
        print(f"\n{len(regressions)} regression(s) past "
              f"{threshold:.2f}x:", file=sys.stderr)
        for name, b, c, ratio in regressions:
            print(f"  {name}: {b:.1f}µs → {c:.1f}µs ({ratio:.2f}x)",
                  file=sys.stderr)
    n_fail = len(regressions) + len(missing_cur) + len(missing_base)
    if n_fail == 0:
        print(f"\nno wall-time regressions past {threshold:.2f}x "
              f"({len(base)} baseline rows)")
    return n_fail


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--suite", default=None,
                    help="comma-separated suite names; compares "
                         "<current-dir>/BENCH_<s>.json against "
                         "<baseline-dir>/BENCH_<s>.json for each "
                         "(default, when --current is not given: the "
                         "blocking set from benchmarks/suites.py)")
    ap.add_argument("--current-dir", default=".",
                    help="directory holding fresh BENCH_<suite>.json files")
    ap.add_argument("--baseline-dir", default="benchmarks/baselines",
                    help="directory holding committed baselines")
    ap.add_argument("--current", default=None,
                    help="fresh BENCH_<suite>.json (single-pair mode)")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline (single-pair mode)")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="fail when current > threshold × baseline")
    ap.add_argument("--min-us", type=float, default=500.0,
                    help="ignore rows faster than this (dispatch noise)")
    args = ap.parse_args(argv)

    if args.suite is None and args.current is None:
        # default to the registry's blocking set — the same table
        # benchmarks/run.py --only reads, so the gate can't drift
        args.suite = _registry_suites()
    if bool(args.suite) == bool(args.current):
        ap.error("pass either --suite or --current/--baseline")
    if args.current and not args.baseline:
        ap.error("--current needs --baseline")

    pairs = [(args.current, args.baseline)] if args.current else [
        (os.path.join(args.current_dir, f"BENCH_{s}.json"),
         os.path.join(args.baseline_dir, f"BENCH_{s}.json"))
        for s in args.suite.split(",") if s]
    failures = 0
    for current, baseline in pairs:
        print(f"== {current} vs {baseline} ==")
        for path in (current, baseline):
            if not os.path.exists(path):
                print(f"  MISSING FILE: {path}", file=sys.stderr)
                failures += 1
                break
        else:
            failures += check_pair(current, baseline, args.threshold,
                                   args.min_us)
        print()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
