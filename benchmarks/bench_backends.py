"""End-to-end execution-backend comparison: numpy oracle vs jax kernels.

Extends the per-kernel microbenchmarks (bench_kernels) to the full query
path: every Q1–Q5 benchmark query runs under both registered backends —
the jax side through the **fused** wave path (one ``run_wave_fused``
dispatch per ⌈shards/wave⌉ wave chaining probe → compact → segment-agg,
device-resident columns; ``REPRO_EXEC_FUSED=0`` restores the legacy
per-primitive wave launches) — and the report shows per-query wall time,
speedup, kernel-launch counts, and a byte-level parity verdict against
the numpy per-shard oracle — the contract every future lowering (GPU,
sharded meshes) must keep.  Timing blocks on the last device output
before the clock stops (jax dispatch is async).  With
``benchmarks.run --profile`` each query row adds a per-stage
(upload/probe/refine/compact/agg) device-time breakdown.

On CPU the jax backend resolves to the ``reference`` kernel impl, so the
timing column measures dispatch overhead, not TPU speedup; run with
``REPRO_KERNEL_IMPL=pallas`` on a TPU host for the hardware numbers.

Every row carries a ``parity`` bit; ``benchmarks.run`` exits non-zero when
any suite reports a false one (the CI bench smoke gate).
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.exec import AdHocEngine, get_backend
from repro.kernels import fused as fused_kernels
from repro.fdb.index import bitmap_from_ids, bitmap_full
from repro.kernels import ops as kernel_ops

from .queries import QUERIES, build_catalog, q_variability

__all__ = ["run", "batches_identical"]


def batches_identical(a, b) -> bool:
    if a.n != b.n or a.paths() != b.paths():
        return False
    for p in a.paths():
        ca, cb = a[p], b[p]
        if ca.values.dtype != cb.values.dtype:
            return False
        if not np.array_equal(ca.values, cb.values):
            return False
        if (ca.row_splits is None) != (cb.row_splits is None):
            return False
        if ca.row_splits is not None and \
                not np.array_equal(ca.row_splits, cb.row_splits):
            return False
        if ca.vocab != cb.vocab:
            return False
    return True


def _sync(out):
    """jax dispatch is async: block on any device values reachable from
    ``out`` so the clock stops at completion, not at enqueue."""
    try:
        import jax
        jax.block_until_ready(out)
    except Exception:
        pass
    return out


def _time(fn, repeats=3):
    _sync(fn())                              # warm (jit compile etc.)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = _sync(fn())
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e3                   # ms


def _bench_primitives(rows, print_fn):
    """Backend primitive microbenches: the three hot-path ops, both ways."""
    rng = np.random.default_rng(0)
    n = 1 << 20
    full = bitmap_full(n)
    probes = [bitmap_from_ids(rng.choice(n, n // 3, replace=False), n)
              for _ in range(4)]
    mask = rng.random(n) < 0.3
    codes = rng.integers(0, 1024, n)
    vals = rng.normal(48.0, 9.0, n)
    for bname in ("numpy", "jax"):
        be = get_backend(bname)
        for op_name, fn in [
                ("intersect_4x1M", lambda: be.intersect_bitmaps(full, probes)),
                ("select_ids_1M", lambda: be.select_ids(full, n)),
                ("compact_1M", lambda: be.compact_mask(mask)),
                ("segment_agg_1M_1024g",
                 lambda: be.segment_aggregate(codes, vals, 1024))]:
            _, ms = _time(fn)
            rows.append({"name": f"backend_{bname}_{op_name}",
                         "us_per_call": round(ms * 1e3, 1),
                         "derived": f"{n / (ms * 1e3):.1f} Melem/s"})
            print_fn(f"  {rows[-1]['name']:44s} "
                     f"{rows[-1]['us_per_call']:10.1f} µs  "
                     f"{rows[-1]['derived']}")


def run(scale: float = 0.5, print_fn=print, raise_on_mismatch: bool = True):
    rows: list = []
    # REPRO_EXEC_PROFILE=1 (benchmarks.run --profile): the fused pipeline
    # runs its stages eagerly with per-stage device sync so each query row
    # carries a "stages" timing breakdown (diagnostic mode — the fused
    # single-dispatch timing above is the real number)
    profile = os.environ.get("REPRO_EXEC_PROFILE") == "1"
    _bench_primitives(rows, print_fn)

    cat = build_catalog(scale=scale)
    engines = {b: AdHocEngine(cat, backend=b) for b in ("numpy", "jax")}
    n_shards = cat.get("SpeedObservations").num_shards
    wave = engines["jax"].wave
    all_parity = True
    for qname, (cities, months) in QUERIES.items():
        flow = q_variability(cities, months)
        results, times = {}, {}
        stages, launches = None, 0
        for bname, eng in engines.items():
            if bname == "jax":
                kernel_ops.reset_launch_counts()
            res, ms = _time(lambda e=eng: e.collect(flow), repeats=2)
            results[bname], times[bname] = res, ms
            if bname != "jax":
                continue
            # kernel dispatches per collect on the batched jax path:
            # launch counts are deterministic, so the 3 timed calls
            # (warm + 2 repeats) divide evenly.  On the fused path the
            # whole query is ⌈shards/wave⌉ ``run_wave_fused`` dispatches
            # total; with REPRO_EXEC_FUSED=0 it is ⌈shards/wave⌉ per
            # primitive
            launches = sum(kernel_ops.launch_counts().values()) // 3
            if profile:
                # per-stage device ms (upload/probe/refine/compact/agg)
                # for ONE post-warm collect, so compile time stays out
                fused_kernels.reset_stage_times()
                _sync(eng.collect(flow))
                stages = {k: round(v, 3)
                          for k, v in fused_kernels.stage_times().items()}
        parity = batches_identical(results["numpy"].batch,
                                   results["jax"].batch) \
            and results["numpy"].profile.rows_selected \
            == results["jax"].profile.rows_selected
        all_parity &= parity
        speedup = times["numpy"] / max(times["jax"], 1e-9)
        rows.append({
            "name": f"backend_e2e_{qname}",
            "us_per_call": round(times["jax"] * 1e3, 1),
            "parity": 1 if parity else 0,
            **({"stages": stages} if stages else {}),
            "derived": (f"numpy={times['numpy']:.1f}ms "
                        f"jax={times['jax']:.1f}ms "
                        f"speedup={speedup:.2f}x "
                        f"rows={results['numpy'].batch.n} "
                        f"launches={launches} "
                        f"shards={n_shards} wave={wave} "
                        f"parity={'OK' if parity else 'MISMATCH'}")})
        print_fn(f"  {qname}: {rows[-1]['derived']}"
                 + (f" stages={stages}" if stages else ""))
    rows.append({"name": "backend_parity_all",
                 "us_per_call": "",
                 "parity": 1 if all_parity else 0,
                 "derived": "OK" if all_parity else "MISMATCH"})
    print_fn(f"  parity across all queries: "
             f"{'OK' if all_parity else 'MISMATCH'}")
    if not all_parity and raise_on_mismatch:
        raise AssertionError("backend parity violated — see report rows")
    return rows
