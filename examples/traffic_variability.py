"""Paper §6: "Which roads have highly variable traffic speeds during
weekday mornings?" — Q1 through Q5 + an ASCII rendering of Figure 10.

Runs the coefficient-of-variation pipeline on each region/time window,
prints per-query profiles (the Figure 11/12 quantities), and "renders"
the Q1 result as a CoV histogram (stand-in for the map of Figure 10).

Run:  PYTHONPATH=src python examples/traffic_variability.py
"""
import sys

sys.path.insert(0, "benchmarks")

from queries import QUERIES, build_catalog, q_variability  # noqa: E402

from repro.core import P, fdb, proto  # noqa: E402
from repro.exec import AdHocEngine  # noqa: E402


def main():
    cat = build_catalog(scale=1.0, num_shards=24)
    engine = AdHocEngine(cat, num_servers=8)

    results = {}
    for qname, (cities, months) in QUERIES.items():
        res = engine.collect(q_variability(cities, months))
        p = res.profile
        results[qname] = res
        print(f"{qname}: {res.n:5d} roads | scanned {p.rows_scanned:7d} "
              f"selected {p.rows_selected:6d} read {p.bytes_read:9d}B "
              f"cpu {p.cpu_ms:7.1f}ms exec {p.exec_ms:7.1f}ms")

    # "Figure 10": CoV distribution for Q1 (San Francisco)
    recs = [r for r in results["Q1"].to_records() if r["n"] >= 3]
    print(f"\nQ1 — normalized speed variation, San Francisco "
          f"({len(recs)} roads with ≥3 obs):")
    buckets = [0.0, 0.05, 0.10, 0.15, 0.20, 0.30, 1.0]
    for lo, hi in zip(buckets[:-1], buckets[1:]):
        n = sum(1 for r in recs if lo <= r["cov"] < hi)
        print(f"  cov [{lo:4.2f},{hi:4.2f})  "
              + "#" * min(n, 60) + f"  {n}")
    worst = sorted(recs, key=lambda r: -r["cov"])[:5]
    print("\nmost variable roads (the map's red segments):")
    for r in worst:
        print(f"  road {r['road_id']:5d}  cov={r['cov']:.3f}  "
              f"n={r['n']}")

    # join back onto geometry for rendering (the paper joins with the
    # road-geometry dataset before mapping)
    top_ids = [int(r["road_id"]) for r in worst]
    geo = (fdb("Roads")
           .find(P.id.in_(top_ids))
           .map(lambda p: proto(id=p.id, lat=p.loc.lat, lng=p.loc.lng))
           ).collect(engine)
    for rec in geo.to_records():
        print(f"  road {rec['id']:5d} @ ({rec['lat']:.4f}, "
              f"{rec['lng']:.4f})")


if __name__ == "__main__":
    main()
