"""Quickstart — the paper's Figure 1 query, in our WFL embedding.

Evaluate a road-speed prediction model: apply the model to San Francisco
roads at 8 am, join predictions onto route requests via a collected dict,
and aggregate the prediction error (mean ± std) — the exact pipeline of
the WFL snippet in the paper, including the vectorized dictionary lookup
``roads[p.route.id]`` over the request's route (a repeated field).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import P, proto, IN, BETWEEN, group, fdb, vsum
from repro.core.exprs import func
from repro.data.synthetic import generate_world
from repro.exec import AdHocEngine, Catalog
from repro.fdb import build_fdb
from repro.ml.integration import MLPRegressor

import sys
sys.path.insert(0, "benchmarks")
from queries import region_for  # noqa: E402


def main():
    # -- setup: the world + a (toy-trained) speed model ------------------
    world = generate_world(scale=0.5, seed=3)
    cat = Catalog()
    cat.register(build_fdb("Roads", world["roads_schema"],
                           world["roads"], num_shards=6))
    cat.register(build_fdb("RouteRequests",
                           world["route_requests_schema"],
                           world["route_requests"], num_shards=6))
    engine = AdHocEngine(cat, num_servers=6)
    sf = region_for(("SF",))

    speed_model = MLPRegressor(num_features=2, hidden=32, depth=1)
    feats = np.array([[r["speed_limit"], 8.0] for r in world["roads"]],
                     np.float32)
    targets = np.array([r["base_speed"] * 0.6 for r in world["roads"]],
                       np.float32)
    speed_model.train(feats, targets, steps=300, lr=5e-3)
    speed_tf_model = speed_model.as_column_model(["speed_limit", "hour"])

    # -- Fig. 1, stage 1: predicted speed + distance per SF road ---------
    roads = (fdb("Roads")
             .find(IN(P.loc, sf))
             .map(lambda p: proto(id=p.id,
                                  distance=func("distance", P.polyline),
                                  speed_limit=p.speed_limit))
             .model_apply(speed_tf_model, output="pred_speed",
                          speed_limit=P.speed_limit,
                          hour=P.speed_limit * 0.0 + 8.0)
             .collect(engine)
             .to_dict("id"))
    print(f"roads in SF with predictions: {roads.n}")

    # -- Fig. 1, stage 2: VectorSum(predicted time) per request ----------
    q = (fdb("RouteRequests")
         .find(IN(P.start_loc, sf) & IN(P.end_loc, sf)
               & BETWEEN(P.hour, 8, 9))
         .map(lambda p: proto(
             error=p.time_s - vsum(
                 roads[p.route.id].distance
                 / (roads[p.route.id].pred_speed + 1.0))))
         .aggregate(group()
                    .avg(mean_error=P.error)
                    .std_dev(std=P.error)
                    .count("n")))
    res = q.collect(engine)
    rec = res.to_records()[0]
    print(f"route requests evaluated: {rec['n']}")
    print(f"prediction error: mean={rec['mean_error']:.1f}s "
          f"std={rec['std']:.1f}s")
    print(f"profile: scanned={res.profile.rows_scanned} "
          f"selected={res.profile.rows_selected} "
          f"read={res.profile.bytes_read}B "
          f"exec={res.profile.exec_ms:.1f}ms")


if __name__ == "__main__":
    main()
