"""Tesseract trip queries — the paper's §2 headline workload.

"All trips passing through region A during time window T1 and region B
during T2": build the synthetic trip world, declare a ``spacetime`` index
on the track field (done by ``trips_schema``), and run a two-constraint
Tesseract query through both execution backends.  The pruning report shows
how many trips the (area-tree cell × time bucket) postings admit vs. the
exact point-in-cover × time-window refine.

The second query is the *ordered* variant: ``Tesseract.then()`` sequences
the constraints — "through SF **and then** Berkeley" — which keeps only
trips whose first SF hit comes strictly before their first Berkeley hit
(SF→Berkeley commutes) and drops the Berkeley→SF direction the unordered
``also()`` query admits.  Ordering is resolved inside the same fused
refine pass via per-constraint first-hit timestamps; ``before(i, j)``
builds arbitrary ordering DAGs on top of ``also()``.

Run:  PYTHONPATH=src python examples/tesseract_trips.py
"""
from repro.core import P, fdb, proto
from repro.data.synthetic import city_region, generate_world
from repro.exec import AdHocEngine, Catalog
from repro.fdb import build_fdb
from repro.tess import Tesseract, tesseract_stats


def main():
    world = generate_world(scale=0.5, seed=0)
    cat = Catalog()
    db = build_fdb("Trips", world["trips_schema"], world["trips"],
                   num_shards=12)
    cat.register(db)
    print(db)

    # Morning commute: through SF during 6–12, through Berkeley during 6–14
    # of day 2 (track timestamps are seconds since the synthetic week's
    # epoch).
    day = 2 * 86400.0
    tess = (Tesseract(city_region("SF"), day + 6 * 3600, day + 12 * 3600)
            .also(city_region("Berkeley"), day + 6 * 3600,
                  day + 14 * 3600))
    print(tess)

    stats = tesseract_stats(db, tess)
    print(f"index probe: {stats['candidates']}/{stats['docs']} candidate "
          f"trips (pruning {stats['pruning']:.1%}), "
          f"{stats['refined']} exact")

    flow = (fdb("Trips").tesseract(tess)
            .map(lambda p: proto(id=p.id, day=p.day,
                                 start_hour=p.start_hour,
                                 duration_s=p.duration_s))
            .sort_asc(P.id))
    for backend in ("numpy", "jax"):
        res = AdHocEngine(cat, num_servers=6, backend=backend).collect(flow)
        ids = res.batch["id"].values.tolist()
        print(f"{backend:>5}: {res.batch.n} trips {ids} "
              f"(scanned={res.profile.rows_scanned}, "
              f"candidates={res.profile.rows_selected})")
    for r in res.to_records():
        print(f"  trip {r['id']}: day {r['day']}, starts "
              f"{r['start_hour']:02d}:00, {r['duration_s'] / 60:.0f} min")

    # Ordered: SF first, THEN Berkeley — first-hit(SF) < first-hit(Berkeley)
    ordered = (Tesseract(city_region("SF"), day + 6 * 3600,
                         day + 12 * 3600)
               .then(city_region("Berkeley"), day + 6 * 3600,
                     day + 14 * 3600))
    print(f"\n{ordered} (SF -> Berkeley direction only)")
    oflow = (fdb("Trips").tesseract(ordered)
             .map(lambda p: proto(id=p.id)).sort_asc(P.id))
    for backend in ("numpy", "jax"):
        res = AdHocEngine(cat, num_servers=6, backend=backend).collect(oflow)
        print(f"{backend:>5}: {res.batch.n} ordered trips "
              f"{res.batch['id'].values.tolist()}")


if __name__ == "__main__":
    main()
