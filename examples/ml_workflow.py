"""Paper §5: the end-to-end ML workflow — the paper's flagship loop.

1. **data selection** (fast, via indices): pull (road, hour) → speed
   training data out of the observations FDb with a WFL query;
2. **train** a speed-prediction model (time-to-trained-model);
3. **large-scale evaluation**: apply the model back over the *full*
   dataset as a WFL operator and aggregate test error per city;
4. **offline annotation**: save predictions as a new FDb ("annotate [the
   roads] with the inferences produced by the model"), registered and
   queryable like any other dataset;
5. persist the model SavedModel-style and reload it.

Run:  PYTHONPATH=src python examples/ml_workflow.py
"""
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, "benchmarks")
from queries import build_catalog  # noqa: E402

from repro.core import P, proto, BETWEEN, group, fdb  # noqa: E402
from repro.exec import AdHocEngine  # noqa: E402
from repro.ml.integration import MLPRegressor  # noqa: E402


def main():
    cat = build_catalog(scale=1.0, num_shards=24)
    engine = AdHocEngine(cat, num_servers=8)

    # 1 -- training-data selection via WFL (join obs → road features):
    # the query selects + shapes the rows, to_dataset() lands them as a
    # TrainingDataset ready for fit()
    t0 = time.perf_counter()
    roads_tbl = (fdb("Roads")
                 .map(lambda p: proto(rid=p.id, sl=p.speed_limit,
                                      var=p.variability))
                 ).collect(engine).to_dict("rid")
    ds = (fdb("SpeedObservations")
          .find(BETWEEN(P.month, 1, 4))            # train split: months 1-4
          .to_dataset(features={"hour": P.hour * 1.0,
                                "dow": P.dow * 1.0,
                                "sl": roads_tbl[P.road_id].sl},
                      target=P.speed, engine=engine))
    t_select = time.perf_counter() - t0
    print(f"selected {len(ds)} training rows in {t_select*1e3:.0f}ms "
          f"(time-to-training-data)")

    # 2 -- train (features: hour, dow, speed_limit → speed)
    y = ds.targets
    t0 = time.perf_counter()
    model, losses = ds.fit(hidden=64, depth=2, steps=400, lr=2e-3)
    t_train = time.perf_counter() - t0
    print(f"trained 400 steps in {t_train:.1f}s "
          f"(loss {losses[0]:.1f} → {losses[-1]:.1f}) "
          f"(time-to-trained-model)")

    # 3 -- large-scale evaluation on the held-out months, as a WFL op
    col_model = model.as_column_model(["hour", "dow", "sl"])
    eval_q = (fdb("SpeedObservations")
              .find(BETWEEN(P.month, 5, 6))          # test split
              .map(lambda p: proto(hour=p.hour * 1.0, dow=p.dow * 1.0,
                                   sl=roads_tbl[p.road_id].sl,
                                   speed=p.speed,
                                   rid=p.road_id))
              .model_apply(col_model, output="pred",
                           hour=P.hour, dow=P.dow, sl=P.sl)
              .map(lambda p: proto(rid=p.rid,
                                   err=(p.pred - p.speed)
                                   * (p.pred - p.speed)))
              .aggregate(group().avg(mse=P.err).count("n")))
    res = engine.collect(eval_q)
    rec = res.to_records()[0]
    rmse = rec["mse"] ** 0.5
    print(f"large-scale eval: n={rec['n']} RMSE={rmse:.2f} "
          f"(naive-mean RMSE={np.std(y):.2f})")
    assert rmse < np.std(y), "model must beat the mean predictor"

    # 4 -- offline annotation: predictions per (road, rush-hour) saved
    annot_q = (fdb("Roads")
               .map(lambda p: proto(rid=p.id, sl=p.speed_limit,
                                    hour=p.speed_limit * 0.0 + 8.0,
                                    dow=p.speed_limit * 0.0 + 2.0))
               .model_apply(col_model, output="pred_speed",
                            hour=P.hour, dow=P.dow, sl=P.sl))
    db = engine.save(annot_q, "RoadSpeedPredictions", num_shards=4)
    check = engine.collect(
        fdb("RoadSpeedPredictions").aggregate(
            group().avg(mean_pred=P.pred_speed).count("n")))
    print(f"annotated FDb: {db.num_docs} roads, "
          f"mean predicted rush-hour speed "
          f"{check.to_records()[0]['mean_pred']:.1f}")

    # 5 -- SavedModel-style persistence round-trip
    d = tempfile.mkdtemp()
    model.save(d, ["hour", "dow", "sl"])
    reloaded = MLPRegressor.load(d)
    a = col_model.apply_columns({"hour": np.array([8.0]),
                                 "dow": np.array([2.0]),
                                 "sl": np.array([50.0])})
    b = reloaded.apply_columns({"hour": np.array([8.0]),
                                "dow": np.array([2.0]),
                                "sl": np.array([50.0])})
    assert np.allclose(a, b), "SavedModel round-trip mismatch"
    print(f"model saved+reloaded: pred@(8am,Tue,sl=50) = {float(b[0]):.1f}")


if __name__ == "__main__":
    main()
