"""Concurrent trip-query serving — coalescing, admission, result cache.

Many clients asking Tesseract trip queries against the same resident
FDb: a :class:`repro.serve.QueryServer` admits each ``submit()`` into a
bounded queue, its scheduler groups compatible concurrent queries into
one **multi-query wave batch** (Q queries ride a single
``run_wave_fused_multi`` device dispatch per wave — ⌈shards/wave⌉
dispatches *total*, not Q×⌈shards/wave⌉), and a TTL result cache answers
repeats without touching the device at all.  Every coalesced result is
byte-identical to the single-query path.

Run:  PYTHONPATH=src python examples/serve_tesseract.py
"""
import time
from concurrent.futures import ThreadPoolExecutor

from repro.core import Session, fdb
from repro.data.synthetic import city_region, generate_world
from repro.exec import AdHocEngine, Catalog
from repro.fdb import build_fdb
from repro.tess import Tesseract


def trip_query(h0: float, h1: float):
    """Through SF during [h0,h1], through Berkeley during [h0,h1+2]."""
    day = 2 * 86400.0
    tess = (Tesseract(city_region("SF"), day + h0 * 3600,
                      day + h1 * 3600)
            .also(city_region("Berkeley"), day + h0 * 3600,
                  day + (h1 + 2) * 3600))
    return fdb("Trips").tesseract(tess)


def main():
    world = generate_world(scale=0.5, seed=0)
    cat = Catalog()
    cat.register(build_fdb("Trips", world["trips_schema"], world["trips"],
                           num_shards=12))
    session = Session(catalog=cat,
                      engine=AdHocEngine(cat, backend="jax"))

    # eight clients, each with its own commute window — compatible plans
    flows = [trip_query(6 + 0.5 * k, 12 + 0.5 * k) for k in range(8)]
    with session.serve(max_pending=64, max_coalesce=16) as srv:
        # concurrent submits from worker threads; the scheduler thread
        # coalesces whatever lands in the same tick
        with ThreadPoolExecutor(max_workers=8) as pool:
            futs = list(pool.map(srv.submit, flows))
        for k, fut in enumerate(futs):
            res = fut.result(120)
            ids = sorted(res.batch["id"].values.tolist())
            print(f"client {k}: {res.batch.n} trips {ids}")
        st = srv.stats()
        print(f"\nserved={st['served']} coalesced={st['coalesced_queries']}"
              f" in {st['coalesced_batches']} batch(es), "
              f"fallback={st['fallback_queries']}")

        # repeats are answered from the TTL result cache — no device work
        t0 = time.perf_counter()
        for f in flows:
            srv.collect(f, timeout=120)
        warm_ms = (time.perf_counter() - t0) * 1e3
        st = srv.stats()
        print(f"warm repeat of all {len(flows)} queries: {warm_ms:.1f}ms, "
              f"cache_hits={st['cache_hits']}")


if __name__ == "__main__":
    main()
