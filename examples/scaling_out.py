"""Scaling out — run one query over P execution partitions.

The planner's pruned shard list splits into P contiguous partitions
(`PartitionPlan`); each partition dispatches its own fused waves and a
single `merge_partials` combine folds the per-partition aggregate
states.  Results are identical at any P by contract — partitioning is
purely a throughput knob — which this script demonstrates by running
the same rush-hour aggregation at P = 1, 2, 4 and comparing results
and launch counts, then killing a partition to show the elastic
reroute path.

Run:  PYTHONPATH=src python examples/scaling_out.py
"""
from repro.core import BETWEEN, P, group, fdb
from repro.core.planner import partition_shards
from repro.data.synthetic import generate_world
from repro.exec import AdHocEngine, Catalog, FaultPlan
from repro.fdb import build_fdb
from repro.kernels import ops

NUM_SHARDS = 8
WAVE = 3


def main():
    world = generate_world(scale=0.3, seed=11)
    cat = Catalog()
    cat.register(build_fdb("Obs", world["observations_schema"],
                           world["observations"], num_shards=NUM_SHARDS))

    # mean/spread of observed speed per road during the morning rush
    flow = (fdb("Obs").find(BETWEEN(P.hour, 7, 9))
            .aggregate(group(P.road_id).count("n").avg(mean=P.speed)
                       .std_dev(sd=P.speed).min(lo=P.speed)
                       .max(hi=P.speed)))

    results = {}
    for parts in (1, 2, 4):
        eng = AdHocEngine(cat, backend="jax", wave=WAVE, partitions=parts)
        eng.collect(flow)                       # warm: prime + jit caches
        ops.reset_launch_counts()
        res = eng.collect(flow)
        results[parts] = res.batch
        pp = partition_shards(range(NUM_SHARDS), parts)
        print(f"P={parts}: partitions {pp.sizes()}, "
              f"launches {dict(ops.launch_counts())} "
              f"(contract: {pp.wave_dispatches(WAVE)} fused dispatches"
              f"{' + 1 merge' if pp.merge_combines() else ''})")

    ref = results[1]
    for parts in (2, 4):
        got = results[parts]
        same = all((ref[p].values == got[p].values).all()
                   for p in ref.paths())
        print(f"P={parts} ≡ P=1: {same} ({got.n} groups)")

    # elastic recovery: partition 1 of 4 dies → its shards reroute to the
    # survivors before dispatch; coverage stays complete
    eng = AdHocEngine(cat, backend="jax", wave=WAVE, partitions=4)
    fp = FaultPlan(fail_always={("partition", 1)}, reroute_after=99)
    res = eng.collect(flow, fault_plan=fp)
    same = all((ref[p].values == res.batch[p].values).all()
               for p in ref.paths())
    print(f"partition 1 dead → rerouted: identical={same}, "
          f"coverage={res.coverage}, retries={res.profile.retries}")


if __name__ == "__main__":
    main()
