"""Batched LM serving demo: prefill + fused decode + continuous batching.

Serves a reduced-config architecture (pick any of the ten with --arch);
this is the decode-shape path the dry-run lowers at 512-chip scale.

Run:  PYTHONPATH=src python examples/serving.py --arch qwen1_5_0_5b
"""
import argparse
import time

import numpy as np

from repro.launch.serve import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_0_5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max_new", type=int, default=12)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    srv = Server(args.arch, reduced=True, max_batch=4)
    reqs = [Request(i,
                    rng.integers(0, srv.cfg.vocab_size,
                                 int(rng.integers(4, 20))
                                 ).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    srv.serve(reqs)
    dt = time.perf_counter() - t0
    print(f"arch={args.arch}: served {sum(r.done for r in reqs)}"
          f"/{len(reqs)} requests in {dt:.2f}s  stats={srv.stats}")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] → {r.out}")


if __name__ == "__main__":
    main()
