"""Live ingestion — append trips, query seconds later, never go stale.

A ``StreamingFDb`` registered as a *live* catalog source: time-sorted
trips stream through the memtable into delta shards (each flush builds
only its own spacetime postings), a Tesseract commute query plans only
the time-overlapping delta shards (partition pruning), and a serving
session with a result cache recomputes — automatically — the moment an
append lands, so the answer always reflects the live data.

Run:  PYTHONPATH=src python examples/streaming_ingest.py
"""
from repro.core import Session, fdb
from repro.core.planner import plan_flow
from repro.data.synthetic import CITIES, city_region, generate_world
from repro.exec import Catalog
from repro.fdb.streaming import StreamingFDb
from repro.tess import Tesseract

DAY = 86400.0


def commute_flow():
    """Through SF during 6–12, through Berkeley during 6–14 of day 2."""
    tess = (Tesseract(city_region("SF"), 2 * DAY + 6 * 3600,
                      2 * DAY + 12 * 3600)
            .also(city_region("Berkeley"), 2 * DAY + 6 * 3600,
                  2 * DAY + 14 * 3600))
    return fdb("Trips").tesseract(tess)


def probe_trip(trip_id):
    """A fresh trip the commute query must select: SF 7:00 → Berkeley
    7:15 on day 2."""
    def center(city):
        lat0, lng0, dlat, dlng = CITIES[city]
        return lat0 + dlat / 2, lng0 + dlng / 2
    t0 = 2 * DAY + 7 * 3600
    pts = [center("SF")] * 3 + [center("Berkeley")] * 3
    return {"id": trip_id, "vehicle": 0, "day": 2, "start_hour": 7,
            "track": {"lat": [p[0] for p in pts],
                      "lng": [p[1] for p in pts],
                      "t": [t0 + 300.0 * k for k in range(6)]},
            "duration_s": 1500.0}


def main():
    world = generate_world(scale=0.5, seed=0)
    trips = sorted(world["trips"],
                   key=lambda r: r["track"]["t"][0] if r["track"]["t"]
                   else 0.0)

    # time-sorted ingestion ⇒ each delta shard covers a time band
    live = StreamingFDb("Trips", world["trips_schema"],
                        flush_threshold=max(64, len(trips) // 10),
                        compact_threshold=0)
    live.extend(trips)
    live.flush()
    st = live.stats()
    print(f"ingested {st['docs']} trips into {st['delta_shards']} "
          f"delta shards (generation {st['generation']})")

    cat = Catalog()
    cat.register(live)                        # live source: snapshots on read
    session = Session(catalog=cat, backend="jax")

    # partition pruning: the day-2 window plans a subset of the shards
    plan = plan_flow(commute_flow(), cat)
    print(f"plan: {len(plan.shard_ids)}/{cat.get('Trips').num_shards} "
          f"shards after time-partition pruning "
          f"(pruned {plan.stats.get('pruned_shards', 0)})")

    with session.serve() as srv:              # auto-watches live sources
        r1 = srv.submit(commute_flow()).result(120)
        print(f"commute trips now: {r1.batch.n}")

        r_cached = srv.submit(commute_flow()).result(120)
        print(f"repeat served from cache: {r_cached is r1}")

        # live append → bound cache invalidated → next answer is fresh
        new_id = max(r["id"] for r in trips) + 1
        live.append(probe_trip(new_id))
        live.flush()
        r2 = srv.submit(commute_flow()).result(120)
        ids = set(int(v) for v in r2.batch["id"].values)
        print(f"after append: {r2.batch.n} trips; "
              f"new trip visible: {new_id in ids}")
        print(f"server stats: {srv.stats()}")


if __name__ == "__main__":
    main()
