# Convenience entry points. PYTHONPATH=src matches the tier-1 command in
# ROADMAP.md.
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test test-fast test-tesseract bench bench-backends bench-tesseract

test:                 ## tier-1 verify
	$(PY) -m pytest -x -q

test-fast:            ## skip @slow end-to-end tests
	$(PY) -m pytest -x -q -m "not slow"

test-tesseract:       ## trip-query subsystem tests only
	$(PY) -m pytest -x -q -m tesseract

bench:                ## full benchmark harness
	$(PY) -m benchmarks.run

bench-backends:       ## numpy-vs-jax backend timing + parity report
	$(PY) -m benchmarks.run --only backends

bench-tesseract:      ## Q6/Q7 trip queries: pruning ratio + backend parity
	$(PY) -m benchmarks.run --only tesseract --json
