# Convenience entry points. PYTHONPATH=src matches the tier-1 command in
# ROADMAP.md.  `make help` lists everything; the `ci*` targets are what
# .github/workflows/ci.yml runs (badge in ROADMAP.md).
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: help test test-fast test-tesseract bench bench-backends \
        bench-tesseract bench-serve bench-streaming bench-partition \
        bench-analytics ci ci-kernels ci-bench bench-regression check-links

# blocking suite set, derived from the single registry in
# benchmarks/suites.py (same table run.py --only reads)
REG_SUITES = $(shell $(PY) -m benchmarks.suites --regression)

help:                 ## list targets (CI runs: ci, ci-kernels, ci-bench)
	@grep -E '^[a-z][a-zA-Z_-]*:.*##' $(MAKEFILE_LIST) | \
	  awk -F':.*## ' '{printf "  make %-18s %s\n", $$1, $$2}'

test:                 ## tier-1 verify
	$(PY) -m pytest -x -q

test-fast:            ## skip @slow end-to-end tests
	$(PY) -m pytest -x -q -m "not slow"

test-tesseract:       ## trip-query subsystem tests only
	$(PY) -m pytest -x -q -m tesseract

ci:                   ## CI leg: tier-1 under $REPRO_EXEC_BACKEND (numpy|jax)
	$(PY) -m pytest -x -q

ci-kernels:           ## CI extra: interpret-vs-reference kernel-body sweeps (incl. count/dwell reduction sweeps)
	$(PY) -m pytest -x -q tests/test_kernels.py tests/test_refine.py tests/test_analytics.py

ci-bench:             ## CI smoke: tiny blocking suites (benchmarks/suites.py registry), exits non-zero on parity fail
	$(PY) -m benchmarks.run --only $(REG_SUITES) --json --scale 0.05

bench-regression:     ## blocking gate: fresh BENCH_<suite>.json vs committed baselines for the registry's blocking set (>1.5x/query fails)
	$(PY) benchmarks/check_regression.py

check-links:          ## docs hygiene: every relative link in docs/, ROADMAP.md, README-tier files resolves
	$(PY) tools/check_links.py

bench:                ## full benchmark harness
	$(PY) -m benchmarks.run

bench-backends:       ## numpy-vs-jax backend timing + parity report
	$(PY) -m benchmarks.run --only backends

bench-tesseract:      ## Q6–Q9 trip queries (Q8/Q9 ordered): pruning + backend parity
	$(PY) -m benchmarks.run --only tesseract --json

bench-serve:          ## concurrent serving: coalesced QPS/latency + cache + launch evidence
	$(PY) -m benchmarks.run --only serve --json

bench-streaming:      ## live ingestion: ingest→queryable latency, pruning + invalidation evidence
	$(PY) -m benchmarks.run --only streaming --json

bench-partition:      ## partitioned execution: P=1 vs P=2 wall time + launch/merge evidence
	$(PY) -m benchmarks.run --only partition --json

bench-analytics:      ## Q10/Q11 dwell+count reductions + time-to-trained-model row
	$(PY) -m benchmarks.run --only analytics --json
