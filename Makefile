# Convenience entry points. PYTHONPATH=src matches the tier-1 command in
# ROADMAP.md.
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test test-fast bench bench-backends

test:                 ## tier-1 verify
	$(PY) -m pytest -x -q

test-fast:            ## skip @slow end-to-end tests
	$(PY) -m pytest -x -q -m "not slow"

bench:                ## full benchmark harness
	$(PY) -m benchmarks.run

bench-backends:       ## numpy-vs-jax backend timing + parity report
	$(PY) -m benchmarks.run --only backends
