# Convenience entry points. PYTHONPATH=src matches the tier-1 command in
# ROADMAP.md.  `make help` lists everything; the `ci*` targets are what
# .github/workflows/ci.yml runs (badge in ROADMAP.md).
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: help test test-fast test-tesseract bench bench-backends \
        bench-tesseract bench-serve bench-streaming bench-partition \
        ci ci-kernels ci-bench bench-regression check-links

help:                 ## list targets (CI runs: ci, ci-kernels, ci-bench)
	@grep -E '^[a-z][a-zA-Z_-]*:.*##' $(MAKEFILE_LIST) | \
	  awk -F':.*## ' '{printf "  make %-18s %s\n", $$1, $$2}'

test:                 ## tier-1 verify
	$(PY) -m pytest -x -q

test-fast:            ## skip @slow end-to-end tests
	$(PY) -m pytest -x -q -m "not slow"

test-tesseract:       ## trip-query subsystem tests only
	$(PY) -m pytest -x -q -m tesseract

ci:                   ## CI leg: tier-1 under $REPRO_EXEC_BACKEND (numpy|jax)
	$(PY) -m pytest -x -q

ci-kernels:           ## CI extra: interpret-vs-reference kernel-body sweeps
	$(PY) -m pytest -x -q tests/test_kernels.py tests/test_refine.py

ci-bench:             ## CI smoke: tiny backends+tesseract+serve+streaming+partition suites, exits non-zero on parity fail
	$(PY) -m benchmarks.run --only backends,tesseract,serve,streaming,partition --json --scale 0.05

bench-regression:     ## blocking gate: fresh BENCH_{backends,tesseract,serve,streaming,partition}.json vs committed baselines (>1.5x/query fails)
	$(PY) benchmarks/check_regression.py --suite backends,tesseract,serve,streaming,partition

check-links:          ## docs hygiene: every relative link in docs/, ROADMAP.md, README-tier files resolves
	$(PY) tools/check_links.py

bench:                ## full benchmark harness
	$(PY) -m benchmarks.run

bench-backends:       ## numpy-vs-jax backend timing + parity report
	$(PY) -m benchmarks.run --only backends

bench-tesseract:      ## Q6–Q9 trip queries (Q8/Q9 ordered): pruning + backend parity
	$(PY) -m benchmarks.run --only tesseract --json

bench-serve:          ## concurrent serving: coalesced QPS/latency + cache + launch evidence
	$(PY) -m benchmarks.run --only serve --json

bench-streaming:      ## live ingestion: ingest→queryable latency, pruning + invalidation evidence
	$(PY) -m benchmarks.run --only streaming --json

bench-partition:      ## partitioned execution: P=1 vs P=2 wall time + launch/merge evidence
	$(PY) -m benchmarks.run --only partition --json
