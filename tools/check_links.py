#!/usr/bin/env python
"""Relative-link checker for the docs tier (stdlib only, no repo imports).

Scans every markdown file in ``docs/`` plus ``ROADMAP.md``, ``README.md``
and ``CHANGES.md`` (when present) for ``[text](target)`` links and fails
(exit 1) when a relative target does not resolve to a file or directory
in the repository.  Skipped, by design:

  * absolute URLs (``http(s)://``, ``mailto:``) — no network in CI,
  * pure in-page anchors (``#section``),
  * targets that escape the repo root (e.g. the ROADMAP badge's
    ``../../actions/workflows/ci.yml`` — a GitHub web route, not a file).

``#anchor`` suffixes on file targets are stripped before resolution;
anchor existence inside the target file is not verified.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: [text](target) — target up to the first unescaped ')'; images included
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")


def _md_files() -> list:
    files = []
    for name in ("ROADMAP.md", "README.md", "CHANGES.md"):
        p = os.path.join(REPO, name)
        if os.path.exists(p):
            files.append(p)
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        for root, _dirs, names in os.walk(docs):
            files.extend(os.path.join(root, n) for n in sorted(names)
                         if n.endswith(".md"))
    return files


def check(path: str) -> list:
    """Broken links in one file as (lineno, target) pairs."""
    broken = []
    base = os.path.dirname(path)
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            for m in LINK_RE.finditer(line):
                target = m.group(1)
                if target.startswith(("http://", "https://", "mailto:",
                                      "#")):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                resolved = os.path.realpath(os.path.join(base, rel))
                if not resolved.startswith(REPO + os.sep):
                    continue                    # web route, not a file
                if not os.path.exists(resolved):
                    broken.append((lineno, target))
    return broken


def main() -> int:
    files = _md_files()
    n_links = 0
    failures = 0
    for path in files:
        broken = check(path)
        with open(path, encoding="utf-8") as fh:
            n_links += sum(len(LINK_RE.findall(line)) for line in fh)
        for lineno, target in broken:
            rel = os.path.relpath(path, REPO)
            print(f"{rel}:{lineno}: broken link -> {target}",
                  file=sys.stderr)
            failures += 1
    print(f"checked {len(files)} file(s), {n_links} link(s), "
          f"{failures} broken")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
