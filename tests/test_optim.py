"""Optimizer stack: AdamW math, clipping, schedules, EF compression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ml.optim import (adamw_init, adamw_update, clip_by_global_norm,
                            compress_ef, cosine_schedule, ef_init)


def test_adamw_matches_reference_math():
    params = {"w": jnp.asarray([[1.0, -2.0]]), "b": jnp.asarray([0.5])}
    grads = {"w": jnp.asarray([[0.1, 0.2]]), "b": jnp.asarray([-0.3])}
    st = adamw_init(params)
    lr, b1, b2, eps, wd = 0.1, 0.9, 0.95, 1e-8, 0.1
    new_p, new_st = adamw_update(params, grads, st, lr, b1=b1, b2=b2,
                                 eps=eps, weight_decay=wd)
    # manual step 1
    for k in ("w", "b"):
        g = np.asarray(grads[k], np.float64)
        m = (1 - b1) * g
        v = (1 - b2) * g * g
        upd = (m / (1 - b1)) / (np.sqrt(v / (1 - b2)) + eps)
        if np.asarray(params[k]).ndim >= 2:
            upd = upd + wd * np.asarray(params[k])
        want = np.asarray(params[k]) - lr * upd
        np.testing.assert_allclose(np.asarray(new_p[k]), want, rtol=1e-5)
    assert int(new_st["step"]) == 1


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, gn = clip_by_global_norm(grads, 1.0)
    assert float(gn) == pytest.approx(10.0)
    total = np.sqrt(sum(float(jnp.sum(g ** 2))
                        for g in jax.tree_util.tree_leaves(clipped)))
    assert total == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100, min_ratio=0.1)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-3)
    assert float(lr(55)) < float(lr(20))


def test_ef_compression_error_feedback():
    """Quantization error must be carried, not lost (EF21 property)."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    err = ef_init(g)
    # accumulate K compressed steps; sum of dequantized ≈ sum of true
    total_true = np.zeros((64, 64), np.float32)
    total_deq = np.zeros((64, 64), np.float32)
    for k in range(20):
        gk = {"w": g["w"] * (1.0 + 0.01 * k)}
        deq, err = compress_ef(gk, err)
        total_true += np.asarray(gk["w"])
        total_deq += np.asarray(deq["w"])
    # residual bounded by one quantization step, NOT accumulating
    resid = np.abs(total_true - total_deq).max()
    scale = np.abs(g["w"]).max() / 127.0
    assert resid < 3 * scale
    # int8 payload: 4× smaller on the wire
    q_bytes = g["w"].size * 1
    f_bytes = g["w"].size * 4
    assert f_bytes / q_bytes == 4


def test_compressed_psum_shard_map():
    """int8 all-gather + local reduce ≈ fp32 psum (within quant error)."""
    import jax
    from jax.sharding import Mesh, PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:   # jax ≤ 0.4.x
        from jax.experimental.shard_map import shard_map
    from repro.ml.optim import compressed_psum

    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(8,)).astype(np.float32))

    try:
        f = shard_map(lambda v: compressed_psum(v, "data"), mesh=mesh,
                      in_specs=P(), out_specs=P(), check_vma=False)
    except TypeError:     # jax ≤ 0.4.x spells it check_rep
        f = shard_map(lambda v: compressed_psum(v, "data"), mesh=mesh,
                      in_specs=P(), out_specs=P(), check_rep=False)
    got = f(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), atol=2e-2,
                               rtol=2e-2)
