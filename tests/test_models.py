"""Per-arch smoke tests (deliverable f): reduced configs, one forward +
one train step on CPU, shape/NaN asserts; prefill↔decode consistency."""
from dataclasses import replace

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs, SHAPES, shape_cells
from repro.ml.transformer import LM
from repro.ml.model import ModelBundle, TrainConfig, input_specs

ARCHS = list_archs()


def _reduced(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe_experts:          # dropless for exact decode consistency
        cfg = replace(cfg, moe_capacity_factor=float(cfg.moe_experts))
    return cfg


def _inputs(cfg, B, S, seed=0):
    # per-call deterministic rng: outcomes must not depend on test order
    # or on the process (hash() is PYTHONHASHSEED-randomized!)
    import zlib
    rng = np.random.default_rng(zlib.crc32(cfg.name.encode()) ^ seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                         jnp.int32)
    kw = {}
    if cfg.frontend == "audio_stub":
        kw["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32),
            jnp.bfloat16)
    return tokens, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = _reduced(arch)
    lm = LM(cfg, impl="reference")
    params = lm.init(jax.random.key(0))
    B, S = 2, 32
    tokens, kw = _inputs(cfg, B, S)
    logits, aux = lm.apply(params, tokens, **kw)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    """One optimizer step must run and produce finite loss + updates."""
    cfg = _reduced(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    mb = ModelBundle(cfg, mesh,
                     train_cfg=TrainConfig(loss_chunk=16, remat="none"))
    params = mb.lm.init(jax.random.key(0))
    opt = mb.init_opt_state(params)
    B, S = 2, 16
    tokens, kw = _inputs(cfg, B, S)
    batch = {"tokens": tokens, "labels": tokens, **kw}
    step = jax.jit(mb.make_train_step())
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt["adam"]["step"]) == 1
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(params),
        jax.tree_util.tree_leaves(new_params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    # f32 activations: tests cache/state SEMANTICS exactly (xlstm measures
    # 0.0 here); bf16 drift through exponential gating is a separate
    # concern covered by test_multi_step_decode
    cfg = replace(_reduced(arch), act_dtype="float32")
    lm = LM(cfg, impl="reference")
    params = lm.init(jax.random.key(0))
    B, S = 2, 24
    tokens, kw = _inputs(cfg, B, S)
    logits_full, _ = lm.apply(params, tokens, **kw)
    want = np.asarray(logits_full[:, -1, :], np.float32)
    _, caches = lm.prefill(params, tokens[:, :S - 1],
                           frames=kw.get("frames"))
    logits_dec, _ = lm.decode_step(params, tokens[:, S - 1:S], caches,
                                   S - 1)
    got = np.asarray(logits_dec[:, -1, :], np.float32)
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-6)
    # tiny residual tolerance: MoE capacity bookkeeping + reduction-order
    # differences between chunked and stepwise paths
    assert err < 0.02, f"{arch}: prefill/decode mismatch {err:.4f}"
    assert (got.argmax(-1) == want.argmax(-1)).all()


@pytest.mark.parametrize("arch", ["qwen1_5_0_5b", "mixtral_8x7b",
                                  "xlstm_1_3b", "jamba_v0_1_52b"])
def test_multi_step_decode(arch):
    """Greedy decode runs several steps with stable caches."""
    cfg = _reduced(arch)
    lm = LM(cfg, impl="reference")
    params = lm.init(jax.random.key(0))
    B, S = 1, 8
    tokens, kw = _inputs(cfg, B, S)
    logits, caches = lm.prefill(params, tokens, frames=kw.get("frames"))
    cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for t in range(4):
        logits, caches = lm.decode_step(params, cur, caches, S + t)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)


def test_shape_cells_gating():
    """long_500k only for sub-quadratic archs (DESIGN §arch-applicability)."""
    eligible = {a for a in ARCHS
                if get_config(a).sub_quadratic}
    assert eligible == {"gemma3_12b", "mixtral_8x7b", "xlstm_1_3b",
                        "jamba_v0_1_52b"}
    for a in ARCHS:
        cells = {s.name for s in shape_cells(get_config(a))}
        if a in eligible:
            assert "long_500k" in cells
        else:
            assert "long_500k" not in cells
        assert {"train_4k", "prefill_32k", "decode_32k"} <= cells
    total = sum(len(shape_cells(get_config(a))) for a in ARCHS)
    assert total == 34        # 10×4 − 6 skips, as documented


def test_input_specs_complete():
    for a in ARCHS:
        cfg = get_config(a)
        for s in shape_cells(cfg):
            specs = input_specs(cfg, s)
            assert "tokens" in specs
            if s.kind == "train":
                assert "labels" in specs
                assert specs["tokens"].shape == (s.global_batch, s.seq_len)
            if s.kind == "decode":
                assert specs["tokens"].shape == (s.global_batch, 1)
            if cfg.frontend == "audio_stub" and s.kind != "decode":
                assert "frames" in specs


def test_params_count_sane():
    """Full-config parameter counts are in the advertised ballpark."""
    approx = {
        "qwen1_5_0_5b": (0.3e9, 0.8e9),
        "gemma3_12b": (9e9, 16e9),
        "smollm_360m": (0.25e9, 0.5e9),
        "command_r_35b": (30e9, 42e9),
        "mixtral_8x7b": (40e9, 52e9),
        # ~2.0B with pf=2 ups + head-wise qkv + sLSTM pf-4/3 MLPs; the
        # advertised 1.3B presumably trims projections we keep faithful
        # to the paper's block diagrams.
        "xlstm_1_3b": (0.9e9, 2.2e9),
        "jamba_v0_1_52b": (45e9, 60e9),
        "qwen2_vl_7b": (6e9, 9e9),
    }
    for a, (lo, hi) in approx.items():
        n = get_config(a).params_count()
        assert lo < n < hi, f"{a}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"
    # MoE active < total
    for a in ("mixtral_8x7b", "llama4_scout_17b_a16e", "jamba_v0_1_52b"):
        cfg = get_config(a)
        assert cfg.active_params_count() < cfg.params_count()
