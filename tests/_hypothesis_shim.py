"""Minimal stand-in for ``hypothesis`` when it is not installed.

The tier-1 suite uses a small slice of the hypothesis API (``given`` /
``settings`` / ``strategies`` with integers, floats, lists, sets, tuples).
This shim keeps those property tests runnable without the dependency: each
strategy draws from a per-test deterministically-seeded RNG, the first
example pins every strategy at its boundary minimum, and ``max_examples``
is honored.  No shrinking, no database — install ``hypothesis`` (see
requirements-optional.txt) for the real engine; test modules import it
first and only fall back here.
"""
from __future__ import annotations

import zlib

import numpy as np

__all__ = ["given", "settings", "st"]


class _Strategy:
    def __init__(self, boundary_fn, draw_fn):
        self._boundary = boundary_fn
        self._draw = draw_fn

    def boundary(self):
        return self._boundary()

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


class st:
    """Shim for ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda: min_value,
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float,
               allow_nan: bool = False) -> _Strategy:
        return _Strategy(
            lambda: float(min_value),
            lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(size)]
        return _Strategy(
            lambda: [elements.boundary() for _ in range(min_size)], draw)

    @staticmethod
    def sets(elements: _Strategy, min_size: int = 0,
             max_size: int = 10) -> _Strategy:
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            out = {elements.draw(rng) for _ in range(size)}
            while len(out) < min_size:
                out.add(elements.draw(rng))
            return out
        def boundary():
            out = set()
            rng = np.random.default_rng(0)
            out.add(elements.boundary())
            while len(out) < min_size:
                out.add(elements.draw(rng))
            return out
        return _Strategy(boundary, draw)

    @staticmethod
    def tuples(*elements: _Strategy) -> _Strategy:
        return _Strategy(
            lambda: tuple(e.boundary() for e in elements),
            lambda rng: tuple(e.draw(rng) for e in elements))


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        max_examples = getattr(fn, "_shim_max_examples", 20)
        seed = zlib.crc32(fn.__name__.encode())

        def wrapper():
            rng = np.random.default_rng(seed)
            for i in range(max_examples):
                drawn = tuple(s.boundary() if i == 0 else s.draw(rng)
                              for s in strategies)
                try:
                    fn(*drawn)
                except Exception:
                    print(f"\n{fn.__name__}: falsifying example "
                          f"(shim, i={i}): {drawn!r}")
                    raise

        # plain attribute copy — functools.wraps would expose the wrapped
        # signature and pytest would mistake strategy params for fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
