"""Query-serving subsystem: multi-query seam parity (base loop-over-
queries oracle vs jax stacked dispatch), the coalesced launch contract
(Q compatible queries ⇒ ⌈shards/wave⌉ total device dispatches), server
admission/coalescing/fallback behavior, the TTL + LRU result cache with
fault injection, and the concurrency-safety satellites (thread-scoped
launch counters, DeviceCache priming under concurrent open/close)."""
import gc
import math
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core import BETWEEN, P, Session, fdb, group, proto
from repro.core.planner import plan_flow
from repro.exec import AdHocEngine, Catalog, JaxBackend, get_backend
from repro.exec.batched import FUSED_ENV
from repro.fdb import DOUBLE, INT, STRING, Schema, build_fdb
from repro.fdb.schema import Field, MESSAGE
from repro.geo import AreaTree, mercator as M
from repro.kernels import ops
from repro.serve import QueryServer, ResultCache, ServerBusy
from repro.tess import Tesseract

SIZES = [32, 31, 64, 65, 1, 0, 33]
RNG = np.random.default_rng(41)


# --------------------------------------------------------------- fixtures

def _dense_db(name):
    schema = Schema(name, [
        Field("road", INT, indexes=("tag",)),
        Field("hour", INT, indexes=("range",)),
        Field("city", STRING, indexes=("tag",)),
        Field("speed", DOUBLE),
    ])
    bounds = np.cumsum([0] + SIZES)
    recs = [{"road": int(RNG.integers(0, 12)),
             "hour": int(RNG.integers(0, 24)),
             "city": ["SF", "OAK", "SJ"][int(RNG.integers(0, 3))],
             "speed": float(RNG.normal(48, 9)),
             "_i": i}
            for i in range(sum(SIZES))]
    key = lambda r: int(np.searchsorted(bounds, r["_i"], "right") - 1)
    return build_fdb(name, schema, recs, num_shards=len(SIZES),
                     shard_key=key)


def _walks_db(name):
    schema = Schema(name, [
        Field("id", INT, indexes=("tag",)),
        Field("track", MESSAGE, fields=[
            Field("lat", DOUBLE, repeated=True),
            Field("lng", DOUBLE, repeated=True),
            Field("t", DOUBLE, repeated=True)],
            indexes=("spacetime",),
            index_params={"level": 6, "bucket_s": 900.0, "epoch": 0.0}),
    ])
    rng = np.random.default_rng(17)
    recs = []
    for i in range(sum(SIZES)):
        ln = 0 if i % 7 == 0 else int(rng.integers(1, 14))
        recs.append({"id": i, "track": {
            "lat": rng.uniform(37.2, 38.0, ln).tolist(),
            "lng": rng.uniform(-122.6, -121.8, ln).tolist(),
            "t": np.sort(rng.uniform(0.0, 3 * 86400.0, ln)).tolist()}})
    bounds = np.cumsum([0] + SIZES)
    key = lambda r: int(np.searchsorted(bounds, r["id"], "right") - 1)
    return build_fdb(name, schema, recs, num_shards=len(SIZES),
                     shard_key=key)


def _region(rng, d=2_000_000):
    ix, iy = M.latlng_to_xy(rng.uniform(37.2, 38.0),
                            rng.uniform(-122.6, -121.8))
    return AreaTree.from_box(int(ix) - d, int(iy) - d,
                             int(ix) + d, int(iy) + d, max_level=7)


@pytest.fixture(scope="module")
def walks_db():
    return _walks_db("ServeWalks")


@pytest.fixture(scope="module")
def dense_db():
    return _dense_db("ServeDense")


@pytest.fixture(scope="module")
def catalog(walks_db, dense_db):
    cat = Catalog(server_slots=16)
    cat.register(walks_db)
    cat.register(dense_db)
    return cat


def _tess_flows(n=5, seed=5):
    rng = np.random.default_rng(seed)
    flows = [fdb("ServeWalks").tesseract(
        Tesseract(_region(rng), 0.0, 2 * 86400.0)) for _ in range(n - 1)]
    flows.append(fdb("ServeWalks").tesseract(
        Tesseract(_region(rng), 0.0, 2 * 86400.0)
        .then(_region(rng), 0.0, 3 * 86400.0)))
    return flows


def assert_identical(a, b):
    assert a.n == b.n
    assert a.paths() == b.paths()
    for p in a.paths():
        ca, cb = a[p], b[p]
        assert ca.values.dtype == cb.values.dtype, p
        assert np.array_equal(ca.values, cb.values), p
        assert ca.vocab == cb.vocab, p


def _server(catalog, backend="jax", **kw):
    srv = QueryServer(catalog=catalog, backend=backend, start=False, **kw)
    srv.engine.wave = 3
    return srv


# ------------------------------------------------- seam: multi-query ops

@pytest.mark.tesseract
def test_seam_multi_ops_match_base_oracle(catalog, walks_db):
    """probe_shards_multi / refine_tracks_multi / run_wave_fused_multi on
    the jax backend ≡ the base-class loop-over-queries oracle, per query,
    byte for byte (ordered and unordered constraint sets, varying probe
    and constraint counts)."""
    rng = np.random.default_rng(3)
    tesses = [Tesseract(_region(rng), 0.0, 2 * 86400.0)
              .also(_region(rng), 43200.0, 3 * 86400.0),
              Tesseract(_region(rng), 0.0, 86400.0),
              Tesseract(_region(rng), 0.0, 2 * 86400.0)
              .then(_region(rng), 0.0, 3 * 86400.0)]
    plans = [plan_flow(fdb("ServeWalks").tesseract(t), catalog)
             for t in tesses]
    shards = [walks_db.shards[s] for s in plans[0].shard_ids]
    probes_multi = [[[pr.run(sh) for pr in p.probes] for sh in shards]
                    for p in plans]
    refines = [p.refines[0] for p in plans]
    npb = get_backend("numpy")
    jxb = JaxBackend()
    jxb.prime_fdb(walks_db)

    fulls = [sh.all_bitmap() for sh in shards]
    want = npb.probe_shards_multi(fulls, probes_multi)
    got = jxb.probe_shards_multi(fulls, probes_multi)
    for wq, gq in zip(want, got):
        for w, g in zip(wq, gq):
            assert np.array_equal(np.asarray(w), np.asarray(g))

    batches = [sh.batch for sh in shards]
    cons_list = [list(r.constraints) for r in refines]
    edges_list = [list(r.edges) for r in refines]
    want = npb.refine_tracks_multi(batches, "track", cons_list,
                                   edges_list=edges_list)
    got = jxb.refine_tracks_multi(batches, "track", cons_list,
                                  edges_list=edges_list)
    for wq, gq in zip(want, got):
        for w, g in zip(wq, gq):
            assert np.array_equal(np.asarray(w), np.asarray(g))
    # first-hit tables are part of the parity surface
    wantf = npb.refine_tracks_multi(batches, "track", cons_list,
                                    with_first_hits=True)
    gotf = jxb.refine_tracks_multi(batches, "track", cons_list,
                                   with_first_hits=True)
    for (wm, wt), (gm, gt) in zip(wantf, gotf):
        for w, g in zip(wt, gt):
            assert np.array_equal(np.asarray(w), np.asarray(g))

    got = jxb.run_wave_fused_multi(shards, probes_multi, refines)
    assert got is not None
    want = npb.run_wave_fused_multi(shards, probes_multi, refines)
    for q, (w, g) in enumerate(zip(want, got)):
        assert g[0] == w[0], q
        for wi, gi in zip(w[1], g[1]):
            assert gi.dtype == np.int64
            assert np.array_equal(gi, wi), q
    # per query it equals the single-query fused path too
    for q in range(3):
        single = jxb.run_wave_fused(shards, probes_multi[q], refines[q],
                                    None)
        assert single[0] == got[q][0]
        for a, b in zip(single[1], got[q][1]):
            assert np.array_equal(a, b)


# ------------------------------------- coalesced launch contract + parity

@pytest.mark.tesseract
def test_coalesced_launch_contract_and_parity(catalog, walks_db, exec_pplan,
                                              monkeypatch):
    """Q coalesced compatible queries cost Σ_p ⌈shards_p/wave⌉ multi
    dispatches TOTAL — not Q×⌈shards/wave⌉ — and every query's rows are
    byte-identical to its single-query numpy-oracle result.  The serve
    tier merges per-query gathers on the host (partition-invariant), so
    no merge combine is launched at any P."""
    monkeypatch.setenv(FUSED_ENV, "1")
    flows = _tess_flows()
    np_eng = AdHocEngine(catalog, num_servers=2, backend="numpy", wave=3)
    oracle = [np_eng.collect(f) for f in flows]
    srv = _server(catalog, cache=False)
    futs = [srv.submit(f) for f in flows]
    srv.run_pending()                          # warm: prime + jit
    for f, o in zip(futs, oracle):
        assert_identical(f.result(60).batch, o.batch)
    futs = [srv.submit(f) for f in flows]
    ops.reset_launch_counts()
    srv.run_pending()
    waves = exec_pplan(walks_db.num_shards,
                       srv.engine.backend).wave_dispatches(3)
    assert dict(ops.launch_counts()) == {"run_wave_fused_multi": waves}
    for f, o in zip(futs, oracle):
        assert_identical(f.result(60).batch, o.batch)
    st = srv.stats()
    assert st["coalesced_queries"] == 2 * len(flows)
    assert st["fallback_queries"] == 0


def test_coalesced_agg_tail_parity(catalog, monkeypatch):
    """Aggregating flows coalesce too — the selection rides the multi
    dispatch, the group-by runs in the per-query host tail — and match
    the numpy oracle bit for bit (min/max included); record-parallel
    server ops (filter/map) coalesce too."""
    monkeypatch.setenv(FUSED_ENV, "1")
    flows = [fdb("ServeDense").find(BETWEEN(P.hour, 8, 17))
             .aggregate(group(P.road).count("n").avg(m=P.speed)),
             fdb("ServeDense").find(BETWEEN(P.hour, 0, 7))
             .aggregate(group(P.road).max(mx=P.speed).min(mn=P.speed)),
             fdb("ServeDense").find(BETWEEN(P.hour, 8, 17))
             .aggregate(group(P.city).count("n")),
             fdb("ServeDense").find(BETWEEN(P.hour, 8, 17))
             .filter(P.speed > 40.0)
             .aggregate(group(P.road).count("n")),
             fdb("ServeDense").find(BETWEEN(P.hour, 8, 17))
             .map(lambda p: proto(road=p.road, fast=p.speed > 50.0))
             .aggregate(group(P.fast).count("n"))]
    np_eng = AdHocEngine(catalog, num_servers=2, backend="numpy", wave=3)
    oracle = [np_eng.collect(f) for f in flows]
    srv = _server(catalog, cache=False)
    futs = [srv.submit(f) for f in flows]
    srv.run_pending()
    for f, o in zip(futs, oracle):
        assert_identical(f.result(60).batch, o.batch)
    assert srv.stats()["coalesced_queries"] == len(flows)


def test_incompatible_plans_fall_through(catalog, monkeypatch):
    """Plans outside the coalesced shape (a residual filter from an
    unindexed find() conjunct) are served through the single-query path —
    never an error — alongside coalesced peers."""
    monkeypatch.setenv(FUSED_ENV, "1")
    flows = [fdb("ServeDense").find(BETWEEN(P.hour, 8, 17)
                                    & (P.speed > 40.0))
             .aggregate(group(P.road).count("n")),      # residual
             fdb("ServeDense").find(BETWEEN(P.hour, 8, 17))
             .aggregate(group(P.road).count("n")),      # coalesceable
             fdb("ServeDense").find(BETWEEN(P.hour, 8, 17))
             .sort_desc(P.speed).limit(10)]             # coalesceable
    np_eng = AdHocEngine(catalog, num_servers=2, backend="numpy", wave=3)
    oracle = [np_eng.collect(f) for f in flows]
    srv = _server(catalog, cache=False)
    futs = [srv.submit(f) for f in flows]
    srv.run_pending()
    for f, o in zip(futs, oracle):
        assert_identical(f.result(60).batch, o.batch)
    assert srv.stats()["fallback_queries"] >= 1


def test_numpy_backend_server_parity(catalog):
    """The server is backend-agnostic: a numpy-backed server coalesces
    through the base-class oracle ops and stays byte-identical."""
    flows = _tess_flows(3, seed=9)
    np_eng = AdHocEngine(catalog, num_servers=2, backend="numpy", wave=3)
    oracle = [np_eng.collect(f) for f in flows]
    srv = _server(catalog, backend="numpy", cache=False)
    futs = [srv.submit(f) for f in flows]
    srv.run_pending()
    for f, o in zip(futs, oracle):
        assert_identical(f.result(60).batch, o.batch)


# ----------------------------------------------------- admission + server

def test_admission_bounds_and_recovery(catalog):
    srv = _server(catalog, backend="numpy", cache=False, max_pending=2)
    f1 = srv.submit(fdb("ServeDense").find(BETWEEN(P.hour, 8, 17)))
    srv.submit(fdb("ServeDense").find(BETWEEN(P.hour, 0, 7)))
    with pytest.raises(ServerBusy):
        srv.submit(fdb("ServeDense").find(BETWEEN(P.hour, 9, 10)))
    assert srv.stats()["rejected"] == 1
    srv.run_pending()                          # queue drains
    assert f1.result(60).batch.n >= 0
    f4 = srv.submit(fdb("ServeDense").find(BETWEEN(P.hour, 9, 10)))
    srv.run_pending()
    assert f4.result(60) is not None


def test_live_scheduler_threaded_submits(catalog):
    """Futures resolve through the running scheduler thread with many
    concurrent submitters; close() drains and joins."""
    flows = _tess_flows(6, seed=13)
    np_eng = AdHocEngine(catalog, num_servers=2, backend="numpy", wave=3)
    oracle = [np_eng.collect(f) for f in flows]
    with QueryServer(catalog=catalog, backend="jax", cache=False,
                     tick_s=0.005) as srv:
        srv.engine.wave = 3
        with ThreadPoolExecutor(max_workers=6) as pool:
            futs = list(pool.map(srv.submit, flows))
        for f, o in zip(futs, oracle):
            assert_identical(f.result(60).batch, o.batch)
        assert srv.stats()["served"] == len(flows)
    with pytest.raises(RuntimeError):
        srv.submit(flows[0])


def test_planning_error_delivered_via_future(catalog):
    srv = _server(catalog, backend="numpy", cache=False)
    fut = srv.submit(fdb("NoSuchDb").find(BETWEEN(P.hour, 0, 1)))
    srv.run_pending()
    with pytest.raises(Exception):
        fut.result(10)


def test_session_serve_integration(catalog):
    sess = Session(catalog=catalog, backend="numpy")
    srv = sess.serve(start=False, cache=False)
    try:
        fut = srv.submit(sess.fdb("ServeDense").find(BETWEEN(P.hour, 8, 17)))
        srv.run_pending()
        assert fut.result(60).batch.n > 0
    finally:
        srv.close()


# ------------------------------------------------------------ result cache

def test_result_cache_hit_skips_recompute(catalog, monkeypatch):
    monkeypatch.setenv(FUSED_ENV, "1")
    flow = _tess_flows(2, seed=21)[0]
    srv = _server(catalog, cache=ResultCache())
    f1 = srv.submit(flow); srv.run_pending()
    r1 = f1.result(60)
    ops.reset_launch_counts()
    f2 = srv.submit(flow); srv.run_pending()
    assert f2.result(60) is r1                 # same object, no recompute
    assert ops.launch_counts().get("run_wave_fused", 0) == 0
    assert ops.launch_counts().get("run_wave_fused_multi", 0) == 0
    assert srv.stats()["cache_hits"] == 1


def test_result_cache_ttl_and_injectable_clock(catalog):
    clock = [0.0]
    cache = ResultCache(ttl_s={"result": 10.0, "postings": 5.0},
                        clock=lambda: clock[0])
    srv = _server(catalog, backend="numpy", cache=cache)
    flow = fdb("ServeDense").find(BETWEEN(P.hour, 8, 17))
    f1 = srv.submit(flow); srv.run_pending(); r1 = f1.result(60)
    clock[0] = 9.0                             # still live
    f2 = srv.submit(flow); srv.run_pending()
    assert f2.result(60) is r1
    clock[0] = 20.0                            # expired
    f3 = srv.submit(flow); srv.run_pending()
    r3 = f3.result(60)
    assert r3 is not r1
    assert_identical(r3.batch, r1.batch)


def test_result_cache_lru_byte_budget():
    clock = [0.0]
    cache = ResultCache(max_bytes=3000, clock=lambda: clock[0])
    a1 = np.zeros(250, dtype=np.float64)       # 2000 bytes
    cache.put("result", b"k1", a1, nbytes=a1.nbytes)
    cache.put("result", b"k2", np.zeros(100), nbytes=800)
    assert cache.get("result", b"k1") is a1    # k1 now most-recent
    cache.put("result", b"k3", np.zeros(100), nbytes=800)   # evicts k2
    assert cache.get("result", b"k2") is None
    assert cache.get("result", b"k1") is a1
    assert cache.stats()["evictions"] == 1
    assert cache.stats()["nbytes"] <= 3000


def test_result_cache_key_isolation(catalog, dense_db):
    """Different plans → different keys; an uncanonicalizable plan is
    simply uncacheable (None key), never a false share."""
    cache = ResultCache()
    p1 = plan_flow(fdb("ServeDense").find(BETWEEN(P.hour, 8, 17)), catalog)
    p2 = plan_flow(fdb("ServeDense").find(BETWEEN(P.hour, 8, 18)), catalog)
    k1 = cache.key_for(dense_db, p1)
    k2 = cache.key_for(dense_db, p2)
    assert k1 is not None and k2 is not None and k1 != k2
    assert cache.key_for(dense_db, p1) == k1   # deterministic
    class Weird:
        pass
    p1b = plan_flow(fdb("ServeDense").find(BETWEEN(P.hour, 8, 17)),
                    catalog)
    p1b.mixer_ops = list(p1b.mixer_ops) + [lambda x: x]    # opaque
    assert cache.key_for(dense_db, p1b) is None


def test_broken_cache_never_fails_a_query(catalog, monkeypatch):
    """Fault injection: a cache whose every method raises degrades the
    server to recomputation — every query still answers correctly."""
    monkeypatch.setenv(FUSED_ENV, "1")

    class BrokenCache:
        def key_for(self, *a, **k): raise RuntimeError("cache down")
        def get(self, *a, **k): raise RuntimeError("cache down")
        def put(self, *a, **k): raise RuntimeError("cache down")
        def stats(self): raise RuntimeError("cache down")

    flows = _tess_flows(3, seed=29)
    np_eng = AdHocEngine(catalog, num_servers=2, backend="numpy", wave=3)
    oracle = [np_eng.collect(f) for f in flows]
    srv = _server(catalog, cache=BrokenCache())
    futs = [srv.submit(f) for f in flows]
    srv.run_pending()
    for f, o in zip(futs, oracle):
        assert_identical(f.result(60).batch, o.batch)
    assert srv.stats()["cache_errors"] > 0


# --------------------------------------------- concurrency-safety satellites

def test_launch_counter_two_threads():
    """record_launch is concurrency-safe: the aggregate view sums both
    threads exactly; scope="thread" sees only the calling thread's own
    launches."""
    ops.reset_launch_counts()
    n = 5000
    per_thread = {}
    barrier = threading.Barrier(2)

    def worker(tid):
        barrier.wait()
        for _ in range(n):
            ops.record_launch("probe_x")
        per_thread[tid] = ops.launch_counts(scope="thread")

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert ops.launch_counts()["probe_x"] == 2 * n     # no lost updates
    assert per_thread[0]["probe_x"] == n
    assert per_thread[1]["probe_x"] == n
    # the main thread recorded nothing
    assert ops.launch_counts(scope="thread").get("probe_x", 0) == 0
    ops.reset_launch_counts()
    assert ops.launch_counts() == {}
    assert ops.launch_counts(scope="thread") == {}
    with pytest.raises(ValueError):
        ops.launch_counts(scope="bogus")


def test_device_cache_concurrent_prime_and_release():
    """Concurrent prime_fdb of the SAME FDb from many threads yields one
    consistent buffer census; concurrent open/close of distinct FDbs
    refcounts correctly (shared-shard snapshots keep buffers alive until
    the last reference dies)."""
    db = _dense_db("ServePrimeRace")
    be = JaxBackend()
    counts = []

    def prime():
        counts.append(be.prime_fdb(db))

    ts = [threading.Thread(target=prime) for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    expect = db.num_shards * 5                 # bitmap + 4 column buffers
    assert len(be.device_cache) == expect
    assert sum(1 for c in counts if c > 0) == 1    # exactly one real prime

    # churn: concurrent open/close of short-lived FDbs never corrupts the
    # census and everything evicts once dead
    def churn(i):
        d = _dense_db(f"ServeChurn{i}")
        be.prime_fdb(d)
        assert be.device_cache.get(d.shards[0].batch["speed"].values) \
            is not None

    with ThreadPoolExecutor(max_workers=4) as pool:
        list(pool.map(churn, range(8)))
    gc.collect()
    time.sleep(0.05)
    gc.collect()
    assert len(be.device_cache) == expect      # only the live db remains
    del db
    gc.collect()
    assert len(be.device_cache) == 0
