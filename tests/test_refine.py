"""Device-side ragged track refine: backend op byte parity (numpy oracle
vs jax kernel path) on ragged/empty tracks and word-boundary doc counts,
the wave launch-count contract including the refine launch, device-side
ragged column gathers, and ``tesseract_stats`` edge cases."""
import math

import numpy as np
import pytest

from repro.core import fdb
from repro.data.synthetic import city_region
from repro.exec import AdHocEngine, Catalog, JaxBackend, get_backend
from repro.fdb import build_fdb
from repro.fdb.schema import Field, Schema, DOUBLE, INT, MESSAGE
from repro.geo import AreaTree, mercator as M
from repro.kernels import ops
from repro.tess import Tesseract, tesseract_stats

pytestmark = pytest.mark.tesseract

RNG = np.random.default_rng(17)


def _track_schema() -> Schema:
    return Schema("Walks", [
        Field("id", INT, indexes=("tag",)),
        Field("track", MESSAGE, fields=[
            Field("lat", DOUBLE, repeated=True),
            Field("lng", DOUBLE, repeated=True),
            Field("t", DOUBLE, repeated=True)],
            indexes=("spacetime",),
            index_params={"level": 6, "bucket_s": 900.0, "epoch": 0.0}),
    ])


def _walks(n, rng, empty_every=7):
    """Random ragged tracks around the bay; every ``empty_every``-th doc
    has an empty track (the refine must return False for those)."""
    recs = []
    for i in range(n):
        ln = 0 if (empty_every and i % empty_every == 0) \
            else int(rng.integers(1, 14))
        lat = rng.uniform(37.2, 38.0, ln)
        lng = rng.uniform(-122.6, -121.8, ln)
        t = np.sort(rng.uniform(0.0, 3 * 86400.0, ln))
        recs.append({"id": i, "track": {"lat": lat.tolist(),
                                        "lng": lng.tolist(),
                                        "t": t.tolist()}})
    return recs


def _region(rng, d=2_000_000):
    ix, iy = M.latlng_to_xy(rng.uniform(37.2, 38.0),
                            rng.uniform(-122.6, -121.8))
    return AreaTree.from_box(int(ix) - d, int(iy) - d,
                             int(ix) + d, int(iy) + d, max_level=7)


@pytest.fixture(scope="module")
def walks_db():
    # word-boundary shard sizes: 32-bit bitmap words must not leak pad docs
    sizes = [32, 31, 64, 65, 1, 0, 33]
    recs = _walks(sum(sizes), RNG)
    bounds = np.cumsum([0] + sizes)
    key = lambda r: int(np.searchsorted(bounds, r["id"], "right") - 1)
    db = build_fdb("Walks", _track_schema(), recs,
                   num_shards=len(sizes), shard_key=key)
    assert [s.n for s in db.shards] == sizes
    return db


# -------------------------------------------------------- backend op parity

@pytest.mark.parametrize("n_constraints", [1, 2, 3])
def test_refine_tracks_backend_parity(walks_db, n_constraints):
    """numpy ≡ jax per-shard refine on ragged/empty tracks, with and
    without a candidate restriction."""
    npb, jxb = get_backend("numpy"), get_backend("jax")
    jxb.prime_fdb(walks_db)
    rng = np.random.default_rng(n_constraints)
    cons = [(_region(rng), float(rng.uniform(0, 86400.0)),
             float(rng.uniform(86400.0, 3 * 86400.0)))
            for _ in range(n_constraints)]
    some_hits = 0
    for shard in walks_db.shards:
        cand = rng.random(shard.n) < 0.7
        for candidates in (None, cand):
            a = npb.refine_tracks(shard.batch, "track", cons, candidates)
            b = jxb.refine_tracks(shard.batch, "track", cons, candidates)
            assert a.dtype == np.bool_ and b.dtype == np.bool_
            assert np.array_equal(a, b)
        some_hits += int(a.sum())
        # empty tracks can never satisfy a constraint
        sp = shard.batch["track.lat"].row_splits
        assert not a[np.diff(sp) == 0].any()
    assert some_hits > 0


def test_refine_tracks_batched_matches_per_shard(walks_db):
    """Wave-stacked refine ≡ loop-over-shards oracle, empty shard incl."""
    rng = np.random.default_rng(5)
    cons = [(_region(rng), 0.0, 2 * 86400.0),
            (_region(rng), 86400.0, 3 * 86400.0)]
    batches = [s.batch for s in walks_db.shards]
    cands = [rng.random(b.n) < 0.8 for b in batches]
    oracle = get_backend("numpy")
    want = [oracle.refine_tracks(b, "track", cons, c)
            for b, c in zip(batches, cands)]
    for bname in ("numpy", "jax"):
        be = get_backend(bname)
        be.prime_fdb(walks_db)
        got = be.refine_tracks_batched(batches, "track", cons, cands)
        for g, w in zip(got, want):
            assert np.array_equal(g, w), bname


def test_refine_empty_region_and_window(walks_db):
    """Empty cover / inverted window kill every doc on both backends."""
    for bname in ("numpy", "jax"):
        be = get_backend(bname)
        for cons in ([(AreaTree.empty(), 0.0, 1e9)],
                     [(_region(np.random.default_rng(0)), 5.0, 1.0)]):
            masks = be.refine_tracks_batched(
                [s.batch for s in walks_db.shards], "track", cons)
            assert not any(m.any() for m in masks), (bname, cons)


# --------------------------------------------------- engine + launch counts

def _tess(rng):
    return Tesseract(_region(rng), 0.0, 2 * 86400.0).also(
        _region(rng), 43200.0, 3 * 86400.0)


def test_engine_refine_parity_and_launch_contract(walks_db, exec_pplan,
                                                  monkeypatch):
    # pin the legacy per-primitive path: this test asserts the pre-fused
    # launch contract (the fused one lives in tests/test_fused.py)
    monkeypatch.setenv("REPRO_EXEC_FUSED", "0")
    cat = Catalog(server_slots=8)
    cat.register(walks_db)
    rng = np.random.default_rng(11)
    tess = _tess(rng)
    flow = fdb("Walks").tesseract(tess)
    ids = {}
    wave = 3
    for bname in ("numpy", "jax"):
        eng = AdHocEngine(cat, num_servers=2, backend=bname, wave=wave)
        res = eng.collect(flow)
        ids[bname] = sorted(res.batch["id"].values.tolist())
    assert ids["numpy"] == ids["jax"]
    assert len(ids["numpy"]) > 0

    # the refine rides the wave contract: ⌈shards/wave⌉ launches per query,
    # one selection compact (the refine mask feeds it), zero per-shard ops
    eng = AdHocEngine(cat, num_servers=2, backend="jax", wave=wave)
    eng.collect(flow)                          # warm
    ops.reset_launch_counts()
    eng.collect(flow)
    lc = ops.launch_counts()
    waves = exec_pplan(walks_db.num_shards,
                       eng.backend).wave_dispatches(wave)
    assert lc.get("bitmap_intersect_batched") == waves
    assert lc.get("refine_tracks_batched") == waves
    assert lc.get("compact_batched") == waves
    assert lc.get("refine_tracks", 0) == 0
    assert lc.get("compact", 0) == 0


def test_refine_without_spacetime_index():
    """InSpaceTime over an unindexed track still routes through the refine
    op (full scan + exact pass) and matches across backends."""
    schema = Schema("Plain", [
        Field("id", INT, indexes=("tag",)),
        Field("track", MESSAGE, fields=[
            Field("lat", DOUBLE, repeated=True),
            Field("lng", DOUBLE, repeated=True),
            Field("t", DOUBLE, repeated=True)])])
    recs = _walks(60, np.random.default_rng(2))
    cat = Catalog()
    cat.register(build_fdb("Plain", schema, recs, num_shards=3))
    from repro.core.planner import plan_flow
    rng = np.random.default_rng(3)
    tess = Tesseract(_region(rng), 0.0, 3 * 86400.0)
    flow = fdb("Plain").find(tess.expr())
    plan = plan_flow(flow, cat)
    assert plan.probes == [] and len(plan.refines) == 1
    ids = {}
    for bname in ("numpy", "jax"):
        res = AdHocEngine(cat, num_servers=2, backend=bname).collect(flow)
        ids[bname] = sorted(res.batch["id"].values.tolist())
    assert ids["numpy"] == ids["jax"]
    assert len(ids["numpy"]) > 0


def test_track_pack_cache_lifecycle():
    """Packed track buffers are only cached when tied to a primed FDb
    (released by its finalizer); refining never-primed batches must not
    pin entries in the backend forever."""
    rng = np.random.default_rng(9)
    cons = [(_region(rng), 0.0, 3 * 86400.0)]
    be = JaxBackend()
    db = build_fdb("W1", _track_schema(), _walks(20, rng), num_shards=2)
    masks = be.refine_tracks_batched([s.batch for s in db.shards],
                                     "track", cons)
    assert len(masks) == 2
    assert len(be._track_packs) == 0           # unprimed → no pinning
    be.prime_fdb(db)
    be.refine_tracks(db.shards[0].batch, "track", cons)
    assert len(be._track_packs) == db.num_shards
    del db, masks                              # finalizer drops the packs
    assert len(be._track_packs) == 0
    assert len(be.device_cache) == 0


# ------------------------------------------------------- ordered constraints

PA, PB, PC = (37.40, -122.40), (37.60, -122.20), (37.90, -121.90)


def _pt_region(latlng, d=100_000):
    ix, iy = M.latlng_to_xy(*latlng)
    return AreaTree.from_box(int(ix) - d, int(iy) - d,
                             int(ix) + d, int(iy) + d, max_level=7)


def _track(*pts):
    """[(latlng, t), …] → track record field."""
    return {"lat": [p[0][0] for p in pts], "lng": [p[0][1] for p in pts],
            "t": [float(p[1]) for p in pts]}


#: handcrafted ordering verdicts for A.then(B): id → (track, A-then-B?)
#: (every case the first-hit semantics must decide: order, reverse order,
#: exact tie, missing hit, minimal strict gap, empty track, and an early
#: B revisited later — first-hit compares the *first* hits, so a later
#: B hit cannot resurrect the doc)
_AB_CASES = [
    (_track((PA, 100.0), (PB, 200.0)), True),     # A then B
    (_track((PB, 100.0), (PA, 200.0)), False),    # B before A
    (_track((PA, 150.0), (PB, 150.0)), False),    # tie ⇒ not-before
    (_track((PA, 100.0)), False),                 # B never hit
    (_track((PA, 100.0), (PB, 100.0000001)), True),  # strict, minimal gap
    (_track(), False),                            # empty track
    (_track((PB, 50.0), (PA, 100.0), (PB, 300.0)), False),  # first(B)<first(A)
]


@pytest.fixture(scope="module")
def ordered_db():
    """_AB_CASES plus random filler, sharded at word-boundary sizes (and
    one empty shard) so the bitset/table pad paths are exercised."""
    recs = [{"id": i, "track": tr} for i, (tr, _) in enumerate(_AB_CASES)]
    rng = np.random.default_rng(23)
    # empty_every=10 keeps the last shard's lone doc (id 63) non-empty so
    # every wave issues a real refine launch (the launch-contract test)
    for r in _walks(len(recs) + 57, rng, empty_every=10)[len(recs):]:
        recs.append(r)
    sizes = [32, 0, 31, 1]
    bounds = np.cumsum([0] + sizes)
    key = lambda r: int(np.searchsorted(bounds, r["id"], "right") - 1)
    db = build_fdb("Ordered", _track_schema(), recs,
                   num_shards=len(sizes), shard_key=key)
    assert [s.n for s in db.shards] == sizes
    return db


def _ab_tess():
    return Tesseract(_pt_region(PA), 0.0, 1000.0).then(
        _pt_region(PB), 0.0, 1000.0)


def test_ordered_refine_semantics_and_parity(ordered_db):
    """Handcrafted first-hit verdicts hold, byte-identically across
    backends, per-shard and wave-batched."""
    cat = Catalog()
    cat.register(ordered_db)
    tess = _ab_tess()
    want = sorted(i for i, (_, ok) in enumerate(_AB_CASES) if ok)
    ids = {}
    for bname in ("numpy", "jax"):
        res = AdHocEngine(cat, num_servers=2, backend=bname,
                          wave=3).collect(fdb("Ordered").tesseract(tess))
        got = sorted(x for x in res.batch["id"].values.tolist()
                     if x < len(_AB_CASES))
        ids[bname] = sorted(res.batch["id"].values.tolist())
        assert got == want, bname
    assert ids["numpy"] == ids["jax"]
    # per-shard (wave=1 path) agrees too
    res1 = AdHocEngine(cat, num_servers=2, backend="jax", wave=1).collect(
        fdb("Ordered").tesseract(tess))
    assert sorted(res1.batch["id"].values.tolist()) == ids["jax"]


def test_ordered_chain_of_three(ordered_db):
    """then().then() chains: every pairwise edge must hold — an
    out-of-order middle leg kills the doc even when the outer pair is
    ordered correctly."""
    recs = [
        {"id": 0, "track": _track((PA, 100.0), (PB, 200.0), (PC, 300.0))},
        {"id": 1, "track": _track((PA, 100.0), (PC, 150.0), (PB, 200.0))},
        {"id": 2, "track": _track((PB, 90.0), (PA, 100.0), (PB, 200.0),
                                  (PC, 300.0))},
        {"id": 3, "track": _track((PA, 100.0), (PB, 200.0), (PC, 200.0))},
    ]
    db = build_fdb("Chain", _track_schema(), recs, num_shards=2)
    cat = Catalog()
    cat.register(db)
    tess = (Tesseract(_pt_region(PA), 0.0, 1000.0)
            .then(_pt_region(PB), 0.0, 1000.0)
            .then(_pt_region(PC), 0.0, 1000.0))
    for bname in ("numpy", "jax"):
        res = AdHocEngine(cat, num_servers=2, backend=bname).collect(
            fdb("Chain").tesseract(tess))
        # 1: C before B; 2: first(B) < first(A); 3: B/C tie
        assert sorted(res.batch["id"].values.tolist()) == [0], bname


def test_ordered_first_hit_table_parity(ordered_db, walks_db):
    """The per-(doc × constraint) first-hit table itself is byte-equal
    across backends — full-shard and candidate-restricted — on both the
    handcrafted and the random word-boundary DBs."""
    from repro.exec.refine import FIRST_HIT_NONE
    npb, jxb = get_backend("numpy"), get_backend("jax")
    rng = np.random.default_rng(3)
    cons = [(_pt_region(PA), 0.0, 1000.0), (_pt_region(PB), 0.0, 1000.0)]
    for db, cs in ((ordered_db, cons),
                   (walks_db, [(_region(rng), 0.0, 2 * 86400.0),
                               (_region(rng), 43200.0, 3 * 86400.0)])):
        jxb.prime_fdb(db)
        batches = [s.batch for s in db.shards]
        cands = [rng.random(b.n) < 0.8 for b in batches]
        for cand_list in (None, cands):
            m_n, t_n = npb.refine_tracks_batched(
                batches, "track", cs, cand_list, with_first_hits=True)
            m_j, t_j = jxb.refine_tracks_batched(
                batches, "track", cs, cand_list, with_first_hits=True)
            for a, b in zip(m_n, m_j):
                assert np.array_equal(a, b)
            for a, b in zip(t_n, t_j):
                assert a.dtype == np.uint64 and b.dtype == np.uint64
                assert np.array_equal(a, b)
    # handcrafted table spot checks (shard 0 holds the _AB_CASES docs)
    _, tables = npb.refine_tracks_batched(
        [ordered_db.shards[0].batch], "track", cons, with_first_hits=True)
    tab = tables[0]
    from repro.exec.refine import f64_sort_key
    assert tab[0, 0] == f64_sort_key(100.0) and \
        tab[0, 1] == f64_sort_key(200.0)
    assert tab[2, 0] == tab[2, 1] == f64_sort_key(150.0)   # exact tie
    assert tab[3, 1] == FIRST_HIT_NONE                     # B never hit
    assert tab[5, 0] == tab[5, 1] == FIRST_HIT_NONE        # empty track
    assert tab[6, 1] == f64_sort_key(50.0)                 # first B hit


def test_ordered_launch_contract(ordered_db, exec_pplan, monkeypatch):
    """Ordering rides the same batched refine launches: still ⌈shards/wave⌉
    refine_tracks_batched dispatches per query, zero per-shard ops (the
    legacy path — the fused single-dispatch contract is in test_fused)."""
    monkeypatch.setenv("REPRO_EXEC_FUSED", "0")
    cat = Catalog()
    cat.register(ordered_db)
    flow = fdb("Ordered").tesseract(_ab_tess())
    wave = 3
    eng = AdHocEngine(cat, num_servers=2, backend="jax", wave=wave)
    eng.collect(flow)                          # warm
    ops.reset_launch_counts()
    res = eng.collect(flow)
    lc = ops.launch_counts()
    # time-partition pruning drops the filler shards (their spans miss the
    # [0, 1000] windows), so waves count over the *planned* shard subset
    kept = len(res.plan.shard_ids)
    assert 0 < kept < ordered_db.num_shards          # pruning fired
    waves = exec_pplan(kept, eng.backend).wave_dispatches(wave)
    assert lc.get("refine_tracks_batched") == waves
    assert lc.get("compact_batched") == waves
    assert lc.get("refine_tracks", 0) == 0
    assert lc.get("compact", 0) == 0


def test_ordered_without_spacetime_index():
    """Ordered constraints over an unindexed track still run through the
    refine op (full scan + first-hit pass) and match across backends."""
    schema = Schema("PlainSeq", [
        Field("id", INT, indexes=("tag",)),
        Field("track", MESSAGE, fields=[
            Field("lat", DOUBLE, repeated=True),
            Field("lng", DOUBLE, repeated=True),
            Field("t", DOUBLE, repeated=True)])])
    recs = [{"id": i, "track": tr} for i, (tr, _) in enumerate(_AB_CASES)]
    cat = Catalog()
    cat.register(build_fdb("PlainSeq", schema, recs, num_shards=3))
    from repro.core.planner import plan_flow
    tess = _ab_tess()
    flow = fdb("PlainSeq").find(tess.expr())
    plan = plan_flow(flow, cat)
    assert plan.probes == [] and len(plan.refines) == 1
    assert plan.refines[0].edges == [(0, 1)]
    want = sorted(i for i, (_, ok) in enumerate(_AB_CASES) if ok)
    for bname in ("numpy", "jax"):
        res = AdHocEngine(cat, num_servers=2, backend=bname).collect(flow)
        assert sorted(res.batch["id"].values.tolist()) == want, bname


def test_ordered_tesseract_stats(ordered_db):
    """tesseract_stats threads the ordering edges: refined counts shrink
    to the ordered survivors while candidates stay index-driven."""
    plain = Tesseract(_pt_region(PA), 0.0, 1000.0).also(
        _pt_region(PB), 0.0, 1000.0)
    for bname in ("numpy", "jax"):
        s_plain = tesseract_stats(ordered_db, plain, backend=bname)
        s_ord = tesseract_stats(ordered_db, _ab_tess(), backend=bname)
        assert s_ord["candidates"] == s_plain["candidates"]
        assert s_ord["refined"] <= s_plain["refined"]
        assert s_ord["refined"] == \
            sum(1 for _, ok in _AB_CASES if ok)


# ------------------------------------------------- device-side ragged gather

def test_device_ragged_gather_parity(walks_db):
    """Repeated (values, row_splits) columns gather from device-resident
    buffers — values, splits, and dtypes byte-equal to the host gather."""
    be = JaxBackend()
    be.prime_fdb(walks_db)
    shard = walks_db.shards[2]
    before = be.device_cache.hits
    for ids in (np.array([], np.int64),
                np.array([3], np.int64),
                np.sort(RNG.choice(shard.n, shard.n // 2, replace=False))):
        paths = shard.batch.paths()
        dev = be.gather_columns(shard.batch, paths, ids)
        host = shard.batch.select_paths(paths).gather(ids)
        assert dev.n == host.n
        for p in paths:
            assert dev[p].values.dtype == host[p].values.dtype, p
            assert np.array_equal(dev[p].values, host[p].values), p
            if host[p].row_splits is None:
                assert dev[p].row_splits is None
            else:
                assert np.array_equal(dev[p].row_splits,
                                      host[p].row_splits), p
    assert be.device_cache.hits > before       # ragged reads hit residency


# ------------------------------------------------------- tesseract_stats

def test_tesseract_stats_zero_doc_fdb():
    """An empty FDb has pruned nothing: pruning must report 0.0, not 1.0."""
    db = build_fdb("Empty", _track_schema(), [], num_shards=3)
    stats = tesseract_stats(db, _tess(np.random.default_rng(0)))
    assert stats["docs"] == 0
    assert stats["candidates"] == 0 and stats["refined"] == 0
    assert stats["pruning"] == 0.0


def test_tesseract_stats_matches_engine(walks_db):
    cat = Catalog()
    cat.register(walks_db)
    tess = _tess(np.random.default_rng(11))
    for bname in ("numpy", "jax"):
        stats = tesseract_stats(walks_db, tess, backend=bname)
        res = AdHocEngine(cat, num_servers=2, backend=bname).collect(
            fdb("Walks").tesseract(tess))
        assert stats["docs"] == walks_db.num_docs
        assert res.batch.n == stats["refined"]
        assert res.profile.rows_selected == stats["candidates"]
        assert stats["refined"] <= stats["candidates"]


def test_engine_out_of_range_window(walks_db):
    """A window entirely before the index epoch selects nothing (and the
    probe short-circuits instead of probing bucket-0 postings)."""
    cat = Catalog()
    cat.register(walks_db)
    tess = Tesseract(city_region("SF"), -9000.0, -100.0)
    for bname in ("numpy", "jax"):
        res = AdHocEngine(cat, num_servers=2, backend=bname).collect(
            fdb("Walks").tesseract(tess))
        assert res.batch.n == 0
