"""StreamingFDb (paper §4.1.1 read-write FDbs): flush-threshold boundaries,
concurrent writers, and consistent merged reader views."""
import threading
import time

import numpy as np
import pytest

from repro.core import P, fdb, group
from repro.exec import AdHocEngine, Catalog
from repro.fdb import DOUBLE, INT, Schema
from repro.fdb.schema import Field
from repro.fdb.streaming import StreamingFDb


def _schema(name="Events"):
    return Schema(name, [
        Field("id", INT, indexes=("tag",)),
        Field("val", DOUBLE, indexes=("range",)),
    ])


def _rec(i):
    return {"id": i, "val": float(i) * 0.5}


# ------------------------------------------------------------- thresholds

def test_flush_threshold_boundary():
    s = StreamingFDb("Events", _schema(), flush_threshold=8)
    for i in range(7):
        s.append(_rec(i))
    assert s.num_docs == 7
    assert len(s._shards) == 0            # below threshold: memtable only
    s.append(_rec(7))                     # hits the threshold exactly
    assert len(s._shards) == 1
    assert s.num_docs == 8
    snap = s.snapshot()
    assert snap.num_shards == 1           # memtable empty → no extra shard
    assert snap.num_docs == 8


def test_extend_crosses_multiple_thresholds():
    s = StreamingFDb("Events", _schema(), flush_threshold=4)
    s.extend([_rec(i) for i in range(11)])
    assert len(s._shards) == 2            # two full flushes of 4
    assert s.num_docs == 11
    snap = s.snapshot()
    assert snap.num_shards == 3           # + memtable tail of 3
    assert [sh.n for sh in snap.shards] == [4, 4, 3]
    # flush() drains the remainder
    s.flush()
    assert len(s._shards) == 3
    assert s.snapshot().num_shards == 3


def test_flush_on_empty_memtable_is_noop():
    s = StreamingFDb("Events", _schema(), flush_threshold=4)
    s.flush()
    assert s.num_docs == 0
    assert s.snapshot().num_shards == 0


# ------------------------------------------------------------ concurrency

def test_concurrent_append_extend_loses_nothing():
    s = StreamingFDb("Events", _schema(), flush_threshold=16)
    n_threads, per_thread = 8, 200

    def writer(t):
        base = t * per_thread
        for j in range(0, per_thread, 4):
            if j % 8 == 0:
                s.extend([_rec(base + j + k) for k in range(4)])
            else:
                for k in range(4):
                    s.append(_rec(base + j + k))

    threads = [threading.Thread(target=writer, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    total = n_threads * per_thread
    assert s.num_docs == total
    snap = s.snapshot()
    assert snap.num_docs == total
    # every record lands exactly once (no loss, no duplication)
    ids = np.concatenate([sh.batch["id"].values for sh in snap.shards])
    assert np.array_equal(np.sort(ids), np.arange(total))


# ----------------------------------------------------------- reader views

def test_readers_see_memtable_and_shards_merged():
    s = StreamingFDb("Events", _schema(), flush_threshold=4)
    s.extend([_rec(i) for i in range(10)])    # 2 flushed shards + 2 in mem
    cat = Catalog(server_slots=8)
    cat.register(s.snapshot())
    eng = AdHocEngine(cat, num_servers=3)
    res = eng.collect(fdb("Events").find(P.val >= 0.0))
    assert sorted(res.batch["id"].values.tolist()) == list(range(10))
    # aggregation across the memtable/shard boundary is seamless
    agg = eng.collect(fdb("Events").aggregate(group().count("n")))
    assert agg.batch["n"].values.tolist() == [10]
    # a snapshot is immutable: later writes don't leak into it
    snap = s.snapshot()
    s.append(_rec(10))
    assert snap.num_docs == 10
    assert s.snapshot().num_docs == 11
    # tag-index probes work on the memtable-backed shard too
    cat2 = Catalog(server_slots=8)
    cat2.register(s.snapshot())
    got = AdHocEngine(cat2, num_servers=3).collect(
        fdb("Events").find(P.id == 10))
    assert got.batch["id"].values.tolist() == [10]


# ----------------------------------------------------- background compaction

def test_appends_never_block_on_compaction():
    """LSM merges run on the background worker; a deliberately slow merge
    must not stall the appending thread (ISSUE 9 satellite)."""
    s = StreamingFDb("Events", _schema(), flush_threshold=4,
                     compact_threshold=2)
    merging = threading.Event()

    def slow_merge():
        merging.set()
        time.sleep(0.5)

    s._compact_hook = slow_merge
    s.extend([_rec(i) for i in range(8)])     # 2 deltas -> compaction due
    assert merging.wait(5.0)                  # merge in flight on the worker
    stalls = []
    for i in range(8, 24):                    # appends during the slow merge
        t0 = time.monotonic()
        s.append(_rec(i))
        stalls.append(time.monotonic() - t0)
    assert max(stalls) < 0.2                  # never blocked on the merge
    s._compact_hook = None
    s.flush()
    s.drain_compaction()
    st = s.stats()
    assert st["compactions"] >= 1
    assert s.num_docs == 24
    snap = s.snapshot()
    ids = np.concatenate([sh.batch["id"].values for sh in snap.shards])
    assert ids.tolist() == list(range(24))    # arrival order preserved


def test_inline_compaction_mode_preserved():
    """``compact_async=False`` keeps the legacy synchronous semantics —
    the merge completes inside the append that crossed the threshold."""
    s = StreamingFDb("Events", _schema(), flush_threshold=4,
                     compact_threshold=2, compact_async=False)
    s.extend([_rec(i) for i in range(8)])
    st = s.stats()
    assert st["compactions"] >= 1             # merged inline, no drain needed
    assert st["delta_shards"] < 2
    assert s.num_docs == 8
