"""Pallas kernels: interpret-mode vs pure-jnp oracle, shape/dtype sweeps."""
import numpy as np
import pytest
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: fall back to shim
    from _hypothesis_shim import given, settings, st

from repro.kernels import ops

RNG = np.random.default_rng(0)


# ------------------------------------------------------------- bitset

@pytest.mark.parametrize("w", [1, 31, 32, 100, 4096, 4097, 20_000])
@pytest.mark.parametrize("op", ["and", "or", "andnot"])
def test_bitset_binary(w, op):
    a = jnp.asarray(RNG.integers(0, 2**32, w, dtype=np.uint32))
    b = jnp.asarray(RNG.integers(0, 2**32, w, dtype=np.uint32))
    got = ops.bitmap_binary(a, b, op, impl="interpret")
    want = ops.bitmap_binary(a, b, op, impl="reference")
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("k,w", [(1, 64), (3, 1000), (5, 8192)])
def test_bitmap_intersect(k, w):
    stack = jnp.asarray(RNG.integers(0, 2**32, (k, w), dtype=np.uint32))
    bm, cnt = ops.bitmap_intersect(stack, impl="interpret")
    bm_r, cnt_r = ops.bitmap_intersect(stack, impl="reference")
    assert (np.asarray(bm) == np.asarray(bm_r)).all()
    assert int(cnt) == int(cnt_r)


@pytest.mark.parametrize("s,k,w", [(1, 1, 64), (3, 2, 1000), (6, 4, 4097),
                                   (2, 1, 513)])
def test_bitmap_intersect_batched(s, k, w):
    """Wave-stacked AND: interpret ≡ reference ≡ per-shard intersect."""
    stack = jnp.asarray(RNG.integers(0, 2**32, (s, k, w), dtype=np.uint32))
    bm_i, cnt_i = ops.bitmap_intersect_batched(stack, impl="interpret")
    bm_r, cnt_r = ops.bitmap_intersect_batched(stack, impl="reference")
    assert (np.asarray(bm_i) == np.asarray(bm_r)).all()
    assert (np.asarray(cnt_i) == np.asarray(cnt_r)).all()
    for i in range(s):
        bm1, cnt1 = ops.bitmap_intersect(stack[i], impl="reference")
        assert (np.asarray(bm1) == np.asarray(bm_r)[i]).all()
        assert int(cnt1) == int(np.asarray(cnt_r)[i])


# ------------------------------------------------------------ compact

@pytest.mark.parametrize("n", [8, 100, 4096, 9_999])
@pytest.mark.parametrize("density", [0.0, 0.3, 1.0])
def test_compact(n, density):
    m = jnp.asarray(RNG.random(n) < density)
    gi, gc = ops.compact(m, impl="interpret")
    ri, rc = ops.compact(m, impl="reference")
    assert int(gc) == int(rc) == int(np.asarray(m).sum())
    k = int(gc)
    assert (np.asarray(gi)[:k] == np.asarray(ri)[:k]).all()
    assert (np.asarray(gi)[k:] == -1).all()


@pytest.mark.parametrize("impl", ["interpret", "reference"])
def test_compact_empty_mask(impl):
    # zero-size masks happen per shard whenever an index probe admits no
    # candidates (common for selective Tesseract queries)
    idx, cnt = ops.compact(jnp.zeros((0,), jnp.bool_), impl=impl)
    assert int(cnt) == 0
    assert np.asarray(idx).shape == (0,)


@given(st.integers(1, 2000), st.integers(0, 2**31))
@settings(max_examples=30, deadline=None)
def test_compact_property(n, seed):
    rng = np.random.default_rng(seed)
    m = rng.random(n) < rng.random()
    idx, cnt = ops.compact(jnp.asarray(m), impl="interpret")
    idx = np.asarray(idx)
    # indices are exactly the set positions, ascending
    assert (idx[:int(cnt)] == np.nonzero(m)[0]).all()


@pytest.mark.parametrize("s,n", [(1, 8), (4, 317), (3, 9000), (2, 4096)])
@pytest.mark.parametrize("density", [0.0, 0.35, 1.0])
def test_compact_batched(s, n, density):
    """Wave-stacked compaction: the carry resets per shard, so each row
    compacts exactly like an independent single-shard launch."""
    m = jnp.asarray(RNG.random((s, n)) < density)
    gi, gc = ops.compact_batched(m, impl="interpret")
    ri, rc = ops.compact_batched(m, impl="reference")
    assert (np.asarray(gi) == np.asarray(ri)).all()
    assert (np.asarray(gc) == np.asarray(rc)).all()
    for i in range(s):
        want = np.nonzero(np.asarray(m)[i])[0]
        cnt = int(np.asarray(gc)[i])
        assert cnt == want.size
        assert (np.asarray(gi)[i][:cnt] == want).all()
        assert (np.asarray(gi)[i][cnt:] == -1).all()


@pytest.mark.parametrize("impl", ["interpret", "reference"])
def test_compact_batched_empty(impl):
    idx, cnt = ops.compact_batched(jnp.zeros((3, 0), jnp.bool_), impl=impl)
    assert np.asarray(idx).shape == (3, 0)
    assert (np.asarray(cnt) == 0).all()


# --------------------------------------------------------- segment_agg

@pytest.mark.parametrize("n,g", [(64, 3), (1000, 130), (5000, 257)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_segment_agg(n, g, dtype):
    gid = jnp.asarray(RNG.integers(-1, g, n, dtype=np.int32))
    v = jnp.asarray(RNG.normal(size=n).astype(dtype))
    got = ops.segment_agg(gid, v, g, impl="interpret")
    want = ops.segment_agg(gid, v, g, impl="reference")
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_segment_agg_vs_host_groupby(world):
    speeds = np.array([o["speed"] for o in world["obs"]], np.float32)
    roads = np.array([o["road_id"] for o in world["obs"]], np.int32)
    cnt, s, s2 = ops.segment_agg(jnp.asarray(roads), jnp.asarray(speeds),
                                 300, impl="interpret")
    for rid in (0, 7, 123):
        sel = speeds[roads == rid]
        assert int(np.asarray(cnt)[rid]) == sel.size
        np.testing.assert_allclose(np.asarray(s)[rid], sel.sum(),
                                   rtol=1e-4)


# -------------------------------------------------------- track refine

def _refine_case(rng, n_docs, max_len, n_constraints, *, empty_every=0):
    """Random ragged tracks + constraints in packed kernel form."""
    from repro.exec.refine import pack_constraints, pack_track_points
    from repro.geo import mercator as M
    from repro.geo.areatree import AreaTree
    lens = rng.integers(0, max_len, n_docs)
    if empty_every:
        lens[::empty_every] = 0                  # force empty tracks
    splits = np.zeros(n_docs + 1, np.int64)
    np.cumsum(lens, out=splits[1:])
    p = int(splits[-1])
    lat = rng.uniform(37.6, 37.9, p)
    lng = rng.uniform(-122.6, -122.2, p)
    t = rng.uniform(0.0, 1e5, p)
    cons = []
    for _ in range(n_constraints):
        ix, iy = M.latlng_to_xy(rng.uniform(37.6, 37.9),
                                rng.uniform(-122.6, -122.2))
        d = int(rng.integers(3_000, 2_000_000))
        cons.append((AreaTree.from_box(int(ix) - d, int(iy) - d,
                                       int(ix) + d, int(iy) + d,
                                       max_level=7),
                     float(rng.uniform(0, 5e4)),
                     float(rng.uniform(5e4, 1e5))))
    pts, rows = pack_track_points(lat, lng, t, splits)
    return ((lat, lng, t, splits), cons,
            jnp.asarray(pts), jnp.asarray(rows),
            jnp.asarray(pack_constraints(cons)))


def _refine_brute(track, cons, n_docs):
    from repro.geo import mercator as M
    lat, lng, t, splits = track
    keys = M.latlng_to_morton(lat, lng)
    out = np.ones(n_docs, dtype=bool)
    row_of = np.repeat(np.arange(n_docs), np.diff(splits))
    for region, t0, t1 in cons:
        hit = region.contains(keys) & (t >= t0) & (t <= t1)
        doc = np.zeros(n_docs, dtype=bool)
        np.logical_or.at(doc, row_of, hit)
        out &= doc
    return out


@pytest.mark.parametrize("n_docs,max_len,c", [(1, 5, 1), (31, 10, 2),
                                              (128, 8, 1), (300, 12, 3)])
def test_refine_tracks(n_docs, max_len, c):
    """Interpret ≡ reference ≡ brute-force numpy on ragged tracks (empty
    tracks included, doc counts off word boundaries)."""
    rng = np.random.default_rng(n_docs * 7 + c)
    track, cons, pts, rows, cov = _refine_case(rng, n_docs, max_len, c,
                                               empty_every=5)
    want = _refine_brute(track, cons, n_docs)
    got_i = np.asarray(ops.refine_tracks(pts, rows, cov, n_docs,
                                         impl="interpret"))
    got_r = np.asarray(ops.refine_tracks(pts, rows, cov, n_docs,
                                         impl="reference"))
    assert np.array_equal(got_i, want)
    assert np.array_equal(got_r, want)


@pytest.mark.parametrize("impl", ["interpret", "reference"])
def test_refine_tracks_batched(impl):
    """Wave-stacked refine: ragged shard sizes (incl. an all-empty-track
    shard) padded into one launch ≡ per-shard refine."""
    rng = np.random.default_rng(3)
    shard_docs = [0, 1, 64, 33]
    cases = [_refine_case(rng, n, 10, 2, empty_every=3)
             for n in shard_docs]
    cov = cases[-1][4]           # same constraints for every shard
    cons = cases[-1][1]
    n_max = max(shard_docs)
    p_max = max(c[2].shape[1] for c in cases)
    pts = np.zeros((len(cases), 4, p_max), np.uint32)
    rows = np.full((len(cases), p_max), -1, np.int32)
    for i, case in enumerate(cases):
        p = case[2].shape[1]
        pts[i, :, :p] = np.asarray(case[2])
        rows[i, :p] = np.asarray(case[3])
    got = np.asarray(ops.refine_tracks_batched(
        jnp.asarray(pts), jnp.asarray(rows), cov, n_max, impl=impl))
    assert got.shape == (len(cases), n_max)
    for i, (case, n) in enumerate(zip(cases, shard_docs)):
        want = _refine_brute(case[0], cons, n)
        assert np.array_equal(got[i, :n], want), i
        assert not got[i, n:].any()              # padding never hits


@pytest.mark.parametrize("n_docs,max_len,c", [(1, 5, 1), (31, 10, 2),
                                              (300, 12, 3)])
def test_refine_tracks_first_hits(n_docs, max_len, c):
    """The first-hit (hi, lo) word tables: interpret ≡ reference ≡ the
    numpy host oracle's packed uint64 min, sentinel where a constraint
    never hits — and the mask output is unchanged by requesting them."""
    from repro.exec.refine import refine_tracks_host
    rng = np.random.default_rng(n_docs * 13 + c)
    track, cons, pts, rows, cov = _refine_case(rng, n_docs, max_len, c,
                                               empty_every=4)
    lat, lng, t, splits = track
    _, want_table = refine_tracks_host(lat, lng, t, splits, n_docs, cons,
                                       with_first_hits=True)
    plain = np.asarray(ops.refine_tracks(pts, rows, cov, n_docs,
                                         impl="reference"))
    for impl in ("interpret", "reference"):
        m, hi, lo = ops.refine_tracks(pts, rows, cov, n_docs, impl=impl,
                                      with_first_hits=True)
        m, hi, lo = np.asarray(m), np.asarray(hi), np.asarray(lo)
        got = ((hi.astype(np.uint64) << np.uint64(32))
               | lo.astype(np.uint64)).T
        assert np.array_equal(m, plain), impl
        assert np.array_equal(got, want_table), impl
        # batched single-shard path agrees word for word
        mb, hib, lob = ops.refine_tracks_batched(
            pts[None], rows[None], cov, n_docs, impl=impl,
            with_first_hits=True)
        assert np.array_equal(np.asarray(mb)[0], m), impl
        assert np.array_equal(np.asarray(hib)[0], hi), impl
        assert np.array_equal(np.asarray(lob)[0], lo), impl


@pytest.mark.parametrize("impl", ["interpret", "reference"])
def test_refine_tracks_first_hits_empty_inputs(impl):
    """Zero docs / zero points / empty shards return all-sentinel tables
    of the right shape."""
    from repro.exec.refine import FIRST_HIT_NONE, pack_constraints
    from repro.geo.areatree import AreaTree
    cov = jnp.asarray(pack_constraints([(AreaTree.empty(), 0.0, 1.0),
                                        (AreaTree.everything(), 0.0, 1.0)]))
    pts0 = jnp.zeros((4, 0), jnp.uint32)
    rows0 = jnp.zeros((0,), jnp.int32)
    m, hi, lo = ops.refine_tracks(pts0, rows0, cov, 5, impl=impl,
                                  with_first_hits=True)
    table = ((np.asarray(hi).astype(np.uint64) << np.uint64(32))
             | np.asarray(lo).astype(np.uint64))
    assert table.shape == (2, 5) and (table == FIRST_HIT_NONE).all()
    assert not np.asarray(m).any()
    mb, hib, lob = ops.refine_tracks_batched(
        jnp.zeros((0, 4, 0), jnp.uint32), jnp.zeros((0, 0), jnp.int32),
        cov, 5, impl=impl, with_first_hits=True)
    assert np.asarray(mb).shape == (0, 5)
    assert np.asarray(hib).shape == (0, 2, 5)


@pytest.mark.parametrize("impl", ["interpret", "reference"])
def test_refine_tracks_empty_inputs(impl):
    """Zero docs, zero points, empty cover region."""
    from repro.exec.refine import pack_constraints
    from repro.geo.areatree import AreaTree
    cov = jnp.asarray(pack_constraints([(AreaTree.empty(), 0.0, 1.0)]))
    pts0 = jnp.zeros((4, 0), jnp.uint32)
    rows0 = jnp.zeros((0,), jnp.int32)
    assert np.asarray(ops.refine_tracks(pts0, rows0, cov, 0,
                                        impl=impl)).shape == (0,)
    got = np.asarray(ops.refine_tracks(pts0, rows0, cov, 7, impl=impl))
    assert got.shape == (7,) and not got.any()
    # points exist but the cover is empty → nothing can match
    rng = np.random.default_rng(0)
    _, _, pts, rows, _ = _refine_case(rng, 16, 6, 1)
    assert not np.asarray(ops.refine_tracks(pts, rows, cov, 16,
                                            impl=impl)).any()


# ------------------------------------------------------ flash attention

def _fa_case(b, hq, hkv, sq, skv, d, dtype=np.float32, **kw):
    q = jnp.asarray(RNG.normal(size=(b, hq, sq, d)).astype(dtype))
    k = jnp.asarray(RNG.normal(size=(b, hkv, skv, d)).astype(dtype))
    v = jnp.asarray(RNG.normal(size=(b, hkv, skv, d)).astype(dtype))
    got = ops.flash_attention(q, k, v, impl="interpret", block_q=64,
                              block_k=128, **kw)
    want = ops.flash_attention(q, k, v, impl="reference", **kw)
    tol = 2e-2 if dtype == np.dtype(np.float16) else 3e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [
    (2, 4, 2, 128, 128, 64),      # GQA causal
    (1, 2, 1, 256, 256, 64),
    (1, 8, 8, 64, 64, 128),       # MHA
    (1, 2, 1, 100, 200, 64),      # ragged + decode offset
    (1, 4, 2, 1, 384, 64),        # single-token decode
])
def test_flash_attention_shapes(shape):
    _fa_case(*shape)


def test_flash_attention_window_softcap():
    _fa_case(1, 2, 1, 256, 256, 64, window=64)
    _fa_case(1, 2, 2, 128, 128, 64, softcap=30.0)
    _fa_case(1, 2, 1, 192, 192, 64, window=50, softcap=20.0)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 2, 128, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 1, 128, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 1, 128, 64))).astype(jnp.bfloat16)
    got = ops.flash_attention(q, k, v, impl="interpret", block_q=64,
                              block_k=64)
    want = ops.flash_attention(q, k, v, impl="reference")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


# ------------------------------------------------------------ ssm scan

@pytest.mark.parametrize("b,l,d", [(2, 64, 32), (1, 500, 130),
                                   (3, 1024, 16), (1, 7, 260)])
def test_ssm_scan(b, l, d):
    a = jnp.asarray(RNG.uniform(0.5, 1.0, (b, l, d)).astype(np.float32))
    bx = jnp.asarray(RNG.normal(size=(b, l, d)).astype(np.float32))
    hg, hTg = ops.ssm_scan(a, bx, impl="interpret", chunk=128)
    hr, hTr = ops.ssm_scan(a, bx, impl="reference")
    np.testing.assert_allclose(np.asarray(hg), np.asarray(hr), rtol=3e-4,
                               atol=3e-4)
    np.testing.assert_allclose(np.asarray(hTg), np.asarray(hTr),
                               rtol=3e-4, atol=3e-4)


@given(st.integers(1, 40), st.integers(1, 6), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_ssm_scan_property(l, b, seed):
    """h_t = a_t h_{t-1} + bx_t against a python loop."""
    rng = np.random.default_rng(seed)
    d = 8
    a = rng.uniform(0.2, 1.0, (b, l, d)).astype(np.float32)
    bx = rng.normal(size=(b, l, d)).astype(np.float32)
    hg, hT = ops.ssm_scan(jnp.asarray(a), jnp.asarray(bx),
                          impl="interpret", chunk=16)
    h = np.zeros((b, d), np.float32)
    for t in range(l):
        h = a[:, t] * h + bx[:, t]
        np.testing.assert_allclose(np.asarray(hg)[:, t], h, rtol=2e-3,
                                   atol=2e-3)
    np.testing.assert_allclose(np.asarray(hT), h, rtol=2e-3, atol=2e-3)
