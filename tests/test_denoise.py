"""De-noising (paper §4.1.3): probabilistic geometry + snapping."""
import numpy as np

from repro.geo import mercator as M
from repro.geo.denoise import (prob_location, prob_path, snap_path,
                               snap_points)


def test_prob_location_covers_uncertainty_disk():
    ix, iy = 5_000_000, 6_000_000
    mpu = 0.05
    area = prob_location(ix, iy, accuracy_m=30.0, meters_per_unit=mpu)
    # the true position may be anywhere within the radius: all inside
    r_units = 30.0 / mpu
    for ang in np.linspace(0, 2 * np.pi, 8, endpoint=False):
        px = np.uint64(ix + 0.9 * r_units * np.cos(ang))
        py = np.uint64(iy + 0.9 * r_units * np.sin(ang))
        assert area.contains(np.array([M.interleave(px, py)]))[0]


def test_prob_path_is_envelope_not_bbox():
    """Paper: the strip is an envelope around the path, NOT the bbox."""
    xs = np.array([0.0, 10_000.0]) + 1_000_000
    ys = np.array([0.0, 10_000.0]) + 1_000_000
    strip = prob_path(xs, ys, accuracy_m=20.0, meters_per_unit=0.05)
    # a bbox corner far from the diagonal must NOT be covered
    corner = M.interleave(np.uint64(1_000_000 + 9_000),
                          np.uint64(1_000_000 + 1_000))
    on_path = M.interleave(np.uint64(1_005_000), np.uint64(1_005_000))
    assert strip.contains(np.array([on_path]))[0]
    assert not strip.contains(np.array([corner]))[0]


def test_snap_points_prefers_near_and_popular():
    mpu = 0.05
    # two candidates: near+unpopular vs slightly-farther+popular
    cand_x = np.array([1000.0, 1400.0])
    cand_y = np.array([1000.0, 1000.0])
    pop = np.array([1.0, 1000.0])
    idx, _ = snap_points([1180.0], [1000.0], cand_x, cand_y, pop, mpu)
    assert idx[0] == 1                    # popularity breaks the near-tie
    # far-but-popular loses when the distance gap is decisive (>4σ)
    cand_x2 = np.array([1000.0, 3000.0])
    idx2, _ = snap_points([1010.0], [1000.0], cand_x2, cand_y, pop, mpu)
    assert idx2[0] == 0


def test_snap_path_viterbi_follows_route():
    """Noisy trace along segment A→B→C snaps to the right sequence."""
    rng = np.random.default_rng(0)
    mpu = 0.05
    # three collinear segments of 2000 units each
    ax = np.array([0.0, 2000.0, 4000.0])
    ay = np.zeros(3)
    bx = ax + 2000.0
    by = np.zeros(3)
    pop = np.ones(3)
    # trace traverses them left to right with noise
    t = np.linspace(0, 6000, 13)
    px = t + rng.normal(0, 60.0, t.size)
    py = rng.normal(0, 60.0, t.size)
    seq = snap_path(px, py, ax, ay, bx, by, pop, mpu)
    # must be monotone non-decreasing and span all three segments
    assert (np.diff(seq) >= 0).all()
    assert seq[0] == 0 and seq[-1] == 2
