"""HLO analyzer: trip counts, dot FLOPs, DUS/slice accounting, collectives.

These parse a hand-written HLO module (the format of
``compiled.as_text()``) so the roofline terms' arithmetic is pinned down
independently of XLA's output drift.
"""
from repro.launch.hlo_analysis import analyze_hlo, _parse_instr_line

HLO = """
HloModule jit_step, entry_computation_layout={()->f32[8,16]{1,0}}

%cond.1 (p.0: (s32[], f32[8,16])) -> pred[] {
  %p.0 = (s32[], f32[8,16]{1,0}) parameter(0)
  %gte.0 = s32[] get-tuple-element(%p.0), index=0
  %constant.5 = s32[] constant(12)
  ROOT %cmp = pred[] compare(%gte.0, %constant.5), direction=LT
}

%body.1 (p.1: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p.1 = (s32[], f32[8,16]{1,0}) parameter(0)
  %gte.1 = s32[] get-tuple-element(%p.1), index=0
  %c1 = s32[] constant(1)
  %add.0 = s32[] add(%gte.1, %c1)
  %gte.2 = f32[8,16]{1,0} get-tuple-element(%p.1), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.0 = f32[8,16]{1,0} dot(%gte.2, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar.0 = f32[8,16]{1,0} all-reduce(%dot.0), replica_groups={}, to_apply=%sum.0
  ROOT %tup = (s32[], f32[8,16]{1,0}) tuple(%add.0, %ar.0)
}

%sum.0 (a.0: f32[], b.0: f32[]) -> f32[] {
  %a.0 = f32[] parameter(0)
  %b.0 = f32[] parameter(1)
  ROOT %s.0 = f32[] add(%a.0, %b.0)
}

ENTRY %main (arg.0: f32[8,16]) -> f32[8,16] {
  %arg.0 = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]{1,0}) tuple(%zero, %arg.0)
  %while.0 = (s32[], f32[8,16]{1,0}) while(%init), condition=%cond.1, body=%body.1
  %gte.3 = f32[8,16]{1,0} get-tuple-element(%while.0), index=1
  %big = f32[1024,8,16]{2,1,0} constant({...})
  %upd = f32[1,8,16]{2,1,0} reshape(%gte.3)
  %dus.0 = f32[1024,8,16]{2,1,0} dynamic-update-slice(%big, %upd, %zero, %zero, %zero)
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%while.0), index=1
}
"""


def test_instr_parser_handles_tuple_types_and_comments():
    line = ("  %while.1 = (s32[], f32[16,512]{1,0}, /*index=2*/f32[4]{0}) "
            "while(%t), condition=%c, body=%b")
    name, typ, op, rest = _parse_instr_line(line)
    assert name == "while.1" and op == "while"
    assert "condition=%c" in rest


def test_trip_count_multiplies_loop_body():
    res = analyze_hlo(HLO)
    # dot: 2·(8·16)·16 = 4096 flops, ×12 trips
    assert res["flops_per_device"] == 12 * 2 * 8 * 16 * 16
    # all-reduce operand: 8·16·4 B, ×12 trips
    ar = res["per_kind"]["all-reduce"]
    assert ar["bytes"] == 12 * 8 * 16 * 4
    assert ar["count"] == 12
    assert not res["warnings"]


def test_dus_charged_at_slice_size():
    res = analyze_hlo(HLO)
    # the DUS writes a [1,8,16] slice into a [1024,8,16] buffer: the
    # bytes model must charge 2×slice (512·2 B), never the 1024× buffer
    dus_charge = 2 * 1 * 8 * 16 * 4
    full_buffer = 1024 * 8 * 16 * 4
    assert res["bytes_per_device"] < full_buffer
    # total = while(12×(dot read/write)) + dus_charge; dot charge per trip:
    # out 512B + operands (8·16 + 16·16)·4B
    per_trip = (8 * 16) * 4 * 2 + (16 * 16) * 4 + (8 * 16) * 4 * 2
    assert res["bytes_per_device"] == 12 * per_trip + dus_charge


def test_unresolved_loops_warn_not_crash():
    broken = HLO.replace("constant(12)", "parameter(1)").replace(
        "(p.0: (s32[], f32[8,16])) -> pred[]",
        "(p.0: (s32[], f32[8,16]), q.0: s32[]) -> pred[]")
    res = analyze_hlo(broken)
    assert res["warnings"]          # trip count unresolvable → warned
    assert res["flops_per_device"] == 2 * 8 * 16 * 16   # counted once
