"""Batched multi-shard execution: stacked-wave kernels vs the per-shard
oracle (byte parity on ragged shard sizes incl. empty shards), the
⌈shards/wave⌉ kernel-launch contract, and device-resident columns."""
import math

import numpy as np
import pytest

from repro.core import BETWEEN, P, group, fdb, proto
from repro.exec import (AdHocEngine, FlumeEngine, Catalog, JaxBackend,
                        get_backend, partition_waves, run_wave_task,
                        wave_size)
from repro.exec.processors import aggregate_produce, aggregate_produce_batched
from repro.exec.task import run_shard_task
from repro.core.planner import plan_flow
from repro.fdb import Schema, build_fdb, DOUBLE, INT, STRING
from repro.fdb.schema import Field
from repro.fdb.index import bitmap_from_ids, bitmap_full
from repro.kernels import ops

RNG = np.random.default_rng(11)


# --------------------------------------------------------------- fixtures

def _ragged_db(num_shards=7, empty_shard=5, rows=900):
    """Skewed shard sizes (≈5:2:1…) with one completely empty shard."""
    schema = Schema("Ragged", [
        Field("road", INT, indexes=("tag",)),
        Field("hour", INT, indexes=("range",)),
        Field("city", STRING, indexes=("tag",)),
        Field("speed", DOUBLE),
    ])
    choices = [s for s in range(num_shards) if s != empty_shard]
    weights = np.linspace(5, 1, len(choices))
    weights /= weights.sum()
    recs = [{"road": int(RNG.integers(0, 40)),
             "hour": int(RNG.integers(0, 24)),
             "city": ["SF", "OAK", "SJ"][int(RNG.integers(0, 3))],
             "speed": float(RNG.normal(48, 9)),
             "_sh": int(RNG.choice(choices, p=weights))}
            for _ in range(rows)]
    db = build_fdb("Ragged", schema, recs, num_shards=num_shards,
                   shard_key=lambda r: r["_sh"])
    sizes = [s.n for s in db.shards]
    assert sizes[empty_shard] == 0 and len(set(sizes)) > 2
    return db


@pytest.fixture(scope="module")
def ragged_catalog():
    cat = Catalog(server_slots=16)
    cat.register(_ragged_db())
    return cat


def assert_identical(a, b):
    assert a.n == b.n
    assert a.paths() == b.paths()
    for p in a.paths():
        ca, cb = a[p], b[p]
        assert ca.values.dtype == cb.values.dtype, p
        assert np.array_equal(ca.values, cb.values), p
        assert ca.vocab == cb.vocab, p


# ------------------------------------------------- backend primitive parity

@pytest.mark.parametrize("bname", ["numpy", "jax"])
def test_probe_shards_matches_per_shard(bname):
    be = get_backend(bname)
    oracle = get_backend("numpy")
    sizes = [0, 1, 31, 700, 64, 4097]
    fulls = [bitmap_full(n) for n in sizes]
    probes = [[bitmap_from_ids(
        RNG.choice(n, size=max(1, n // 2), replace=False), n)
        for _ in range(k)] if n else []
        for k, n in zip([2, 0, 1, 3, 2, 1], sizes)]
    got = be.probe_shards(fulls, probes)
    for bm, f, ps, n in zip(got, fulls, probes, sizes):
        want = oracle.intersect_bitmaps(f, ps)
        assert bm.dtype == np.uint32
        assert np.array_equal(bm, want), n


@pytest.mark.parametrize("bname", ["numpy", "jax"])
def test_compact_masks_ragged_parity(bname):
    be = get_backend(bname)
    oracle = get_backend("numpy")
    masks = [RNG.random(n) < d
             for n, d in [(0, 0.0), (1, 1.0), (317, 0.4), (5000, 0.01),
                          (64, 0.0)]]
    got = be.compact_masks(masks)
    for ids, m in zip(got, masks):
        want = oracle.compact_mask(m)
        assert ids.dtype == np.int64
        assert np.array_equal(ids, want)


@pytest.mark.parametrize("bname", ["numpy", "jax"])
def test_segment_aggregate_batched_parity(bname):
    be = get_backend(bname)
    oracle = get_backend("numpy")
    shards = [(0, 1), (1000, 7), (1, 1), (333, 12)]
    codes = [RNG.integers(-1, g, n) for n, g in shards]
    vals = [RNG.normal(50.0, 9.0, n) for n, _ in shards]
    groups = [g for _, g in shards]
    got = be.segment_aggregate_batched(codes, vals, groups)
    for (cg, sg, s2g), c, v, g in zip(got, codes, vals, groups):
        cn, sn, s2n = oracle.segment_aggregate(c, v, g)
        assert np.array_equal(cg, cn)
        assert np.array_equal(sg, sn)          # bit-equal f64 accumulation
        assert np.array_equal(s2g, s2n)


def test_aggregate_produce_batched_matches_per_shard(ragged_catalog):
    db = ragged_catalog.get("Ragged")
    flow = fdb("Ragged").aggregate(
        group(P.road).count("n").avg(m=P.speed).std_dev(s=P.speed))
    plan = plan_flow(flow, ragged_catalog)
    spec = plan.mixer_ops[0].spec
    batches = [s.batch for s in db.shards]
    for bname in ("numpy", "jax"):
        be = get_backend(bname)
        batched = aggregate_produce_batched(batches, spec, be)
        single = [aggregate_produce(b, spec, be) for b in batches]
        for pb, ps in zip(batched, single):
            assert pb.groups == ps.groups


# -------------------------------------------------- wave runner vs per-shard

QUERIES = [
    fdb("Ragged").find(BETWEEN(P.hour, 8, 17))
        .aggregate(group(P.road).count("n").avg(m=P.speed)
                   .std_dev(s=P.speed)),
    fdb("Ragged").find(BETWEEN(P.hour, 6, 20) & (P.speed > 40.0))
        .sort_desc(P.speed).limit(25),
    fdb("Ragged").find(P.city == "SF")
        .map(lambda p: proto(road=p.road, fast=p.speed > 50.0)),
    fdb("Ragged").aggregate(group(P.city).min(lo=P.speed).max(hi=P.speed)
                            .sum(tot=P.speed)),
]


@pytest.mark.parametrize("qi", range(len(QUERIES)))
def test_wave_task_matches_shard_tasks(ragged_catalog, qi):
    db = ragged_catalog.get("Ragged")
    plan = plan_flow(QUERIES[qi], ragged_catalog)
    for bname in ("numpy", "jax"):
        be = get_backend(bname)
        be.prime_fdb(db)
        parts, failed = run_wave_task(db, plan, plan.shard_ids, None,
                                      ragged_catalog, backend=be)
        assert failed == []
        singles = [run_shard_task(db, plan, sid, None, ragged_catalog,
                                  backend=be) for sid in plan.shard_ids]
        for pw, psh in zip(parts, singles):
            assert pw.shard_id == psh.shard_id
            assert pw.rows_scanned == psh.rows_scanned
            assert pw.rows_selected == psh.rows_selected
            if psh.agg is not None:
                assert pw.agg.groups == psh.agg.groups
            else:
                assert_identical(pw.batch, psh.batch)


@pytest.mark.parametrize("qi", range(len(QUERIES)))
@pytest.mark.parametrize("wave", [1, 3, 16])
def test_engine_parity_on_ragged_shards(ragged_catalog, qi, wave):
    rn = AdHocEngine(ragged_catalog, num_servers=4, backend="numpy",
                     wave=wave).collect(QUERIES[qi])
    rj = AdHocEngine(ragged_catalog, num_servers=4, backend="jax",
                     wave=wave).collect(QUERIES[qi])
    assert_identical(rn.batch, rj.batch)
    assert rn.profile.rows_scanned == rj.profile.rows_scanned
    assert rn.profile.rows_selected == rj.profile.rows_selected


def test_flume_wave_error_does_not_abort_siblings(ragged_catalog, tmp_path,
                                                  monkeypatch):
    """A wave that errors outright must not discard completed waves'
    checkpoints; its shards fall through to the per-shard machinery."""
    import repro.exec.flume as flume_mod
    real = flume_mod.run_wave_task

    def flaky(db, plan, sids, *a, **kw):
        if 0 in list(sids):
            raise RuntimeError("injected wave crash")
        return real(db, plan, sids, *a, **kw)

    monkeypatch.setattr(flume_mod, "run_wave_task", flaky)
    q = QUERIES[0]
    fl = FlumeEngine(ragged_catalog, ckpt_dir=str(tmp_path), max_workers=4,
                     backend="numpy", wave=3)
    res = fl.collect(q)
    ref = AdHocEngine(ragged_catalog, num_servers=4,
                      backend="numpy").collect(q)
    assert_identical(ref.batch, res.batch)
    # 4 shards via surviving waves + 3 via the per-shard fallback
    assert fl.stats["tasks_run"] == 7


def test_flume_wave_path_parity(ragged_catalog, tmp_path):
    q = QUERIES[0]
    ref = AdHocEngine(ragged_catalog, num_servers=4,
                      backend="numpy").collect(q)
    fl = FlumeEngine(ragged_catalog, ckpt_dir=str(tmp_path), max_workers=4,
                     backend="jax", wave=3)
    res = fl.collect(q)
    assert_identical(ref.batch, res.batch)
    assert fl.stats["tasks_run"] == 7          # one checkpoint per shard
    again = fl.collect(q)                      # recovery from wave ckpts
    assert_identical(ref.batch, again.batch)
    assert fl.stats["tasks_skipped"] >= 7


# ------------------------------------------------- launch-count contract

def test_launch_count_is_ceil_shards_over_wave(ragged_catalog, exec_pplan,
                                               monkeypatch):
    """Per query the jax path dispatches ⌈shards_p/wave⌉ stacked launches
    per primitive per partition — not one per shard.  Pinned to the legacy
    per-primitive path; the fused single-dispatch contract is in
    tests/test_fused.py (the legacy path carries no raw segment states, so
    no merge combine fires at any P)."""
    monkeypatch.setenv("REPRO_EXEC_FUSED", "0")
    db = ragged_catalog.get("Ragged")
    n_shards = db.num_shards
    wave = 3
    eng = AdHocEngine(ragged_catalog, num_servers=2, backend="jax",
                      wave=wave)
    q = (fdb("Ragged").find(BETWEEN(P.hour, 8, 17))
         .aggregate(group(P.road).count("n").avg(m=P.speed)))
    eng.collect(q)                             # warm: prime + plan caches
    ops.reset_launch_counts()
    eng.collect(q)
    lc = ops.launch_counts()
    waves = exec_pplan(n_shards, eng.backend).wave_dispatches(wave)
    assert lc.get("bitmap_intersect_batched") == waves
    assert lc.get("compact_batched") == waves            # selection compact
    assert lc.get("segment_agg") == waves                # one value column
    # nothing fell back to per-shard dispatch
    assert lc.get("bitmap_intersect", 0) == 0
    assert lc.get("compact", 0) == 0
    # and the whole query is O(waves), not O(shards)
    assert sum(lc.values()) == 3 * waves < 3 * n_shards


def test_wave_size_resolution(ragged_catalog, monkeypatch):
    monkeypatch.delenv("REPRO_EXEC_WAVE", raising=False)
    assert wave_size() == 8
    assert wave_size(3) == 3
    monkeypatch.setenv("REPRO_EXEC_WAVE", "5")
    assert wave_size() == 5
    assert wave_size(2) == 2                   # explicit arg wins over env
    assert partition_waves(range(7), 3) == [[0, 1, 2], [3, 4, 5], [6]]
    # backend default: wide waves only when batched ops amortize launches;
    # the loop-over-shards numpy backend keeps per-shard parallelism
    monkeypatch.delenv("REPRO_EXEC_WAVE")
    assert AdHocEngine(ragged_catalog, backend="jax").wave == 8
    assert AdHocEngine(ragged_catalog, backend="numpy").wave == 1
    assert AdHocEngine(ragged_catalog, backend="numpy", wave=4).wave == 4


# ------------------------------------------------- device-resident columns

def test_device_cache_primed_once_and_hit(ragged_catalog, monkeypatch):
    # legacy path: the fused agg pipeline reads its own stacked buffers
    # and never issues the per-column gathers this test counts as hits
    monkeypatch.setenv("REPRO_EXEC_FUSED", "0")
    db = ragged_catalog.get("Ragged")
    be = JaxBackend()
    n_buffers = be.prime_fdb(db)
    # every shard: 4 dense columns + valid-doc bitmap (empty shard incl.)
    assert n_buffers == len(be.device_cache) == db.num_shards * 5
    assert be.prime_fdb(db) == 0               # idempotent per FDb open
    before = be.device_cache.hits
    eng = AdHocEngine(ragged_catalog, num_servers=2, backend=be)
    res = eng.collect(fdb("Ragged").find(BETWEEN(P.hour, 8, 17))
                      .aggregate(group(P.road).count("n")))
    assert res.batch.n > 0
    assert be.device_cache.hits > before       # gathers hit resident bufs
    stats = be.device_cache.stats()
    assert stats["buffers"] == n_buffers and stats["nbytes"] > 0


def test_device_cache_evicts_collected_fdb():
    db = _ragged_db(num_shards=3, empty_shard=2, rows=60)
    be = JaxBackend()
    assert be.prime_fdb(db) == len(be.device_cache) > 0
    del db                                     # finalizer drops buffers
    assert len(be.device_cache) == 0


def test_device_cache_refcounts_shared_shards():
    """StreamingFDb snapshots share flushed Shards: buffers must survive
    until the *last* FDb referencing them is collected, and stay usable."""
    from repro.fdb.fdb import FDb
    db1 = _ragged_db(num_shards=3, empty_shard=2, rows=60)
    db2 = FDb("RaggedView", db1.schema, db1.shards)     # shares Shards
    be = JaxBackend()
    n = be.prime_fdb(db1)
    assert n == len(be.device_cache) > 0
    assert be.prime_fdb(db2) == 0              # same buffers, new refs
    shard = db1.shards[0]
    del db1                                    # db2 still references all
    assert len(be.device_cache) == n
    assert be.device_cache.get(shard.batch["speed"].values) is not None
    del db2
    assert len(be.device_cache) == 0


def test_device_gather_parity_with_host(ragged_catalog):
    db = ragged_catalog.get("Ragged")
    be = JaxBackend()
    be.prime_fdb(db)
    shard = db.shards[0]
    ids = np.sort(RNG.choice(shard.n, size=shard.n // 2, replace=False))
    paths = shard.batch.paths()
    dev = be.gather_columns(shard.batch, paths, ids)
    host = shard.batch.select_paths(paths).gather(ids)
    assert_identical(dev, host)
