"""Dwell/count reduction analytics, distinct_approx, to_dataset, and the
consolidated ExecConfig: edge-case semantics on handcrafted tracks (empty
tracks, tied timestamps, dwell exactly at threshold, k = 0 / k > hits),
numpy ≡ jax byte parity at word-boundary shard sizes with and without the
fused path, the launch contract (reductions ride the existing wave
dispatches), partition invariance of the HyperLogLog lowering, and the
time-to-trained-model hand-off."""
import numpy as np
import pytest

from repro.core import P, fdb, group, proto
from repro.exec import AdHocEngine, Catalog, ExecConfig, get_backend
from repro.fdb import build_fdb
from repro.fdb.schema import Field, Schema, DOUBLE, INT, STRING, MESSAGE
from repro.geo import AreaTree, mercator as M
from repro.kernels import ops
from repro.tess import Tesseract

pytestmark = pytest.mark.tesseract


# ------------------------------------------------------------ handcrafted db

PA, PB = (37.40, -122.40), (37.60, -122.20)


def _pt_region(latlng, d=100_000):
    ix, iy = M.latlng_to_xy(*latlng)
    return AreaTree.from_box(int(ix) - d, int(iy) - d,
                             int(ix) + d, int(iy) + d, max_level=7)


def _track(*pts):
    return {"lat": [p[0][0] for p in pts], "lng": [p[0][1] for p in pts],
            "t": [float(p[1]) for p in pts]}


def _track_schema(name="Visits") -> Schema:
    return Schema(name, [
        Field("id", INT, indexes=("tag",)),
        Field("track", MESSAGE, fields=[
            Field("lat", DOUBLE, repeated=True),
            Field("lng", DOUBLE, repeated=True),
            Field("t", DOUBLE, repeated=True)],
            indexes=("spacetime",),
            index_params={"level": 6, "bucket_s": 900.0, "epoch": 0.0}),
    ])


#: every reduction edge case in one fixture: id → (track, A-hits, A-span)
_CASES = [
    _track(),                                             # 0: empty track
    _track((PA, 100.0)),                                  # 1: single A hit
    _track((PA, 100.0), (PA, 200.0), (PA, 300.0)),        # 2: 3 hits, span 200
    _track((PA, 100.0), (PA, 100.0), (PA, 100.0)),        # 3: tied ts, span 0
    _track((PA, 100.0), (PA, 400.0)),                     # 4: span exactly 300
    _track((PB, 100.0)),                                  # 5: B only
    _track((PA, 100.0), (PB, 200.0)),                     # 6: A and B
]


@pytest.fixture(scope="module")
def visits_db():
    recs = [{"id": i, "track": tr} for i, tr in enumerate(_CASES)]
    sizes = [4, 0, 3]                 # incl. an empty shard
    bounds = np.cumsum([0] + sizes)
    key = lambda r: int(np.searchsorted(bounds, r["id"], "right") - 1)
    db = build_fdb("Visits", _track_schema(), recs,
                   num_shards=len(sizes), shard_key=key)
    assert [s.n for s in db.shards] == sizes
    return db


def _select(db, tess, backend, fused, wave=2, partitions=None):
    cat = Catalog(server_slots=4)
    cat.register(db)
    eng = AdHocEngine(cat, backend=backend, wave=wave,
                      partitions=partitions,
                      config=ExecConfig(fused=fused))
    res = eng.collect(fdb(db.name).tesseract(tess).map(
        lambda p: proto(id=p.id)))
    return sorted(res.batch["id"].values.tolist())


#: (tesseract builder, expected ids) — handcrafted reduction verdicts
_SCENARIOS = [
    # count ≥ 2 distinct window hits (id4 has 2, id2/3 have 3)
    (lambda A, B: Tesseract(A, 0.0, 1000.0).at_least(2), [2, 3, 4]),
    # k > hits: nothing reaches 4
    (lambda A, B: Tesseract(A, 0.0, 1000.0).at_least(4), []),
    # k = 0 alone is vacuous: every doc passes, empty track included
    (lambda A, B: Tesseract(A, 0.0, 1000.0).at_least(0),
     [0, 1, 2, 3, 4, 5, 6]),
    # k = 0 on A composed with a real B constraint: verdict is B's
    (lambda A, B: Tesseract(A, 0.0, 1000.0).at_least(0)
     .also(B, 0.0, 1000.0), [5, 6]),
    # dwell exactly at the threshold is inclusive (id4 span == 300)
    (lambda A, B: Tesseract(A, 0.0, 1000.0).dwell(300.0), [4]),
    # just past the exact span: id4 drops
    (lambda A, B: Tesseract(A, 0.0, 1000.0).dwell(300.5), []),
    # dwell 0 still requires a hit: tied timestamps (span 0) pass,
    # empty/B-only tracks don't
    (lambda A, B: Tesseract(A, 0.0, 1000.0).dwell(0.0), [1, 2, 3, 4, 6]),
    # dwell + count compose on one constraint
    (lambda A, B: Tesseract(A, 0.0, 1000.0).at_least(3).dwell(150.0), [2]),
]


@pytest.mark.parametrize("case", range(len(_SCENARIOS)))
@pytest.mark.parametrize("backend", ["numpy", "jax"])
@pytest.mark.parametrize("fused", [True, False])
def test_reduction_semantics(visits_db, case, backend, fused):
    """Handcrafted count/dwell verdicts hold on both backends, fused and
    legacy per-primitive paths alike."""
    build, want = _SCENARIOS[case]
    got = _select(visits_db, build(_pt_region(PA), _pt_region(PB)),
                  backend, fused)
    assert got == want, (case, backend, fused)


def test_reduction_partition_invariance(visits_db):
    """P = 2 splits the shard axis; reduction verdicts are unchanged."""
    tess = Tesseract(_pt_region(PA), 0.0, 1000.0).at_least(2).also(
        _pt_region(PB), 0.0, 1000.0).dwell(0.0)
    for backend in ("numpy", "jax"):
        base = _select(visits_db, tess, backend, True, partitions=1)
        assert _select(visits_db, tess, backend, True,
                       partitions=2) == base


# ------------------------------------------- word-boundary analytics parity

RNG = np.random.default_rng(29)


def _walks(n, rng, empty_every=7):
    recs = []
    for i in range(n):
        ln = 0 if (empty_every and i % empty_every == 0) \
            else int(rng.integers(1, 14))
        lat = rng.uniform(37.2, 38.0, ln)
        lng = rng.uniform(-122.6, -121.8, ln)
        t = np.sort(rng.uniform(0.0, 3 * 86400.0, ln))
        recs.append({"id": i, "track": {"lat": lat.tolist(),
                                        "lng": lng.tolist(),
                                        "t": t.tolist()}})
    return recs


def _region(rng, d=2_000_000):
    ix, iy = M.latlng_to_xy(rng.uniform(37.2, 38.0),
                            rng.uniform(-122.6, -121.8))
    return AreaTree.from_box(int(ix) - d, int(iy) - d,
                             int(ix) + d, int(iy) + d, max_level=7)


@pytest.fixture(scope="module")
def walks_db():
    sizes = [32, 31, 64, 65, 1, 0, 33]    # 32-bit word boundaries + empty
    recs = _walks(sum(sizes), RNG)
    bounds = np.cumsum([0] + sizes)
    key = lambda r: int(np.searchsorted(bounds, r["id"], "right") - 1)
    db = build_fdb("Walks", _track_schema("Walks"), recs,
                   num_shards=len(sizes), shard_key=key)
    assert [s.n for s in db.shards] == sizes
    return db


def test_analytics_tables_batched_parity(walks_db):
    """Wave-stacked analytics (mask + first/last/count tables) byte-equal
    across backends at word-boundary shard sizes, with candidates."""
    rng = np.random.default_rng(3)
    cons = [(_region(rng), 0.0, 2 * 86400.0),
            (_region(rng), 43200.0, 3 * 86400.0)]
    batches = [s.batch for s in walks_db.shards]
    cands = [rng.random(b.n) < 0.8 for b in batches]
    outs = {}
    for bname in ("numpy", "jax"):
        be = get_backend(bname)
        be.prime_fdb(walks_db)
        outs[bname] = be.refine_tracks_batched(
            batches, "track", cons, cands, min_counts=(2, 1),
            dwells=(None, 600.0), with_analytics=True)
    for part in range(4):                 # masks, firsts, lasts, counts
        for a, b in zip(outs["numpy"][part], outs["jax"][part]):
            assert a.dtype == b.dtype
            assert np.array_equal(a, b), part
    masks = outs["numpy"][0]
    assert any(m.any() for m in masks)    # non-vacuous evidence


def test_reduction_launch_contract(walks_db, exec_pplan):
    """Count/dwell reductions ride the existing fused wave dispatches —
    zero extra launches versus a plain trip query."""
    cat = Catalog(server_slots=4)
    cat.register(walks_db)
    rng = np.random.default_rng(7)
    tess = Tesseract(_region(rng), 0.0, 2 * 86400.0).at_least(2).also(
        _region(rng), 43200.0, 3 * 86400.0).dwell(600.0)
    flow = fdb("Walks").tesseract(tess).map(lambda p: proto(id=p.id))
    wave = 3
    eng = AdHocEngine(cat, backend="jax", wave=wave,
                      config=ExecConfig(fused=True))
    eng.collect(flow)                     # warm (jit compile)
    ops.reset_launch_counts()
    eng.collect(flow)
    lc = ops.launch_counts()
    waves = exec_pplan(walks_db.num_shards,
                       eng.backend).wave_dispatches(wave)
    assert lc.get("run_wave_fused") == waves
    assert lc.get("refine_tracks_batched", 0) == 0
    assert lc.get("refine_tracks", 0) == 0


# ------------------------------------------------- Tesseract label plumbing

def test_labels_and_before():
    A, B = _pt_region(PA), _pt_region(PB)
    by_label = (Tesseract(A, 0.0, 1000.0, label="home")
                .also(B, 0.0, 1000.0, label="work").before("home", "work"))
    by_index = (Tesseract(A, 0.0, 1000.0)
                .also(B, 0.0, 1000.0).before(0, 1))
    assert by_label.order_edges == by_index.order_edges == ((0, 1),)
    # selectors also resolve for reductions, by label or index
    t = (Tesseract(A, 0.0, 1000.0, label="home")
         .also(B, 0.0, 1000.0, label="work")
         .at_least(2, "home").dwell(60.0, 1))
    assert t.min_counts == (2, 1)
    assert t.dwells == (None, 60.0)
    with pytest.raises(ValueError):
        Tesseract(A, 0.0, 1000.0, label="home").before("home", "gym")


# --------------------------------------------------- distinct_approx (HLL)

@pytest.fixture(scope="module")
def events_db():
    schema = Schema("Events", [
        Field("id", INT, indexes=("tag",)),
        Field("day", INT, indexes=("tag",)),
        Field("city", STRING, indexes=("tag",)),
    ])
    rng = np.random.default_rng(41)
    cities = ["SF", "Berkeley", "Oakland", "Fremont", "LA"]
    recs = [{"id": int(i), "day": int(rng.integers(0, 3)),
             "city": cities[int(rng.integers(0, len(cities)))]}
            for i in range(600)]
    return recs, build_fdb("Events", schema, recs, num_shards=7)


def test_distinct_approx_matches_hll_oracle(events_db):
    """Grouped approx_distinct through the segment-max lowering equals a
    per-group HyperLogLog built directly from the raw values."""
    from repro.core.sketches import HyperLogLog
    recs, db = events_db
    cat = Catalog(server_slots=4)
    cat.register(db)
    res = AdHocEngine(cat, backend="numpy").collect(
        fdb("Events").aggregate(group(P.day).approx_distinct(
            "n_cities", expr=P.city)))
    got = {int(d): float(v) for d, v in zip(res.batch["day"].values,
                                            res.batch["n_cities"].values)}
    for day in sorted(got):
        strs = [r["city"] for r in recs if r["day"] == day]
        want = HyperLogLog().add(np.arange(len(strs)),
                                 vocab=strs).estimate()
        assert got[day] == pytest.approx(want, abs=1e-9)


def test_distinct_approx_partition_and_backend_invariant(events_db):
    """Flow.distinct_approx: identical estimate at P = 1/2/4 on both
    backends (register max is commutative + idempotent)."""
    _, db = events_db
    cat = Catalog(server_slots=4)
    cat.register(db)
    flow = fdb("Events").distinct_approx(P.id, name="n_ids")
    ests = set()
    for backend in ("numpy", "jax"):
        for parts in (1, 2, 4):
            eng = AdHocEngine(cat, backend=backend, wave=3,
                              partitions=parts)
            res = eng.collect(flow)
            assert res.batch.n == 1
            ests.add(float(res.batch["n_ids"].values[0]))
    assert len(ests) == 1
    est = ests.pop()
    assert abs(est - 600) / 600 < 0.1


# --------------------------------------------- to_dataset → trained model

def test_to_dataset_trains_end_to_end():
    schema = Schema("Obs", [
        Field("id", INT, indexes=("tag",)),
        Field("x", DOUBLE),
        Field("y", DOUBLE),
        Field("split", INT, indexes=("tag",)),
    ])
    rng = np.random.default_rng(5)
    x = rng.uniform(-2.0, 2.0, 400)
    y = 3.0 * x + 1.0 + rng.normal(0.0, 0.05, x.size)
    recs = [{"id": int(i), "x": float(a), "y": float(b),
             "split": int(i % 4 != 0)}
            for i, (a, b) in enumerate(zip(x, y))]
    cat = Catalog(server_slots=4)
    cat.register(build_fdb("Obs", schema, recs, num_shards=5))
    eng = AdHocEngine(cat, backend="numpy")

    ds = (fdb("Obs").find(P.split == 1)
          .to_dataset(features={"x": P.x}, target=P.y, engine=eng))
    assert len(ds) == sum(1 for r in recs if r["split"] == 1)
    assert ds.feature_names == ["x"] and ds.num_features == 1

    tr, te = ds.split(frac=0.8, seed=0)
    assert len(tr) + len(te) == len(ds) and len(te) > 0
    fb, tb = next(iter(tr.batches(32)))
    assert fb.shape == (32, 1) and tb.shape == (32,)

    model, losses = ds.fit(hidden=16, depth=1, steps=200, lr=5e-2,
                           batch=128)
    assert losses[-1] < losses[0] * 0.5        # actually learned
    pred = model.as_column_model(["x"]).apply_columns(
        {"x": np.array([0.0, 1.0])})
    assert pred[0] == pytest.approx(1.0, abs=0.5)
    assert pred[1] == pytest.approx(4.0, abs=0.5)

    # sequence-of-fields form infers names from the field refs
    ds2 = fdb("Obs").to_dataset(features=[P.x], target=P.y, engine=eng)
    assert ds2.feature_names == ["x"] and len(ds2) == len(recs)


# ------------------------------------------------------------- ExecConfig

def test_exec_config_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_EXEC_BACKEND", raising=False)
    monkeypatch.delenv("REPRO_EXEC_WAVE", raising=False)
    monkeypatch.delenv("REPRO_EXEC_FUSED", raising=False)
    monkeypatch.delenv("REPRO_EXEC_PROFILE", raising=False)
    # defaults
    cfg = ExecConfig()
    assert type(cfg.resolve_backend()).__name__ == "NumpyBackend"
    assert cfg.resolved_fused() is True
    assert cfg.resolved_profile() is False
    # env fallback when the field is unset
    monkeypatch.setenv("REPRO_EXEC_FUSED", "0")
    monkeypatch.setenv("REPRO_EXEC_PROFILE", "1")
    monkeypatch.setenv("REPRO_EXEC_WAVE", "5")
    assert ExecConfig().resolved_fused() is False
    assert ExecConfig().resolved_profile() is True
    assert ExecConfig().resolve_wave() == 5
    # explicit field beats the env
    assert ExecConfig(fused=True).resolved_fused() is True
    assert ExecConfig(profile=False).resolved_profile() is False
    assert ExecConfig(wave=2).resolve_wave() == 2
    # legacy kwargs fill only unset fields
    filled = ExecConfig(wave=4).fill(wave=9, backend="jax")
    assert filled.wave == 4 and filled.backend == "jax"


def test_exec_config_engine_shims(events_db, monkeypatch):
    """Engines accept config=, legacy kwargs keep working, and an
    explicit fused=True overrides REPRO_EXEC_FUSED=0."""
    _, db = events_db
    cat = Catalog(server_slots=4)
    cat.register(db)
    flow = fdb("Events").find(P.day == 1).map(lambda p: proto(id=p.id))
    want = sorted(AdHocEngine(cat, backend="numpy").collect(
        flow).batch["id"].values.tolist())

    eng = AdHocEngine(cat, config=ExecConfig(backend="jax", wave=2,
                                             partitions=2))
    assert eng.wave == 2 and eng.partitions == 2
    assert sorted(eng.collect(flow).batch["id"].values.tolist()) == want

    monkeypatch.setenv("REPRO_EXEC_FUSED", "0")
    eng2 = AdHocEngine(cat, config=ExecConfig(backend="jax", fused=True))
    eng2.collect(flow)                    # warm
    ops.reset_launch_counts()
    eng2.collect(flow)
    assert ops.launch_counts().get("run_wave_fused", 0) > 0

    # legacy kwarg form still resolves identically
    eng3 = AdHocEngine(cat, backend="jax", wave=2)
    assert eng3.wave == 2
    assert sorted(eng3.collect(flow).batch["id"].values.tolist()) == want
