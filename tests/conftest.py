"""Shared fixtures: a small synthetic spatiotemporal world + catalog.

NOTE: no XLA_FLAGS here — tests must see the real single CPU device; only
launch/dryrun.py forces 512 host devices (see the dry-run contract).
"""
import numpy as np
import pytest

from repro.fdb import (Schema, build_fdb, DOUBLE, INT, STRING, MESSAGE)
from repro.fdb.schema import Field
from repro.exec import Catalog, AdHocEngine


@pytest.fixture
def exec_pplan():
    """Partition-aware launch-contract arithmetic: the PartitionPlan the
    engine resolves for ``n_shards`` (pruned) shards under the env-resolved
    partition count — the ``REPRO_EXEC_PARTITIONS=2`` CI leg changes the
    expected dispatch counts, so contracts must compute them through the
    same ``PartitionPlan`` helpers the scheduler uses."""
    from repro.core.planner import num_partitions, partition_shards

    def _pp(n_shards, backend=None):
        return partition_shards(range(int(n_shards)),
                                num_partitions(backend=backend))
    return _pp


@pytest.fixture(scope="session")
def world():
    """Deterministic mini world: roads + speed observations (paper §6)."""
    rng = np.random.default_rng(7)
    roads_schema = Schema("Roads", [
        Field("id", INT, indexes=("tag",)),
        Field("city", STRING, indexes=("tag",)),
        Field("loc", MESSAGE, fields=[Field("lat", DOUBLE),
                                      Field("lng", DOUBLE)],
              indexes=("location",)),
        Field("polyline", MESSAGE, fields=[
            Field("lat", DOUBLE, repeated=True),
            Field("lng", DOUBLE, repeated=True)],
            indexes=("area",), index_params={"level": 6, "width_m": 30.0}),
        Field("speed_limit", DOUBLE, indexes=("range",)),
    ])
    roads = []
    for i in range(300):
        lat = 37.70 + rng.uniform(0, 0.12)
        lng = -122.52 + rng.uniform(0, 0.14)
        roads.append({
            "id": i, "city": "SF" if lat < 37.78 else "OAK",
            "loc": {"lat": lat, "lng": lng},
            "polyline": {"lat": [lat, lat + 5e-4, lat + 1e-3],
                         "lng": [lng, lng + 5e-4, lng + 1e-3]},
            "speed_limit": float(rng.uniform(20, 80))})
    obs_schema = Schema("Obs", [
        Field("road_id", INT, indexes=("tag",)),
        Field("hour", INT, indexes=("range",)),
        Field("dow", INT, indexes=("range",)),
        Field("speed", DOUBLE),
    ])
    obs = [{"road_id": int(rng.integers(0, 300)),
            "hour": int(rng.integers(0, 24)),
            "dow": int(rng.integers(0, 7)),
            "speed": float(rng.normal(48, 9))} for _ in range(4000)]
    return {"roads": roads, "obs": obs,
            "roads_schema": roads_schema, "obs_schema": obs_schema}


@pytest.fixture(scope="session")
def catalog(world):
    cat = Catalog(server_slots=16)
    cat.register(build_fdb("Roads", world["roads_schema"], world["roads"],
                           num_shards=5))
    cat.register(build_fdb("Obs", world["obs_schema"], world["obs"],
                           num_shards=5))
    return cat


@pytest.fixture(scope="session")
def engine(catalog):
    return AdHocEngine(catalog, num_servers=5)
