"""Structural dry-run check on a tiny forced-device mesh.

The full 512-device sweep runs via ``python -m repro.launch.dryrun`` (see
EXPERIMENTS §Dry-run).  This test proves the machinery — forced host
devices, mesh build, pjit lowering with our shardings, HLO analysis — in a
*subprocess* (the device count must be set before jax initializes, which
pytest's process already did)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from dataclasses import replace
from repro.configs.base import SHAPES, ShapeConfig, get_config
from repro.ml.model import ModelBundle, TrainConfig
from repro.launch.hlo_analysis import analyze_hlo

mesh = jax.make_mesh((2, 4), ("data", "model"))
assert len(jax.devices()) == 8

cfg = get_config("qwen1_5_0_5b").reduced()
shape = ShapeConfig("tiny_train", 64, 8, "train")
mb = ModelBundle(cfg, mesh, impl="reference",
                 train_cfg=TrainConfig(remat="full", loss_chunk=32,
                                       zero1=True))
lowered = mb.lower_train(shape)
compiled = lowered.compile()
mem = compiled.memory_analysis()
res = analyze_hlo(compiled.as_text())
print(json.dumps({
    "temp_bytes": mem.temp_size_in_bytes,
    "flops": res["flops_per_device"],
    "coll": res["collective_bytes"],
    "warnings": len(res["warnings"]),
}))

# decode path too
shape_d = ShapeConfig("tiny_decode", 64, 8, "decode")
mb.lower_decode(shape_d).compile()
print("DECODE_OK")
"""


@pytest.mark.slow
def test_dryrun_machinery_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=500,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    line = out.stdout.strip().splitlines()
    stats = json.loads(line[0])
    assert stats["flops"] > 0
    assert stats["coll"] > 0          # model-axis TP must communicate
    assert "DECODE_OK" in out.stdout
