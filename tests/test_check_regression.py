"""The bench-regression gate script: pass/fail semantics, the --suite
multi-pair mode, and the readable row-set-mismatch diff (regression:
missing rows used to be silently informational; as a blocking CI gate
they must fail instead — and never crash with a KeyError)."""
import json

import pytest

from benchmarks import check_regression


def _write(path, rows, suite="backends"):
    path.write_text(json.dumps({"suite": suite, "scale": 0.05,
                                "rows": rows}))
    return str(path)


def _row(name, us, **kw):
    return {"name": name, "us_per_call": us, "derived": "", **kw}


def test_pass_and_threshold_fail(tmp_path, capsys):
    base = _write(tmp_path / "base.json",
                  [_row("q1", 1000.0), _row("q2", 2000.0)])
    ok = _write(tmp_path / "ok.json",
                [_row("q1", 1400.0), _row("q2", 1000.0)])
    assert check_regression.main(["--current", ok, "--baseline",
                                  base]) == 0
    slow = _write(tmp_path / "slow.json",
                  [_row("q1", 1600.0), _row("q2", 2000.0)])
    assert check_regression.main(["--current", slow, "--baseline",
                                  base]) == 1
    assert "REGRESSION" in capsys.readouterr().out


def test_min_us_rows_are_informational(tmp_path):
    base = _write(tmp_path / "base.json", [_row("tiny", 10.0)])
    cur = _write(tmp_path / "cur.json", [_row("tiny", 400.0)])
    assert check_regression.main(["--current", cur, "--baseline",
                                  base]) == 0


def test_missing_row_fails_with_readable_diff(tmp_path, capsys):
    """A query on one side only must fail with a two-column diff (not
    crash, not silently pass) — either direction."""
    base = _write(tmp_path / "base.json",
                  [_row("q1", 1000.0), _row("gone", 1000.0)])
    cur = _write(tmp_path / "cur.json",
                 [_row("q1", 1000.0), _row("new", 1000.0)])
    assert check_regression.main(["--current", cur, "--baseline",
                                  base]) == 1
    err = capsys.readouterr().err
    assert "row-set mismatch" in err
    assert "- gone" in err and "missing from current run" in err
    assert "+ new" in err and "missing from baseline" in err


def test_non_numeric_rows_never_match(tmp_path):
    """Parity-summary rows (us_per_call == \"\") stay out of the row-set
    comparison entirely."""
    base = _write(tmp_path / "base.json",
                  [_row("q1", 1000.0), _row("parity_all", "")])
    cur = _write(tmp_path / "cur.json", [_row("q1", 1000.0)])
    assert check_regression.main(["--current", cur, "--baseline",
                                  base]) == 0


def test_suite_mode(tmp_path, capsys):
    """--suite a,b resolves BENCH_<s>.json in both dirs and fails if any
    pair fails or a file is missing."""
    cur_dir, base_dir = tmp_path / "cur", tmp_path / "base"
    cur_dir.mkdir(), base_dir.mkdir()
    for d in (cur_dir, base_dir):
        _write(d / "BENCH_a.json", [_row("qa", 1000.0)], suite="a")
        _write(d / "BENCH_b.json", [_row("qb", 1000.0)], suite="b")
    args = ["--current-dir", str(cur_dir), "--baseline-dir", str(base_dir)]
    assert check_regression.main(["--suite", "a,b", *args]) == 0
    _write(cur_dir / "BENCH_b.json", [_row("qb", 9000.0)], suite="b")
    assert check_regression.main(["--suite", "a,b", *args]) == 1
    assert check_regression.main(["--suite", "a", *args]) == 0
    assert check_regression.main(["--suite", "a,missing", *args]) == 1
    assert "MISSING FILE" in capsys.readouterr().err


def test_no_args_defaults_to_registry(tmp_path, capsys):
    """Bare invocation compares the blocking set from the
    benchmarks/suites.py registry (same table run.py --only reads) —
    an empty current dir fails on every suite, it is not an arg error."""
    from benchmarks.suites import REGRESSION_SUITES

    assert check_regression.main(["--current-dir", str(tmp_path)]) == 1
    err = capsys.readouterr().err
    for suite in REGRESSION_SUITES:
        assert f"BENCH_{suite}.json" in err
    assert "analytics" in REGRESSION_SUITES


def test_arg_validation():
    with pytest.raises(SystemExit):
        check_regression.main(["--suite", "a", "--current", "x",
                               "--baseline", "y"])
    with pytest.raises(SystemExit):
        check_regression.main(["--current", "x"])
