"""FDb: columnar batches, every index kind vs brute force, persistence."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: fall back to shim
    from _hypothesis_shim import given, settings, st

from repro.fdb import (FDb, Schema, StreamingFDb, build_fdb,
                       bitmap_count, ids_from_bitmap, DOUBLE, INT, STRING,
                       MESSAGE)
from repro.fdb.columnar import Column, ColumnBatch
from repro.fdb.schema import Field
from repro.geo import AreaTree, mercator as M


def test_columnar_roundtrip():
    schema = Schema.dynamic("t", {
        "a": INT, "b": DOUBLE, "s": STRING, "v": (DOUBLE, True),
        "m.x": INT})
    recs = [{"a": 1, "b": 2.5, "s": "x", "v": [1.0, 2.0], "m": {"x": 7}},
            {"a": 2, "b": -1.0, "s": "y", "v": [], "m": {"x": 8}},
            {"a": 3, "b": 0.0, "s": "x", "v": [3.0], "m": {"x": 9}}]
    cb = ColumnBatch.from_records(schema, recs)
    assert cb.to_records() == recs
    # gather preserves ragged structure
    g = cb.gather(np.array([2, 0]))
    assert g.to_records() == [recs[2], recs[0]]
    # concat with distinct vocabs remaps codes
    c2 = ColumnBatch.concat([cb, g])
    assert [r["s"] for r in c2.to_records()] == ["x", "y", "x", "x", "x"]


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                max_size=200),
       st.floats(-50, 50), st.floats(0, 60))
@settings(max_examples=50, deadline=None)
def test_range_index_matches_brute_force(vals, lo, width):
    from repro.fdb.index import RangeIndex
    hi = lo + width
    arr = np.asarray(vals)
    idx = RangeIndex.build(arr, len(vals))
    got = set(ids_from_bitmap(idx.lookup(lo, hi), len(vals)).tolist())
    want = set(np.nonzero((arr >= lo) & (arr <= hi))[0].tolist())
    assert got == want


def test_tag_index(world):
    db = build_fdb("R2", world["roads_schema"], world["roads"],
                   num_shards=3)
    for shard in db.shards:
        decoded = shard.batch["city"].decode()
        for city in ("SF", "OAK"):
            ids = ids_from_bitmap(shard.index("city", "tag").lookup(city),
                                  shard.n)
            assert set(ids) == {i for i in range(shard.n)
                                if decoded[i] == city}


def test_location_index_exactness(world):
    db = build_fdb("R3", world["roads_schema"], world["roads"],
                   num_shards=2)
    lat0, lat1, lng0, lng1 = 37.72, 37.79, -122.50, -122.42
    ix, iy = M.latlng_to_xy(np.array([lat0, lat1]),
                            np.array([lng0, lng1]))
    region = AreaTree.from_box(int(ix[0]), int(iy[1]), int(ix[1]),
                               int(iy[0]), max_level=9)
    for shard in db.shards:
        got = set(ids_from_bitmap(
            shard.index("loc", "location").lookup(region), shard.n))
        lats = shard.batch["loc.lat"].values
        lngs = shard.batch["loc.lng"].values
        want = set(np.nonzero((lats >= lat0) & (lats <= lat1)
                              & (lngs >= lng0) & (lngs <= lng1))[0])
        # conservative cover may add boundary docs but never drops any
        assert got >= want
        assert len(got) <= len(want) + 5


def test_area_index_selects_nearby_paths(world):
    db = build_fdb("R4", world["roads_schema"], world["roads"],
                   num_shards=1)
    shard = db.shards[0]
    # region around one road's polyline must select that road
    r = world["roads"][0]
    ix, iy = M.latlng_to_xy(r["polyline"]["lat"][0], r["polyline"]["lng"][0])
    region = AreaTree.from_circle(int(ix), int(iy), 500.0, max_level=7)
    bm = shard.index("polyline", "area").lookup_region(region)
    sel = set(ids_from_bitmap(bm, shard.n))
    road_row = shard.batch["id"].values.tolist().index(0)
    assert road_row in sel
    # points query
    bm2 = shard.index("polyline", "area").lookup_points(
        [r["polyline"]["lat"][1]], [r["polyline"]["lng"][1]])
    assert road_row in set(ids_from_bitmap(bm2, shard.n))


def test_virtual_field_index():
    schema = Schema("V", [
        Field("speed", DOUBLE),
        Field("bucket", INT, indexes=("range",),
              virtual=lambda cols: (cols["speed"].values // 10
                                    ).astype(np.int64)),
    ])
    recs = [{"speed": float(s)} for s in range(0, 100, 7)]
    db = build_fdb("V", schema, recs, num_shards=1)
    shard = db.shards[0]
    ids = ids_from_bitmap(shard.index("bucket", "range").lookup(3, 4),
                          shard.n)
    speeds = shard.batch["speed"].values
    assert set(ids) == set(np.nonzero((speeds >= 30) & (speeds < 50))[0])
    # virtual fields are never materialized
    assert "bucket" not in shard.batch.columns


def test_save_load_roundtrip(tmp_path, world):
    db = build_fdb("R5", world["roads_schema"], world["roads"],
                   num_shards=3)
    db.save(str(tmp_path))
    db2 = FDb.load(str(tmp_path))
    assert db2.num_docs == db.num_docs
    s, s2 = db.shards[1], db2.shards[1]
    assert np.array_equal(s2.index("city", "tag").lookup("SF"),
                          s.index("city", "tag").lookup("SF"))
    assert np.allclose(s2.batch["speed_limit"].values,
                       s.batch["speed_limit"].values)


def test_minimal_viable_schema(world):
    schema = world["roads_schema"]
    mvs = schema.minimal_viable(["loc.lat", "speed_limit"])
    assert mvs.has("loc.lat") and mvs.has("speed_limit")
    assert not mvs.has("polyline.lat") and not mvs.has("city")
    assert mvs.node_count() < schema.node_count()


def test_streaming_fdb():
    schema = Schema("log", [Field("q", STRING, indexes=("tag",)),
                            Field("ms", DOUBLE)])
    s = StreamingFDb("log", schema, flush_threshold=8)
    for i in range(20):
        s.append({"q": f"q{i % 2}", "ms": float(i)})
    snap = s.snapshot()
    assert snap.num_docs == 20
    assert snap.num_shards == 3          # 2 flushed + memtable
    total = sum(bitmap_count(sh.index("q", "tag").lookup("q0"))
                for sh in snap.shards)
    assert total == 10
