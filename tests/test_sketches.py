"""HLL / Bloom / interval sketches: accuracy + mergeability properties."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: fall back to shim
    from _hypothesis_shim import given, settings, st

from repro.core.sketches import BloomFilter, HyperLogLog, IntervalSet


@pytest.mark.parametrize("n", [100, 5_000, 100_000])
def test_hll_accuracy(n):
    h = HyperLogLog(p=12)
    h.add(np.arange(n, dtype=np.int64))
    est = h.estimate()
    assert abs(est - n) / n < 0.06       # p=12 → σ ≈ 1.6%


def test_hll_merge_equals_union():
    a = HyperLogLog()
    b = HyperLogLog()
    a.add(np.arange(0, 6000, dtype=np.int64))
    b.add(np.arange(4000, 10000, dtype=np.int64))
    u = HyperLogLog()
    u.add(np.arange(10000, dtype=np.int64))
    a.merge(b)
    assert abs(a.estimate() - u.estimate()) < 1e-9   # identical registers


def test_hll_string_hashing_stable_across_shards():
    # shard-local vocab codes differ; hashes must come from the strings
    a = HyperLogLog().add(np.array([0, 1]), vocab=["x", "y"])
    b = HyperLogLog().add(np.array([1, 0]), vocab=["y", "x"])
    assert np.array_equal(a.registers, b.registers)


@given(st.sets(st.integers(0, 10**6), min_size=1, max_size=500),
       st.sets(st.integers(0, 10**6), min_size=1, max_size=500))
@settings(max_examples=20, deadline=None)
def test_bloom_no_false_negatives(members, probes):
    bf = BloomFilter(num_bits=1 << 14)
    bf.add(np.array(sorted(members), dtype=np.int64))
    got = bf.contains(np.array(sorted(members), dtype=np.int64))
    assert got.all()                      # never a false negative
    # false-positive rate sane for this sizing
    outside = np.array(sorted(set(probes) - members), dtype=np.int64)
    if outside.size:
        fp = bf.contains(outside).mean()
        assert fp < 0.2


def test_bloom_merge():
    a = BloomFilter()
    b = BloomFilter()
    a.add(np.array([1, 2, 3]))
    b.add(np.array([7, 8]))
    a.merge(b)
    assert a.contains(np.array([1, 7, 8])).all()


@given(st.lists(st.tuples(st.floats(0, 1000), st.floats(0, 100)),
                min_size=1, max_size=200),
       st.floats(0, 1100), st.floats(0, 50))
@settings(max_examples=50, deadline=None)
def test_interval_counts_match_brute_force(raw, q, width):
    starts = np.array([s for s, _ in raw])
    ends = starts + np.array([w for _, w in raw])
    iv = IntervalSet(starts, ends)
    got = int(iv.count_overlaps(q, q + width))
    want = int(np.sum((starts <= q + width) & (ends >= q)))
    assert got == want
