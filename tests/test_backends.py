"""ExecBackend parity: the jax (kernels.ops) backend must be byte-identical
to the numpy oracle across the query surface, including the benchmark
query suite in benchmarks/queries.py."""
import os
import sys

import numpy as np
import pytest

from repro.core import BETWEEN, IN, P, group, fdb, proto
from repro.core.session import Session
from repro.exec import (AdHocEngine, FlumeEngine, JaxBackend, NumpyBackend,
                        as_backend, backend_names, get_backend)
from repro.exec.backend import ExecBackend
from repro.fdb.index import bitmap_from_ids, bitmap_full, ids_from_bitmap

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "benchmarks"))

RNG = np.random.default_rng(42)


def assert_identical(a, b):
    """Byte-identical ColumnBatch comparison (values, splits, vocab)."""
    assert a.n == b.n
    assert a.paths() == b.paths()
    for p in a.paths():
        ca, cb = a[p], b[p]
        assert ca.values.dtype == cb.values.dtype, p
        assert np.array_equal(ca.values, cb.values), p
        if ca.row_splits is None:
            assert cb.row_splits is None, p
        else:
            assert np.array_equal(ca.row_splits, cb.row_splits), p
        assert ca.vocab == cb.vocab, p


def collect_pair(catalog, flow, **kw):
    rn = AdHocEngine(catalog, num_servers=4, backend="numpy").collect(flow, **kw)
    rj = AdHocEngine(catalog, num_servers=4, backend="jax").collect(flow, **kw)
    assert_identical(rn.batch, rj.batch)
    assert rn.profile.rows_scanned == rj.profile.rows_scanned
    assert rn.profile.rows_selected == rj.profile.rows_selected
    assert rn.profile.shards_done == rj.profile.shards_done
    return rn, rj


# ------------------------------------------------------------ primitives

@pytest.mark.parametrize("n", [1, 31, 64, 1000, 9999])
@pytest.mark.parametrize("k", [0, 1, 4])
def test_intersect_and_select_parity(n, k):
    npb, jxb = get_backend("numpy"), get_backend("jax")
    full = bitmap_full(n)
    probes = [bitmap_from_ids(
        RNG.choice(n, size=max(1, n // 2), replace=False), n)
        for _ in range(k)]
    bn = npb.intersect_bitmaps(full, probes)
    bj = jxb.intersect_bitmaps(full, probes)
    assert np.array_equal(bn, bj)
    assert np.array_equal(npb.select_ids(bn, n), jxb.select_ids(bj, n))


@pytest.mark.parametrize("n,density", [(1, 0.0), (100, 0.5), (5000, 0.9)])
def test_compact_mask_parity(n, density):
    mask = RNG.random(n) < density
    got = get_backend("jax").compact_mask(mask)
    want = get_backend("numpy").compact_mask(mask)
    assert got.dtype == want.dtype == np.int64
    assert np.array_equal(got, want)


@pytest.mark.parametrize("n,g", [(1, 1), (1000, 7), (20000, 300)])
def test_segment_aggregate_parity(n, g):
    codes = RNG.integers(0, g, n)
    vals = RNG.normal(50.0, 9.0, n)
    cn, sn, s2n = get_backend("numpy").segment_aggregate(codes, vals, g)
    cj, sj, s2j = get_backend("jax").segment_aggregate(codes, vals, g)
    assert np.array_equal(cn, cj)
    # float64 row-order accumulation on both sides → bit-equal
    assert np.array_equal(sn, sj)
    assert np.array_equal(s2n, s2j)


# --------------------------------------------------------------- queries

def test_find_aggregate_parity(catalog):
    q = (fdb("Obs").find(BETWEEN(P.hour, 8, 9) & BETWEEN(P.dow, 0, 4))
         .aggregate(group(P.road_id).avg(m=P.speed).std_dev(s=P.speed)
                    .count("n"))
         .map(lambda p: proto(road_id=p.road_id, n=p.n, cov=p.s / p.m)))
    rn, _ = collect_pair(catalog, q)
    assert rn.batch.n > 0


def test_residual_filter_sort_limit_parity(catalog):
    q = (fdb("Obs").find(BETWEEN(P.hour, 6, 20))
         .filter(P.speed > 40.0)
         .sort_desc(P.speed).limit(25))
    rn, _ = collect_pair(catalog, q)
    assert rn.batch.n == 25


def test_global_aggs_parity(catalog):
    q = fdb("Obs").aggregate(group().min(lo=P.speed).max(hi=P.speed)
                             .sum(tot=P.speed).approx_distinct(d=P.road_id))
    collect_pair(catalog, q)


def test_string_group_distinct_parity(catalog):
    q = fdb("Roads").aggregate(group(P.city).count("n"))
    collect_pair(catalog, q)
    collect_pair(catalog, fdb("Roads").distinct(P.city))


def test_flume_jax_matches_adhoc_numpy(catalog, tmp_path):
    q = (fdb("Obs").find(BETWEEN(P.hour, 8, 9))
         .aggregate(group(P.road_id).avg(m=P.speed).count("n")))
    ref = AdHocEngine(catalog, num_servers=4, backend="numpy").collect(q)
    fl = FlumeEngine(catalog, ckpt_dir=str(tmp_path), max_workers=4,
                     backend="jax").collect(q)
    assert_identical(ref.batch, fl.batch)


def test_benchmark_suite_parity():
    """Q1–Q5 of benchmarks/queries.py: numpy ≡ jax, all selection modes."""
    import queries as Q
    cat = Q.build_catalog(scale=0.05, num_shards=8, seed=1)
    for name, (cities, months) in Q.QUERIES.items():
        for mode in ("multi_index", "geo_index", "full_scan"):
            flow = Q.q_variability(cities, months, mode=mode)
            collect_pair(cat, flow)


# ---------------------------------------------------------- configuration

def test_backend_registry_and_env(monkeypatch):
    assert {"numpy", "jax"} <= set(backend_names())
    assert isinstance(get_backend("numpy"), NumpyBackend)
    assert isinstance(get_backend("jax"), JaxBackend)
    with pytest.raises(ValueError):
        get_backend("cuda-someday")
    monkeypatch.setenv("REPRO_EXEC_BACKEND", "jax")
    assert isinstance(get_backend(), JaxBackend)
    assert isinstance(as_backend(None), JaxBackend)
    eng = AdHocEngine(num_servers=1)
    assert eng.backend.name == "jax"
    monkeypatch.delenv("REPRO_EXEC_BACKEND")
    assert isinstance(get_backend(), NumpyBackend)
    inst = NumpyBackend()
    assert as_backend(inst) is inst


def test_session_backend_option(catalog):
    s = Session(backend="jax", catalog=catalog)
    assert isinstance(s.engine.backend, JaxBackend)
    res = s.run(s.fdb("Obs").aggregate(group().count("n")), name="tot")
    assert s["tot"] is res
    want = AdHocEngine(catalog, backend="numpy").collect(
        fdb("Obs").aggregate(group().count("n")))
    assert_identical(res.batch, want.batch)


def test_backend_selection_precedence(catalog, monkeypatch):
    """engine arg > Session(backend=) > $REPRO_EXEC_BACKEND."""
    monkeypatch.setenv("REPRO_EXEC_BACKEND", "jax")
    # engine arg beats the env default
    eng = AdHocEngine(catalog, backend="numpy")
    assert isinstance(eng.backend, NumpyBackend)
    # Session(backend=) beats the env default
    s = Session(backend="numpy", catalog=catalog)
    assert isinstance(s.engine.backend, NumpyBackend)
    # an explicit engine beats Session(backend=): the engine keeps its own
    s2 = Session(engine=eng, backend="jax")
    assert s2.engine is eng
    assert isinstance(s2.engine.backend, NumpyBackend)
    # env decides when neither engine nor session pin a backend
    assert isinstance(AdHocEngine(catalog).backend, JaxBackend)
    monkeypatch.setenv("REPRO_EXEC_BACKEND", "numpy")
    assert isinstance(AdHocEngine(catalog).backend, NumpyBackend)
    # ExecBackend instances pass through untouched at every level
    inst = NumpyBackend()
    assert AdHocEngine(catalog, backend=inst).backend is inst
    assert Session(backend=inst, catalog=catalog).engine.backend is inst


def test_custom_backend_registration():
    from repro.exec import register_backend

    class Flaky(NumpyBackend):
        name = "flaky"

    register_backend("flaky", Flaky)
    try:
        assert isinstance(get_backend("flaky"), Flaky)
        assert "flaky" in backend_names()
    finally:
        from repro.exec import backend as B
        B._FACTORIES.pop("flaky", None)
        B._INSTANCES.pop("flaky", None)
