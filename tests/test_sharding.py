"""Sharding rules: path matching, divisibility fallbacks, ZeRO-1/FSDP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.ml import sharding as sh
from repro.ml.model import ModelBundle, TrainConfig, _cache_spec_leaf
from repro.ml.transformer import LM


@pytest.fixture(scope="module")
def mesh16():
    # Shape-rule checks don't need real devices — abstract mesh suffices.
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh((16, 16), ("data", "model"))
    except TypeError:   # jax ≤ 0.4.x: shape_tuple of (name, size) pairs
        return AbstractMesh((("data", 16), ("model", 16)))


def _specs_for(arch, mesh):
    cfg = get_config(arch).reduced()
    lm = LM(cfg)
    shape = jax.eval_shape(lm.init, jax.random.key(0))
    return sh.param_specs(shape, mesh), shape


def test_attention_tp_rules(mesh16):
    specs, shape = _specs_for("command_r_35b", mesh16)
    blk = specs["blocks"]["slot0"]
    # wq [G, D, H*hd] → out-dim on model; wo [G, H*hd, D] → in-dim
    assert blk["attn"]["wq"][-1] == "model"
    assert blk["attn"]["wo"][-2] == "model"
    assert blk["mlp"]["w_up"][-1] == "model"
    assert blk["mlp"]["w_down"][-2] == "model"
    # norms replicated
    assert specs["final_norm"]["scale"] == P()


def test_divisibility_fallback(mesh16):
    """Dims that don't divide the axis fall back or replicate (pjit
    rejects uneven shards)."""
    cfg = get_config("mixtral_8x7b")      # 8 experts on a 16-way axis
    lm = LM(cfg)
    shape = jax.eval_shape(lm.init, jax.random.key(0))
    specs = sh.param_specs(shape, mesh16)
    w_gate = specs["blocks"]["slot0"]["moe"]["experts"]["w_gate"]
    # E=8 can't shard 16 ways → the FFN dim (14336) takes the axis
    sizes = jax.tree_util.tree_leaves(
        shape)[0]  # just ensure no exception; check spec directly
    assert "model" in tuple(w_gate)
    assert w_gate[1] != "model"           # E dim NOT sharded


def test_ep_when_divisible(mesh16):
    cfg = get_config("jamba_v0_1_52b")    # 16 experts on 16-way axis
    lm = LM(cfg)
    shape = jax.eval_shape(lm.init, jax.random.key(0))
    specs = sh.param_specs(shape, mesh16)
    # find a moe slot
    for s in range(8):
        blk = specs["blocks"][f"slot{s}"]
        if "moe" in blk:
            assert blk["moe"]["experts"]["w_gate"][1] == "model"
            return
    raise AssertionError("no moe slot found")


def test_zero1_and_fsdp_extend(mesh16):
    specs, shape = _specs_for("qwen1_5_0_5b", mesh16)
    z = sh.extend_specs(specs, mesh16, shape, "data")
    w = z["blocks"]["slot0"]["attn"]["wq"]
    assert "data" in tuple(w) and "model" in tuple(w)


def test_cache_specs_head_vs_seq(mesh16):
    # qwen kv=16 divides → heads on model
    leaf = jax.ShapeDtypeStruct((24, 128, 16, 1024, 64), jnp.bfloat16)
    path = (jax.tree_util.DictKey("k"),)
    spec = _cache_spec_leaf(path, leaf, mesh16)
    assert spec[2] == "model"
    # command-r kv=8 does not divide 16 → cache length takes the axis
    leaf = jax.ShapeDtypeStruct((40, 128, 8, 32768, 128), jnp.bfloat16)
    spec = _cache_spec_leaf(path, leaf, mesh16)
    assert spec[2] is None and spec[3] == "model"
    # long-context B=1 → sequence-parallel over the batch axes too
    leaf = jax.ShapeDtypeStruct((40, 1, 8, 524288, 128), jnp.bfloat16)
    spec = _cache_spec_leaf(path, leaf, mesh16)
    assert spec[1] is None
    flat = []
    for ax in spec:
        if isinstance(ax, tuple):
            flat.extend(ax)
        elif ax:
            flat.append(ax)
    assert "data" in flat                 # context parallelism engaged


def test_constrain_noop_without_mesh():
    sh.set_active_mesh(None)
    x = jnp.ones((4, 4))
    y = sh.constrain(x, ("batch", "model"))
    assert y is x
