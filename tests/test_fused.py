"""Fused per-wave device pipeline: byte parity of ``run_wave_fused``
(numpy loop-over-stages oracle vs the jax single-dispatch pipeline, on
ragged/empty/word-boundary shards, with and without the segment-agg
tail), the one-fused-dispatch-per-wave launch contract, the async
prefetch ordering evidence, the keyed stacked-buffer cache, the
``postings_bitmap`` lowering of ``SpaceTimeIndex.lookup``, and parity of
every fallback path that must decline fusion."""
import gc
import math

import numpy as np
import pytest

from repro.core import BETWEEN, P, group, fdb
from repro.core.planner import plan_flow
from repro.exec import AdHocEngine, Catalog, JaxBackend, get_backend
from repro.exec.batched import (FUSED_ENV, FusedAggPlan, fused_agg_plan,
                                fused_enabled)
from repro.fdb import Schema, build_fdb, DOUBLE, INT, STRING
from repro.fdb.schema import Field, MESSAGE
from repro.geo import AreaTree, mercator as M
from repro.kernels import ops
from repro.tess import Tesseract

RNG = np.random.default_rng(23)

#: word-boundary shard sizes — 32-bit bitmap words must not leak pad docs
SIZES = [32, 31, 64, 65, 1, 0, 33]


# --------------------------------------------------------------- fixtures

def _dense_db(name="FusedAgg"):
    """Word-boundary shard sizes incl. an empty shard, dense columns only
    (the fused agg tail requires them)."""
    schema = Schema(name, [
        Field("road", INT, indexes=("tag",)),
        Field("hour", INT, indexes=("range",)),
        Field("city", STRING, indexes=("tag",)),
        Field("speed", DOUBLE),
    ])
    bounds = np.cumsum([0] + SIZES)
    recs = [{"road": int(RNG.integers(0, 12)),
             "hour": int(RNG.integers(0, 24)),
             "city": ["SF", "OAK", "SJ"][int(RNG.integers(0, 3))],
             "speed": float(RNG.normal(48, 9)),
             "_i": i}
            for i in range(sum(SIZES))]
    key = lambda r: int(np.searchsorted(bounds, r["_i"], "right") - 1)
    db = build_fdb(name, schema, recs, num_shards=len(SIZES),
                   shard_key=key)
    assert [s.n for s in db.shards] == SIZES
    return db


def _walks_db(name="FusedWalks"):
    """Ragged spacetime tracks, empty tracks and an empty shard included."""
    schema = Schema(name, [
        Field("id", INT, indexes=("tag",)),
        Field("track", MESSAGE, fields=[
            Field("lat", DOUBLE, repeated=True),
            Field("lng", DOUBLE, repeated=True),
            Field("t", DOUBLE, repeated=True)],
            indexes=("spacetime",),
            index_params={"level": 6, "bucket_s": 900.0, "epoch": 0.0}),
    ])
    rng = np.random.default_rng(7)
    recs = []
    for i in range(sum(SIZES)):
        ln = 0 if i % 7 == 0 else int(rng.integers(1, 14))
        recs.append({"id": i, "track": {
            "lat": rng.uniform(37.2, 38.0, ln).tolist(),
            "lng": rng.uniform(-122.6, -121.8, ln).tolist(),
            "t": np.sort(rng.uniform(0.0, 3 * 86400.0, ln)).tolist()}})
    bounds = np.cumsum([0] + SIZES)
    key = lambda r: int(np.searchsorted(bounds, r["id"], "right") - 1)
    db = build_fdb(name, schema, recs, num_shards=len(SIZES),
                   shard_key=key)
    assert [s.n for s in db.shards] == SIZES
    return db


def _region(rng, d=2_000_000):
    ix, iy = M.latlng_to_xy(rng.uniform(37.2, 38.0),
                            rng.uniform(-122.6, -121.8))
    return AreaTree.from_box(int(ix) - d, int(iy) - d,
                             int(ix) + d, int(iy) + d, max_level=7)


@pytest.fixture(scope="module")
def dense_db():
    return _dense_db()


@pytest.fixture(scope="module")
def walks_db():
    return _walks_db()


@pytest.fixture(scope="module")
def dense_catalog(dense_db):
    cat = Catalog(server_slots=16)
    cat.register(dense_db)
    return cat


@pytest.fixture(scope="module")
def walks_catalog(walks_db):
    cat = Catalog(server_slots=16)
    cat.register(walks_db)
    return cat


AGG_FLOW = (fdb("FusedAgg").find(BETWEEN(P.hour, 8, 17))
            .aggregate(group(P.road).count("n").avg(m=P.speed)
                       .std_dev(s=P.speed)))

MINMAX_FLOW = (fdb("FusedAgg").find(BETWEEN(P.hour, 8, 17))
               .aggregate(group(P.road).count("n").min(mn=P.speed)
                          .max(mx=P.speed).avg(m=P.speed)))


def _tess(rng):
    return Tesseract(_region(rng), 0.0, 2 * 86400.0).also(
        _region(rng), 43200.0, 3 * 86400.0)


def assert_identical(a, b):
    assert a.n == b.n
    assert a.paths() == b.paths()
    for p in a.paths():
        ca, cb = a[p], b[p]
        assert ca.values.dtype == cb.values.dtype, p
        assert np.array_equal(ca.values, cb.values), p
        assert ca.vocab == cb.vocab, p


# ------------------------------------------------ direct op parity (oracle)

def _agg_call_args(catalog, db, flow=AGG_FLOW):
    """(shards, probes, fused_agg) for a direct run_wave_fused call."""
    plan = plan_flow(flow, catalog)
    shards = [db.shards[s] for s in plan.shard_ids]
    probes = [[p.run(sh) for p in plan.probes] for sh in shards]
    agg = fused_agg_plan(plan, shards)
    assert isinstance(agg, FusedAggPlan)       # eligibility, not a fluke
    return shards, probes, agg


@pytest.mark.parametrize("impl", ["reference", "interpret"])
def test_run_wave_fused_agg_parity(dense_catalog, dense_db, impl):
    """jax fused pipeline ≡ numpy loop-over-stages oracle: candidate
    counts and selected ids bit-exact; segment partials bit-exact on the
    reference impl, allclose on interpret (f32 value staging)."""
    shards, probes, agg = _agg_call_args(dense_catalog, dense_db)
    npb = get_backend("numpy")
    jxb = JaxBackend(impl=impl)
    jxb.prime_fdb(dense_db)
    want = npb.run_wave_fused(shards, probes, None, agg)
    got = jxb.run_wave_fused(shards, probes, None, agg)
    assert got is not None
    exact = impl == "reference"
    _assert_fused_equal(want, got, exact=exact)


def _assert_fused_equal(want, got, exact=True):
    wn, wids, wseg = want
    gn, gids, gseg = got
    assert gn == wn
    for gi, wi in zip(gids, wids):
        assert gi.dtype == np.int64
        assert np.array_equal(gi, wi)
    if wseg is None:
        assert gseg is None
        return
    assert len(gseg) == len(wseg)
    for (wu, wslots), (gu, gslots) in zip(wseg, gseg):
        assert np.array_equal(gu, wu)
        assert len(gslots) == len(wslots)
        # slots are (count, sum, sumsq[, min, max]) — min/max planes only
        # on slots a min/max agg reads
        for wslot, gslot in zip(wslots, gslots):
            assert len(gslot) == len(wslot)
            assert np.array_equal(gslot[0], wslot[0])  # counts always exact
            for k, (wa, ga) in enumerate(zip(wslot[1:], gslot[1:]), 1):
                if exact:
                    assert np.array_equal(ga, wa), k
                else:
                    assert np.allclose(ga, wa, rtol=1e-4), k


@pytest.mark.parametrize("impl", ["reference", "interpret"])
def test_run_wave_fused_minmax_parity(dense_catalog, dense_db, impl):
    """min/max lowered into the fused agg tail: the extra segment min/max
    planes match the host oracle — bit-exact on the reference impl (f64
    segment reductions are order-independent), allclose on interpret
    (the monotone f64→f32 value cast commutes with min/max)."""
    shards, probes, agg = _agg_call_args(dense_catalog, dense_db,
                                         MINMAX_FLOW)
    assert agg.minmax == (True,)               # speed slot carries min/max
    npb = get_backend("numpy")
    jxb = JaxBackend(impl=impl)
    jxb.prime_fdb(dense_db)
    want = npb.run_wave_fused(shards, probes, None, agg)
    got = jxb.run_wave_fused(shards, probes, None, agg)
    assert got is not None
    # min/max planes actually present: 5-wide slots on the flagged slot
    assert all(len(slot) == 5 for _u, slots in want[2] if slots
               for slot in slots)
    _assert_fused_equal(want, got, exact=impl == "reference")


def test_fused_launch_contract_minmax(dense_catalog, dense_db, exec_pplan,
                                      monkeypatch):
    monkeypatch.setenv(FUSED_ENV, "1")
    """A min/max group-by no longer declines fusion: whole query in
    ⌈shards_p/wave⌉ fused dispatches per partition (+ one merge combine
    when P>1), result identical to the numpy host path."""
    a = AdHocEngine(dense_catalog, num_servers=2, backend="numpy",
                    wave=3).collect(MINMAX_FLOW)
    eng = AdHocEngine(dense_catalog, num_servers=2, backend="jax", wave=3)
    eng.collect(MINMAX_FLOW)                   # warm
    ops.reset_launch_counts()
    b = eng.collect(MINMAX_FLOW)
    pp = exec_pplan(dense_db.num_shards, eng.backend)
    want = {"run_wave_fused": pp.wave_dispatches(3)}
    if pp.merge_combines():
        want["merge_partials"] = pp.merge_combines()
    assert dict(ops.launch_counts()) == want
    assert_identical(a.batch, b.batch)


@pytest.mark.tesseract
@pytest.mark.parametrize("ordered", [False, True])
def test_run_wave_fused_refine_parity(walks_catalog, walks_db, ordered):
    """Fused probe→refine→compact ≡ oracle on ragged/empty tracks, with
    unordered and ordered (first-hit edge) constraint sets."""
    rng = np.random.default_rng(3)
    tess = Tesseract(_region(rng), 0.0, 2 * 86400.0)
    tess = (tess.then if ordered else tess.also)(
        _region(rng), 43200.0, 3 * 86400.0)
    plan = plan_flow(fdb("FusedWalks").tesseract(tess), walks_catalog)
    assert len(plan.refines) == 1
    if ordered:
        assert plan.refines[0].edges == [(0, 1)]
    shards = [walks_db.shards[s] for s in plan.shard_ids]
    probes = [[p.run(sh) for p in plan.probes] for sh in shards]
    npb = get_backend("numpy")
    jxb = JaxBackend()
    jxb.prime_fdb(walks_db)
    want = npb.run_wave_fused(shards, probes, plan.refines[0], None)
    got = jxb.run_wave_fused(shards, probes, plan.refines[0], None)
    assert got is not None
    _assert_fused_equal(want, got)
    assert sum(len(i) for i in got[1]) > 0     # the query actually selects


def test_run_wave_fused_declines_to_legacy_path(walks_db):
    """The fused override returns None — engine falls back to the
    per-primitive path — when the refine exceeds the kernel's packed
    constraint budget (>30), and when every track in the wave is empty
    (the legacy path's host shortcut already covers that)."""
    rng = np.random.default_rng(4)
    jxb = JaxBackend()
    jxb.prime_fdb(walks_db)
    cat = Catalog(); cat.register(walks_db)
    # 31 constraints exceed the refine kernel's packed-constraint budget
    many = _tess(rng)
    for _ in range(29):
        many = many.also(_region(rng), 0.0, 86400.0)
    plan = plan_flow(fdb("FusedWalks").tesseract(many), cat)
    assert len(plan.refines[0].constraints) == 31
    shards = [walks_db.shards[s] for s in plan.shard_ids]
    probes = [[p.run(sh) for p in plan.probes] for sh in shards]
    assert jxb.run_wave_fused(shards, probes, plan.refines[0], None) is None
    # all-empty tracks → zero-width point stack → decline (p_max == 0)
    schema = walks_db.schema
    recs = [{"id": i, "track": {"lat": [], "lng": [], "t": []}}
            for i in range(12)]
    empty_db = build_fdb("FusedEmptyTracks", schema, recs, num_shards=3)
    cat2 = Catalog(); cat2.register(empty_db)
    plan2 = plan_flow(fdb("FusedEmptyTracks").tesseract(
        _tess(np.random.default_rng(1))), cat2)
    jxb.prime_fdb(empty_db)
    shards2 = [empty_db.shards[s] for s in plan2.shard_ids]
    probes2 = [[p.run(sh) for p in plan2.probes] for sh in shards2]
    assert jxb.run_wave_fused(shards2, probes2, plan2.refines[0],
                              None) is None
    # the engine still answers (empty) through the fallback
    res = AdHocEngine(cat2, num_servers=2, backend=jxb, wave=3).collect(
        fdb("FusedEmptyTracks").tesseract(_tess(np.random.default_rng(1))))
    assert res.batch.n == 0


# ------------------------------------------------- engine launch contract

def test_fused_launch_contract_agg(dense_catalog, dense_db, exec_pplan,
                                   monkeypatch):
    monkeypatch.setenv(FUSED_ENV, "1")   # fused on even on the fused=0 CI leg
    """One fused dispatch per wave is the WHOLE query: launch counts are
    exactly {run_wave_fused: Σ_p ⌈shards_p/wave⌉} plus one merge combine
    when P>1 — no per-primitive launches."""
    for wave in (3, 1):                        # wave=1 covers empty waves
        eng = AdHocEngine(dense_catalog, num_servers=2, backend="jax",
                          wave=wave)
        eng.collect(AGG_FLOW)                  # warm: prime + jit caches
        ops.reset_launch_counts()
        res = eng.collect(AGG_FLOW)
        assert res.batch.n > 0
        pp = exec_pplan(dense_db.num_shards, eng.backend)
        want = {"run_wave_fused": pp.wave_dispatches(wave)}
        if pp.merge_combines():
            want["merge_partials"] = pp.merge_combines()
        assert dict(ops.launch_counts()) == want


@pytest.mark.tesseract
def test_fused_launch_contract_refine(walks_catalog, walks_db, exec_pplan,
                                      monkeypatch):
    monkeypatch.setenv(FUSED_ENV, "1")
    """Tesseract selection rides the same single dispatch: zero batched
    per-primitive refine/compact launches (and no merge combine — the
    selection path concatenates, it doesn't aggregate)."""
    flow = fdb("FusedWalks").tesseract(_tess(np.random.default_rng(11)))
    wave = 3
    eng = AdHocEngine(walks_catalog, num_servers=2, backend="jax",
                      wave=wave)
    eng.collect(flow)                          # warm
    ops.reset_launch_counts()
    eng.collect(flow)
    lc = ops.launch_counts()
    waves = exec_pplan(walks_db.num_shards,
                       eng.backend).wave_dispatches(wave)
    assert lc.get("run_wave_fused") == waves
    assert lc.get("merge_partials", 0) == 0
    assert lc.get("bitmap_intersect_batched", 0) == 0
    assert lc.get("refine_tracks_batched", 0) == 0
    assert lc.get("refine_tracks", 0) == 0
    assert lc.get("compact_batched", 0) == 0


def test_fused_env_kill_switch(dense_catalog, monkeypatch):
    """REPRO_EXEC_FUSED=0 restores the legacy per-primitive wave path,
    byte-identically."""
    monkeypatch.setenv(FUSED_ENV, "1")
    fused = AdHocEngine(dense_catalog, num_servers=2, backend="jax",
                        wave=3).collect(AGG_FLOW)
    monkeypatch.setenv(FUSED_ENV, "0")
    assert not fused_enabled()
    legacy = AdHocEngine(dense_catalog, num_servers=2, backend="jax",
                         wave=3).collect(AGG_FLOW)
    ops.reset_launch_counts()
    AdHocEngine(dense_catalog, num_servers=2, backend="jax",
                wave=3).collect(AGG_FLOW)
    assert ops.launch_counts().get("run_wave_fused", 0) == 0
    assert_identical(fused.batch, legacy.batch)


# ----------------------------------------------- prefetch + keyed caching

def test_prefetch_stages_next_wave_before_wave_done(dense_catalog,
                                                    monkeypatch,
                                                    exec_pplan):
    monkeypatch.setenv(FUSED_ENV, "1")
    """The fused dispatch hands wave k+1's buffers to the device while
    wave k computes: a ("prefetch", n) trace marker lands before wave k's
    ("wave_done", ...) marker, for every non-final wave.  Prefetch runs
    within each execution partition, so the expected counts follow the
    PartitionPlan: Σ_p waves_p dispatches, Σ_p max(waves_p − 1, 0)
    prefetches (a single-wave partition stages nothing ahead)."""
    be = JaxBackend()
    be.prime_fdb(dense_catalog.get("FusedAgg"))
    eng = AdHocEngine(dense_catalog, num_servers=1, backend=be, wave=3)
    eng.collect(AGG_FLOW)                      # warm
    be.trace_events = []
    eng.collect(AGG_FLOW)
    ev = be.trace_events
    be.trace_events = None
    kinds = [e[0] for e in ev]
    pp = exec_pplan(dense_catalog.get("FusedAgg").num_shards, be)
    part_waves = [math.ceil(s / 3) for s in pp.sizes() if s]
    assert kinds.count("wave_done") == sum(part_waves)
    assert kinds.count("prefetch") == sum(w - 1 for w in part_waves)
    # wave k's prefetch-of-(k+1) precedes wave k's own completion marker
    if part_waves and part_waves[0] > 1:
        assert kinds[0] == "prefetch" and kinds[1] == "wave_done"
    for i, e in enumerate(ev):
        if e[0] == "prefetch":
            assert ev[i + 1][0] == "wave_done"


def test_keyed_cache_reused_and_separate(dense_catalog, dense_db,
                                         monkeypatch):
    monkeypatch.setenv(FUSED_ENV, "1")
    """Stacked wave buffers are cached under composite keys: reused on
    the next query (keyed_hits grows), kept OUT of the per-column buffer
    count the priming contract asserts on."""
    be = JaxBackend()
    n_buffers = be.prime_fdb(dense_db)
    assert n_buffers == len(be.device_cache) == dense_db.num_shards * 5
    eng = AdHocEngine(dense_catalog, num_servers=2, backend=be, wave=3)
    eng.collect(AGG_FLOW)
    stats = be.device_cache.stats()
    assert stats["keyed"] > 0                  # stacks were cached
    assert stats["buffers"] == len(be.device_cache) == n_buffers
    before = stats["keyed_hits"]
    eng.collect(AGG_FLOW)
    assert be.device_cache.stats()["keyed_hits"] > before


def test_keyed_cache_evicted_with_fdb(monkeypatch):
    monkeypatch.setenv(FUSED_ENV, "1")
    """Dropping the FDb drops its keyed stacks along with its buffers."""
    db = _dense_db("FusedEvict")
    cat = Catalog(); cat.register(db)
    be = JaxBackend()
    be.prime_fdb(db)
    flow = (fdb("FusedEvict").find(BETWEEN(P.hour, 8, 17))
            .aggregate(group(P.road).count("n").avg(m=P.speed)))
    AdHocEngine(cat, num_servers=2, backend=be, wave=3).collect(flow)
    assert be.device_cache.stats()["keyed"] > 0
    del cat, db, flow
    gc.collect()
    assert len(be.device_cache) == 0
    assert be.device_cache.stats()["keyed"] == 0


# ------------------------------------------- postings_bitmap behind the seam

@pytest.mark.tesseract
def test_postings_bitmap_lookup_parity(walks_db):
    """SpaceTimeIndex.lookup(backend=jax) ≡ host math, including the
    empty-window / out-of-range short circuits."""
    jxb = JaxBackend()
    jxb.prime_fdb(walks_db)
    rng = np.random.default_rng(9)
    windows = [(0.0, 86400.0), (43200.0, 3 * 86400.0),
               (5.0, 1.0),                     # inverted → empty
               (-1e12, -1e11), (1e15, 2e15)]   # outside representable
    checked = 0
    for sh in walks_db.shards:
        ix = sh.indexes[("track", "spacetime")]
        for _ in range(3):
            reg = _region(rng)
            for t0, t1 in windows:
                host = ix.lookup(reg, t0, t1)
                dev = ix.lookup(reg, t0, t1, backend=jxb)
                assert dev.dtype == np.uint32
                assert np.array_equal(host, dev), (sh.n, t0, t1)
                checked += int(host.any())
    assert checked > 0                         # some probes actually hit


# ---------------------------------------------------- fallback-path parity

@pytest.mark.parametrize("case", ["residual", "approx", "sortlimit"])
def test_fallback_paths_match_numpy(dense_catalog, case, monkeypatch):
    """Queries the fused pipeline must decline (residual filter, agg
    kinds outside count/sum/avg/std_dev/min/max, sort+limit tail) still
    match the numpy oracle with fusion enabled."""
    monkeypatch.setenv(FUSED_ENV, "1")
    assert fused_enabled()
    base = fdb("FusedAgg").find(BETWEEN(P.hour, 8, 17))
    if case == "residual":
        q = (base.filter(P.speed > 40.0)
             .aggregate(group(P.road).count("n").avg(m=P.speed)))
    elif case == "approx":
        q = base.aggregate(group(P.road).approx_distinct(d=P.hour))
    else:
        q = base.sort_desc(P.speed).limit(20)
    a = AdHocEngine(dense_catalog, num_servers=2, backend="numpy",
                    wave=3).collect(q)
    b = AdHocEngine(dense_catalog, num_servers=2, backend="jax",
                    wave=3).collect(q)
    assert_identical(a.batch, b.batch)
