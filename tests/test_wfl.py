"""WFL: expression semantics, flow operators vs brute force, planning."""
import collections
import statistics

import numpy as np
import pytest

from repro.core import (P, proto, IN, BETWEEN, group, fdb, vsum, vcount,
                        Session, BloomFilter)
from repro.core.exprs import EvalContext, eval_expr, func
from repro.core.flow import FindOp
from repro.core.planner import plan_flow, split_find_pred
from repro.fdb.columnar import ColumnBatch
from repro.fdb.schema import Schema
from repro.fdb import DOUBLE, INT, STRING
from repro.geo import AreaTree, mercator as M


def _batch(**cols):
    spec = {}
    data = {}
    n = None
    for k, v in cols.items():
        if isinstance(v[0], str):
            spec[k] = STRING
        elif isinstance(v[0], list):
            spec[k] = (DOUBLE, True)
        elif isinstance(v[0], float):
            spec[k] = DOUBLE
        else:
            spec[k] = INT
        n = len(v)
    schema = Schema.dynamic("t", spec)
    recs = [{k: cols[k][i] for k in cols} for i in range(n)]
    return ColumnBatch.from_records(schema, recs)


def test_vector_broadcast_semantics():
    """§4.2.2: ops extend element-wise over repeated operands."""
    b = _batch(d=[[2.0, 4.0], [10.0]], s=[2.0, 5.0])
    ctx = EvalContext(b)
    v = eval_expr((P.d / P.s)._expr, ctx)
    assert v.is_repeated
    assert np.allclose(v.values, [1.0, 2.0, 2.0])
    # reduction back to singular
    tot = eval_expr(vsum(P.d / P.s)._expr, ctx)
    assert not tot.is_repeated
    assert np.allclose(tot.values, [3.0, 2.0])
    cnt = eval_expr(vcount(P.d)._expr, ctx)
    assert np.array_equal(cnt.values, [2, 1])


def test_string_and_set_ops():
    b = _batch(city=["SF", "OAK", "SF"], x=[1, 2, 3])
    ctx = EvalContext(b)
    assert np.array_equal(eval_expr((P.city == "SF")._expr, ctx).values,
                          [True, False, True])
    assert np.array_equal(eval_expr(IN(P.x, [1, 3])._expr, ctx).values,
                          [True, False, True])
    bf = BloomFilter()
    bf.add(np.array([1, 3]))
    assert np.array_equal(eval_expr(IN(P.x, bf)._expr, ctx).values,
                          [True, False, True])


def test_find_pred_split(catalog):
    pred = (IN(P.loc, AreaTree.from_box(0, 0, 100, 100))
            & BETWEEN(P.speed_limit, 30, 60)
            & (P.city == "SF")
            & (P.speed_limit * 2.0 > 80.0))      # not indexable
    probes, refines, residual = split_find_pred(pred._expr,
                                       catalog.schema_of("Roads"))
    kinds = sorted(p.kind for p in probes)
    assert kinds == ["location", "range", "tag"]
    assert refines == []          # no space-time conjuncts → no refine
    assert residual is not None


def test_planner_minimal_read_set(catalog):
    q = (fdb("Roads").find(BETWEEN(P.speed_limit, 30, 60))
         .map(lambda p: proto(c=p.city)))
    plan = plan_flow(q, catalog)
    # BETWEEN is fully served by the range index ⇒ speed_limit is never
    # read — the paper's index-only selection.
    assert plan.source_paths == ["city"]
    assert [type(o).__name__ for o in plan.server_ops] == ["MapOp"]
    # a non-indexable residual forces the column into the read set
    q2 = (fdb("Roads").find((P.speed_limit * 2.0 > 60.0))
          .map(lambda p: proto(c=p.city)))
    assert plan_flow(q2, catalog).source_paths == ["city", "speed_limit"]


def test_or_pushdown_tag_lookup_any(catalog, engine):
    """Disjunctions of tag lookups on one field → bitmap OR, no residual."""
    pred = (P.city == "SF") | IN(P.city, ["OAK"])
    probes, refines, residual = split_find_pred(pred._expr,
                                       catalog.schema_of("Roads"))
    assert [p.kind for p in probes] == ["tag"]
    assert probes[0].args == (("SF", "OAK"),)
    assert residual is None
    # engine result identical to the residual-only evaluation
    got = engine.collect(fdb("Roads").find(pred))
    want = engine.collect(fdb("Roads").filter(pred))
    assert sorted(got.batch["id"].values.tolist()) \
        == sorted(want.batch["id"].values.tolist())
    assert got.batch.n > 0


def test_or_pushdown_rejects_mixed_or_unindexed(catalog):
    schema = catalog.schema_of("Roads")
    # mixed fields: stays residual
    probes, refines, residual = split_find_pred(
        ((P.city == "SF") | (P.id == 3))._expr, schema)
    assert probes == [] and residual is not None
    # non-tag field (speed_limit is range-indexed only): stays residual
    probes, refines, residual = split_find_pred(
        ((P.speed_limit == 30.0) | (P.speed_limit == 50.0))._expr, schema)
    assert all(p.kind != "tag" for p in probes)
    assert residual is not None
    # OR with a non-leaf disjunct: stays residual
    probes, refines, residual = split_find_pred(
        ((P.city == "SF") | (P.speed_limit * 2.0 > 80.0))._expr, schema)
    assert probes == [] and residual is not None


def test_aggregate_matches_brute_force(world, engine):
    q = (fdb("Obs").find(BETWEEN(P.hour, 8, 9))
         .aggregate(group(P.road_id).count("n").avg(m=P.speed)
                    .std_dev(sd=P.speed).min(lo=P.speed).max(hi=P.speed)))
    res = engine.collect(q)
    got = {r["road_id"]: r for r in res.to_records()}
    by_road = collections.defaultdict(list)
    for o in world["obs"]:
        if 8 <= o["hour"] <= 9:
            by_road[o["road_id"]].append(o["speed"])
    assert set(got) == set(by_road)
    for rid, speeds in by_road.items():
        r = got[rid]
        assert r["n"] == len(speeds)
        assert abs(r["m"] - statistics.fmean(speeds)) < 1e-9
        assert abs(r["sd"] - statistics.pstdev(speeds)) < 1e-9
        assert r["lo"] == min(speeds) and r["hi"] == max(speeds)


def test_approx_distinct(engine, world):
    q = fdb("Obs").aggregate(group().approx_distinct(d=P.road_id))
    est = engine.collect(q).to_records()[0]["d"]
    true = len({o["road_id"] for o in world["obs"]})
    assert abs(est - true) / true < 0.05      # HLL p=12 → ~1.6% typical


def test_flatten(engine, catalog, world):
    q = (fdb("Roads").find(P.city == "SF")
         .map(lambda p: proto(id=p.id, lat=p.polyline.lat))
         .flatten("lat"))
    res = engine.collect(q)
    n_sf = sum(1 for r in world["roads"] if r["city"] == "SF")
    assert res.n == 3 * n_sf      # 3 waypoints per road


def test_sort_limit_distinct(engine, world):
    top = (fdb("Roads").map(lambda p: proto(sl=p.speed_limit))
           .sort_desc(P.sl).limit(7)).collect(engine)
    sls = sorted((r["speed_limit"] for r in world["roads"]), reverse=True)
    got = [r["sl"] for r in top.to_records()]
    assert np.allclose(got, sls[:7])
    cities = (fdb("Roads").map(lambda p: proto(c=p.city)).distinct(P.c)
              ).collect(engine)
    assert sorted(r["c"] for r in cities.to_records()) == ["OAK", "SF"]


def test_join_and_dict_lookup(engine, world):
    # Fig. 1 pattern: collect roads to a dict, join obs via lookup
    roads_flow = fdb("Roads").map(lambda p: proto(rid=p.id, sl=p.speed_limit))
    roads_tbl = engine.collect(roads_flow).to_dict("rid")
    q = (fdb("Obs").find(BETWEEN(P.hour, 8, 8))
         .map(lambda p: proto(over=roads_tbl[p.road_id].sl < p.speed,
                              rid=p.road_id)))
    res = engine.collect(q).to_records()
    for r in res:
        sl = world["roads"][r["rid"]]["speed_limit"]
        # find the matching obs is ambiguous; verify type/consistency
        assert isinstance(r["over"], bool)
    # full hash-join path
    q2 = (fdb("Obs").find(BETWEEN(P.hour, 8, 8))
          .join(roads_flow, left_key=P.road_id, right_key=P.rid,
                alias="rd")
          .map(lambda p: proto(rid=p.road_id, sl=p.rd.sl)))
    for r in engine.collect(q2).to_records():
        assert r["sl"] == world["roads"][r["rid"]]["speed_limit"]


def test_sub_flow_index_join(engine, world):
    q = (fdb("Obs").find(BETWEEN(P.hour, 9, 9))
         .sub_flow("Roads", key=P.road_id, index_path="id", alias="rd")
         .map(lambda p: proto(rid=p.road_id, city=p.rd.city)).limit(20))
    for r in engine.collect(q).to_records():
        assert r["city"] == world["roads"][r["rid"]]["city"]


def test_geospatial_find(engine, world):
    ix, iy = M.latlng_to_xy(np.array([37.72, 37.76]),
                            np.array([-122.50, -122.45]))
    region = AreaTree.from_box(int(ix[0]), int(iy[1]), int(ix[1]),
                               int(iy[0]), max_level=9)
    q = fdb("Roads").find(IN(P.loc, region)).aggregate(group().count("n"))
    got = engine.collect(q).to_records()[0]["n"]
    want = sum(1 for r in world["roads"]
               if 37.72 <= r["loc"]["lat"] <= 37.76
               and -122.50 <= r["loc"]["lng"] <= -122.45)
    assert abs(got - want) <= 2   # conservative cover boundary slack


def test_distance_function(engine, world):
    q = (fdb("Roads").find(P.id == 0)
         .map(lambda p: proto(d=func("distance", P.polyline))))
    d = engine.collect(q).to_records()[0]["d"]
    assert 100 < d < 1000         # ~250m for 1e-3 deg of lat+lng


def test_session_and_autocomplete(engine):
    s = Session(engine=engine)
    assert "Roads" in s.complete("Ro")
    assert "speed_limit" in s.complete("Roads.s")
    assert s.complete("Roads.city=S") == ["SF"]
    res = s.run(s.fdb("Roads").map(lambda p: proto(c=p.city)).limit(3),
                name="sample")
    assert s["sample"].n == 3


def test_dynamic_schema_derivation(engine, catalog):
    q = (fdb("Obs").find(BETWEEN(P.hour, 8, 9))
         .map(lambda p: proto(x=p.speed * 2.0, road=p.road_id))
         .aggregate(group(P.road).avg(m=P.x)))
    schema = q.schema_after(catalog)
    spec = schema.spec()
    assert spec["road"][0] in (INT, DOUBLE)
    assert spec["m"] == (DOUBLE, False)
