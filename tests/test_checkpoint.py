"""Checkpointing: atomic commit, async writes, retention, elastic restore."""
import os
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt.checkpoint import (CheckpointManager, latest_step,
                                   restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.normal(size=(16, 8)
                                                   ).astype(np.float32)),
                       "blocks": {"slot0": jnp.asarray(
                           rng.normal(size=(4, 8)).astype(np.float32))}},
            "step": np.int64(7)}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t)
    restored, step = restore_checkpoint(str(tmp_path), t)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(t["params"]["w"]))
    assert int(restored["step"]) == 7


def test_atomic_commit_ignores_partial(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    # a crashed save leaves only a .tmp dir — must be invisible
    os.makedirs(tmp_path / "step-00000009.tmp")
    assert latest_step(str(tmp_path)) == 5
    _, step = restore_checkpoint(str(tmp_path), t)
    assert step == 5


def test_keep_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (10, 20, 30, 40):
        mgr.save(s, t, blocking=True)
    mgr.wait()
    mgr._gc()
    steps = sorted(int(f.split("-")[1]) for f in os.listdir(tmp_path)
                   if f.startswith("step-") and not f.endswith(".tmp"))
    assert steps == [30, 40]


def test_async_save_snapshot_semantics(tmp_path):
    """Async save must snapshot values at call time (donation-safe)."""
    t = _tree()
    w_before = np.asarray(t["params"]["w"]).copy()
    th = save_checkpoint(str(tmp_path), 1, t, blocking=False)
    # mutate the host dict while the writer runs
    t["params"]["w"] = jnp.zeros_like(t["params"]["w"])
    th.join()
    restored, _ = restore_checkpoint(str(tmp_path), _tree())
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  w_before)


def test_elastic_restore_with_shardings(tmp_path):
    """Restore re-places leaves with target shardings (mesh-agnostic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    mesh = jax.make_mesh((1,), ("model",))
    sh = {"params": {"w": NamedSharding(mesh, P("model", None)),
                     "blocks": {"slot0": NamedSharding(mesh, P())}},
          "step": None}
    restored, _ = restore_checkpoint(str(tmp_path), t, shardings=sh)
    assert restored["params"]["w"].sharding.spec == P("model", None)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"), _tree())
