"""Partition layer (explicit shards → P partitions): `PartitionPlan`
arithmetic, the `merge_partials` backend op (numpy loop-over-partitions
oracle vs the jax shard_map combine, bit for bit), P=1/2/4 result
identity on both backends and engines — selection byte-identical,
aggregation float64-reference-identical — empty partitions, ragged
shard counts, all-pruned partitions, the ordered first-hit path,
partition-axis fault rerouting, the partitioned serve tier, and eager
device-buffer retirement on streaming snapshot turnover."""
import math
import tempfile

import numpy as np
import pytest

from repro.core import BETWEEN, P, group, fdb
from repro.core.planner import (PARTITIONS_ENV, PartitionPlan,
                                num_partitions, partition_shards, plan_flow)
from repro.exec import (AdHocEngine, Catalog, FaultPlan, FlumeEngine,
                        JaxBackend, get_backend)
from repro.exec.batched import FUSED_ENV
from repro.fdb import DOUBLE, INT, Schema, build_fdb
from repro.fdb.schema import Field, MESSAGE
from repro.fdb.streaming import StreamingFDb
from repro.geo import AreaTree, mercator as M
from repro.kernels import ops
from repro.launch.elastic import reroute_partitions
from repro.launch.mesh import default_exec_partitions
from repro.serve import QueryServer
from repro.tess import Tesseract

RNG = np.random.default_rng(17)
SIZES = [16, 15, 32, 33, 1, 0, 9]          # ragged + an empty shard
DAY = 86400.0


# --------------------------------------------------------------- fixtures

def _dense_db(name="PartDense"):
    schema = Schema(name, [
        Field("road", INT, indexes=("tag",)),
        Field("hour", INT, indexes=("range",)),
        Field("speed", DOUBLE),
    ])
    bounds = np.cumsum([0] + SIZES)
    recs = [{"road": int(RNG.integers(0, 8)),
             "hour": int(RNG.integers(0, 24)),
             "speed": float(RNG.normal(48, 9)),
             "_i": i}
            for i in range(sum(SIZES))]
    key = lambda r: int(np.searchsorted(bounds, r["_i"], "right") - 1)
    db = build_fdb(name, schema, recs, num_shards=len(SIZES),
                   shard_key=key)
    assert [s.n for s in db.shards] == SIZES
    return db


def _track_schema(name):
    return Schema(name, [
        Field("id", INT, indexes=("tag",)),
        Field("track", MESSAGE, fields=[
            Field("lat", DOUBLE, repeated=True),
            Field("lng", DOUBLE, repeated=True),
            Field("t", DOUBLE, repeated=True)],
            indexes=("spacetime",),
            index_params={"level": 6, "bucket_s": 900.0, "epoch": 0.0}),
    ])


def _walks_db(name="PartWalks", n=64, sizes=(16, 15, 0, 33)):
    rng = np.random.default_rng(5)
    recs = []
    for i in range(sum(sizes)):
        ln = 0 if i % 9 == 0 else int(rng.integers(1, 12))
        recs.append({"id": i, "track": {
            "lat": rng.uniform(37.2, 38.0, ln).tolist(),
            "lng": rng.uniform(-122.6, -121.8, ln).tolist(),
            "t": np.sort(rng.uniform(0.0, 2 * DAY, ln)).tolist()}})
    bounds = np.cumsum([0] + list(sizes))
    key = lambda r: int(np.searchsorted(bounds, r["id"], "right") - 1)
    return build_fdb(name, _track_schema(name), recs,
                     num_shards=len(sizes), shard_key=key)


def _region(rng, d=2_500_000):
    ix, iy = M.latlng_to_xy(rng.uniform(37.3, 37.9),
                            rng.uniform(-122.5, -121.9))
    return AreaTree.from_box(int(ix) - d, int(iy) - d,
                             int(ix) + d, int(iy) + d, max_level=7)


@pytest.fixture(scope="module")
def dense_db():
    return _dense_db()


@pytest.fixture(scope="module")
def dense_catalog(dense_db):
    cat = Catalog(server_slots=16)
    cat.register(dense_db)
    return cat


@pytest.fixture(scope="module")
def walks_db():
    return _walks_db()


@pytest.fixture(scope="module")
def walks_catalog(walks_db):
    cat = Catalog(server_slots=16)
    cat.register(walks_db)
    return cat


#: every fused aggregate kind in one spec — the merge must carry
#: (n, Σ, Σ²) and the min/max planes through the combine
ALL_AGG = (fdb("PartDense").find(BETWEEN(P.hour, 7, 18))
           .aggregate(group(P.road).count("n").sum(s=P.speed)
                      .avg(a=P.speed).std_dev(sd=P.speed)
                      .min(lo=P.speed).max(hi=P.speed)))

SELECT = fdb("PartDense").find(BETWEEN(P.hour, 7, 18))


def assert_identical(a, b):
    assert a.n == b.n
    assert a.paths() == b.paths()
    for p in a.paths():
        ca, cb = a[p], b[p]
        assert ca.values.dtype == cb.values.dtype, p
        assert np.array_equal(ca.values, cb.values), p
        assert ca.vocab == cb.vocab, p


# ------------------------------------------------------ plan arithmetic

def test_partition_shards_contiguous_and_balanced():
    pp = partition_shards(range(7), 3)
    assert pp.parts == [[0, 1, 2], [3, 4], [5, 6]]   # contiguous, ±1
    assert [s for part in pp.parts for s in part] == list(range(7))
    assert pp.sizes() == [3, 2, 2]
    # P > shards: tail partitions are empty, shard order preserved
    pp = partition_shards([4, 9], 4)
    assert pp.parts == [[4], [9], [], []]
    assert partition_shards([], 3).parts == [[], [], []]
    assert partition_shards(range(5), 1).parts == [list(range(5))]


def test_partition_plan_launch_helpers():
    pp = PartitionPlan([[0, 1, 2, 3], [4, 5, 6]])
    assert pp.wave_dispatches(3) == 2 + 1            # ⌈4/3⌉ + ⌈3/3⌉
    assert pp.wave_dispatches(1) == 7
    assert pp.merge_combines() == 1
    # empty partitions dispatch nothing; one live partition needs no merge
    assert PartitionPlan([[0], [], []]).wave_dispatches(3) == 1
    assert PartitionPlan([[0], [], []]).merge_combines() == 0
    assert PartitionPlan([[], [], []]).wave_dispatches(3) == 0
    assert PartitionPlan([[], [], []]).merge_combines() == 0
    assert PartitionPlan([list(range(5))]).merge_combines() == 0


def test_num_partitions_resolution(monkeypatch):
    monkeypatch.delenv(PARTITIONS_ENV, raising=False)
    assert num_partitions(3) == 3                    # engine arg wins
    assert num_partitions() == 1
    assert num_partitions(backend=get_backend("numpy")) == 1
    # batched backends fall back to the accelerator mesh size
    assert num_partitions(backend=get_backend("jax")) == \
        default_exec_partitions()
    monkeypatch.setenv(PARTITIONS_ENV, "4")
    assert num_partitions() == 4                     # env beats mesh
    assert num_partitions(2) == 2                    # … but not the arg


def test_reroute_partitions_round_robin():
    parts = [[0, 1], [2, 3], [4]]
    out = reroute_partitions(parts, [1])
    assert out == [[0, 1, 2], [], [4, 3]]            # orphans round-robin
    assert sorted(s for p in out for s in p) == list(range(5))
    assert out[1] == []                              # failed slot drained
    assert len(out) == len(parts)                    # slot count preserved
    # no survivors: keep the assignment, per-shard retries take over
    assert reroute_partitions(parts, [0, 1, 2]) == parts


# -------------------------------------------- merge op: oracle vs device

def _state(keys, *slots):
    return (np.asarray(keys, np.int64),
            [tuple(np.asarray(a, np.float64) if i else
                   np.asarray(a, np.int64) for i, a in enumerate(slot))
             for slot in slots])


def test_merge_partials_matches_hand_oracle():
    """Disjoint + overlapping key spaces, an empty state, two value slots
    (one with min/max planes): the numpy base-class merge equals the hand
    reduction and the jax shard_map combine equals it bit for bit."""
    # slot layout: (count, sum, sum_sq[, min, max]) per group
    a = _state([1, 3],
               ([2, 1], [4.0, 5.0], [10.0, 25.0]),
               ([2, 1], [1.0, 2.0], [0.5, 4.0], [0.25, 2.0], [0.75, 2.0]))
    b = _state([3, 7],
               ([1, 4], [3.0, 8.0], [9.0, 20.0]),
               ([1, 4], [5.0, 3.0], [25.0, 2.25], [5.0, 0.5], [5.0, 1.0]))
    empty = _state([])
    states = [a, empty, b]
    npb = get_backend("numpy")
    uniq, slots = npb.merge_partials(states, minmax=(False, True),
                                    parts=[2, 1])
    assert uniq.tolist() == [1, 3, 7]
    cnt0, s0, s20 = slots[0][:3]
    assert cnt0.tolist() == [2, 1 + 1, 4]
    assert s0.tolist() == [4.0, 5.0 + 3.0, 8.0]
    assert s20.tolist() == [10.0, 25.0 + 9.0, 20.0]
    cnt1, s1, s21, mn1, mx1 = slots[1]
    assert cnt1.tolist() == [2, 2, 4]
    assert mn1.tolist() == [0.25, 2.0, 0.5]          # min plane element-wise
    assert mx1.tolist() == [0.75, 5.0, 1.0]          # max plane element-wise
    jxb = JaxBackend()
    juniq, jslots = jxb.merge_partials(states, minmax=(False, True),
                                       parts=[2, 1])
    assert np.array_equal(juniq, uniq)
    assert len(jslots) == len(slots)
    for ws, gs in zip(slots, jslots):
        assert len(gs) == len(ws)
        for wa, ga in zip(ws, gs):
            assert np.array_equal(np.asarray(ga), np.asarray(wa))


def test_merge_partials_all_empty_states():
    """All-pruned / nothing-selected partitions: the combine degenerates
    cleanly to an empty key space on both backends."""
    states = [_state([]), _state([])]
    for be in (get_backend("numpy"), JaxBackend()):
        uniq, slots = be.merge_partials(states, minmax=(), parts=[1, 1])
        assert uniq.size == 0 and slots == []


# ------------------------------------- engine identity across P = 1/2/4

@pytest.mark.parametrize("bname", ["numpy", "jax"])
def test_adhoc_agg_identical_across_partitions(dense_catalog, bname,
                                               monkeypatch):
    monkeypatch.setenv(FUSED_ENV, "1")
    ref = AdHocEngine(dense_catalog, num_servers=2, backend=bname,
                      wave=3, partitions=1).collect(ALL_AGG)
    for p in (2, 4):
        got = AdHocEngine(dense_catalog, num_servers=2, backend=bname,
                          wave=3, partitions=p).collect(ALL_AGG)
        assert_identical(ref.batch, got.batch)
    assert ref.batch.n > 0


@pytest.mark.parametrize("bname", ["numpy", "jax"])
def test_adhoc_selection_identical_across_partitions(dense_catalog, bname):
    ref = AdHocEngine(dense_catalog, num_servers=2, backend=bname,
                      wave=3, partitions=1).collect(SELECT)
    for p in (2, 4):
        got = AdHocEngine(dense_catalog, num_servers=2, backend=bname,
                          wave=3, partitions=p).collect(SELECT)
        assert_identical(ref.batch, got.batch)     # byte-identical rows
    assert ref.batch.n > 0


@pytest.mark.parametrize("bname", ["numpy", "jax"])
def test_flume_identical_across_partitions(dense_catalog, bname,
                                           monkeypatch):
    monkeypatch.setenv(FUSED_ENV, "1")
    ref = AdHocEngine(dense_catalog, num_servers=2, backend=bname,
                      wave=3, partitions=1).collect(ALL_AGG)
    for p in (2, 4):
        fl = FlumeEngine(dense_catalog, ckpt_dir=tempfile.mkdtemp(),
                         max_workers=4, backend=bname, wave=3,
                         partitions=p)
        assert_identical(ref.batch, fl.collect(ALL_AGG).batch)


# ------------------------------------------------------- launch contract

def test_partitioned_launch_contract(dense_catalog, dense_db, monkeypatch):
    """⌈shards_p/wave⌉ fused dispatches per partition + exactly one merge
    combine per query at P>1; the P=1 path keeps the legacy contract (no
    combine launch — the sequential host merge IS the reference)."""
    monkeypatch.setenv(FUSED_ENV, "1")
    for p, want_waves in ((1, math.ceil(7 / 3)),      # [7] → 3
                          (2, 2 + 1),                 # [4, 3] → ⌈4/3⌉+⌈3/3⌉
                          (4, 4)):                    # [2,2,2,1] → 1+1+1+1
        eng = AdHocEngine(dense_catalog, num_servers=2, backend="jax",
                          wave=3, partitions=p)
        eng.collect(ALL_AGG)                          # warm
        ops.reset_launch_counts()
        eng.collect(ALL_AGG)
        pp = partition_shards(range(dense_db.num_shards), p)
        assert pp.wave_dispatches(3) == want_waves
        want = {"run_wave_fused": want_waves}
        if p > 1:
            assert pp.merge_combines() == 1
            want["merge_partials"] = 1
        assert dict(ops.launch_counts()) == want, p


def test_empty_partitions_more_partitions_than_shards(monkeypatch):
    """P > shard count: tail partitions are empty, dispatch nothing, and
    results stay identical."""
    monkeypatch.setenv(FUSED_ENV, "1")
    schema = Schema("PartTiny", [
        Field("road", INT, indexes=("tag",)),
        Field("hour", INT, indexes=("range",)),
        Field("speed", DOUBLE),
    ])
    recs = [{"road": int(i % 5), "hour": int(i % 24),
             "speed": float(i) * 0.5, "_i": i} for i in range(20)]
    tiny = build_fdb("PartTiny", schema, recs, num_shards=2,
                     shard_key=lambda r: 0 if r["_i"] < 11 else 1)
    cat = Catalog(server_slots=8)
    cat.register(tiny)
    flow = (fdb("PartTiny").find(BETWEEN(P.hour, 0, 23))
            .aggregate(group(P.road).count("n").sum(s=P.speed)))
    ref = AdHocEngine(cat, num_servers=2, backend="jax", wave=3,
                      partitions=1).collect(flow)
    eng = AdHocEngine(cat, num_servers=2, backend="jax", wave=3,
                      partitions=4)
    eng.collect(flow)                                 # warm
    ops.reset_launch_counts()
    got = eng.collect(flow)
    assert_identical(ref.batch, got.batch)
    # [1], [1], [], [] → two dispatches, one combine
    assert dict(ops.launch_counts()) == {"run_wave_fused": 2,
                                         "merge_partials": 1}


# ------------------------------------------- pruning × partitions

def _banded_stream(name, n=48, flush=12):
    """Time-sorted ingestion ⇒ disjoint per-shard time bands (pruned)."""
    rng = np.random.default_rng(11)
    s = StreamingFDb(name, _track_schema(name), flush_threshold=flush,
                     compact_threshold=0)
    span = 2 * DAY
    for i in range(n):
        t0 = span * i / n
        ln = 5
        s.append({"id": i, "track": {
            "lat": rng.uniform(37.6, 37.9, ln).tolist(),
            "lng": rng.uniform(-122.5, -122.2, ln).tolist(),
            "t": (t0 + np.arange(ln) * 60.0).tolist()}})
    s.flush()
    return s


def _bay_region():
    ix, iy = M.latlng_to_xy(37.75, -122.35)
    d = 4_000_000
    return AreaTree.from_box(int(ix) - d, int(iy) - d,
                             int(ix) + d, int(iy) + d, max_level=7)


@pytest.mark.tesseract
def test_all_pruned_partitions(monkeypatch):
    """Pruning runs BEFORE partitioning: a window misses every shard →
    every partition is empty, zero dispatches, empty result; a window
    keeping fewer shards than P leaves trailing partitions empty."""
    monkeypatch.setenv(FUSED_ENV, "1")
    s = _banded_stream("PartPrune")
    cat = Catalog()
    cat.register(s)
    # all pruned: window far beyond the data's 2-day span
    none = fdb("PartPrune").tesseract(
        Tesseract(_bay_region(), 10 * DAY, 11 * DAY))
    assert plan_flow(none, cat).shard_ids == []
    for bname in ("numpy", "jax"):
        eng = AdHocEngine(cat, num_servers=2, backend=bname, wave=3,
                          partitions=4)
        assert eng.collect(none).batch.n == 0
    # partial prune, kept < P: results identical to the P=1 reference
    some = fdb("PartPrune").tesseract(
        Tesseract(_bay_region(), 0.0, 0.4 * DAY))
    kept = len(plan_flow(some, cat).shard_ids)
    assert 0 < kept < cat.get("PartPrune").num_shards
    for bname in ("numpy", "jax"):
        ref = AdHocEngine(cat, num_servers=2, backend=bname, wave=3,
                          partitions=1).collect(some)
        got = AdHocEngine(cat, num_servers=2, backend=bname, wave=3,
                          partitions=max(4, kept + 1)).collect(some)
        assert_identical(ref.batch, got.batch)
        assert ref.batch.n > 0


# ------------------------------------------- ordered first-hit path

@pytest.mark.tesseract
def test_ordered_first_hit_identical_across_partitions(walks_catalog):
    """The ordered Tesseract path (first-hit table + ordering edges) is a
    selection — partitioned runs must stay byte-identical at any P."""
    rng = np.random.default_rng(3)
    tess = Tesseract(_region(rng), 0.0, 1.5 * DAY).then(
        _region(rng), 0.0, 2 * DAY)
    flow = fdb("PartWalks").tesseract(tess)
    for bname in ("numpy", "jax"):
        ref = AdHocEngine(walks_catalog, num_servers=2, backend=bname,
                          wave=3, partitions=1).collect(flow)
        for p in (2, 4):
            got = AdHocEngine(walks_catalog, num_servers=2, backend=bname,
                              wave=3, partitions=p).collect(flow)
            assert_identical(ref.batch, got.batch)


# ------------------------------------------- partition-axis fault path

@pytest.mark.parametrize("engine_kind", ["adhoc", "flume"])
def test_partition_fault_reroutes_to_survivors(dense_catalog, engine_kind,
                                               monkeypatch):
    """A dead partition drains before dispatch and its shards reroute to
    the survivors (launch/elastic.py) — full coverage, identical result,
    and the recovery is visible on the profile."""
    monkeypatch.setenv(FUSED_ENV, "1")
    fp = FaultPlan(fail_always={("partition", 1)}, reroute_after=99)
    if engine_kind == "adhoc":
        eng = AdHocEngine(dense_catalog, num_servers=2, backend="jax",
                          wave=3, partitions=3)
        ref = eng.collect(ALL_AGG)
        res = eng.collect(ALL_AGG, fault_plan=fp)
        assert res.coverage == 1.0
    else:
        ref = FlumeEngine(dense_catalog, ckpt_dir=tempfile.mkdtemp(),
                          max_workers=4, backend="jax", wave=3,
                          partitions=3).collect(ALL_AGG)
        res = FlumeEngine(dense_catalog, ckpt_dir=tempfile.mkdtemp(),
                          max_workers=4, backend="jax", wave=3,
                          partitions=3).collect(ALL_AGG, fault_plan=fp)
    assert_identical(ref.batch, res.batch)
    assert res.profile.retries >= 1


# ------------------------------------------------- partitioned serve tier

@pytest.mark.tesseract
def test_serve_coalesced_rides_partition_layer(walks_catalog, walks_db,
                                               monkeypatch):
    """The coalesced multi-query path dispatches per partition but keeps
    its host-side per-query gather merge (partition-invariant) — parity
    with the numpy oracle and no merge combine launch."""
    monkeypatch.setenv(FUSED_ENV, "1")
    rng = np.random.default_rng(29)
    flows = [fdb("PartWalks").tesseract(
                 Tesseract(_region(rng), 0.0, 1.5 * DAY)),
             fdb("PartWalks").tesseract(
                 Tesseract(_region(rng), 0.3 * DAY, 2 * DAY))]
    np_eng = AdHocEngine(walks_catalog, num_servers=2, backend="numpy",
                         wave=3)
    oracle = [np_eng.collect(f) for f in flows]
    srv = QueryServer(catalog=walks_catalog, backend="jax", start=False,
                      cache=False)
    srv.engine.wave = 3
    srv.engine.partitions = 2
    futs = [srv.submit(f) for f in flows]
    srv.run_pending()                                 # warm
    for f, o in zip(futs, oracle):
        assert_identical(f.result(60).batch, o.batch)
    futs = [srv.submit(f) for f in flows]
    ops.reset_launch_counts()
    srv.run_pending()
    pp = partition_shards(range(walks_db.num_shards), 2)
    assert dict(ops.launch_counts()) == {
        "run_wave_fused_multi": pp.wave_dispatches(3)}
    for f, o in zip(futs, oracle):
        assert_identical(f.result(60).batch, o.batch)


# ------------------------------- eager buffer retirement (streaming)

def test_snapshot_turnover_retires_stale_buffers():
    """Priming a newer streaming generation eagerly drops the replaced
    generation's device buffers (no wait for the FDb finalizer) and the
    `retired_buffers` counter records it; re-priming the same snapshot
    retires nothing."""
    s = StreamingFDb("PartRetire", Schema("PartRetire", [
        Field("id", INT, indexes=("tag",)),
        Field("val", DOUBLE, indexes=("range",)),
    ]), flush_threshold=4, compact_threshold=0)
    # 10 docs, flush=4: 2 delta shards + a 2-doc memtable — snapshot1
    # materializes a memtable-backed shard EXCLUSIVE to this generation,
    # which is exactly what must retire on turnover
    s.extend([{"id": i, "val": float(i)} for i in range(10)])
    be = JaxBackend()
    snap1 = s.snapshot()
    be.prime_fdb(snap1)
    n1 = len(be.device_cache)
    assert n1 > 0
    assert be.device_cache.stats()["retired_buffers"] == 0
    s.extend([{"id": i, "val": float(i)} for i in range(10, 18)])
    snap2 = s.snapshot()
    be.prime_fdb(snap2)
    st = be.device_cache.stats()
    # snap1's delta shards carry over into snap2 (shared objects) — only
    # buffers exclusive to the replaced generation retire
    assert st["retired_buffers"] > 0
    retired = st["retired_buffers"]
    be.prime_fdb(snap2)                               # idempotent
    assert be.device_cache.stats()["retired_buffers"] == retired
