"""End-to-end drivers: train loop (ckpt/resume/SIGTERM-safe), serving,
data pipeline determinism, elastic reshard plan."""
import os

import numpy as np
import jax
import pytest

from repro.data.pipeline import TokenPipeline
from repro.launch.train import train_loop
from repro.launch.serve import Request, Server


def test_pipeline_deterministic_and_restartable():
    p1 = TokenPipeline(100, 4, 16, seed=7)
    batches = [next(p1) for _ in range(5)]
    state = {"seed": 7, "step": 3}
    p2 = TokenPipeline.restore(state, 100, 4, 16)
    b3 = next(p2)
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])
    p1.close()
    p2.close()


def test_pipeline_labels_shifted():
    p = TokenPipeline(50, 2, 8, seed=0)
    b = next(p)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    p.close()


@pytest.mark.slow
def test_train_decreases_loss_and_resumes(tmp_path):
    d = str(tmp_path / "ckpt")
    _, _, losses = train_loop("smollm_360m", reduced=True, steps=40,
                              batch=4, seq=64, ckpt_dir=d, ckpt_every=20,
                              log_every=39, print_fn=lambda *a: None)
    assert np.isfinite(losses[-1][1])
    # resume continues from the checkpointed step
    _, _, losses2 = train_loop("smollm_360m", reduced=True, steps=50,
                               batch=4, seq=64, ckpt_dir=d, resume=True,
                               log_every=1, print_fn=lambda *a: None)
    assert losses2[0][0] >= 40


@pytest.mark.slow
def test_server_generates():
    rng = np.random.default_rng(0)
    srv = Server("qwen1_5_0_5b", reduced=True, max_batch=2)
    reqs = [Request(i, rng.integers(0, srv.cfg.vocab_size,
                                    6).astype(np.int32), max_new=4)
            for i in range(3)]
    srv.serve(reqs)
    assert all(r.done and len(r.out) == 4 for r in reqs)
    assert all(0 <= t < srv.cfg.vocab_size for r in reqs for t in r.out)


def test_reshard_plan():
    from repro.configs.base import get_config
    from repro.launch.elastic import reshard_plan
    from repro.ml.model import ModelBundle
    cfg = get_config("smollm_360m").reduced()
    m1 = jax.make_mesh((1, 1), ("data", "model"))
    mb1 = ModelBundle(cfg, m1)
    plan = reshard_plan(mb1, mb1)
    assert plan["ratio"] == pytest.approx(1.0)
    assert plan["param_bytes_per_device_before"] > 0
