"""Execution engines: AdHoc vs Flume equivalence, failures, stragglers,
checkpoint recovery, resource isolation, profiling log."""
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.core import P, proto, BETWEEN, group, fdb
from repro.exec import (AdHocEngine, Catalog, FaultPlan, FlumeEngine,
                        ResourceManager)


@pytest.fixture()
def q():
    return (fdb("Obs").find(BETWEEN(P.hour, 8, 9))
            .aggregate(group(P.road_id).count("n").avg(m=P.speed)))


def test_adhoc_flume_equivalence(engine, catalog, q):
    fl = FlumeEngine(catalog, ckpt_dir=tempfile.mkdtemp(), max_workers=5)
    a = engine.collect(q).to_records()
    b = fl.collect(q).to_records()
    assert a == b


def test_flume_checkpoint_recovery(catalog, q):
    fl = FlumeEngine(catalog, ckpt_dir=tempfile.mkdtemp(), max_workers=5)
    first = fl.collect(q).to_records()
    ran = fl.stats["tasks_run"]
    again = fl.collect(q).to_records()
    assert again == first
    assert fl.stats["tasks_run"] == ran          # nothing recomputed
    assert fl.stats["tasks_skipped"] >= 5


def test_flume_resumes_after_partial_failure(catalog, q):
    """Crash mid-job → rerun completes from stage checkpoints."""
    ckpt = tempfile.mkdtemp()
    fl = FlumeEngine(catalog, ckpt_dir=ckpt, max_workers=5, max_attempts=1)
    fp = FaultPlan(fail_always={("server", 3)}, reroute_after=99)
    with pytest.raises(Exception):
        fl.collect(q, fault_plan=fp, job_id="job1")
    # "machine replaced": rerun without faults reuses completed tasks
    fl2 = FlumeEngine(catalog, ckpt_dir=ckpt, max_workers=5)
    res = fl2.collect(q, job_id="job1")
    clean = FlumeEngine(catalog, ckpt_dir=tempfile.mkdtemp(),
                        max_workers=5).collect(q)
    assert res.to_records() == clean.to_records()
    assert fl2.stats["tasks_skipped"] >= 4       # recovered work reused


def test_adhoc_best_effort_drops_and_reports(engine, q):
    fp = FaultPlan(fail_always={("server", 2)}, reroute_after=99)
    res = engine.collect(q, fault_plan=fp)
    assert res.coverage == pytest.approx(4 / 5)
    assert res.profile.dropped_shards == [2]


def test_adhoc_transient_retry(engine, q):
    fp = FaultPlan(fail_once={("server", 0)})
    res = engine.collect(q, fault_plan=fp)
    assert res.coverage == 1.0
    assert res.profile.retries == 1


def test_flume_reroutes_dead_machine(catalog, engine, q):
    fp = FaultPlan(fail_always={("server", 1)}, reroute_after=3)
    fl = FlumeEngine(catalog, ckpt_dir=tempfile.mkdtemp(), max_workers=5)
    res = fl.collect(q, fault_plan=fp)
    assert res.to_records() == engine.collect(q).to_records()
    assert fl.stats["retries"] >= 2


def test_speculative_execution_beats_straggler(catalog, q):
    fp = FaultPlan(straggle={("server", 0): 1.5})
    fl = FlumeEngine(catalog, ckpt_dir=tempfile.mkdtemp(), max_workers=5,
                     speculation=True, speculation_factor=3.0)
    t0 = time.perf_counter()
    res = fl.collect(q, fault_plan=fp)
    elapsed = time.perf_counter() - t0
    # NOTE: the straggler sleeps on *every* attempt, so speculation cannot
    # beat it here — but it must launch, and results must stay exact.
    assert fl.stats["speculative_launched"] >= 1
    assert res.profile.shards_done == 5


def test_resource_queueing():
    rm = ResourceManager(total_slots=2)
    got = rm.acquire(2)
    order = []

    def waiter():
        n = rm.acquire(2)
        order.append("acquired")
        rm.release(n)

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    assert order == []             # queued behind the running query
    rm.release(got)
    t.join(timeout=2)
    assert order == ["acquired"]
    assert rm.stats["waited"] >= 1


def test_sampling_uses_shard_subset(engine, catalog):
    q_full = fdb("Obs").aggregate(group().count("n"))
    q_samp = fdb("Obs").sample(0.4).aggregate(group().count("n"))
    full = engine.collect(q_full)
    samp = engine.collect(q_samp)
    assert samp.profile.shards_total == 2        # 40% of 5 shards
    n_full = full.to_records()[0]["n"]
    n_samp = samp.to_records()[0]["n"]
    assert 0.25 * n_full < n_samp < 0.55 * n_full


def test_profile_log_queryable_with_wfl(engine, q):
    """Query profiles land in a streaming FDb queryable by WarpFlow itself."""
    engine.collect(q)
    snap = engine.profile_log.snapshot()
    local = Catalog(server_slots=4)
    local.register(snap)
    sub = AdHocEngine(local, num_servers=2)
    res = sub.collect(fdb("warpflow.query_log")
                      .map(lambda p: proto(src=p.source,
                                           rows=p.rows_scanned)))
    recs = res.to_records()
    assert any(r["src"] == "Obs" and r["rows"] > 0 for r in recs)


def test_save_registers_new_fdb(engine, catalog):
    q = (fdb("Roads").find(P.city == "SF")
         .map(lambda p: proto(rid=p.id, sl=p.speed_limit)))
    db = engine.save(q, "SFRoads", num_shards=3)
    assert "SFRoads" in catalog.names()
    res = engine.collect(fdb("SFRoads").aggregate(group().count("n")))
    n_sf = res.to_records()[0]["n"]
    assert n_sf == db.num_docs > 0
