"""Tesseract subsystem: space-time index correctness, engine wiring,
backend parity, and pruning power (ISSUE-2 acceptance criteria)."""
import numpy as np
import pytest

from repro.core import P, fdb, proto
from repro.core.exprs import EvalContext, InSpaceTime, FieldRef, eval_expr
from repro.core.planner import plan_flow
from repro.data.synthetic import CITIES, city_region, generate_world
from repro.exec import AdHocEngine, Catalog, FlumeEngine
from repro.fdb import FDb, build_fdb
from repro.fdb.index import ids_from_bitmap
from repro.geo import AreaTree, mercator as M
from repro.tess import SpaceTimeIndex, Tesseract, tesseract_stats

pytestmark = pytest.mark.tesseract

DAY = 2
NUM_SHARDS = 12          # acceptance: ≥ 10 shards


def window(h0, h1, day=DAY):
    return day * 86400.0 + h0 * 3600.0, day * 86400.0 + h1 * 3600.0


@pytest.fixture(scope="module")
def trips_world():
    return generate_world(scale=0.2, seed=0)


@pytest.fixture(scope="module")
def trips_catalog(trips_world):
    cat = Catalog(server_slots=32)
    cat.register(build_fdb("Trips", trips_world["trips_schema"],
                           trips_world["trips"], num_shards=NUM_SHARDS))
    return cat


@pytest.fixture(scope="module")
def two_leg_tess():
    """The §2 query: through SF during T1 AND through Berkeley during T2."""
    sf_t = window(6, 12)
    bk_t = window(6, 14)
    return (Tesseract(city_region("SF"), *sf_t)
            .also(city_region("Berkeley"), *bk_t))


def brute_force_ids(trips, tess):
    """Reference semantics straight off the record dicts."""
    out = []
    for tr in trips:
        keys = M.latlng_to_morton(np.asarray(tr["track"]["lat"]),
                                  np.asarray(tr["track"]["lng"]))
        ts = np.asarray(tr["track"]["t"])
        ok = True
        for region, t0, t1 in tess.constraints:
            if not np.any(region.contains(keys) & (ts >= t0) & (ts <= t1)):
                ok = False
                break
        if ok:
            out.append(tr["id"])
    return sorted(out)


# ------------------------------------------------------------------ index

def test_spacetime_index_is_conservative(trips_world):
    """Index candidates are always a superset of the exact matches."""
    trips = trips_world["trips"]
    db = build_fdb("T", trips_world["trips_schema"], trips, num_shards=4)
    rng = np.random.default_rng(0)
    regions = [city_region(c) for c in CITIES]
    for _ in range(20):
        region = regions[int(rng.integers(0, len(regions)))]
        day = int(rng.integers(0, 7))
        h0 = float(rng.uniform(0, 20))
        t0, t1 = window(h0, h0 + float(rng.uniform(0.5, 6.0)), day)
        pred = InSpaceTime(FieldRef("track"), region, t0, t1)
        for shard in db.shards:
            idx = shard.index("track", "spacetime")
            cand = set(ids_from_bitmap(idx.lookup(region, t0, t1),
                                       shard.n).tolist())
            v = eval_expr(pred, EvalContext(shard.batch))
            exact = set(np.nonzero(np.asarray(v.values,
                                              dtype=bool))[0].tolist())
            assert exact <= cand


def test_spacetime_index_empty_cases(trips_world):
    trips = trips_world["trips"]
    db = build_fdb("T", trips_world["trips_schema"], trips, num_shards=2)
    idx = db.shards[0].index("track", "spacetime")
    assert isinstance(idx, SpaceTimeIndex)
    n = db.shards[0].n
    # empty region / inverted window → zero candidates
    assert ids_from_bitmap(idx.lookup(AreaTree.empty(), 0.0, 1e9),
                           n).size == 0
    assert ids_from_bitmap(idx.lookup(city_region("SF"), 100.0, 50.0),
                           n).size == 0
    # window outside the whole week → span prune clears everything
    assert ids_from_bitmap(idx.lookup(city_region("SF"), 2e7, 3e7),
                           n).size == 0


def test_spacetime_index_out_of_range_windows(trips_world):
    """Windows entirely outside the representable bucket range must return
    empty instead of aliasing into the clamped boundary buckets'
    postings (regression: pre-epoch windows used to probe bucket 0)."""
    trips = trips_world["trips"]
    db = build_fdb("T", trips_world["trips_schema"], trips, num_shards=2)
    idx = db.shards[0].index("track", "spacetime")   # epoch=0.0 (schema)
    n = db.shards[0].n
    region = city_region("SF")
    # entirely before epoch: b1 < 0 — not bucket 0
    assert idx._bucket_range(-5000.0, -1.0) is None
    assert ids_from_bitmap(idx.lookup(region, -5000.0, -1.0), n).size == 0
    # entirely past the last representable bucket: b0 > 2^20 − 1
    horizon = idx.epoch + (1 << 20) * idx.bucket_s
    assert idx._bucket_range(horizon + 1e9, horizon + 2e9) is None
    assert ids_from_bitmap(idx.lookup(region, horizon + 1e9, horizon + 2e9),
                           n).size == 0
    # straddling epoch: clamps to bucket 0 and stays conservative
    assert idx._bucket_range(-5000.0, 1.0) == (0, 0)
    week = ids_from_bitmap(idx.lookup(region, 0.0, 7 * 86400.0), n)
    straddle = ids_from_bitmap(idx.lookup(region, -5000.0, 900.0), n)
    assert set(straddle.tolist()) <= set(week.tolist())


def test_spacetime_index_clamped_epoch_stays_conservative(trips_world):
    """If build clamped pre-epoch points into bucket 0 (epoch chosen above
    the data's earliest t), a pre-epoch window must collapse onto bucket 0
    and stay a superset of the exact matches — find() may never silently
    drop docs that filter() returns."""
    trips = trips_world["trips"]
    lo_t = min(min(tr["track"]["t"], default=np.inf) for tr in trips)
    epoch = float(lo_t) + 4 * 86400.0          # violates epoch ≤ min t
    sh = build_fdb("T", trips_world["trips_schema"], trips,
                   num_shards=1).shards[0]
    tt = sh.batch["track.t"]
    idx = SpaceTimeIndex.build(sh.batch["track.lat"].values,
                               sh.batch["track.lng"].values, tt.values,
                               sh.n, tt.row_splits, level=6,
                               bucket_s=900.0, epoch=epoch)
    assert idx.clamped_lo and not idx.clamped_hi
    region = city_region("SF")
    t0, t1 = float(lo_t), float(lo_t) + 86400.0      # entirely pre-epoch
    assert idx._bucket_range(t0, t1) == (0, 0)       # boundary collapse
    cand = set(ids_from_bitmap(idx.lookup(region, t0, t1), sh.n).tolist())
    pred = InSpaceTime(FieldRef("track"), region, t0, t1)
    v = eval_expr(pred, EvalContext(sh.batch))
    exact = set(np.nonzero(np.asarray(v.values, dtype=bool))[0].tolist())
    assert exact and exact <= cand


def test_spacetime_index_time_discrimination(trips_world):
    """Same region, disjoint window → candidates don't leak across time."""
    trips = trips_world["trips"]
    db = build_fdb("T", trips_world["trips_schema"], trips, num_shards=1)
    idx = db.shards[0].index("track", "spacetime")
    region = city_region("SF")
    week = ids_from_bitmap(idx.lookup(region, 0.0, 7 * 86400.0),
                           db.shards[0].n)
    one_hour = ids_from_bitmap(idx.lookup(region, *window(3, 4, day=6)),
                               db.shards[0].n)
    assert set(one_hour.tolist()) <= set(week.tolist())
    assert one_hour.size < week.size


# ---------------------------------------------------------------- planner

def test_planner_compiles_probes_plus_refine(trips_catalog, two_leg_tess):
    plan = plan_flow(fdb("Trips").tesseract(two_leg_tess), trips_catalog)
    assert [p.kind for p in plan.probes] == ["spacetime", "spacetime"]
    # conservative probes compile the exact constraints into one refine
    # spec over the track field (device-side pass), not the residual
    assert plan.residual is None
    assert len(plan.refines) == 1
    assert plan.refines[0].path == "track"
    assert len(plan.refines[0].constraints) == 2
    # raw collect still reads every stored column, tracks included
    assert {"track.lat", "track.lng", "track.t"} <= set(plan.source_paths)
    assert "track refine" in plan.describe()


def test_planner_refine_composes_with_residual(trips_catalog, two_leg_tess):
    """Non-indexable conjuncts stay in the residual next to the refine."""
    flow = fdb("Trips").find(two_leg_tess.expr()
                             & (P.duration_s * 2.0 > 100.0))
    plan = plan_flow(flow, trips_catalog)
    assert len(plan.refines) == 1
    assert plan.residual is not None
    eng = AdHocEngine(trips_catalog, num_servers=4)
    res = eng.collect(flow)
    assert np.all(res.batch["duration_s"].values * 2.0 > 100.0)


def test_tesseract_composes_with_other_conjuncts(trips_catalog,
                                                 two_leg_tess):
    flow = fdb("Trips").find(two_leg_tess.expr() & (P.day == DAY))
    plan = plan_flow(flow, trips_catalog)
    kinds = sorted(p.kind for p in plan.probes)
    assert kinds == ["range", "spacetime", "spacetime"]   # day eq → range
    eng = AdHocEngine(trips_catalog, num_servers=4)
    res = eng.collect(flow)
    days = res.batch["day"].values
    assert np.all(days == DAY)


def test_tesseract_window_validation():
    with pytest.raises(ValueError):
        Tesseract(AreaTree.everything(), 10.0, 5.0)
    with pytest.raises(ValueError):
        Tesseract(AreaTree.everything(), 0.0, 1.0).also(
            AreaTree.everything(), 10.0, 5.0)
    with pytest.raises(ValueError):
        Tesseract(AreaTree.everything(), 0.0, 1.0).then(
            AreaTree.everything(), 10.0, 5.0)


# ----------------------------------------------------- ordered constraints

def test_then_before_builder():
    """then() = also() + edge(prev, new); before() adds arbitrary edges;
    builders stay immutable (no edge leaks into the parent)."""
    ev = AreaTree.everything()
    base = Tesseract(ev, 0.0, 1.0).also(ev, 2.0, 3.0)
    assert base.order_edges == ()
    chained = Tesseract(ev, 0.0, 1.0).then(ev, 2.0, 3.0).then(ev, 4.0, 5.0)
    assert chained.order_edges == ((0, 1), (1, 2))
    assert base.order_edges == ()                 # parent untouched
    dag = base.also(ev, 4.0, 5.0).before(0, 2).before(1, 2)
    assert dag.order_edges == ((0, 2), (1, 2))
    with pytest.raises(ValueError):
        base.before(0, 5)                         # out of range
    with pytest.raises(ValueError):
        base.before(1, 1)                         # self-edge
    assert "2 ordering edges" in repr(chained)
    # unordered builders keep compiling to plain InSpaceTime conjuncts
    from repro.core.exprs import InSpaceTimeSeq
    assert isinstance(chained.expr()._expr, InSpaceTimeSeq)
    assert not isinstance(base.expr()._expr, InSpaceTimeSeq)


def test_planner_compiles_ordered_refine(trips_catalog, two_leg_tess):
    """Ordered constraints compile to per-constraint spacetime probes plus
    ONE RefineSpec carrying the edges — and merging with plain InSpaceTime
    conjuncts offsets the edges to the merged indices."""
    sf_t, bk_t = window(6, 12), window(6, 14)
    ordered = (Tesseract(city_region("SF"), *sf_t)
               .then(city_region("Berkeley"), *bk_t))
    plan = plan_flow(fdb("Trips").tesseract(ordered), trips_catalog)
    assert [p.kind for p in plan.probes] == ["spacetime", "spacetime"]
    assert plan.residual is None
    assert len(plan.refines) == 1
    assert plan.refines[0].constraints and plan.refines[0].edges == [(0, 1)]
    assert "ordering edges" in plan.describe()
    # plain conjunct ahead of the ordered node: edges shift past it
    plain = Tesseract(city_region("LA"), *window(0, 23)).expr()
    plan2 = plan_flow(fdb("Trips").find(plain & ordered.expr()),
                      trips_catalog)
    assert len(plan2.refines) == 1
    assert len(plan2.refines[0].constraints) == 3
    assert plan2.refines[0].edges == [(1, 2)]


def brute_force_ordered_ids(trips, tess):
    """Reference ordered semantics straight off the record dicts: every
    constraint hits AND first-hit(i) strictly before first-hit(j) per
    edge (first hit = min t among the constraint's satisfying points)."""
    out = []
    for tr in trips:
        keys = M.latlng_to_morton(np.asarray(tr["track"]["lat"]),
                                  np.asarray(tr["track"]["lng"]))
        ts = np.asarray(tr["track"]["t"])
        firsts, ok = [], True
        for region, t0, t1 in tess.constraints:
            hit = region.contains(keys) & (ts >= t0) & (ts <= t1)
            if not np.any(hit):
                ok = False
                break
            firsts.append(ts[hit].min())
        if ok:
            for i, j in tess.order_edges:
                if not firsts[i] < firsts[j]:
                    ok = False
                    break
        if ok:
            out.append(tr["id"])
    return sorted(out)


def test_ordered_query_matches_brute_force(trips_world, trips_catalog):
    """Acceptance: ordered trip-id sets byte-identical across backends on
    ≥10 shards, and both match reference semantics — with ordering a
    strict subset of the unordered result on this world."""
    sf_t, bk_t = window(6, 12), window(6, 14)
    ordered = (Tesseract(city_region("SF"), *sf_t)
               .then(city_region("Berkeley"), *bk_t))
    unordered = (Tesseract(city_region("SF"), *sf_t)
                 .also(city_region("Berkeley"), *bk_t))
    want = brute_force_ordered_ids(trips_world["trips"], ordered)
    ids = {}
    for b in ("numpy", "jax"):
        res = AdHocEngine(trips_catalog, num_servers=4,
                          backend=b).collect(
            fdb("Trips").tesseract(ordered))
        ids[b] = sorted(res.batch["id"].values.tolist())
    assert ids["numpy"] == ids["jax"] == want
    plain = set(brute_force_ids(trips_world["trips"], unordered))
    assert set(want) <= plain


def test_ordered_flume_matches_adhoc(trips_catalog, tmp_path):
    ordered = (Tesseract(city_region("SF"), *window(6, 12))
               .then(city_region("Berkeley"), *window(6, 14)))
    flow = (fdb("Trips").tesseract(ordered)
            .map(lambda p: proto(id=p.id)))
    ref = AdHocEngine(trips_catalog, num_servers=4,
                      backend="numpy").collect(flow)
    fl = FlumeEngine(trips_catalog, ckpt_dir=str(tmp_path), max_workers=4,
                     backend="jax").collect(flow)
    assert sorted(ref.batch["id"].values.tolist()) \
        == sorted(fl.batch["id"].values.tolist())


def test_spacetime_index_rejects_overflowing_level():
    # (6·level + TIME_BITS) bits must fit a uint64 packed key; level 8+
    # would silently wrap and drop matches, so build refuses it
    z = np.zeros(0)
    for level in (8, 9, 10, 0):
        with pytest.raises(ValueError):
            SpaceTimeIndex.build(z, z, z, 0, None, level=level)
    with pytest.raises(ValueError):
        SpaceTimeIndex.build(z, z, z, 0, None, bucket_s=0.0)
    SpaceTimeIndex.build(z, z, z, 0, None, level=7)   # max legal level


# ------------------------------------------------------- engines + parity

def test_two_constraint_parity_numpy_vs_jax(trips_world, trips_catalog,
                                            two_leg_tess):
    """Acceptance: identical trip-id sets across backends over ≥10 shards."""
    db = trips_catalog.get("Trips")
    assert db.num_shards >= 10
    flow = (fdb("Trips").tesseract(two_leg_tess)
            .map(lambda p: proto(id=p.id, duration_s=p.duration_s)))
    ids = {}
    for b in ("numpy", "jax"):
        res = AdHocEngine(trips_catalog, num_servers=4,
                          backend=b).collect(flow)
        ids[b] = sorted(res.batch["id"].values.tolist())
    assert ids["numpy"] == ids["jax"]
    # ...and both match brute-force reference semantics
    assert ids["numpy"] == brute_force_ids(trips_world["trips"],
                                           two_leg_tess)
    assert len(ids["numpy"]) > 0


def test_flume_engine_matches_adhoc(trips_catalog, two_leg_tess, tmp_path):
    flow = (fdb("Trips").tesseract(two_leg_tess)
            .map(lambda p: proto(id=p.id)))
    ref = AdHocEngine(trips_catalog, num_servers=4,
                      backend="numpy").collect(flow)
    fl = FlumeEngine(trips_catalog, ckpt_dir=str(tmp_path), max_workers=4,
                     backend="jax").collect(flow)
    assert sorted(ref.batch["id"].values.tolist()) \
        == sorted(fl.batch["id"].values.tolist())


def test_pruning_ratio_selective_region(trips_catalog, two_leg_tess):
    """Acceptance: the index prunes ≥ 90 % of trips for selective regions."""
    db = trips_catalog.get("Trips")
    stats = tesseract_stats(db, two_leg_tess)
    assert stats["docs"] == db.num_docs
    assert stats["refined"] <= stats["candidates"]
    assert stats["pruning"] >= 0.9
    # stats' exact pass agrees with the engine result
    res = AdHocEngine(trips_catalog, num_servers=4).collect(
        fdb("Trips").tesseract(two_leg_tess))
    assert res.batch.n == stats["refined"]
    # profile's candidate accounting matches the stats probe
    assert res.profile.rows_selected == stats["candidates"]


def test_save_load_roundtrip_preserves_spacetime_index(trips_world,
                                                       two_leg_tess,
                                                       tmp_path):
    db = build_fdb("Trips", trips_world["trips_schema"],
                   trips_world["trips"], num_shards=NUM_SHARDS)
    db.save(str(tmp_path))
    db2 = FDb.load(str(tmp_path))
    cat = Catalog()
    cat.register(db2)
    res = AdHocEngine(cat, num_servers=4).collect(
        fdb("Trips").tesseract(two_leg_tess))
    assert sorted(res.batch["id"].values.tolist()) \
        == brute_force_ids(trips_world["trips"], two_leg_tess)
