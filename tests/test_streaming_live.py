"""Live ingestion end-to-end: incremental delta-shard indexing at flush,
LSM compaction equivalence, generation-cached snapshots, time-partition
shard pruning (plan- and launch-visible), incremental device priming of
delta buffers only, ingest-while-serving snapshot isolation (no torn
reads), and the append → cache-invalidation → recompute chain."""
import math
import threading

import numpy as np
import pytest

from repro.core import BETWEEN, P, fdb
from repro.core.planner import plan_flow
from repro.exec import AdHocEngine, Catalog, JaxBackend
from repro.exec.batched import FUSED_ENV
from repro.fdb import DOUBLE, INT, Schema
from repro.fdb.schema import Field, MESSAGE
from repro.fdb.streaming import StreamingFDb
from repro.geo import AreaTree, mercator as M
from repro.kernels import ops
from repro.serve import QueryServer, ResultCache
from repro.tess import Tesseract

DAY = 86400.0


# --------------------------------------------------------------- fixtures

def _track_schema(name):
    return Schema(name, [
        Field("id", INT, indexes=("tag",)),
        Field("track", MESSAGE, fields=[
            Field("lat", DOUBLE, repeated=True),
            Field("lng", DOUBLE, repeated=True),
            Field("t", DOUBLE, repeated=True)],
            indexes=("spacetime",),
            index_params={"level": 6, "bucket_s": 900.0, "epoch": 0.0}),
    ])


def _track_rec(i, t0, rng, n=6):
    """One short track near SF starting at ``t0`` (spans ~25 min)."""
    return {"id": i, "track": {
        "lat": rng.uniform(37.6, 37.9, n).tolist(),
        "lng": rng.uniform(-122.5, -122.2, n).tolist(),
        "t": (t0 + np.arange(n) * 300.0).tolist()}}


def _time_sorted_stream(name, n=96, flush=16, compact=0):
    """Time-sorted ingestion ⇒ each delta shard covers a disjoint time
    band — the partitioned-table layout the pruner exploits."""
    rng = np.random.default_rng(7)
    s = StreamingFDb(name, _track_schema(name), flush_threshold=flush,
                     compact_threshold=compact)
    span = 3 * DAY
    for i in range(n):
        s.append(_track_rec(i, t0=span * i / n, rng=rng))
    s.flush()
    return s


def _bay_region():
    ix, iy = M.latlng_to_xy(37.75, -122.35)
    d = 4_000_000
    return AreaTree.from_box(int(ix) - d, int(iy) - d,
                             int(ix) + d, int(iy) + d, max_level=7)


def _ids(batch):
    return sorted(int(v) for v in batch["id"].values)


def _dense_schema(name):
    return Schema(name, [
        Field("id", INT, indexes=("tag",)),
        Field("hour", INT, indexes=("range",)),
        Field("speed", DOUBLE),
    ])


# ------------------------------------------- incremental indexing + LSM

def test_flush_builds_delta_indexes_incrementally():
    s = _time_sorted_stream("LiveIdx", n=40, flush=10)
    assert s.stats()["delta_shards"] == 4
    for sh in s._shards:
        idx = sh.index("track", "spacetime")
        assert idx is not None
        lo, hi = idx.span()
        assert 0.0 <= lo <= hi <= 3 * DAY + 3600
    # delta spans are disjoint time bands (time-sorted ingestion)
    spans = [sh.index("track", "spacetime").span() for sh in s._shards]
    for (_, hi), (lo, _) in zip(spans, spans[1:]):
        assert hi <= lo + 1e-9


def test_compaction_preserves_rows_and_order():
    s = _time_sorted_stream("LiveCompact", n=40, flush=10)
    before = s.snapshot()
    ids_before = np.concatenate(
        [sh.batch["id"].values for sh in before.shards])
    assert s.compact()
    st = s.stats()
    assert st["sealed_shards"] == 1 and st["delta_shards"] == 0
    assert st["compactions"] == 1
    after = s.snapshot()
    ids_after = np.concatenate(
        [sh.batch["id"].values for sh in after.shards])
    assert np.array_equal(ids_before, ids_after)   # row order preserved
    assert after.shards[0].index("track", "spacetime") is not None
    assert not s.compact()                         # <2 deltas → no-op


def test_auto_compaction_at_threshold():
    rng = np.random.default_rng(3)
    s = StreamingFDb("LiveAuto", _track_schema("LiveAuto"),
                     flush_threshold=4, compact_threshold=3)
    s.extend([_track_rec(i, t0=100.0 * i, rng=rng) for i in range(12)])
    s.drain_compaction()          # merges run on the background worker
    st = s.stats()
    assert st["compactions"] >= 1
    assert st["delta_shards"] < 3
    assert s.num_docs == 12


def test_snapshot_identity_cached_per_generation():
    rng = np.random.default_rng(5)
    s = StreamingFDb("LiveGen", _track_schema("LiveGen"),
                     flush_threshold=8)
    s.append(_track_rec(0, t0=0.0, rng=rng))
    g1 = s.generation
    snap1 = s.snapshot()
    assert s.snapshot() is snap1               # stable while unchanged
    s.append(_track_rec(1, t0=300.0, rng=rng))
    assert s.generation > g1
    snap2 = s.snapshot()
    assert snap2 is not snap1
    assert snap2.num_docs == 2 and snap1.num_docs == 1


# ------------------------------------------------- pruning: plan + launch

@pytest.mark.tesseract
def test_pruning_shrinks_plan_and_fused_launches(exec_pplan, monkeypatch):
    monkeypatch.setenv(FUSED_ENV, "1")
    s = _time_sorted_stream("LivePrune", n=96, flush=16)
    cat = Catalog()
    cat.register(s)
    db = cat.get("LivePrune")
    total = db.num_shards
    # a half-day window inside day 0 → only the first time band(s) survive
    flow = fdb("LivePrune").tesseract(
        Tesseract(_bay_region(), 0.0, 0.5 * DAY))
    plan = plan_flow(flow, cat)
    kept = len(plan.shard_ids)
    assert 0 < kept < total
    assert plan.stats.get("pruned_shards") == total - kept
    wave = 3
    eng = AdHocEngine(cat, num_servers=2, backend="jax", wave=wave)
    eng.collect(flow)                              # warm
    ops.reset_launch_counts()
    res = eng.collect(flow)
    lc = ops.launch_counts()
    # partition-aware contract: the PartitionPlan is built over the PRUNED
    # shard list, so pruning shrinks every partition's wave count
    assert lc.get("run_wave_fused") == \
        exec_pplan(kept, eng.backend).wave_dispatches(wave)
    # fewer dispatches than the unpruned plan (== only when per-partition
    # ceils coincide at P>1; the kept-based count above is the contract)
    assert exec_pplan(kept, eng.backend).wave_dispatches(wave) <= \
        exec_pplan(total, eng.backend).wave_dispatches(wave)
    assert kept < total
    # parity: numpy oracle over the same live snapshot
    want = AdHocEngine(cat, num_servers=2, backend="numpy",
                       wave=wave).collect(flow)
    assert _ids(res.batch) == _ids(want.batch)
    assert res.batch.n > 0


@pytest.mark.tesseract
def test_pruning_launch_contract_unfused(exec_pplan, monkeypatch):
    monkeypatch.setenv(FUSED_ENV, "0")
    s = _time_sorted_stream("LivePruneU", n=64, flush=16)
    cat = Catalog()
    cat.register(s)
    flow = fdb("LivePruneU").tesseract(
        Tesseract(_bay_region(), 0.0, 0.5 * DAY))
    kept = len(plan_flow(flow, cat).shard_ids)
    assert 0 < kept < cat.get("LivePruneU").num_shards
    wave = 2
    eng = AdHocEngine(cat, num_servers=2, backend="jax", wave=wave)
    eng.collect(flow)                              # warm
    ops.reset_launch_counts()
    eng.collect(flow)
    lc = ops.launch_counts()
    assert lc.get("refine_tracks_batched") == \
        exec_pplan(kept, eng.backend).wave_dispatches(wave)
    assert lc.get("refine_tracks", 0) == 0


@pytest.mark.tesseract
def test_prune_all_shards_yields_empty_result():
    s = _time_sorted_stream("LiveNone", n=32, flush=8)
    cat = Catalog()
    cat.register(s)
    # window far beyond every ingested timestamp → every shard pruned
    flow = fdb("LiveNone").tesseract(
        Tesseract(_bay_region(), 30 * DAY, 31 * DAY))
    plan = plan_flow(flow, cat)
    assert plan.shard_ids == []
    res = AdHocEngine(cat, num_servers=2, backend="numpy").collect(flow)
    assert res.batch.n == 0


# ----------------------------------------------------- incremental prime

@pytest.mark.tesseract
def test_prime_uploads_only_new_delta_buffers():
    rng = np.random.default_rng(11)
    s = StreamingFDb("LivePrime", _track_schema("LivePrime"),
                     flush_threshold=8, compact_threshold=0)
    s.extend([_track_rec(i, t0=300.0 * i, rng=rng) for i in range(16)])
    jxb = JaxBackend()
    snap1 = s.snapshot()
    n1 = jxb.prime_fdb(snap1)
    assert n1 > 0
    assert jxb.prime_fdb(snap1) == 0               # idempotent per gen
    buffers1 = jxb.device_cache.stats()["buffers"]
    # one more flushed delta shard → exactly its buffers upload
    s.extend([_track_rec(16 + i, t0=300.0 * (16 + i), rng=rng)
              for i in range(8)])
    snap2 = s.snapshot()
    assert snap2 is not snap1
    n2 = jxb.prime_fdb(snap2)
    assert 0 < n2 < n1                             # delta only, not re-all
    assert jxb.device_cache.stats()["buffers"] == buffers1 + n2


# ------------------------------------- serving: isolation + invalidation

def test_ingest_while_serving_never_tears(monkeypatch):
    """Concurrent appends against a serving engine: every result is a
    contiguous prefix of the append order — pre- or post-append snapshot,
    never a torn mix of generations."""
    name = "LiveTorn"
    s = StreamingFDb(name, _dense_schema(name), flush_threshold=5)
    cat = Catalog()
    cat.register(s)
    eng = AdHocEngine(cat, num_servers=2, backend="numpy")
    flow = fdb(name).find(BETWEEN(P.hour, 0, 23))
    s.append({"id": 0, "hour": 1, "speed": 1.0})

    stop = threading.Event()
    err: list = []

    def writer():
        i = 1
        while not stop.is_set() and i < 400:
            s.append({"id": i, "hour": i % 24, "speed": float(i)})
            i += 1

    def reader():
        try:
            for _ in range(25):
                got = [int(v) for v in
                       eng.collect(flow).batch["id"].values]
                assert got == list(range(len(got))), got
        except Exception as e:                     # pragma: no cover
            err.append(e)

    w = threading.Thread(target=writer)
    readers = [threading.Thread(target=reader) for _ in range(3)]
    w.start()
    [r.start() for r in readers]
    [r.join() for r in readers]
    stop.set()
    w.join()
    assert not err


def test_append_invalidates_live_server_cache():
    """A live QueryServer never serves a pre-append cached result: the
    bound ResultCache is invalidated by the append and the next submit
    recomputes against the new snapshot."""
    name = "LiveInval"
    s = StreamingFDb(name, _dense_schema(name), flush_threshold=4)
    s.extend([{"id": i, "hour": 8, "speed": 1.0} for i in range(8)])
    cat = Catalog()
    cat.register(s)
    cache = ResultCache()
    srv = QueryServer(catalog=cat, backend="numpy", cache=cache,
                      start=False)
    try:
        flow = fdb(name).find(BETWEEN(P.hour, 0, 23))
        f1 = srv.submit(flow); srv.run_pending()
        r1 = f1.result(60)
        assert r1.batch.n == 8
        f2 = srv.submit(flow); srv.run_pending()
        assert f2.result(60) is r1                 # cached while unchanged
        assert srv.stats()["cache_hits"] == 1
        s.extend([{"id": 8, "hour": 9, "speed": 2.0}])
        assert cache.stats()["invalidations"] >= 1
        f3 = srv.submit(flow); srv.run_pending()
        r3 = f3.result(60)
        assert r3 is not r1                        # recomputed, not stale
        assert r3.batch.n == 9
        assert 8 in set(int(v) for v in r3.batch["id"].values)
    finally:
        srv.close()


def test_listener_errors_do_not_fail_ingest():
    s = StreamingFDb("LiveErr", _dense_schema("LiveErr"),
                     flush_threshold=4)
    calls = []
    s.add_listener(lambda stale: calls.append(stale))
    s.add_listener(lambda stale: (_ for _ in ()).throw(RuntimeError()))
    s.append({"id": 0, "hour": 0, "speed": 0.0})
    assert s.snapshot().num_docs == 1
    s.append({"id": 1, "hour": 1, "speed": 1.0})   # listener fires now
    assert s.num_docs == 2
    assert len(calls) == 1                         # stale snap existed once
