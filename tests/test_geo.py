"""Geo substrate: mercator projection + area-tree set algebra (property)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: fall back to shim
    from _hypothesis_shim import given, settings, st

from repro.geo import AreaTree, mercator as M
from repro.geo.geometry import mercator_dist_m, polyline_length_m


# ----------------------------------------------------------- mercator

@given(st.floats(-85.0, 85.0), st.floats(-179.99, 179.99))
@settings(max_examples=200, deadline=None)
def test_mercator_roundtrip(lat, lng):
    ix, iy = M.latlng_to_xy(lat, lng)
    lat2, lng2 = M.xy_to_latlng(ix, iy)
    # one cell ≈ 3.7cm ≈ 3.4e-7 deg at equator
    assert abs(float(lat2) - lat) < 1e-5
    assert abs(float(lng2) - lng) < 1e-5


@given(st.integers(0, 2**30 - 1), st.integers(0, 2**30 - 1))
@settings(max_examples=200, deadline=None)
def test_morton_roundtrip(ix, iy):
    k = M.interleave(np.uint64(ix), np.uint64(iy))
    ix2, iy2 = M.deinterleave(k)
    assert int(ix2) == ix and int(iy2) == iy


def test_morton_prefix_is_cell():
    k = M.latlng_to_morton(37.77, -122.41)
    for level in (1, 4, 7, 10):
        cell = M.cell_of(k, level)
        lo, hi = M.cell_range(cell, level)
        assert lo <= k < hi


def test_known_distance():
    a = M.latlng_to_xy(37.7749, -122.4194)   # SF
    b = M.latlng_to_xy(37.8044, -122.2711)   # Oakland
    d = float(mercator_dist_m(a[0], a[1], b[0], b[1]))
    assert 12_000 < d < 15_000               # ~13.4 km


# ----------------------------------------------------------- area trees

def _rand_box(rng, span=1 << 22):
    x0 = int(rng.integers(1 << 24, (1 << 24) + span))
    y0 = int(rng.integers(1 << 24, (1 << 24) + span))
    return AreaTree.from_box(x0, y0, x0 + int(rng.integers(1, span)),
                             y0 + int(rng.integers(1, span)), max_level=7)


@pytest.mark.parametrize("seed", range(5))
def test_set_algebra_inclusion_exclusion(seed):
    rng = np.random.default_rng(seed)
    a, b = _rand_box(rng), _rand_box(rng)
    u, i = a | b, a & b
    assert u.num_keys() == a.num_keys() + b.num_keys() - i.num_keys()
    d = a - b
    assert d.num_keys() == a.num_keys() - i.num_keys()
    # difference disjoint from b; union superset of both
    assert (d & b).is_empty
    assert (u & a) == a and (u & b) == b


@given(st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_box_cover_contains_interior(seed):
    rng = np.random.default_rng(seed)
    x0, y0 = 5_000_000, 6_000_000
    x1, y1 = x0 + 3000, y0 + 2000
    area = AreaTree.from_box(x0, y0, x1, y1, max_level=8)
    xs = rng.integers(x0, x1 + 1, 100).astype(np.uint64)
    ys = rng.integers(y0, y1 + 1, 100).astype(np.uint64)
    assert area.contains(M.interleave(xs, ys)).all()


def test_cells_roundtrip_and_node_masks():
    a = AreaTree.from_box(1_000_000, 2_000_000, 1_003_000, 2_002_000,
                          max_level=8)
    cells, levels = a.to_cells()
    assert AreaTree.from_cells(cells, levels) == a
    masks = a.node_masks(8)
    # total child bits == number of level-8 cells covered
    shift = 6 * (M.MAX_LEVEL - 8)
    n_cells = sum(int(hi - lo) >> shift for lo, hi in zip(a.lo, a.hi))
    assert sum(bin(int(m)).count("1") for m in masks.values()) == n_cells


def test_circle_and_path_covers():
    c = AreaTree.from_circle(500_000, 500_000, 2000.0, max_level=8)
    k = M.interleave(np.uint64(500_000), np.uint64(500_000))
    assert c.contains(np.array([k]))[0]
    far = M.interleave(np.uint64(600_000), np.uint64(600_000))
    assert not c.contains(np.array([far]))[0]
    # strip cover contains waypoints; preserves area ≥ circle of same width
    xs = np.array([100_000.0, 101_000.0, 102_000.0])
    ys = np.array([100_000.0, 100_500.0, 101_500.0])
    strip = AreaTree.from_path(xs, ys, 300.0, max_level=8)
    keys = M.interleave(xs.astype(np.uint64), ys.astype(np.uint64))
    assert strip.contains(keys).all()


def test_polygon_cover():
    # triangle
    xs = np.array([1_000_000.0, 1_010_000.0, 1_000_000.0])
    ys = np.array([1_000_000.0, 1_000_000.0, 1_010_000.0])
    tri = AreaTree.from_polygon(xs, ys, max_level=7)
    inside = M.interleave(np.uint64(1_002_000), np.uint64(1_002_000))
    outside = M.interleave(np.uint64(1_009_000), np.uint64(1_009_000))
    assert tri.contains(np.array([inside]))[0]
    assert not tri.contains(np.array([outside]))[0]


def test_polygon_cover_horizontal_edges():
    """Axis-aligned polygons have fully horizontal edges whose ray-cast
    denominator is 0 — must not warn (RuntimeWarning → error under
    pytest.ini) and must classify interiors correctly (regression for the
    overflow-in-divide in ``_points_in_polygon``)."""
    x0, y0, x1, y1 = 1_000_000.0, 1_000_000.0, 1_008_000.0, 1_006_000.0
    xs = np.array([x0, x1, x1, x0])          # rectangle: 2 horizontal edges
    ys = np.array([y0, y0, y1, y1])
    rect = AreaTree.from_polygon(xs, ys, max_level=7)
    box = AreaTree.from_box(int(x0), int(y0), int(x1), int(y1), max_level=7)
    # same region → covers agree on interior/exterior probes
    inside = M.interleave(np.uint64(1_004_000), np.uint64(1_003_000))
    outside = M.interleave(np.uint64(1_020_000), np.uint64(1_020_000))
    assert rect.contains(np.array([inside]))[0]
    assert box.contains(np.array([inside]))[0]
    assert not rect.contains(np.array([outside]))[0]
    # point-level helper directly: on-row queries vs horizontal edges
    from repro.geo.areatree import _points_in_polygon
    qx = np.array([x0 + 10.0, x0 - 10.0, (x0 + x1) / 2])
    qy = np.array([(y0 + y1) / 2, (y0 + y1) / 2, y0 - 5.0])
    got = _points_in_polygon(qx, qy, xs, ys)
    assert got.tolist() == [True, False, False]


def test_polyline_length():
    # 1km east along equator ≈ 1000m
    ix0, iy0 = M.latlng_to_xy(0.0, 0.0)
    ix1, iy1 = M.latlng_to_xy(0.0, 0.008983)   # ~1km of longitude
    L = polyline_length_m(np.array([float(ix0), float(ix1)]),
                          np.array([float(iy0), float(iy1)]))
    assert abs(L - 1000) < 10
