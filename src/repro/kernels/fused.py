"""Fused per-wave pipeline: probe → refine → compact → segment-agg in ONE
dispatch (paper §4: pipelined evaluation; the flash-attention kernel is the
in-repo exemplar of a fused multi-stage pass).

The legacy batched path issues one launch *per primitive* per wave and
round-trips host↔device between stages.  :func:`run_wave_fused` chains the
same stage math inside a single ``jax.jit`` composition — the stacked
bitmap AND, the exact track refine (with the ordered-query first-hit edge
compare), mask compaction, and the offset-coded segment aggregation — so a
wave of shards costs one dispatch and zero intermediate host syncs.  Under
``impl="pallas"``/``"interpret"`` each stage lowers to its Pallas kernel
inside the jit; under ``"reference"`` the pure-jnp oracles compose (and the
whole call runs under ``enable_x64`` so aggregation accumulates float64 in
row order, bit-equal to the numpy oracle).

Inputs are the wave-stacked buffers the backend seam already builds:

* ``probe_stack`` [S, K, W] uint32 — row 0 the shard's valid-doc bitmap,
  rows 1.. the probe bitmaps, pad rows copies of row 0 (identity for AND).
* ``ns`` [S] int32 — per-shard doc counts (rows beyond are padding).
* ``pts``/``rows``/``cov`` — packed ragged tracks + constraint cover, or
  ``None`` when the plan has no refine stage.
* ``codes`` [S, N] int32 — per-row group codes already offset into the
  wave-global group space (−1 = padding), or ``None`` without aggregation.
* ``vals`` — tuple of [S, N] float value stacks, one per distinct
  aggregated column (a single zeros stack for count-only plans).

Returns ``(cand [S], sel_idx [S, N], sel_counts [S], segs)`` with ``cand``
the pre-refine candidate counts, ``sel_idx``/``sel_counts`` the compacted
survivor row ids, and ``segs`` a list of ``(count, sum, sumsq)`` triples
over the wave-global group space (``None`` without aggregation).

``profile=True`` runs the same stage math eagerly with a device sync after
each stage and records wall-clock per stage into :func:`stage_times` —
the ``--profile`` bench flag's data source.  This module never imports
``kernels.ops`` (ops wraps *it* and owns launch counting).
"""
from __future__ import annotations

import functools
import threading
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import bitset as _bitset
from . import compact as _compact
from . import ref as _ref
from . import refine as _refine
from . import segment_agg as _seg

__all__ = ["run_wave_fused", "run_wave_fused_multi", "postings_bitmap",
           "record_stage", "stage_times", "reset_stage_times"]


# --------------------------------------------------------------------------
# Per-stage wall-clock (bench --profile); engines run in worker threads.
# --------------------------------------------------------------------------

_STAGE_MS: Dict[str, float] = {}
_STAGE_LOCK = threading.Lock()


def record_stage(name: str, ms: float) -> None:
    """Accumulate ``ms`` milliseconds of wall-clock under stage ``name``."""
    with _STAGE_LOCK:
        _STAGE_MS[name] = _STAGE_MS.get(name, 0.0) + ms


def stage_times() -> Dict[str, float]:
    """Snapshot of accumulated per-stage milliseconds since last reset."""
    with _STAGE_LOCK:
        return dict(_STAGE_MS)


def reset_stage_times() -> None:
    with _STAGE_LOCK:
        _STAGE_MS.clear()


# --------------------------------------------------------------------------
# Stage bodies (shared by the jitted composition and the profiled path)
# --------------------------------------------------------------------------

def _probe_stage(impl: str, probe_stack):
    if impl == "reference":
        bm, _ = _ref.bitmap_intersect_batched_ref(probe_stack)
    else:
        bm, _ = _bitset.bitmap_intersect_batched(
            probe_stack, interpret=(impl == "interpret"))
    return bm


def _mask_stage(bm, ns, num_docs: int):
    """Word bitmaps [S, W] → per-doc bool masks [S, num_docs]."""
    docs = jnp.arange(num_docs, dtype=jnp.int32)
    words = bm[:, docs >> 5]
    bits = (words >> (docs & 31).astype(jnp.uint32)) & jnp.uint32(1)
    return (bits != 0) & (docs[None, :] < ns[:, None])


def _refine_stage(impl: str, pts, rows, cov, num_docs: int,
                  edges: Tuple[Tuple[int, int], ...]):
    wf = bool(edges)
    if impl == "reference":
        r = _ref.refine_tracks_batched_ref(pts, rows, cov,
                                           num_docs=num_docs,
                                           with_first_hits=wf)
    else:
        r = _refine.refine_tracks_batched(pts, rows, cov, num_docs,
                                          interpret=(impl == "interpret"),
                                          with_first_hits=wf)
    if not wf:
        return r
    out, fh_hi, fh_lo = r
    for i, j in edges:               # A-then-B: first hit of i before j's
        a_hi, a_lo = fh_hi[:, i, :], fh_lo[:, i, :]
        b_hi, b_lo = fh_hi[:, j, :], fh_lo[:, j, :]
        out = out & ((a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo)))
    return out


def _compact_stage(impl: str, mask):
    if impl == "reference":
        return _ref.compact_batched_ref(mask)
    return _compact.compact_batched(mask, interpret=(impl == "interpret"))


def _agg_stage(impl: str, mask, codes, vals, total_groups: int,
               minmax: Tuple[bool, ...] = ()):
    """Per-value-slot segment partials.  Slots flagged in ``minmax`` grow
    per-group min/max reductions in the same pass — pure-jnp
    ``segment_min``/``segment_max`` under every impl (min/max commute with
    the f64→f32 staging cast, so interpret/pallas stay allclose and
    ``reference`` f64 is exact/order-independent); those slots return
    5-tuples ``(count, sum, sumsq, min, max)``, the rest the usual
    triples.  Groups with count 0 carry ±inf fills and are dropped by the
    backend's ``count > 0`` keep-filter."""
    gc = jnp.where(mask, codes, jnp.int32(-1)).reshape(-1)
    valid = gc >= 0
    gid = jnp.where(valid, gc, 0)
    segs = []
    for k, v in enumerate(vals):
        vv = v.reshape(-1)
        if impl == "reference":
            seg = _ref.segment_agg_ref(gc, vv, total_groups)
        else:
            seg = _seg.segment_agg(gc, vv, total_groups,
                                   interpret=(impl == "interpret"))
        if k < len(minmax) and minmax[k]:
            inf = jnp.asarray(jnp.inf, vv.dtype)
            mn = jax.ops.segment_min(jnp.where(valid, vv, inf), gid,
                                     num_segments=total_groups)
            mx = jax.ops.segment_max(jnp.where(valid, vv, -inf), gid,
                                     num_segments=total_groups)
            seg = (*seg, mn, mx)
        segs.append(seg)
    return segs


@functools.lru_cache(maxsize=None)
def _fused_fn(impl: str, num_docs: int,
              edges: Tuple[Tuple[int, int], ...], total_groups: int,
              has_refine: bool, minmax: Tuple[bool, ...] = ()):
    """One jitted end-to-end wave pipeline for a static stage config."""

    def fn(probe_stack, ns, pts, rows, cov, codes, vals):
        mask = _mask_stage(_probe_stage(impl, probe_stack), ns, num_docs)
        cand = mask.sum(axis=1).astype(jnp.int32)
        if has_refine:
            mask = mask & _refine_stage(impl, pts, rows, cov, num_docs,
                                        edges)
        sel_idx, sel_counts = _compact_stage(impl, mask)
        segs = None
        if total_groups > 0:
            segs = _agg_stage(impl, mask, codes, vals, total_groups,
                              minmax)
        return cand, sel_idx, sel_counts, segs

    # Donating the probe stack lets XLA reuse its buffer for the stage
    # intermediates on TPU; CPU donation only emits warnings.
    donate = (0,) if jax.default_backend() == "tpu" else ()
    return jax.jit(fn, donate_argnums=donate)


def _profiled(impl, probe_stack, ns, pts, rows, cov, codes, vals,
              num_docs, edges, total_groups, has_refine, minmax=()):
    """Same math, eager stage-by-stage with a sync + timer per stage."""
    t = time.perf_counter
    t0 = t()
    mask = _mask_stage(_probe_stage(impl, probe_stack), ns, num_docs)
    cand = jax.block_until_ready(mask.sum(axis=1).astype(jnp.int32))
    t1 = t()
    record_stage("probe", (t1 - t0) * 1e3)
    if has_refine:
        mask = jax.block_until_ready(
            mask & _refine_stage(impl, pts, rows, cov, num_docs, edges))
        t2 = t()
        record_stage("refine", (t2 - t1) * 1e3)
        t1 = t2
    sel_idx, sel_counts = jax.block_until_ready(_compact_stage(impl, mask))
    t2 = t()
    record_stage("compact", (t2 - t1) * 1e3)
    segs = None
    if total_groups > 0:
        segs = jax.block_until_ready(
            _agg_stage(impl, mask, codes, vals, total_groups, minmax))
        record_stage("agg", (t() - t2) * 1e3)
    return cand, sel_idx, sel_counts, segs


def run_wave_fused(probe_stack, ns, pts=None, rows=None, cov=None,
                   codes=None, vals=(), *, num_docs: int,
                   edges=(), total_groups: int = 0,
                   impl: str = "reference", profile: bool = False,
                   minmax=()):
    """Run one wave through the fused pipeline (see module docstring).
    ``minmax`` flags which value slots also reduce per-group min/max
    (5-tuple partials) — same dispatch, no extra launches."""
    edges = tuple(tuple(e) for e in edges)
    vals = tuple(vals)
    minmax = tuple(bool(m) for m in minmax)
    has_refine = pts is not None
    if impl == "reference":
        # f64 value stacks + f64 accumulation, bit-equal to the host oracle
        with jax.experimental.enable_x64():
            if profile:
                return _profiled(impl, probe_stack, ns, pts, rows, cov,
                                 codes, vals, num_docs, edges,
                                 total_groups, has_refine, minmax)
            return _fused_fn(impl, num_docs, edges, total_groups,
                             has_refine, minmax)(probe_stack, ns, pts,
                                                 rows, cov, codes, vals)
    if profile:
        return _profiled(impl, probe_stack, ns, pts, rows, cov, codes,
                         vals, num_docs, edges, total_groups, has_refine,
                         minmax)
    return _fused_fn(impl, num_docs, edges, total_groups, has_refine,
                     minmax)(probe_stack, ns, pts, rows, cov, codes, vals)


# --------------------------------------------------------------------------
# Multi-query fused wave — the serve layer's coalesced dispatch
# --------------------------------------------------------------------------

def _refine_multi_stage(impl: str, pts, rows, cov, num_docs: int,
                        edges_multi):
    """Query-axis refine: cov [Q, C, 8, R] → masks [Q, S, num_docs], with
    each query's ordering edges applied against its own slice of the
    first-hit tables (static per-query compare chain, zero launches)."""
    wf = any(len(e) > 0 for e in edges_multi)
    if impl == "reference":
        r = _ref.refine_tracks_multi_ref(pts, rows, cov,
                                         num_docs=num_docs,
                                         with_first_hits=wf)
    else:
        r = _refine.refine_tracks_multi(pts, rows, cov, num_docs,
                                        interpret=(impl == "interpret"),
                                        with_first_hits=wf)
    if not wf:
        return r
    out, fh_hi, fh_lo = r
    per_q = []
    for qi, edges in enumerate(edges_multi):
        m = out[qi]
        for i, j in edges:           # A-then-B: first hit of i before j's
            a_hi, a_lo = fh_hi[qi, :, i, :], fh_lo[qi, :, i, :]
            b_hi, b_lo = fh_hi[qi, :, j, :], fh_lo[qi, :, j, :]
            m = m & ((a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo)))
        per_q.append(m)
    return jnp.stack(per_q)


@functools.lru_cache(maxsize=None)
def _fused_multi_fn(impl: str, num_docs: int, edges_multi, has_refine):
    """One jitted multi-query wave pipeline (probe → refine → compact).
    The query axis is folded into the shard axis for the probe and compact
    stages (the stacked kernels are shape-agnostic in S) and kept leading
    through the refine kernel's per-query constraint tables."""

    def fn(probe_stacks, ns, pts, rows, cov):
        q, s = probe_stacks.shape[0], probe_stacks.shape[1]
        flat = probe_stacks.reshape((q * s,) + probe_stacks.shape[2:])
        ns_flat = jnp.tile(ns, q)                     # [(Q·S)]
        mask = _mask_stage(_probe_stage(impl, flat), ns_flat, num_docs)
        mask = mask.reshape(q, s, num_docs)
        cand = mask.sum(axis=2).astype(jnp.int32)
        if has_refine:
            mask = mask & _refine_multi_stage(impl, pts, rows, cov,
                                              num_docs, edges_multi)
        sel_idx, sel_counts = _compact_stage(
            impl, mask.reshape(q * s, num_docs))
        return (cand, sel_idx.reshape(q, s, num_docs),
                sel_counts.reshape(q, s))

    return jax.jit(fn)


def run_wave_fused_multi(probe_stacks, ns, pts=None, rows=None, cov=None,
                         *, num_docs: int, edges_multi=(),
                         impl: str = "reference"):
    """Q coalesced queries through one wave in ONE dispatch.

    ``probe_stacks`` [Q, S, K, W] uint32 — each query's wave-stacked probe
    bitmaps (pad rows AND-identity as in the single-query path); ``cov``
    [Q, C, 8, R] uint32 — per-query constraint tables padded to common
    C/R (always-hit constraints / never-hit range slots); track buffers
    are shared.  ``edges_multi`` is one edge tuple per query.  Returns
    ``(cand [Q, S], sel_idx [Q, S, N], sel_counts [Q, S])``.
    """
    edges_multi = tuple(tuple(tuple(e) for e in es) for es in edges_multi)
    has_refine = pts is not None
    fn = _fused_multi_fn(impl, num_docs, edges_multi, has_refine)
    if impl == "reference":
        with jax.experimental.enable_x64():
            return fn(probe_stacks, ns, pts, rows, cov)
    return fn(probe_stacks, ns, pts, rows, cov)


# --------------------------------------------------------------------------
# Postings OR — SpaceTimeIndex.lookup's tail lowered behind the seam
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_docs",))
def _postings_bitmap(ids, t_min, t_max, t0, t1, n_docs: int):
    nw = (n_docs + 31) // 32
    hit = jnp.zeros((nw * 32,), jnp.bool_).at[ids].set(True, mode="drop")
    overlap = jnp.zeros((nw * 32,), jnp.bool_).at[:n_docs].set(
        (t_min <= t1) & (t_max >= t0))
    bits = (hit & overlap).reshape(nw, 32).astype(jnp.uint32)
    # doc 32·w + b → word w, bit b: the bitmap_from_ids word layout
    return (bits << jnp.arange(32, dtype=jnp.uint32)[None, :]).sum(
        axis=1, dtype=jnp.uint32)


def postings_bitmap(ids, t_min, t_max, t0, t1, n_docs: int):
    """OR doc ``ids`` into a word bitmap and prune docs whose ``[t_min,
    t_max]`` track span misses ``[t0, t1]`` — the host tail of
    ``SpaceTimeIndex.lookup`` as one device pass (pure-jnp lowering under
    every ``impl``; scatter-OR has no Pallas kernel).  Runs under
    ``enable_x64`` so the float64 span compare matches the host exactly.
    """
    if n_docs <= 0:
        return jnp.zeros((0,), jnp.uint32)
    with jax.experimental.enable_x64():
        return _postings_bitmap(jnp.asarray(ids), t_min, t_max,
                                jnp.float64(t0), jnp.float64(t1), n_docs)
