"""Fused per-wave pipeline: probe → refine → compact → segment-agg in ONE
dispatch (paper §4: pipelined evaluation; the flash-attention kernel is the
in-repo exemplar of a fused multi-stage pass).

The legacy batched path issues one launch *per primitive* per wave and
round-trips host↔device between stages.  :func:`run_wave_fused` chains the
same stage math inside a single ``jax.jit`` composition — the stacked
bitmap AND, the exact track refine (with the ordered-query first-hit edge
compare), mask compaction, and the offset-coded segment aggregation — so a
wave of shards costs one dispatch and zero intermediate host syncs.  Under
``impl="pallas"``/``"interpret"`` each stage lowers to its Pallas kernel
inside the jit; under ``"reference"`` the pure-jnp oracles compose (and the
whole call runs under ``enable_x64`` so aggregation accumulates float64 in
row order, bit-equal to the numpy oracle).

Inputs are the wave-stacked buffers the backend seam already builds:

* ``probe_stack`` [S, K, W] uint32 — row 0 the shard's valid-doc bitmap,
  rows 1.. the probe bitmaps, pad rows copies of row 0 (identity for AND).
* ``ns`` [S] int32 — per-shard doc counts (rows beyond are padding).
* ``pts``/``rows``/``cov`` — packed ragged tracks + constraint cover, or
  ``None`` when the plan has no refine stage.
* ``codes`` [S, N] int32 — per-row group codes already offset into the
  wave-global group space (−1 = padding), or ``None`` without aggregation.
* ``vals`` — tuple of [S, N] float value stacks, one per distinct
  aggregated column (a single zeros stack for count-only plans).

Returns ``(cand [S], sel_idx [S, N], sel_counts [S], segs)`` with ``cand``
the pre-refine candidate counts, ``sel_idx``/``sel_counts`` the compacted
survivor row ids, and ``segs`` a list of ``(count, sum, sumsq)`` triples
over the wave-global group space (``None`` without aggregation).

``profile=True`` runs the same stage math eagerly with a device sync after
each stage and records wall-clock per stage into :func:`stage_times` —
the ``--profile`` bench flag's data source.  This module never imports
``kernels.ops`` (ops wraps *it* and owns launch counting).
"""
from __future__ import annotations

import contextlib
import functools
import threading
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import bitset as _bitset
from . import compact as _compact
from . import ref as _ref
from . import refine as _refine
from . import segment_agg as _seg

__all__ = ["run_wave_fused", "run_wave_fused_multi", "postings_bitmap",
           "segment_hll", "record_stage", "stage_times",
           "reset_stage_times"]


# --------------------------------------------------------------------------
# Per-stage wall-clock (bench --profile); engines run in worker threads.
# --------------------------------------------------------------------------

_STAGE_MS: Dict[str, float] = {}
_STAGE_LOCK = threading.Lock()


def record_stage(name: str, ms: float) -> None:
    """Accumulate ``ms`` milliseconds of wall-clock under stage ``name``."""
    with _STAGE_LOCK:
        _STAGE_MS[name] = _STAGE_MS.get(name, 0.0) + ms


def stage_times() -> Dict[str, float]:
    """Snapshot of accumulated per-stage milliseconds since last reset."""
    with _STAGE_LOCK:
        return dict(_STAGE_MS)


def reset_stage_times() -> None:
    with _STAGE_LOCK:
        _STAGE_MS.clear()


# --------------------------------------------------------------------------
# Stage bodies (shared by the jitted composition and the profiled path)
# --------------------------------------------------------------------------

def _probe_stage(impl: str, probe_stack):
    if impl == "reference":
        bm, _ = _ref.bitmap_intersect_batched_ref(probe_stack)
    else:
        bm, _ = _bitset.bitmap_intersect_batched(
            probe_stack, interpret=(impl == "interpret"))
    return bm


def _mask_stage(bm, ns, num_docs: int):
    """Word bitmaps [S, W] → per-doc bool masks [S, num_docs]."""
    docs = jnp.arange(num_docs, dtype=jnp.int32)
    words = bm[:, docs >> 5]
    bits = (words >> (docs & 31).astype(jnp.uint32)) & jnp.uint32(1)
    return (bits != 0) & (docs[None, :] < ns[:, None])


def _unpack_sort_key(hi, lo):
    """uint32 (hi, lo) packed-timestamp words → float64 (inverse of the
    order-preserving IEEE-754 sort-key map).  Needs x64 enabled — callers
    wrap dwell-carrying pipelines in ``enable_x64``."""
    k = (hi.astype(jnp.uint64) << jnp.uint64(32)) | lo.astype(jnp.uint64)
    sign = (k >> jnp.uint64(63)) != 0
    bits = jnp.where(sign, k & ~(jnp.uint64(1) << jnp.uint64(63)), ~k)
    return jax.lax.bitcast_convert_type(bits, jnp.float64)


def _reduction_verdict(fh_hi, fh_lo, lh_hi, lh_lo, cnt, edges,
                       min_counts, dwells):
    """Per-doc verdict recomputed from the reduction tables (leading axes
    arbitrary; constraint axis second-to-last).  The kernel's bits==full
    mask can't express k=0 (vacuous) constraints, so the verdict ANDs
    per-constraint ``ok`` terms built from the count table instead:
    ``doc_hit ≡ cnt > 0`` exactly.  Static python loop — zero launches."""
    n_c = cnt.shape[-2]
    out = None
    for c in range(n_c):
        doc_hit = cnt[..., c, :] > 0
        k = int(min_counts[c]) if c < len(min_counts) else 1
        if k == 1:
            ok = doc_hit
        elif k <= 0:
            ok = jnp.ones_like(doc_hit)
        else:
            ok = cnt[..., c, :] >= k
        d = dwells[c] if c < len(dwells) else None
        if d is not None:
            span = _unpack_sort_key(lh_hi[..., c, :], lh_lo[..., c, :]) \
                - _unpack_sort_key(fh_hi[..., c, :], fh_lo[..., c, :])
            ok = ok & doc_hit & (span >= float(d))
        out = ok if out is None else (out & ok)
    for i, j in edges:               # A-then-B: first hit of i before j's
        a_hi, a_lo = fh_hi[..., i, :], fh_lo[..., i, :]
        b_hi, b_lo = fh_hi[..., j, :], fh_lo[..., j, :]
        out = out & ((a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo)))
    return out


def _has_reductions(min_counts, dwells) -> bool:
    return any(int(k) != 1 for k in min_counts) \
        or any(d is not None for d in dwells)


def _refine_stage(impl: str, pts, rows, cov, num_docs: int,
                  edges: Tuple[Tuple[int, int], ...],
                  min_counts: Tuple[int, ...] = (),
                  dwells: Tuple[Optional[float], ...] = ()):
    wa = _has_reductions(min_counts, dwells)
    wf = bool(edges) and not wa
    if impl == "reference":
        r = _ref.refine_tracks_batched_ref(pts, rows, cov,
                                           num_docs=num_docs,
                                           with_first_hits=wf,
                                           with_analytics=wa)
    else:
        r = _refine.refine_tracks_batched(pts, rows, cov, num_docs,
                                          interpret=(impl == "interpret"),
                                          with_first_hits=wf,
                                          with_analytics=wa)
    if wa:
        _, fh_hi, fh_lo, lh_hi, lh_lo, cnt = r
        return _reduction_verdict(fh_hi, fh_lo, lh_hi, lh_lo, cnt, edges,
                                  min_counts, dwells)
    if not wf:
        return r
    out, fh_hi, fh_lo = r
    for i, j in edges:               # A-then-B: first hit of i before j's
        a_hi, a_lo = fh_hi[:, i, :], fh_lo[:, i, :]
        b_hi, b_lo = fh_hi[:, j, :], fh_lo[:, j, :]
        out = out & ((a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo)))
    return out


def _compact_stage(impl: str, mask):
    if impl == "reference":
        return _ref.compact_batched_ref(mask)
    return _compact.compact_batched(mask, interpret=(impl == "interpret"))


def _agg_stage(impl: str, mask, codes, vals, total_groups: int,
               minmax: Tuple[bool, ...] = ()):
    """Per-value-slot segment partials.  Slots flagged in ``minmax`` grow
    per-group min/max reductions in the same pass — pure-jnp
    ``segment_min``/``segment_max`` under every impl (min/max commute with
    the f64→f32 staging cast, so interpret/pallas stay allclose and
    ``reference`` f64 is exact/order-independent); those slots return
    5-tuples ``(count, sum, sumsq, min, max)``, the rest the usual
    triples.  Groups with count 0 carry ±inf fills and are dropped by the
    backend's ``count > 0`` keep-filter."""
    gc = jnp.where(mask, codes, jnp.int32(-1)).reshape(-1)
    valid = gc >= 0
    gid = jnp.where(valid, gc, 0)
    segs = []
    for k, v in enumerate(vals):
        vv = v.reshape(-1)
        if impl == "reference":
            seg = _ref.segment_agg_ref(gc, vv, total_groups)
        else:
            seg = _seg.segment_agg(gc, vv, total_groups,
                                   interpret=(impl == "interpret"))
        if k < len(minmax) and minmax[k]:
            inf = jnp.asarray(jnp.inf, vv.dtype)
            mn = jax.ops.segment_min(jnp.where(valid, vv, inf), gid,
                                     num_segments=total_groups)
            mx = jax.ops.segment_max(jnp.where(valid, vv, -inf), gid,
                                     num_segments=total_groups)
            seg = (*seg, mn, mx)
        segs.append(seg)
    return segs


@functools.lru_cache(maxsize=None)
def _fused_fn(impl: str, num_docs: int,
              edges: Tuple[Tuple[int, int], ...], total_groups: int,
              has_refine: bool, minmax: Tuple[bool, ...] = (),
              min_counts: Tuple[int, ...] = (),
              dwells: Tuple[Optional[float], ...] = ()):
    """One jitted end-to-end wave pipeline for a static stage config."""

    def fn(probe_stack, ns, pts, rows, cov, codes, vals):
        mask = _mask_stage(_probe_stage(impl, probe_stack), ns, num_docs)
        cand = mask.sum(axis=1).astype(jnp.int32)
        if has_refine:
            mask = mask & _refine_stage(impl, pts, rows, cov, num_docs,
                                        edges, min_counts, dwells)
        sel_idx, sel_counts = _compact_stage(impl, mask)
        segs = None
        if total_groups > 0:
            segs = _agg_stage(impl, mask, codes, vals, total_groups,
                              minmax)
        return cand, sel_idx, sel_counts, segs

    # Donating the probe stack lets XLA reuse its buffer for the stage
    # intermediates on TPU; CPU donation only emits warnings.
    donate = (0,) if jax.default_backend() == "tpu" else ()
    return jax.jit(fn, donate_argnums=donate)


def _profiled(impl, probe_stack, ns, pts, rows, cov, codes, vals,
              num_docs, edges, total_groups, has_refine, minmax=(),
              min_counts=(), dwells=()):
    """Same math, eager stage-by-stage with a sync + timer per stage."""
    t = time.perf_counter
    t0 = t()
    mask = _mask_stage(_probe_stage(impl, probe_stack), ns, num_docs)
    cand = jax.block_until_ready(mask.sum(axis=1).astype(jnp.int32))
    t1 = t()
    record_stage("probe", (t1 - t0) * 1e3)
    if has_refine:
        mask = jax.block_until_ready(
            mask & _refine_stage(impl, pts, rows, cov, num_docs, edges,
                                 min_counts, dwells))
        t2 = t()
        record_stage("refine", (t2 - t1) * 1e3)
        t1 = t2
    sel_idx, sel_counts = jax.block_until_ready(_compact_stage(impl, mask))
    t2 = t()
    record_stage("compact", (t2 - t1) * 1e3)
    segs = None
    if total_groups > 0:
        segs = jax.block_until_ready(
            _agg_stage(impl, mask, codes, vals, total_groups, minmax))
        record_stage("agg", (t() - t2) * 1e3)
    return cand, sel_idx, sel_counts, segs


def run_wave_fused(probe_stack, ns, pts=None, rows=None, cov=None,
                   codes=None, vals=(), *, num_docs: int,
                   edges=(), min_counts=(), dwells=(),
                   total_groups: int = 0,
                   impl: str = "reference", profile: bool = False,
                   minmax=()):
    """Run one wave through the fused pipeline (see module docstring).
    ``minmax`` flags which value slots also reduce per-group min/max
    (5-tuple partials); ``min_counts``/``dwells`` apply per-constraint
    count/dwell verdicts inside the refine stage — same dispatch, no
    extra launches.  Dwell verdicts unpack packed timestamps to float64
    in the jit epilogue, so dwell-carrying pipelines run under
    ``enable_x64`` on every impl (the integer kernels are unaffected)."""
    edges = tuple(tuple(e) for e in edges)
    min_counts = tuple(int(k) for k in min_counts)
    dwells = tuple(None if d is None else float(d) for d in dwells)
    vals = tuple(vals)
    minmax = tuple(bool(m) for m in minmax)
    has_refine = pts is not None
    any_dwell = any(d is not None for d in dwells)
    # reference: f64 value stacks + f64 accumulation, bit-equal to the
    # host oracle
    ctx = jax.experimental.enable_x64() \
        if (impl == "reference" or any_dwell) else contextlib.nullcontext()
    with ctx:
        if profile:
            return _profiled(impl, probe_stack, ns, pts, rows, cov,
                             codes, vals, num_docs, edges, total_groups,
                             has_refine, minmax, min_counts, dwells)
        return _fused_fn(impl, num_docs, edges, total_groups,
                         has_refine, minmax, min_counts,
                         dwells)(probe_stack, ns, pts, rows, cov, codes,
                                 vals)


# --------------------------------------------------------------------------
# Multi-query fused wave — the serve layer's coalesced dispatch
# --------------------------------------------------------------------------

def _refine_multi_stage(impl: str, pts, rows, cov, num_docs: int,
                        edges_multi, min_counts_multi=(),
                        dwells_multi=()):
    """Query-axis refine: cov [Q, C, 8, R] → masks [Q, S, num_docs], with
    each query's ordering edges applied against its own slice of the
    first-hit tables (static per-query compare chain, zero launches).
    Queries carrying count/dwell reductions get their verdict recomputed
    from their slice of the analytics tables instead — same launch."""
    wa = any(_has_reductions(mc, ()) for mc in min_counts_multi) \
        or any(_has_reductions((), dw) for dw in dwells_multi)
    wf = any(len(e) > 0 for e in edges_multi) and not wa
    if impl == "reference":
        r = _ref.refine_tracks_multi_ref(pts, rows, cov,
                                         num_docs=num_docs,
                                         with_first_hits=wf,
                                         with_analytics=wa)
    else:
        r = _refine.refine_tracks_multi(pts, rows, cov, num_docs,
                                        interpret=(impl == "interpret"),
                                        with_first_hits=wf,
                                        with_analytics=wa)
    if wa:
        out, fh_hi, fh_lo, lh_hi, lh_lo, cnt = r
        per_q = []
        for qi, edges in enumerate(edges_multi):
            mc = min_counts_multi[qi] if qi < len(min_counts_multi) else ()
            dw = dwells_multi[qi] if qi < len(dwells_multi) else ()
            if _has_reductions(mc, dw):
                m = _reduction_verdict(fh_hi[qi], fh_lo[qi], lh_hi[qi],
                                       lh_lo[qi], cnt[qi], edges, mc, dw)
            else:
                m = out[qi]
                for i, j in edges:
                    a_hi, a_lo = fh_hi[qi, :, i, :], fh_lo[qi, :, i, :]
                    b_hi, b_lo = fh_hi[qi, :, j, :], fh_lo[qi, :, j, :]
                    m = m & ((a_hi < b_hi)
                             | ((a_hi == b_hi) & (a_lo < b_lo)))
            per_q.append(m)
        return jnp.stack(per_q)
    if not wf:
        return r
    out, fh_hi, fh_lo = r
    per_q = []
    for qi, edges in enumerate(edges_multi):
        m = out[qi]
        for i, j in edges:           # A-then-B: first hit of i before j's
            a_hi, a_lo = fh_hi[qi, :, i, :], fh_lo[qi, :, i, :]
            b_hi, b_lo = fh_hi[qi, :, j, :], fh_lo[qi, :, j, :]
            m = m & ((a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo)))
        per_q.append(m)
    return jnp.stack(per_q)


@functools.lru_cache(maxsize=None)
def _fused_multi_fn(impl: str, num_docs: int, edges_multi, has_refine,
                    min_counts_multi=(), dwells_multi=()):
    """One jitted multi-query wave pipeline (probe → refine → compact).
    The query axis is folded into the shard axis for the probe and compact
    stages (the stacked kernels are shape-agnostic in S) and kept leading
    through the refine kernel's per-query constraint tables."""

    def fn(probe_stacks, ns, pts, rows, cov):
        q, s = probe_stacks.shape[0], probe_stacks.shape[1]
        flat = probe_stacks.reshape((q * s,) + probe_stacks.shape[2:])
        ns_flat = jnp.tile(ns, q)                     # [(Q·S)]
        mask = _mask_stage(_probe_stage(impl, flat), ns_flat, num_docs)
        mask = mask.reshape(q, s, num_docs)
        cand = mask.sum(axis=2).astype(jnp.int32)
        if has_refine:
            mask = mask & _refine_multi_stage(impl, pts, rows, cov,
                                              num_docs, edges_multi,
                                              min_counts_multi,
                                              dwells_multi)
        sel_idx, sel_counts = _compact_stage(
            impl, mask.reshape(q * s, num_docs))
        return (cand, sel_idx.reshape(q, s, num_docs),
                sel_counts.reshape(q, s))

    return jax.jit(fn)


def run_wave_fused_multi(probe_stacks, ns, pts=None, rows=None, cov=None,
                         *, num_docs: int, edges_multi=(),
                         min_counts_multi=(), dwells_multi=(),
                         impl: str = "reference"):
    """Q coalesced queries through one wave in ONE dispatch.

    ``probe_stacks`` [Q, S, K, W] uint32 — each query's wave-stacked probe
    bitmaps (pad rows AND-identity as in the single-query path); ``cov``
    [Q, C, 8, R] uint32 — per-query constraint tables padded to common
    C/R (always-hit constraints / never-hit range slots); track buffers
    are shared.  ``edges_multi`` is one edge tuple per query;
    ``min_counts_multi``/``dwells_multi`` one reduction tuple per query
    (pad constraints keep the k=1 / no-dwell defaults).  Returns
    ``(cand [Q, S], sel_idx [Q, S, N], sel_counts [Q, S])``.
    """
    edges_multi = tuple(tuple(tuple(e) for e in es) for es in edges_multi)
    min_counts_multi = tuple(tuple(int(k) for k in mc)
                             for mc in min_counts_multi)
    dwells_multi = tuple(tuple(None if d is None else float(d) for d in dw)
                         for dw in dwells_multi)
    has_refine = pts is not None
    any_dwell = any(d is not None for dw in dwells_multi for d in dw)
    fn = _fused_multi_fn(impl, num_docs, edges_multi, has_refine,
                         min_counts_multi, dwells_multi)
    ctx = jax.experimental.enable_x64() \
        if (impl == "reference" or any_dwell) else contextlib.nullcontext()
    with ctx:
        return fn(probe_stacks, ns, pts, rows, cov)


# --------------------------------------------------------------------------
# Postings OR — SpaceTimeIndex.lookup's tail lowered behind the seam
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_docs",))
def _postings_bitmap(ids, t_min, t_max, t0, t1, n_docs: int):
    nw = (n_docs + 31) // 32
    hit = jnp.zeros((nw * 32,), jnp.bool_).at[ids].set(True, mode="drop")
    overlap = jnp.zeros((nw * 32,), jnp.bool_).at[:n_docs].set(
        (t_min <= t1) & (t_max >= t0))
    bits = (hit & overlap).reshape(nw, 32).astype(jnp.uint32)
    # doc 32·w + b → word w, bit b: the bitmap_from_ids word layout
    return (bits << jnp.arange(32, dtype=jnp.uint32)[None, :]).sum(
        axis=1, dtype=jnp.uint32)


def postings_bitmap(ids, t_min, t_max, t0, t1, n_docs: int):
    """OR doc ``ids`` into a word bitmap and prune docs whose ``[t_min,
    t_max]`` track span misses ``[t0, t1]`` — the host tail of
    ``SpaceTimeIndex.lookup`` as one device pass (pure-jnp lowering under
    every ``impl``; scatter-OR has no Pallas kernel).  Runs under
    ``enable_x64`` so the float64 span compare matches the host exactly.
    """
    if n_docs <= 0:
        return jnp.zeros((0,), jnp.uint32)
    with jax.experimental.enable_x64():
        return _postings_bitmap(jnp.asarray(ids), t_min, t_max,
                                jnp.float64(t0), jnp.float64(t1), n_docs)


# --------------------------------------------------------------------------
# Segment HLL — per-group HyperLogLog register max behind the seam
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_groups",))
def _segment_hll(group_ids, regs, num_groups: int):
    valid = group_ids >= 0
    gid = jnp.where(valid, group_ids, 0)
    r = jnp.where(valid[:, None], regs, jnp.uint8(0))
    return jax.ops.segment_max(r, gid, num_segments=num_groups)


def segment_hll(group_ids, regs, num_groups: int):
    """Per-group HLL register max: group_ids [N] int32 (< 0 masked out) ×
    regs [N, M] uint8 register rows → [num_groups, M] maxed planes.
    ``segment_max``'s identity for uint8 is 0 — exactly an empty HLL
    register — so groups with no rows come back as empty sketches.
    Register max is the HLL merge: commutative and idempotent, so the
    result is invariant to row order and partitioning by construction.
    """
    if num_groups <= 0:
        return jnp.zeros((0, int(regs.shape[1])), jnp.uint8)
    return _segment_hll(jnp.asarray(group_ids), jnp.asarray(regs),
                        num_groups)
