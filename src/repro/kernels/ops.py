"""Unified jit'd entry points for every kernel, with implementation select.

``impl``:
  * ``"pallas"``     — compiled Pallas kernel (TPU target)
  * ``"interpret"``  — Pallas kernel body interpreted on CPU (correctness
                       validation of the exact kernel code)
  * ``"reference"``  — pure-jnp oracle (CPU tests at scale; the 512-device
                       dry-run lowers this path)

Default: ``pallas`` on TPU backends, ``reference`` elsewhere — override
with ``REPRO_KERNEL_IMPL`` or per call.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from . import bitset as _bitset
from . import compact as _compact
from . import flash_attention as _fa
from . import ref as _ref
from . import segment_agg as _seg
from . import ssm_scan as _ssm

__all__ = ["default_impl", "bitmap_binary", "bitmap_intersect", "compact",
           "segment_agg", "flash_attention", "ssm_scan"]


def default_impl() -> str:
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def _resolve(impl: Optional[str]) -> str:
    impl = impl or default_impl()
    if impl not in ("pallas", "interpret", "reference"):
        raise ValueError(f"unknown kernel impl {impl!r}")
    return impl


def bitmap_binary(a, b, op: str = "and", impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "reference":
        return {"and": _ref.bitset_and_ref, "or": _ref.bitset_or_ref,
                "andnot": _ref.bitset_andnot_ref}[op](a, b)
    return _bitset.bitset_binary(a, b, op=op,
                                 interpret=(impl == "interpret"))


def bitmap_intersect(stack, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "reference":
        bm = _ref.bitmap_intersect_ref(stack)
        return bm, _ref.popcount_ref(bm)
    return _bitset.bitmap_intersect(stack, interpret=(impl == "interpret"))


def compact(mask, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "reference":
        return _ref.compact_ref(mask)
    return _compact.compact(mask, interpret=(impl == "interpret"))


def segment_agg(group_ids, values, num_groups: int,
                impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "reference":
        return _ref.segment_agg_ref(group_ids, values, num_groups)
    return _seg.segment_agg(group_ids, values, num_groups,
                            interpret=(impl == "interpret"))


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    softcap=None, scale=None, impl: Optional[str] = None,
                    **block_kw):
    impl = _resolve(impl)
    if impl == "reference":
        return _ref.flash_attention_ref(q, k, v, causal=causal,
                                        window=window, softcap=softcap,
                                        scale=scale)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale,
                               interpret=(impl == "interpret"), **block_kw)


def ssm_scan(a, bx, impl: Optional[str] = None, **kw):
    impl = _resolve(impl)
    if impl == "reference":
        return _ref.ssm_scan_ref(a, bx)
    return _ssm.ssm_scan(a, bx, interpret=(impl == "interpret"), **kw)
