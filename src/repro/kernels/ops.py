"""Unified jit'd entry points for every kernel, with implementation select.

``impl``:
  * ``"pallas"``     — compiled Pallas kernel (TPU target)
  * ``"interpret"``  — Pallas kernel body interpreted on CPU (correctness
                       validation of the exact kernel code)
  * ``"reference"``  — pure-jnp oracle (CPU tests at scale; the 512-device
                       dry-run lowers this path)

Default: ``pallas`` on TPU backends, ``reference`` elsewhere — override
with ``REPRO_KERNEL_IMPL`` or per call.

Every public op records one **launch** per call in a process-wide counter
(:func:`launch_counts` / :func:`reset_launch_counts`), regardless of the
selected ``impl`` — a call is one logical kernel dispatch, which is what
the batched execution path amortizes (one ``*_batched`` launch per wave of
shards instead of one launch per shard).  Tests and benchmarks use the
counter to assert the ⌈shards/wave⌉ dispatch contract.

:func:`run_wave_fused` is one logical dispatch covering *all* stages of a
wave (probe → refine → compact → segment-agg fused in a single jit; see
``kernels.fused``) — on the fused path the contract tightens to
⌈shards/wave⌉ **total** dispatches per query, not per primitive.
"""
from __future__ import annotations

import os
import threading
from collections import Counter
from typing import Dict, Optional

import jax

from . import bitset as _bitset
from . import compact as _compact
from . import flash_attention as _fa
from . import fused as _fused
from . import merge as _merge
from . import ref as _ref
from . import refine as _refine
from . import segment_agg as _seg
from . import ssm_scan as _ssm

__all__ = ["default_impl", "bitmap_binary", "bitmap_intersect",
           "bitmap_intersect_batched", "compact", "compact_batched",
           "segment_agg", "segment_hll", "refine_tracks",
           "refine_tracks_batched", "refine_tracks_multi",
           "run_wave_fused", "run_wave_fused_multi", "postings_bitmap",
           "merge_partials",
           "flash_attention", "ssm_scan",
           "launch_counts", "reset_launch_counts", "record_launch"]


def default_impl() -> str:
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def _resolve(impl: Optional[str]) -> str:
    impl = impl or default_impl()
    if impl not in ("pallas", "interpret", "reference"):
        raise ValueError(f"unknown kernel impl {impl!r}")
    return impl


# --------------------------------------------------------------------------
# Launch counting — engines (and now the query server) dispatch from many
# worker threads concurrently.  Each thread owns a lock-free thread-local
# counter; the aggregate view the launch-contract tests read is the
# lock-protected process-wide sum.  ``scope="thread"`` exposes the calling
# thread's private counts (a dispatch attributed to another thread never
# leaks in), with an epoch stamp so a global reset invalidates every
# thread's stale view.
# --------------------------------------------------------------------------

_LAUNCHES: Counter = Counter()
_LAUNCH_LOCK = threading.Lock()
_LAUNCH_EPOCH = 0
_TL = threading.local()


def _thread_counter() -> Counter:
    """The calling thread's private counter for the current epoch."""
    if getattr(_TL, "epoch", None) != _LAUNCH_EPOCH:
        _TL.epoch = _LAUNCH_EPOCH
        _TL.counts = Counter()
    return _TL.counts


def record_launch(op: str) -> None:
    """Count one logical kernel dispatch under ``op``."""
    _thread_counter()[op] += 1          # thread-local: no lock needed
    with _LAUNCH_LOCK:
        _LAUNCHES[op] += 1


def launch_counts(scope: str = "aggregate") -> Dict[str, int]:
    """Snapshot of per-op dispatch counts since the last reset.

    ``scope="aggregate"`` (default) sums dispatches across all threads —
    what the ⌈shards/wave⌉ contract tests assert, since engines dispatch
    from pool threads.  ``scope="thread"`` returns only dispatches
    recorded by the *calling* thread."""
    if scope == "thread":
        return dict(_thread_counter())
    if scope != "aggregate":
        raise ValueError(f"unknown launch_counts scope {scope!r}")
    with _LAUNCH_LOCK:
        return dict(_LAUNCHES)


def reset_launch_counts() -> None:
    """Zero the aggregate counter and invalidate every thread's local
    view (their next record/read starts a fresh epoch)."""
    global _LAUNCH_EPOCH
    with _LAUNCH_LOCK:
        _LAUNCHES.clear()
        _LAUNCH_EPOCH += 1


# --------------------------------------------------------------------------
# Ops
# --------------------------------------------------------------------------

def bitmap_binary(a, b, op: str = "and", impl: Optional[str] = None):
    impl = _resolve(impl)
    record_launch("bitmap_binary")
    if impl == "reference":
        return {"and": _ref.bitset_and_ref, "or": _ref.bitset_or_ref,
                "andnot": _ref.bitset_andnot_ref}[op](a, b)
    return _bitset.bitset_binary(a, b, op=op,
                                 interpret=(impl == "interpret"))


def bitmap_intersect(stack, impl: Optional[str] = None):
    impl = _resolve(impl)
    record_launch("bitmap_intersect")
    if impl == "reference":
        bm = _ref.bitmap_intersect_ref(stack)
        return bm, _ref.popcount_ref(bm)
    return _bitset.bitmap_intersect(stack, interpret=(impl == "interpret"))


def bitmap_intersect_batched(stack, impl: Optional[str] = None):
    """Wave-stacked AND-reduce [S, K, W] → (bitmaps [S, W], counts [S])."""
    impl = _resolve(impl)
    record_launch("bitmap_intersect_batched")
    if impl == "reference":
        return _ref.bitmap_intersect_batched_ref(stack)
    return _bitset.bitmap_intersect_batched(stack,
                                            interpret=(impl == "interpret"))


def compact(mask, impl: Optional[str] = None):
    impl = _resolve(impl)
    record_launch("compact")
    if impl == "reference":
        return _ref.compact_ref(mask)
    return _compact.compact(mask, interpret=(impl == "interpret"))


def compact_batched(masks, impl: Optional[str] = None):
    """Wave-stacked compaction [S, N] → (indices [S, N], counts [S])."""
    impl = _resolve(impl)
    record_launch("compact_batched")
    if impl == "reference":
        return _ref.compact_batched_ref(masks)
    return _compact.compact_batched(masks, interpret=(impl == "interpret"))


def segment_agg(group_ids, values, num_groups: int,
                impl: Optional[str] = None):
    impl = _resolve(impl)
    record_launch("segment_agg")
    if impl == "reference":
        return _ref.segment_agg_ref(group_ids, values, num_groups)
    return _seg.segment_agg(group_ids, values, num_groups,
                            interpret=(impl == "interpret"))


def refine_tracks(pts, rows, cov, num_docs: int, impl: Optional[str] = None,
                  with_first_hits: bool = False,
                  with_analytics: bool = False):
    """Exact point-in-cover × time-window refine over one shard's packed
    ragged track → per-doc hit mask [num_docs] bool (see kernels.refine).
    ``with_first_hits`` adds the per-(constraint × doc) first-hit uint32
    (hi, lo) word tables the ordered-query edge compare consumes;
    ``with_analytics`` the full (first, last, count) reduction family —
    same fused pass, still one launch."""
    impl = _resolve(impl)
    record_launch("refine_tracks")
    if impl == "reference":
        return _ref.refine_tracks_ref(pts, rows, cov, num_docs=num_docs,
                                      with_first_hits=with_first_hits,
                                      with_analytics=with_analytics)
    return _refine.refine_tracks(pts, rows, cov, num_docs,
                                 interpret=(impl == "interpret"),
                                 with_first_hits=with_first_hits,
                                 with_analytics=with_analytics)


def refine_tracks_batched(pts, rows, cov, num_docs: int,
                          impl: Optional[str] = None,
                          with_first_hits: bool = False,
                          with_analytics: bool = False):
    """Wave-stacked refine [S, 4, P] × [C, 8, R] → hit masks
    [S, num_docs] bool — one launch per wave of shards
    (+ first-hit word tables [S, C, num_docs] × 2 under
    ``with_first_hits``; + last-hit word tables and the int32 hit-count
    table under ``with_analytics``)."""
    impl = _resolve(impl)
    record_launch("refine_tracks_batched")
    if impl == "reference":
        return _ref.refine_tracks_batched_ref(
            pts, rows, cov, num_docs=num_docs,
            with_first_hits=with_first_hits,
            with_analytics=with_analytics)
    return _refine.refine_tracks_batched(pts, rows, cov, num_docs,
                                         interpret=(impl == "interpret"),
                                         with_first_hits=with_first_hits,
                                         with_analytics=with_analytics)


def refine_tracks_multi(pts, rows, cov, num_docs: int,
                        impl: Optional[str] = None,
                        with_first_hits: bool = False,
                        with_analytics: bool = False):
    """Query-axis refine: Q coalesced queries' constraint tables
    [Q, C, 8, R] against one wave's shared track buffers [S, 4, P] →
    hit masks [Q, S, num_docs] bool in ONE launch (+ first-hit word
    tables [Q, S, C, num_docs] × 2 under ``with_first_hits``; the full
    reduction family under ``with_analytics``)."""
    impl = _resolve(impl)
    record_launch("refine_tracks_multi")
    if impl == "reference":
        return _ref.refine_tracks_multi_ref(
            pts, rows, cov, num_docs=num_docs,
            with_first_hits=with_first_hits,
            with_analytics=with_analytics)
    return _refine.refine_tracks_multi(pts, rows, cov, num_docs,
                                       interpret=(impl == "interpret"),
                                       with_first_hits=with_first_hits,
                                       with_analytics=with_analytics)


def run_wave_fused(probe_stack, ns, pts=None, rows=None, cov=None,
                   codes=None, vals=(), *, num_docs: int, edges=(),
                   min_counts=(), dwells=(), total_groups: int = 0,
                   impl: Optional[str] = None, profile: bool = False,
                   minmax=()):
    """Whole-wave fused pipeline (probe → refine → compact → segment-agg)
    in ONE dispatch — see ``kernels.fused``.  Counts as a single launch:
    the fused path's ⌈shards/wave⌉ *total*-dispatch contract hangs off
    this counter.  Each stage lowers to its Pallas kernel under
    ``pallas``/``interpret`` and to the jnp oracle under ``reference``.
    ``minmax`` flags value slots that also reduce per-group min/max in the
    same dispatch; ``min_counts``/``dwells`` apply the per-constraint
    count/dwell reduction verdicts inside the refine stage — same single
    dispatch."""
    impl = _resolve(impl)
    record_launch("run_wave_fused")
    return _fused.run_wave_fused(probe_stack, ns, pts, rows, cov, codes,
                                 vals, num_docs=num_docs, edges=edges,
                                 min_counts=min_counts, dwells=dwells,
                                 total_groups=total_groups, impl=impl,
                                 profile=profile, minmax=minmax)


def run_wave_fused_multi(probe_stacks, ns, pts=None, rows=None, cov=None, *,
                         num_docs: int, edges_multi=(),
                         min_counts_multi=(), dwells_multi=(),
                         impl: Optional[str] = None):
    """Multi-query fused wave (probe → refine → compact) for Q coalesced
    queries against ONE resident wave of shards, in ONE dispatch.  The
    query axis leads every per-query table (``probe_stacks`` [Q, S, K, W],
    ``cov`` [Q, C, 8, R]); track buffers (``pts``/``rows``) are shared.
    ``min_counts_multi``/``dwells_multi`` carry per-query reduction tuples
    (aligned with ``edges_multi``).  Counts as a single launch: Q
    coalesced queries still cost ⌈shards/wave⌉ **total** dispatches — the
    serve-layer contract."""
    impl = _resolve(impl)
    record_launch("run_wave_fused_multi")
    return _fused.run_wave_fused_multi(probe_stacks, ns, pts, rows, cov,
                                       num_docs=num_docs,
                                       edges_multi=edges_multi,
                                       min_counts_multi=min_counts_multi,
                                       dwells_multi=dwells_multi,
                                       impl=impl)


def segment_hll(group_ids, regs, num_groups: int,
                impl: Optional[str] = None):
    """Per-group HyperLogLog register max: group_ids [N] int32 (< 0
    masked out) × regs [N, M] uint8 register rows → [num_groups, M]
    maxed register planes.  Register max is the HLL merge operation —
    commutative and idempotent, so the lowering is partition-invariant by
    construction.  Segment-max is a pure-jnp lowering under every
    ``impl`` (like ``postings_bitmap``) but still counts one launch."""
    _resolve(impl)                    # validate; lowering is impl-agnostic
    record_launch("segment_hll")
    return _fused.segment_hll(group_ids, regs, num_groups)


def postings_bitmap(ids, t_min, t_max, t0, t1, n_docs: int,
                    impl: Optional[str] = None):
    """Spacetime postings OR + track-span prune on device (the tail of
    ``SpaceTimeIndex.lookup``).  Scatter-OR is a pure-jnp lowering under
    every ``impl`` — there is no Pallas scatter kernel — but it still
    counts one launch."""
    _resolve(impl)                    # validate; lowering is impl-agnostic
    record_launch("postings_bitmap")
    return _fused.postings_bitmap(ids, t_min, t_max, t0, t1, n_docs)


def merge_partials(cnt, s, s2, mn, mx, msk, mesh=None,
                   impl: Optional[str] = None):
    """Cross-partition combine of aligned segment-aggregate state stacks
    (counts/sums/sum-squares accumulate in states order, min/max planes
    element-wise, presence masks OR) under ``shard_map`` over the mesh's
    ``"part"`` axis.  Like the Mixer's host merge this always runs in
    float64, so the lowering is impl-agnostic — but it still counts one
    launch: the partitioned launch contract is sum over partitions of
    ceil(shards_p/wave) fused dispatches plus exactly one merge combine
    per aggregated query."""
    _resolve(impl)                    # validate; lowering is impl-agnostic
    record_launch("merge_partials")
    return _merge.merge_partials(cnt, s, s2, mn, mx, msk, mesh=mesh)


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    softcap=None, scale=None, impl: Optional[str] = None,
                    **block_kw):
    impl = _resolve(impl)
    record_launch("flash_attention")
    if impl == "reference":
        return _ref.flash_attention_ref(q, k, v, causal=causal,
                                        window=window, softcap=softcap,
                                        scale=scale)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale,
                               interpret=(impl == "interpret"), **block_kw)


def ssm_scan(a, bx, impl: Optional[str] = None, **kw):
    impl = _resolve(impl)
    record_launch("ssm_scan")
    if impl == "reference":
        return _ref.ssm_scan_ref(a, bx)
    return _ssm.ssm_scan(a, bx, interpret=(impl == "interpret"), **kw)
