"""Pallas stream-compaction (prefix-scan) kernel.

After index intersection, the engine needs the *positions* of set mask bits
to gather selected documents.  The parallel primitive is an exclusive
prefix sum over the mask; the scatter that finishes compaction is left to
XLA (it is memory-bound either way).

The kernel walks row-blocks sequentially, carrying the running count in
SMEM scratch — the canonical "scan with carry" pattern on TPU where grid
steps execute in order.  Within a block, a 2-D (8, L) tile is scanned
row-major: lane-wise cumsum + per-sublane offsets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["mask_prefix_sum", "compact", "mask_prefix_sum_batched",
           "compact_batched"]

DEFAULT_BLOCK = 8 * 512


def _scan_kernel(mask_ref, pos_ref, total_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0, 0] = 0

    x = mask_ref[...].astype(jnp.int32)            # (1, 8, L)
    lane_cs = jnp.cumsum(x, axis=2)                # inclusive along lanes
    row_tot = lane_cs[:, :, -1]                    # (1, 8)
    row_off = jnp.cumsum(row_tot, axis=1) - row_tot
    carry = carry_ref[0, 0]
    pos_ref[...] = lane_cs - x + row_off[:, :, None] + carry   # exclusive
    block_total = row_tot.sum()
    carry_ref[0, 0] = carry + block_total
    total_ref[0, 0] = carry + block_total          # running total per block


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def mask_prefix_sum(mask: jnp.ndarray, block: int = DEFAULT_BLOCK,
                    interpret: bool = False):
    """mask [N] bool → (exclusive prefix sum [N] int32, count int32)."""
    n = mask.shape[0]
    if n == 0:    # zero-size grid: nothing to scan (empty candidate sets)
        return jnp.zeros((0,), jnp.int32), jnp.int32(0)
    padded = pl.cdiv(n, block) * block
    m_p = jnp.zeros((padded,), jnp.bool_).at[:n].set(mask)
    m2 = m_p.reshape(-1, 8, block // 8)
    nblk = m2.shape[0]
    pos, totals = pl.pallas_call(
        _scan_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((1, 8, block // 8), lambda i: (i, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, 8, block // 8), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(m2.shape, jnp.int32),
            jax.ShapeDtypeStruct((nblk, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(m2)
    return pos.reshape(-1)[:n], totals[-1, 0]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def compact(mask: jnp.ndarray, block: int = DEFAULT_BLOCK,
            interpret: bool = False):
    """mask [N] → (indices [N] int32, -1 padded; count int32)."""
    n = mask.shape[0]
    pos, count = mask_prefix_sum(mask, block=block, interpret=interpret)
    slot = jnp.where(mask, pos, n)
    idx = jnp.full((n,), -1, jnp.int32)
    idx = idx.at[slot].set(jnp.arange(n, dtype=jnp.int32), mode="drop")
    return idx, count


def _scan_batched_kernel(mask_ref, pos_ref, total_ref, carry_ref):
    """Per-(shard, row-block) scan step; the carry resets at each shard's
    first block, so one launch scans a whole wave of shards."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[0, 0] = 0

    x = mask_ref[...].astype(jnp.int32)            # (1, 1, 8, L)
    lane_cs = jnp.cumsum(x, axis=3)                # inclusive along lanes
    row_tot = lane_cs[..., -1]                     # (1, 1, 8)
    row_off = jnp.cumsum(row_tot, axis=2) - row_tot
    carry = carry_ref[0, 0]
    pos_ref[...] = lane_cs - x + row_off[..., None] + carry    # exclusive
    block_total = row_tot.sum()
    carry_ref[0, 0] = carry + block_total
    total_ref[0, 0] = carry + block_total          # running total per block


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def mask_prefix_sum_batched(masks: jnp.ndarray, block: int = DEFAULT_BLOCK,
                            interpret: bool = False):
    """masks [S, N] bool → (exclusive prefix sums [S, N] int32, counts [S]).

    The wave dimension S stacks shards (ragged lengths False-padded to the
    wave max by the caller); the grid walks (shard, row-block) with the
    running count carried in SMEM and reset per shard, so the whole wave is
    one kernel launch.  Grid order is sequential in both dimensions
    (``arbitrary`` semantics) — the scan-with-carry pattern requires it.
    """
    s, n = masks.shape
    if n == 0 or s == 0:
        return (jnp.zeros((s, n), jnp.int32), jnp.zeros((s,), jnp.int32))
    padded = pl.cdiv(n, block) * block
    m_p = jnp.zeros((s, padded), jnp.bool_).at[:, :n].set(masks)
    m2 = m_p.reshape(s, -1, 8, block // 8)
    nblk = m2.shape[1]
    pos, totals = pl.pallas_call(
        _scan_batched_kernel,
        grid=(s, nblk),
        in_specs=[pl.BlockSpec((1, 1, 8, block // 8),
                               lambda i, j: (i, j, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, 1, 8, block // 8), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(m2.shape, jnp.int32),
            jax.ShapeDtypeStruct((s, nblk), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(m2)
    return pos.reshape(s, -1)[:, :n], totals[:, -1]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def compact_batched(masks: jnp.ndarray, block: int = DEFAULT_BLOCK,
                    interpret: bool = False):
    """masks [S, N] → (indices [S, N] int32, -1 padded; counts [S])."""
    s, n = masks.shape
    pos, counts = mask_prefix_sum_batched(masks, block=block,
                                          interpret=interpret)
    slot = jnp.where(masks, pos, n)
    rows = jax.lax.broadcasted_iota(jnp.int32, (s, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (s, n), 1)
    idx = jnp.full((s, n), -1, jnp.int32)
    idx = idx.at[rows, slot].set(cols, mode="drop")
    return idx, counts
