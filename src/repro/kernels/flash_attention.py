"""Pallas flash attention (forward) for the serving/training stack.

Tiled online-softmax attention with:
  * GQA — Hq query heads read Hkv ≤ Hq KV heads via the index map,
  * causal masking with a *decode offset* (Sq may be shorter than Skv,
    aligned to the end — covers prefill-with-cache and single-token decode),
  * sliding-window masking (Mixtral SWA, Gemma-3 local layers),
  * tanh logit soft-capping (Gemma),
  * fully-masked KV blocks are skipped (causal/window block pruning).

Grid: (B·Hq, Sq/bq, Skv/bk), KV innermost & sequential; running max m,
denominator l and the output accumulator live in VMEM scratch across the
KV loop.  Blocks default to (bq, d) = (256, head_dim) and bk = 256:
q/k/v tiles are ≤ 256·256·4 B = 256 KiB total — comfortably inside VMEM,
and every matmul dimension is a multiple of the 128-wide MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["flash_attention"]

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256
_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int | None,
                  softcap: float | None, sq: int, skv: int,
                  block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions (query block sits at the *end* of the kv axis)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + (skv - sq)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # block-level pruning: skip kv blocks fully outside the mask
    q_last = qi * block_q + block_q - 1 + (skv - sq)
    k_first = ki * block_k
    k_last = k_first + block_k - 1
    needed = True
    if causal:
        needed = k_first <= q_last
    if window is not None:
        q_first = qi * block_q + (skv - sq)
        needed = jnp.logical_and(needed, k_last > q_first - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0].astype(jnp.float32)              # (bk, d)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        mask = jnp.ones_like(logits, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        mask &= k_pos < skv                            # kv padding
        logits = jnp.where(mask, logits, _NEG_INF)

        m_prev = m_ref[...][:, :1]                     # (bq, 1)
        m_cur = jnp.max(logits, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)                    # (bq, bk)
        correction = jnp.exp(m_prev - m_new)           # (bq, 1)
        l_prev = l_ref[...][:, :1]
        l_new = l_prev * correction + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * correction + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...][:, :1]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k",
    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None,
                    softcap: float | None = None,
                    scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """q [B,Hq,Sq,D], k/v [B,Hkv,Skv,D] → [B,Hq,Sq,D] (GQA)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, "GQA requires Hq % Hkv == 0"
    group = hq // hkv
    scale_v = scale if scale is not None else 1.0 / np.sqrt(d)

    bq = min(block_q, max(sq, 8))
    bk = min(block_k, max(skv, 128))
    sq_p = pl.cdiv(sq, bq) * bq
    skv_p = pl.cdiv(skv, bk) * bk
    qp = jnp.zeros((b, hq, sq_p, d), q.dtype).at[:, :, :sq].set(q)
    kp = jnp.zeros((b, hkv, skv_p, d), k.dtype).at[:, :, :skv].set(k)
    vp = jnp.zeros((b, hkv, skv_p, d), v.dtype).at[:, :, :skv].set(v)
    q3 = qp.reshape(b * hq, sq_p, d)
    k3 = kp.reshape(b * hkv, skv_p, d)
    v3 = vp.reshape(b * hkv, skv_p, d)

    def kv_head(bh):
        return (bh // hq) * hkv + (bh % hq) // group

    grid = (b * hq, sq_p // bq, skv_p // bk)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale_v, causal=causal, window=window,
            softcap=softcap, sq=sq, skv=skv, block_q=bq, block_k=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda bh, qi, ki: (kv_head(bh), ki, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda bh, qi, ki: (kv_head(bh), ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),     # m
            pltpu.VMEM((bq, 128), jnp.float32),     # l
            pltpu.VMEM((bq, d), jnp.float32),       # acc
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q3, k3, v3)
    return out.reshape(b, hq, sq_p, d)[:, :, :sq, :]
