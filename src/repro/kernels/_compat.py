"""jax-version compatibility shims for the Pallas TPU kernels.

Newer jax exposes ``pltpu.CompilerParams``; older releases (≤0.4.x) call
the same dataclass ``pltpu.TPUCompilerParams``.  Resolve once here so every
kernel imports a single name that works under either.
"""
from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or getattr(_pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
