"""Cross-partition merge of segment-aggregate states (the Mixer combine).

One query executed over P partitions produces per-shard segment states —
for each value slot a ``(count, sum, sum_sq[, min, max])`` vector over
that shard's group key space.  This module combines the states, aligned
to the union key space by the host, in a single device dispatch:

* counts / sums / sums-of-squares accumulate **sequentially in states
  order** (an in-order ``fori_loop``, not a tree reduce) so the float64
  result is bit-equal to the numpy loop-over-partitions oracle and to
  the P=1 sequential reference — absent groups contribute the additive
  identity 0, which changes no bits;
* min / max planes reduce element-wise against ±inf identities;
* per-group presence masks OR.

Under a multi-device ``"part"`` mesh the leading states axis is sharded
with ``shard_map`` and the per-device partial accumulations combine via
``psum`` / ``pmin`` / ``pmax``.  On a one-device host the mesh axis has
size 1, so the shard_map path is still exercised while the arithmetic
stays the exact sequential order — CPU CI emulates P>1 partitions
without changing a single result bit.  Precision note: like the Mixer's
host merge, the combine always accumulates float64 regardless of
``REPRO_KERNEL_IMPL`` (the per-shard *aggregation* is where the
float32-on-MXU trade lives, not the merge).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["merge_partials"]


def _combine_local(cnt, s, s2, mn, mx, msk):
    """Sequential in-order accumulation over the leading states axis."""
    n_states = cnt.shape[0]

    def body(i, acc):
        c, a, a2, lo, hi, m = acc
        return (c + cnt[i], a + s[i], a2 + s2[i],
                jnp.minimum(lo, mn[i]), jnp.maximum(hi, mx[i]),
                m | msk[i])

    init = (jnp.zeros_like(cnt[0]), jnp.zeros_like(s[0]),
            jnp.zeros_like(s2[0]),
            jnp.full_like(mn[0], jnp.inf),
            jnp.full_like(mx[0], -jnp.inf),
            jnp.zeros_like(msk[0]))
    return jax.lax.fori_loop(0, n_states, body, init)


@functools.lru_cache(maxsize=None)
def _sharded_combine(mesh):
    spec = P("part")

    @jax.jit
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(spec,) * 6,
                       out_specs=(P(),) * 6)
    def run(cnt, s, s2, mn, mx, msk):
        c, a, a2, lo, hi, m = _combine_local(cnt, s, s2, mn, mx, msk)
        # in-order within a device, then a cross-device combine.  With a
        # size-1 axis (CPU CI's emulated mesh) this is exactly the
        # sequential oracle order; counts (ints) and min/max/OR are exact
        # at any axis size, float sums become per-device subtotals on a
        # real multi-device mesh (the usual tree-reduce trade)
        return (jax.lax.psum(c, "part"), jax.lax.psum(a, "part"),
                jax.lax.psum(a2, "part"),
                jax.lax.pmin(lo, "part"), jax.lax.pmax(hi, "part"),
                jax.lax.psum(m.astype(jnp.int32), "part") > 0)

    return run


def merge_partials(cnt, s, s2, mn, mx, msk, mesh=None):
    """Combine aligned segment-state stacks.

    ``cnt/s/s2/mn/mx`` are ``[S, K, G]`` (states x value slots x union
    groups), ``msk`` is ``[S, G]`` bool.  Returns the same tuple with the
    leading axis reduced.  ``mesh`` is a 1-D ``"part"`` mesh (see
    ``launch.mesh.make_exec_mesh``); S is zero-padded to a multiple of
    the axis size (identity states: zeros / +-inf / False).
    """
    cnt = jnp.asarray(cnt)
    s = jnp.asarray(s, jnp.float64)
    s2 = jnp.asarray(s2, jnp.float64)
    mn = jnp.asarray(mn, jnp.float64)
    mx = jnp.asarray(mx, jnp.float64)
    msk = jnp.asarray(msk, bool)
    if mesh is None:
        return _combine_local(cnt, s, s2, mn, mx, msk)
    axis = mesh.shape["part"]
    pad = (-cnt.shape[0]) % axis
    if pad:
        def _pad(x, fill):
            width = ((0, pad),) + ((0, 0),) * (x.ndim - 1)
            return jnp.pad(x, width, constant_values=fill)

        cnt, s, s2 = _pad(cnt, 0), _pad(s, 0.0), _pad(s2, 0.0)
        mn, mx = _pad(mn, jnp.inf), _pad(mx, -jnp.inf)
        msk = _pad(msk, False)
    return _sharded_combine(mesh)(cnt, s, s2, mn, mx, msk)
