"""Pallas group-by partial-aggregation kernel (aggregate_produce, §4.3.4).

Per-shard servers reduce (count, sum, sumsq) per group — enough to finish
count/sum/avg/std_dev at the Mixer.  On TPU the natural formulation is a
one-hot matmul: for a row tile T and group tile G,

    onehot[T, G] = (gid[:, None] == group_base + iota(G))
    sum   += onehotᵀ @ v          (MXU)
    sumsq += onehotᵀ @ v²         (MXU)
    count += onehotᵀ @ 1          (MXU)

which turns a scatter-heavy reduction into dense systolic work — the
paper's CPU hash aggregation re-thought for the MXU (see DESIGN.md
§hardware adaptation).  Grid: (group-blocks, row-blocks); row dimension is
sequential and accumulates into the same output block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["segment_agg"]

DEFAULT_ROW_BLOCK = 512
DEFAULT_GROUP_BLOCK = 128


def _seg_kernel(gid_ref, val_ref, cnt_ref, sum_ref, ssq_ref, *,
                group_block: int):
    g = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)
        ssq_ref[...] = jnp.zeros_like(ssq_ref)

    gid = gid_ref[...]                                # (1, T) int32
    v = val_ref[...].astype(jnp.float32)              # (1, T)
    base = g * group_block
    groups = base + jax.lax.broadcasted_iota(jnp.int32, (1, group_block), 1)
    onehot = (gid[0, :, None] == groups[0, None, :]).astype(jnp.float32)
    vv = v[0]                                         # (T,)
    cnt_ref[...] += jnp.sum(onehot, axis=0, keepdims=True)
    sum_ref[...] += (vv @ onehot)[None, :]            # (1, G) via MXU
    ssq_ref[...] += ((vv * vv) @ onehot)[None, :]


@functools.partial(jax.jit, static_argnames=("num_groups", "row_block",
                                             "group_block", "interpret"))
def segment_agg(group_ids: jnp.ndarray, values: jnp.ndarray,
                num_groups: int, row_block: int = DEFAULT_ROW_BLOCK,
                group_block: int = DEFAULT_GROUP_BLOCK,
                interpret: bool = False):
    """group_ids [N] int32 (−1 = masked), values [N] → count/sum/sumsq [G]."""
    n = group_ids.shape[0]
    padded_n = pl.cdiv(n, row_block) * row_block
    padded_g = pl.cdiv(num_groups, group_block) * group_block
    gid = jnp.full((padded_n,), -1, jnp.int32).at[:n].set(
        group_ids.astype(jnp.int32))
    val = jnp.zeros((padded_n,), jnp.float32).at[:n].set(
        values.astype(jnp.float32))
    gid2 = gid.reshape(1, -1)
    val2 = val.reshape(1, -1)
    n_row_blocks = padded_n // row_block
    n_grp_blocks = padded_g // group_block
    cnt, s, s2 = pl.pallas_call(
        functools.partial(_seg_kernel, group_block=group_block),
        grid=(n_grp_blocks, n_row_blocks),
        in_specs=[
            pl.BlockSpec((1, row_block), lambda g, t: (0, t)),
            pl.BlockSpec((1, row_block), lambda g, t: (0, t)),
        ],
        out_specs=[
            pl.BlockSpec((1, group_block), lambda g, t: (0, g)),
            pl.BlockSpec((1, group_block), lambda g, t: (0, g)),
            pl.BlockSpec((1, group_block), lambda g, t: (0, g)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, padded_g), jnp.float32),
            jax.ShapeDtypeStruct((1, padded_g), jnp.float32),
            jax.ShapeDtypeStruct((1, padded_g), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(gid2, val2)
    return (cnt[0, :num_groups], s[0, :num_groups], s2[0, :num_groups])
