"""Pallas chunked linear-recurrence scan (Mamba / mLSTM inner loop).

Computes h_t = a_t ⊙ h_{t-1} + bx_t over the time axis, with the state
carried across time-chunks in VMEM scratch (grid steps execute in order on
TPU, so scratch persists across the sequential chunk dimension).  Within a
chunk the recurrence is solved with an *associative scan* — log₂(T) vector
steps instead of T sequential steps, which is what makes the SSM layers
compute-dense enough to keep up with the MXU-bound attention layers.

Shapes: a, bx [B, L, D] → h [B, L, D].  D is the flattened channel×state
dim (diagonal SSM), padded to the 128-lane boundary by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["ssm_scan"]

DEFAULT_CHUNK = 256


def _scan_kernel(a_ref, bx_ref, h_ref, carry_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0].astype(jnp.float32)        # (T, D)
    bx = bx_ref[0].astype(jnp.float32)      # (T, D)

    def combine(x, y):
        ax, bxx = x
        ay, byy = y
        return ax * ay, byy + ay * bxx

    a_sc, b_sc = jax.lax.associative_scan(combine, (a, bx), axis=0)
    h0 = carry_ref[...]                      # (1, D)
    h = b_sc + a_sc * h0                     # broadcast over T
    h_ref[0] = h.astype(h_ref.dtype)
    carry_ref[...] = h[-1:, :]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(a: jnp.ndarray, bx: jnp.ndarray, chunk: int = DEFAULT_CHUNK,
             interpret: bool = False):
    """a, bx [B, L, D] → (h [B, L, D], h_final [B, D])."""
    B, L, D = a.shape
    c = min(chunk, L)
    L_p = pl.cdiv(L, c) * c
    D_p = pl.cdiv(D, 128) * 128
    a_p = jnp.zeros((B, L_p, D_p), jnp.float32).at[:, :L, :D].set(
        a.astype(jnp.float32))
    bx_p = jnp.zeros((B, L_p, D_p), jnp.float32).at[:, :L, :D].set(
        bx.astype(jnp.float32))
    h = pl.pallas_call(
        _scan_kernel,
        grid=(B, L_p // c),
        in_specs=[
            pl.BlockSpec((1, c, D_p), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, c, D_p), lambda b, t: (b, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, D_p), lambda b, t: (b, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, L_p, D_p), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, D_p), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(a_p, bx_p)
    h = h[:, :L, :D]
    return h, h[:, -1, :]
