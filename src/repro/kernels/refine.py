"""Pallas ragged track-refine kernel (the Tesseract exact pass, paper §2).

After the conservative ``spacetime`` index probe, every candidate trip must
be checked *exactly*: does some track point fall inside the query region's
Morton-range cover during the time window — for **every** constraint of the
query?  Host-side this is the `eval_expr(InSpaceTime)` loop; here it is one
fused device pass over the shard's CSR track buffers.

Input packing (all integer words, so the pass is exact on any impl):

  * ``pts`` — uint32 ``[4, P]`` per-point words: Morton key split into
    (hi, lo) 32-bit halves, and the float64 timestamp mapped through the
    order-preserving IEEE-754 trick (flip sign bit for positives, all bits
    for negatives) and split the same way.  Point-in-range and in-window
    become 64-bit *lexicographic* integer compares — byte-identical to the
    host's uint64 searchsorted + float64 compares, with no f64 on device.
  * ``rows`` — int32 ``[P]`` doc id per point (CSR ``row_splits`` expanded;
    ``-1`` marks padding and never matches a doc).
  * ``cov`` — uint32 ``[C, 8, R]`` per-constraint range table: each of the
    R slots holds (key_lo, key_hi) cover-range bounds and the constraint's
    (win_lo, win_hi) window, all as (hi, lo) word pairs.  Padding slots use
    an empty range (lo = 2^64−1, hi = 0) and never hit.

The kernel walks a ``(doc-block, point-block)`` grid like ``segment_agg``:
per point block it evaluates all C constraints against the R ranges on the
VPU, reduces hits per doc through the one-hot ``rows == doc_iota`` compare,
and OR-accumulates a **per-doc constraint bitset** (bit c set ⇔ some point
satisfied constraint c).  A doc passes iff its bitset is full — computed in
the jit epilogue.  ``refine_tracks_batched`` stacks a whole wave of shards
(ragged P and doc counts zero-padded) and adds a leading shard grid axis,
so a wave costs **one** launch, mirroring ``compact_batched``.

Under ``with_first_hits`` the same grid walk also min-reduces a
per-(doc × constraint) **first-hit** timestamp — the lexicographic
(t_hi, t_lo) minimum over the doc's satisfying points, kept as two uint32
word planes with a (0xFFFFFFFF, 0xFFFFFFFF) "never hit" sentinel (only
NaN timestamps could collide with it, and NaN never passes a window
compare).  Ordered Tesseract queries (A before B) compare that table
edge-wise on device; the ordering adds outputs, not launches.

``with_analytics`` generalizes that min-reduce into the whole reduction
family, still in the same one-hot compare pass: alongside the first-hit
planes it max-reduces a **last-hit** (t_hi, t_lo) pair per
(doc × constraint) — dual sentinel (0, 0); packed key 0 only encodes −NaN,
which never passes a window compare — and sum-accumulates an int32
**hit count** across the sequential point-grid axis.  Count thresholds
(``at_least(k)``) and dwell verdicts (``last − first >= n`` seconds) are
pure epilogue compares over these tables; the reductions add outputs to
the existing ⌈shards/wave⌉ dispatches, never launches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._compat import CompilerParams

__all__ = ["refine_tracks", "refine_tracks_batched", "refine_tracks_multi",
           "DEFAULT_POINT_BLOCK", "DEFAULT_DOC_BLOCK"]

DEFAULT_POINT_BLOCK = 512
DEFAULT_DOC_BLOCK = 128
_RANGE_PAD = 128               # cover-range slots padded to the lane width


def _ge(a_hi, a_lo, b_hi, b_lo):
    """a >= b over (hi, lo) uint32 word pairs (64-bit lexicographic)."""
    return (a_hi > b_hi) | ((a_hi == b_hi) & (a_lo >= b_lo))


def _lt(a_hi, a_lo, b_hi, b_lo):
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo))


def _le(a_hi, a_lo, b_hi, b_lo):
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo <= b_lo))


_FH_SENT = 0xFFFFFFFF          # first-hit "no hit" sentinel word


def _refine_kernel(pts_ref, rows_ref, cov_ref, out_ref, *aux_refs,
                   doc_block: int, n_constraints: int):
    g = pl.program_id(1)
    t = pl.program_id(2)
    sent = jnp.uint32(_FH_SENT)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        for fh in aux_refs[:2]:                    # first-hit planes → sent
            fh[...] = jnp.full_like(fh, sent)
        for ref in aux_refs[2:]:                   # last-hit / count → 0
            ref[...] = jnp.zeros_like(ref)

    k_hi = pts_ref[0, 0, :][:, None]               # (T, 1) uint32
    k_lo = pts_ref[0, 1, :][:, None]
    t_hi = pts_ref[0, 2, :][:, None]
    t_lo = pts_ref[0, 3, :][:, None]
    rows = rows_ref[0, :]                          # (T,) int32
    docs = g * doc_block + jax.lax.broadcasted_iota(
        jnp.int32, (1, doc_block), 1)              # (1, D)
    onehot = rows[:, None] == docs                 # (T, D) bool
    acc = jnp.zeros((1, doc_block), jnp.int32)
    for c in range(n_constraints):
        lo_hi = cov_ref[c, 0, :][None, :]          # (1, R)
        lo_lo = cov_ref[c, 1, :][None, :]
        hi_hi = cov_ref[c, 2, :][None, :]
        hi_lo = cov_ref[c, 3, :][None, :]
        w0_hi = cov_ref[c, 4, :][None, :]
        w0_lo = cov_ref[c, 5, :][None, :]
        w1_hi = cov_ref[c, 6, :][None, :]
        w1_lo = cov_ref[c, 7, :][None, :]
        hit = (_ge(k_hi, k_lo, lo_hi, lo_lo)       # key in [lo, hi)
               & _lt(k_hi, k_lo, hi_hi, hi_lo)
               & _ge(t_hi, t_lo, w0_hi, w0_lo)     # t in [w0, w1]
               & _le(t_hi, t_lo, w1_hi, w1_lo))
        hit_pt = jnp.any(hit, axis=1)              # (T,)
        hit2d = onehot & hit_pt[:, None]           # (T, D)
        contrib = jnp.any(hit2d, axis=0)           # (D,)
        acc = acc | jnp.left_shift(contrib[None, :].astype(jnp.int32), c)
        if aux_refs:
            # per-doc lexicographic (t_hi, t_lo) min over this point
            # block, two passes: min hi, then min lo among points whose
            # hi equals that min (exact — the second pass only sees the
            # argmin-hi candidates; no-hit docs stay at the sentinel)
            fh_hi_ref, fh_lo_ref = aux_refs[0], aux_refs[1]
            blk_hi = jnp.min(jnp.where(hit2d, t_hi, sent), axis=0)  # (D,)
            at_min = hit2d & (t_hi == blk_hi[None, :])
            blk_lo = jnp.min(jnp.where(at_min, t_lo, sent), axis=0)
            acc_hi = fh_hi_ref[0, c, :]
            acc_lo = fh_lo_ref[0, c, :]
            take = (blk_hi < acc_hi) \
                | ((blk_hi == acc_hi) & (blk_lo < acc_lo))
            fh_hi_ref[0, c, :] = jnp.where(take, blk_hi, acc_hi)
            fh_lo_ref[0, c, :] = jnp.where(take, blk_lo, acc_lo)
        if len(aux_refs) > 2:
            # last-hit dual: lexicographic max with (0, 0) init — safe as
            # a sentinel because packed key 0 only encodes −NaN, which
            # never passes a window compare; count sums hits across the
            # sequential point-grid axis
            lh_hi_ref, lh_lo_ref, cnt_ref = aux_refs[2:]
            zero = jnp.uint32(0)
            lblk_hi = jnp.max(jnp.where(hit2d, t_hi, zero), axis=0)
            at_max = hit2d & (t_hi == lblk_hi[None, :])
            lblk_lo = jnp.max(jnp.where(at_max, t_lo, zero), axis=0)
            lacc_hi = lh_hi_ref[0, c, :]
            lacc_lo = lh_lo_ref[0, c, :]
            ltake = (lblk_hi > lacc_hi) \
                | ((lblk_hi == lacc_hi) & (lblk_lo > lacc_lo))
            lh_hi_ref[0, c, :] = jnp.where(ltake, lblk_hi, lacc_hi)
            lh_lo_ref[0, c, :] = jnp.where(ltake, lblk_lo, lacc_lo)
            cnt_ref[0, c, :] = cnt_ref[0, c, :] \
                + jnp.sum(hit2d.astype(jnp.int32), axis=0)
    out_ref[...] = out_ref[...] | acc


def _pad_cov(cov: jnp.ndarray) -> jnp.ndarray:
    """Pad the range axis to the lane width with never-hit slots."""
    c, _, r = cov.shape
    padded_r = max(_RANGE_PAD, pl.cdiv(max(r, 1), _RANGE_PAD) * _RANGE_PAD)
    if r == padded_r:
        return cov
    pad = jnp.zeros((c, 8, padded_r), jnp.uint32)
    # empty range: key >= 0xFFFF…FFFF is unsatisfiable for 60-bit keys and
    # key < 0 is always false — either kills the slot
    pad = pad.at[:, 0, :].set(jnp.uint32(0xFFFFFFFF))
    pad = pad.at[:, 1, :].set(jnp.uint32(0xFFFFFFFF))
    return pad.at[:, :, :r].set(cov)


@functools.partial(jax.jit, static_argnames=("num_docs", "point_block",
                                             "doc_block", "interpret",
                                             "with_first_hits",
                                             "with_analytics"))
def refine_tracks_batched(pts: jnp.ndarray, rows: jnp.ndarray,
                          cov: jnp.ndarray, num_docs: int,
                          point_block: int = DEFAULT_POINT_BLOCK,
                          doc_block: int = DEFAULT_DOC_BLOCK,
                          interpret: bool = False,
                          with_first_hits: bool = False,
                          with_analytics: bool = False):
    """pts [S, 4, P] uint32, rows [S, P] int32 (−1 pad), cov [C, 8, R]
    uint32 → per-doc hit mask [S, num_docs] bool (wave-ragged doc counts
    zero-padded to ``num_docs`` by the caller; slice per shard).

    ``with_first_hits`` grows the same fused pass with a per-(doc ×
    constraint) **first-hit** min-reduce and returns
    ``(mask, first_hi, first_lo)`` — uint32 ``[S, C, num_docs]`` word
    pairs, the lexicographic minimum (t_hi, t_lo) over each doc's points
    satisfying constraint c, (0xFFFFFFFF, 0xFFFFFFFF) when none.  Ordered
    (A-before-B) queries compare this table edge-wise; still one launch
    per wave.

    ``with_analytics`` (implies first hits) returns the full reduction
    family ``(mask, fh_hi, fh_lo, lh_hi, lh_lo, cnt)``: **last-hit**
    lexicographic max word pairs with a (0, 0) no-hit sentinel, and an
    int32 ``[S, C, num_docs]`` **hit-count** table — count/dwell verdicts
    are epilogue compares at the caller, same single launch per wave.
    """
    s, _, p = pts.shape
    n_constraints = int(cov.shape[0])
    full = jnp.int32((1 << n_constraints) - 1)
    sent = jnp.uint32(_FH_SENT)

    def table(fill, dtype=jnp.uint32):
        return jnp.full((s, n_constraints, num_docs), fill, dtype)

    def empty(out):
        if with_analytics:
            return (out, table(sent), table(sent), table(0), table(0),
                    table(0, jnp.int32))
        return (out, table(sent), table(sent)) if with_first_hits else out

    if s == 0 or num_docs == 0:
        return empty(jnp.zeros((s, num_docs), jnp.bool_))
    if p == 0 or n_constraints == 0:
        # no points → no constraint can hit; no constraints → vacuous truth
        return empty(jnp.full((s, num_docs), n_constraints == 0))
    cov = _pad_cov(cov)
    r_pad = cov.shape[2]
    padded_p = pl.cdiv(p, point_block) * point_block
    padded_d = pl.cdiv(num_docs, doc_block) * doc_block
    pts_p = jnp.zeros((s, 4, padded_p), jnp.uint32).at[:, :, :p].set(pts)
    rows_p = jnp.full((s, padded_p), -1, jnp.int32).at[:, :p].set(rows)
    out_shape = [jax.ShapeDtypeStruct((s, padded_d), jnp.int32)]
    out_specs = [pl.BlockSpec((1, doc_block), lambda i, g, t: (i, g))]
    if with_first_hits or with_analytics:
        tbl_shape = jax.ShapeDtypeStruct((s, n_constraints, padded_d),
                                         jnp.uint32)
        tbl_spec = pl.BlockSpec((1, n_constraints, doc_block),
                                lambda i, g, t: (i, 0, g))
        out_shape += [tbl_shape, tbl_shape]
        out_specs += [tbl_spec, tbl_spec]
        if with_analytics:
            cnt_shape = jax.ShapeDtypeStruct((s, n_constraints, padded_d),
                                             jnp.int32)
            out_shape += [tbl_shape, tbl_shape, cnt_shape]
            out_specs += [tbl_spec, tbl_spec, tbl_spec]
    outs = pl.pallas_call(
        functools.partial(_refine_kernel, doc_block=doc_block,
                          n_constraints=n_constraints),
        grid=(s, padded_d // doc_block, padded_p // point_block),
        in_specs=[
            pl.BlockSpec((1, 4, point_block), lambda i, g, t: (i, 0, t)),
            pl.BlockSpec((1, point_block), lambda i, g, t: (i, t)),
            pl.BlockSpec((n_constraints, 8, r_pad),
                         lambda i, g, t: (0, 0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pts_p, rows_p, cov)
    bits = outs[0]
    mask = bits[:, :num_docs] == full
    if with_analytics or with_first_hits:
        return (mask, *(o[:, :, :num_docs] for o in outs[1:]))
    return mask


def _refine_kernel_multi(pts_ref, rows_ref, cov_ref, out_ref, *aux_refs,
                         doc_block: int, n_constraints: int):
    """Query-axis variant of ``_refine_kernel``: grid (q, s, g, t), the
    constraint table block is the q-th query's [C, 8, R] slice, track
    blocks are shared across queries (indexed by s alone)."""
    g = pl.program_id(2)
    t = pl.program_id(3)
    sent = jnp.uint32(_FH_SENT)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        for fh in aux_refs[:2]:                    # first-hit planes → sent
            fh[...] = jnp.full_like(fh, sent)
        for ref in aux_refs[2:]:                   # last-hit / count → 0
            ref[...] = jnp.zeros_like(ref)

    k_hi = pts_ref[0, 0, :][:, None]               # (T, 1) uint32
    k_lo = pts_ref[0, 1, :][:, None]
    t_hi = pts_ref[0, 2, :][:, None]
    t_lo = pts_ref[0, 3, :][:, None]
    rows = rows_ref[0, :]                          # (T,) int32
    docs = g * doc_block + jax.lax.broadcasted_iota(
        jnp.int32, (1, doc_block), 1)              # (1, D)
    onehot = rows[:, None] == docs                 # (T, D) bool
    acc = jnp.zeros((1, doc_block), jnp.int32)
    for c in range(n_constraints):
        lo_hi = cov_ref[0, c, 0, :][None, :]       # (1, R)
        lo_lo = cov_ref[0, c, 1, :][None, :]
        hi_hi = cov_ref[0, c, 2, :][None, :]
        hi_lo = cov_ref[0, c, 3, :][None, :]
        w0_hi = cov_ref[0, c, 4, :][None, :]
        w0_lo = cov_ref[0, c, 5, :][None, :]
        w1_hi = cov_ref[0, c, 6, :][None, :]
        w1_lo = cov_ref[0, c, 7, :][None, :]
        hit = (_ge(k_hi, k_lo, lo_hi, lo_lo)       # key in [lo, hi)
               & _lt(k_hi, k_lo, hi_hi, hi_lo)
               & _ge(t_hi, t_lo, w0_hi, w0_lo)     # t in [w0, w1]
               & _le(t_hi, t_lo, w1_hi, w1_lo))
        hit_pt = jnp.any(hit, axis=1)              # (T,)
        hit2d = onehot & hit_pt[:, None]           # (T, D)
        contrib = jnp.any(hit2d, axis=0)           # (D,)
        acc = acc | jnp.left_shift(contrib[None, :].astype(jnp.int32), c)
        if aux_refs:
            fh_hi_ref, fh_lo_ref = aux_refs[0], aux_refs[1]
            blk_hi = jnp.min(jnp.where(hit2d, t_hi, sent), axis=0)  # (D,)
            at_min = hit2d & (t_hi == blk_hi[None, :])
            blk_lo = jnp.min(jnp.where(at_min, t_lo, sent), axis=0)
            acc_hi = fh_hi_ref[0, 0, c, :]
            acc_lo = fh_lo_ref[0, 0, c, :]
            take = (blk_hi < acc_hi) \
                | ((blk_hi == acc_hi) & (blk_lo < acc_lo))
            fh_hi_ref[0, 0, c, :] = jnp.where(take, blk_hi, acc_hi)
            fh_lo_ref[0, 0, c, :] = jnp.where(take, blk_lo, acc_lo)
        if len(aux_refs) > 2:
            lh_hi_ref, lh_lo_ref, cnt_ref = aux_refs[2:]
            zero = jnp.uint32(0)
            lblk_hi = jnp.max(jnp.where(hit2d, t_hi, zero), axis=0)
            at_max = hit2d & (t_hi == lblk_hi[None, :])
            lblk_lo = jnp.max(jnp.where(at_max, t_lo, zero), axis=0)
            lacc_hi = lh_hi_ref[0, 0, c, :]
            lacc_lo = lh_lo_ref[0, 0, c, :]
            ltake = (lblk_hi > lacc_hi) \
                | ((lblk_hi == lacc_hi) & (lblk_lo > lacc_lo))
            lh_hi_ref[0, 0, c, :] = jnp.where(ltake, lblk_hi, lacc_hi)
            lh_lo_ref[0, 0, c, :] = jnp.where(ltake, lblk_lo, lacc_lo)
            cnt_ref[0, 0, c, :] = cnt_ref[0, 0, c, :] \
                + jnp.sum(hit2d.astype(jnp.int32), axis=0)
    out_ref[...] = out_ref[...] | acc


@functools.partial(jax.jit, static_argnames=("num_docs", "point_block",
                                             "doc_block", "interpret",
                                             "with_first_hits",
                                             "with_analytics"))
def refine_tracks_multi(pts: jnp.ndarray, rows: jnp.ndarray,
                        cov: jnp.ndarray, num_docs: int,
                        point_block: int = DEFAULT_POINT_BLOCK,
                        doc_block: int = DEFAULT_DOC_BLOCK,
                        interpret: bool = False,
                        with_first_hits: bool = False,
                        with_analytics: bool = False):
    """Multi-query wave refine: Q coalesced queries' constraint tables
    against ONE wave of shards' track buffers in a single launch.

    pts [S, 4, P] uint32 and rows [S, P] int32 are shared across queries
    (the wave's resident track buffers, uploaded once); cov [Q, C, 8, R]
    uint32 carries each query's packed cover-range × window table with a
    leading query axis (constraint / range counts padded across queries by
    the caller: never-hit slots on the range axis, always-hit constraints
    on the C axis).  Returns hit masks [Q, S, num_docs] bool, plus uint32
    first-hit word tables [Q, S, C, num_docs] × 2 under
    ``with_first_hits``; ``with_analytics`` adds last-hit word tables
    (0-sentinel) and an int32 hit-count table, same launch.
    """
    s, _, p = pts.shape
    n_queries = int(cov.shape[0])
    n_constraints = int(cov.shape[1])
    full = jnp.int32((1 << n_constraints) - 1)
    sent = jnp.uint32(_FH_SENT)

    def table(fill, dtype=jnp.uint32):
        return jnp.full((n_queries, s, n_constraints, num_docs), fill,
                        dtype)

    def empty(out):
        if with_analytics:
            return (out, table(sent), table(sent), table(0), table(0),
                    table(0, jnp.int32))
        return (out, table(sent), table(sent)) if with_first_hits else out

    if n_queries == 0 or s == 0 or num_docs == 0:
        return empty(jnp.zeros((n_queries, s, num_docs), jnp.bool_))
    if p == 0 or n_constraints == 0:
        return empty(jnp.full((n_queries, s, num_docs), n_constraints == 0))
    cov = jnp.stack([_pad_cov(cov[q]) for q in range(n_queries)])
    r_pad = cov.shape[3]
    padded_p = pl.cdiv(p, point_block) * point_block
    padded_d = pl.cdiv(num_docs, doc_block) * doc_block
    pts_p = jnp.zeros((s, 4, padded_p), jnp.uint32).at[:, :, :p].set(pts)
    rows_p = jnp.full((s, padded_p), -1, jnp.int32).at[:, :p].set(rows)
    out_shape = [jax.ShapeDtypeStruct((n_queries, s, padded_d), jnp.int32)]
    out_specs = [pl.BlockSpec((1, 1, doc_block),
                              lambda q, i, g, t: (q, i, g))]
    if with_first_hits or with_analytics:
        tbl_shape = jax.ShapeDtypeStruct(
            (n_queries, s, n_constraints, padded_d), jnp.uint32)
        tbl_spec = pl.BlockSpec((1, 1, n_constraints, doc_block),
                                lambda q, i, g, t: (q, i, 0, g))
        out_shape += [tbl_shape, tbl_shape]
        out_specs += [tbl_spec, tbl_spec]
        if with_analytics:
            cnt_shape = jax.ShapeDtypeStruct(
                (n_queries, s, n_constraints, padded_d), jnp.int32)
            out_shape += [tbl_shape, tbl_shape, cnt_shape]
            out_specs += [tbl_spec, tbl_spec, tbl_spec]
    outs = pl.pallas_call(
        functools.partial(_refine_kernel_multi, doc_block=doc_block,
                          n_constraints=n_constraints),
        grid=(n_queries, s, padded_d // doc_block, padded_p // point_block),
        in_specs=[
            pl.BlockSpec((1, 4, point_block),
                         lambda q, i, g, t: (i, 0, t)),
            pl.BlockSpec((1, point_block), lambda q, i, g, t: (i, t)),
            pl.BlockSpec((1, n_constraints, 8, r_pad),
                         lambda q, i, g, t: (q, 0, 0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(pts_p, rows_p, cov)
    bits = outs[0]
    mask = bits[:, :, :num_docs] == full
    if with_analytics or with_first_hits:
        return (mask, *(o[..., :num_docs] for o in outs[1:]))
    return mask


@functools.partial(jax.jit, static_argnames=("num_docs", "point_block",
                                             "doc_block", "interpret",
                                             "with_first_hits",
                                             "with_analytics"))
def refine_tracks(pts: jnp.ndarray, rows: jnp.ndarray, cov: jnp.ndarray,
                  num_docs: int, point_block: int = DEFAULT_POINT_BLOCK,
                  doc_block: int = DEFAULT_DOC_BLOCK,
                  interpret: bool = False, with_first_hits: bool = False,
                  with_analytics: bool = False):
    """Single-shard refine: pts [4, P], rows [P], cov [C, 8, R] →
    hit mask [num_docs] bool (+ uint32 first-hit word tables
    [C, num_docs] × 2 under ``with_first_hits``; the full
    (mask, fh, lh, cnt) reduction family under ``with_analytics``)."""
    out = refine_tracks_batched(pts[None], rows[None], cov, num_docs,
                                point_block=point_block,
                                doc_block=doc_block,
                                interpret=interpret,
                                with_first_hits=with_first_hits,
                                with_analytics=with_analytics)
    if with_analytics or with_first_hits:
        return tuple(o[0] for o in out)
    return out[0]
