"""Pallas bitset kernels — the index-intersection hot loop.

The paper's find() intersects per-index postings; with bitmap postings that
is word-wise AND/OR/ANDNOT plus a popcount for selectivity stats.  On TPU
this is pure VPU work: uint32 lanes, 8×128 vregs.  The kernels tile the
word array into VMEM blocks; ``bitmap_intersect`` AND-reduces K stacked
probe bitmaps in one pass and emits per-block popcounts so the host gets
``rows_selected`` without a second pass.

Blocks are (8, 512) words = 16 KiB per operand — far under VMEM, wide
enough to keep all 8 sublanes × 128 lanes busy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bitset_binary", "bitmap_intersect", "bitmap_intersect_batched",
           "DEFAULT_BLOCK_WORDS"]

DEFAULT_BLOCK_WORDS = 8 * 512       # one (8, 512) vreg-aligned tile


def _binary_kernel(a_ref, b_ref, o_ref, *, op: str):
    a = a_ref[...]
    b = b_ref[...]
    if op == "and":
        o_ref[...] = a & b
    elif op == "or":
        o_ref[...] = a | b
    elif op == "andnot":
        o_ref[...] = a & ~b
    else:
        raise ValueError(op)


@functools.partial(jax.jit, static_argnames=("op", "block_words",
                                             "interpret"))
def bitset_binary(a: jnp.ndarray, b: jnp.ndarray, op: str = "and",
                  block_words: int = DEFAULT_BLOCK_WORDS,
                  interpret: bool = False) -> jnp.ndarray:
    """Element-wise bitmap algebra over uint32 word arrays [W]."""
    w = a.shape[0]
    padded = pl.cdiv(w, block_words) * block_words
    a_p = jnp.zeros((padded,), jnp.uint32).at[:w].set(a)
    b_p = jnp.zeros((padded,), jnp.uint32).at[:w].set(b)
    a2 = a_p.reshape(-1, 8, block_words // 8)
    b2 = b_p.reshape(-1, 8, block_words // 8)
    grid = (a2.shape[0],)
    out = pl.pallas_call(
        functools.partial(_binary_kernel, op=op),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 8, block_words // 8), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 8, block_words // 8), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 8, block_words // 8), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(a2.shape, jnp.uint32),
        interpret=interpret,
    )(a2, b2)
    return out.reshape(-1)[:w]


def _intersect_kernel(stack_ref, o_ref, cnt_ref):
    """AND-reduce K bitmaps for one word-block + popcount the result."""
    k = stack_ref.shape[0]
    acc = stack_ref[0]
    for i in range(1, k):           # K is small & static (probes per query)
        acc = acc & stack_ref[i]
    o_ref[...] = acc
    x = acc
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    per_word = (x * jnp.uint32(0x01010101)) >> jnp.uint32(24)
    cnt_ref[0, 0] = per_word.astype(jnp.int32).sum()


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def bitmap_intersect(stack: jnp.ndarray,
                     block_words: int = DEFAULT_BLOCK_WORDS,
                     interpret: bool = False):
    """AND-reduce probe bitmaps [K, W] → (bitmap [W], total popcount).

    The grid walks word-blocks; each step reduces all K probes for its
    block (K is tiny — one per index probe) and emits a per-block count;
    the host-side sum of the per-block counts is ``rows_selected``.
    """
    k, w = stack.shape
    padded = pl.cdiv(w, block_words) * block_words
    s_p = jnp.zeros((k, padded), jnp.uint32).at[:, :w].set(stack)
    s2 = s_p.reshape(k, -1, 8, block_words // 8)
    nblk = s2.shape[1]
    out, cnt = pl.pallas_call(
        _intersect_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((k, 1, 8, block_words // 8),
                               lambda i: (0, i, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, 8, block_words // 8), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk, 8, block_words // 8), jnp.uint32),
            jax.ShapeDtypeStruct((nblk, 1), jnp.int32),
        ],
        interpret=interpret,
    )(s2)
    return out.reshape(-1)[:w], cnt.sum()


def _intersect_batched_kernel(stack_ref, o_ref, cnt_ref):
    """One (shard, word-block) grid step: AND-reduce that shard's K probes
    for the block + popcount."""
    k = stack_ref.shape[1]
    acc = stack_ref[0, 0, 0]
    for i in range(1, k):           # K is small & static (probes per query)
        acc = acc & stack_ref[0, i, 0]
    o_ref[...] = acc[None, None]
    x = acc
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    per_word = (x * jnp.uint32(0x01010101)) >> jnp.uint32(24)
    cnt_ref[0, 0] = per_word.astype(jnp.int32).sum()


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def bitmap_intersect_batched(stack: jnp.ndarray,
                             block_words: int = DEFAULT_BLOCK_WORDS,
                             interpret: bool = False):
    """Multi-shard AND-reduce [S, K, W] → (bitmaps [S, W], popcounts [S]).

    The wave dimension S stacks shards (ragged word counts zero-padded to
    the wave max by the caller); one launch covers the whole wave instead
    of one ``bitmap_intersect`` per shard.  Zero padding is sound for the
    result: every stack includes the shard's valid-doc mask, whose padding
    words are zero, so AND keeps the pad region clear.
    """
    s, k, w = stack.shape
    padded = pl.cdiv(w, block_words) * block_words
    s_p = jnp.zeros((s, k, padded), jnp.uint32).at[:, :, :w].set(stack)
    s2 = s_p.reshape(s, k, -1, 8, block_words // 8)
    nblk = s2.shape[2]
    out, cnt = pl.pallas_call(
        _intersect_batched_kernel,
        grid=(s, nblk),
        in_specs=[pl.BlockSpec((1, k, 1, 8, block_words // 8),
                               lambda i, j: (i, 0, j, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, 1, 8, block_words // 8),
                         lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, nblk, 8, block_words // 8), jnp.uint32),
            jax.ShapeDtypeStruct((s, nblk), jnp.int32),
        ],
        interpret=interpret,
    )(s2)
    return out.reshape(s, -1)[:, :w], cnt.sum(axis=1)
