"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: kernel unit tests sweep shapes/dtypes
and assert_allclose against these; the 512-device dry-run lowers *these*
(kernels compile for the TPU target, not the CPU host platform), so the
roofline FLOPs/bytes come from the same math the kernels implement.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["bitset_and_ref", "bitset_or_ref", "bitset_andnot_ref",
           "popcount_ref", "bitmap_intersect_ref",
           "bitmap_intersect_batched_ref", "compact_ref",
           "compact_batched_ref", "segment_agg_ref", "refine_tracks_ref",
           "refine_tracks_batched_ref", "refine_tracks_multi_ref",
           "flash_attention_ref", "ssm_scan_ref", "decode_attention_ref"]


# ----------------------------------------------------------------- bitsets

def bitset_and_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a & b


def bitset_or_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a | b


def bitset_andnot_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a & ~b


def _popcount_words(a: jnp.ndarray) -> jnp.ndarray:
    """Per-word SWAR popcount of a uint32 array → int32, same shape."""
    x = a.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> jnp.uint32(24)) \
        .astype(jnp.int32)


def popcount_ref(a: jnp.ndarray) -> jnp.ndarray:
    """Total set bits over a uint32 word array → int32 scalar."""
    return _popcount_words(a).sum()


def bitmap_intersect_ref(stack: jnp.ndarray) -> jnp.ndarray:
    """AND-reduce K probe bitmaps [K, W] → [W] (the find() hot loop)."""
    return jax.lax.reduce(stack, jnp.uint32(0xFFFFFFFF),
                          jax.lax.bitwise_and, dimensions=(0,))


def bitmap_intersect_batched_ref(stack: jnp.ndarray):
    """Wave-stacked AND-reduce [S, K, W] → (bitmaps [S, W], counts [S])."""
    bm = jax.lax.reduce(stack, jnp.uint32(0xFFFFFFFF),
                        jax.lax.bitwise_and, dimensions=(1,))
    return bm, _popcount_words(bm).sum(axis=1)


# ------------------------------------------------------------- compaction

def compact_ref(mask: jnp.ndarray):
    """mask [N] bool → (indices [N] int32 with -1 padding, count int32).

    Stream compaction: indices[:count] are the positions of set bits in
    ascending order; the tail is -1.
    """
    n = mask.shape[0]
    mask_i = mask.astype(jnp.int32)
    count = mask_i.sum()
    pos = jnp.where(mask, jnp.cumsum(mask_i) - 1, n)  # target slot per hit
    src = jnp.arange(n, dtype=jnp.int32)
    idx = jnp.full((n,), -1, dtype=jnp.int32)
    idx = idx.at[pos].set(src, mode="drop")
    return idx, count.astype(jnp.int32)


def compact_batched_ref(masks: jnp.ndarray):
    """masks [S, N] bool → (indices [S, N] int32, -1 padded; counts [S])."""
    s, n = masks.shape
    mask_i = masks.astype(jnp.int32)
    counts = mask_i.sum(axis=1).astype(jnp.int32)
    slot = jnp.where(masks, jnp.cumsum(mask_i, axis=1) - 1, n)
    rows = jax.lax.broadcasted_iota(jnp.int32, (s, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (s, n), 1)
    idx = jnp.full((s, n), -1, dtype=jnp.int32)
    idx = idx.at[rows, slot].set(cols, mode="drop")
    return idx, counts


# -------------------------------------------------------- group-by partials

def segment_agg_ref(group_ids: jnp.ndarray, values: jnp.ndarray,
                    num_groups: int):
    """Per-group (count, sum, sumsq) — aggregate_produce's inner loop.

    group_ids [N] int32 in [0, num_groups); ids < 0 are masked out.
    """
    valid = group_ids >= 0
    gid = jnp.where(valid, group_ids, 0)
    vals = jnp.asarray(values)
    if not jnp.issubdtype(vals.dtype, jnp.floating):
        vals = vals.astype(jnp.float32)
    v = jnp.where(valid, vals, 0)
    ones = valid.astype(v.dtype)
    count = jax.ops.segment_sum(ones, gid, num_segments=num_groups)
    s = jax.ops.segment_sum(v, gid, num_segments=num_groups)
    s2 = jax.ops.segment_sum(v * v, gid, num_segments=num_groups)
    return count, s, s2


# ------------------------------------------------------------ track refine

def _pair_ge(a_hi, a_lo, b_hi, b_lo):
    """a >= b over (hi, lo) uint32 word pairs (64-bit lexicographic)."""
    return (a_hi > b_hi) | ((a_hi == b_hi) & (a_lo >= b_lo))


def _pair_lt(a_hi, a_lo, b_hi, b_lo):
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo))


def _pair_le(a_hi, a_lo, b_hi, b_lo):
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo <= b_lo))


_FH_SENT = 0xFFFFFFFF          # first-hit "no hit" sentinel word (uint32)


@functools.partial(jax.jit, static_argnames=("num_docs", "with_first_hits",
                                             "with_analytics"))
def refine_tracks_ref(pts: jnp.ndarray, rows: jnp.ndarray,
                      cov: jnp.ndarray, num_docs: int,
                      with_first_hits: bool = False,
                      with_analytics: bool = False):
    """Exact Tesseract refine over one shard's packed ragged track.

    pts [4, P] uint32 — per-point (key_hi, key_lo, t_hi, t_lo) words;
    rows [P] int32 — doc id per point (−1 = padding);
    cov [C, 8, R] uint32 — per-constraint cover-range + window word table
    (see ``kernels.refine``).  → bool hit mask [num_docs]: doc d passes iff
    for *every* constraint some point of d lies in a cover range during the
    window.  Pure integer work — byte-equal to the host numpy oracle.

    ``with_first_hits`` additionally returns the per-(constraint × doc)
    **first-hit** packed timestamp as uint32 (hi, lo) word pairs
    ``[C, num_docs]`` — the lexicographic min of (t_hi, t_lo) over the
    doc's satisfying points, (0xFFFFFFFF, 0xFFFFFFFF) when none — the
    table ordered queries compare edge-wise.

    ``with_analytics`` (implies first hits) returns the full reduction
    family from the same one-hot pass:
    ``(mask, fh_hi, fh_lo, lh_hi, lh_lo, cnt)`` — the **last-hit**
    lexicographic max as uint32 word pairs with a (0, 0) "never hit"
    sentinel (key 0 only packs −NaN, which never passes a window compare),
    and the per-(constraint × doc) **hit count** int32 table.  Count and
    dwell (last − first) verdicts are applied by the caller.
    """
    n_constraints = int(cov.shape[0])
    p = pts.shape[1]
    sent = jnp.uint32(_FH_SENT)
    need_first = with_first_hits or with_analytics

    def table(fill, dtype=jnp.uint32):
        return jnp.full((n_constraints, num_docs), fill, dtype)

    def empty(out):
        if with_analytics:
            return (out, table(sent), table(sent), table(0), table(0),
                    table(0, jnp.int32))
        return (out, table(sent), table(sent)) if with_first_hits else out

    if num_docs == 0:
        return empty(jnp.zeros((0,), jnp.bool_))
    if p == 0 or n_constraints == 0:
        return empty(jnp.full((num_docs,), n_constraints == 0))
    k_hi, k_lo, t_hi, t_lo = pts[0], pts[1], pts[2], pts[3]
    safe_rows = jnp.where(rows >= 0, rows, num_docs)    # pad → dropped
    out = jnp.ones((num_docs,), jnp.bool_)
    fh_his, fh_los = [], []
    lh_his, lh_los, cnts = [], [], []
    for c in range(n_constraints):
        in_win = (_pair_ge(t_hi, t_lo, cov[c, 4, 0], cov[c, 5, 0])
                  & _pair_le(t_hi, t_lo, cov[c, 6, 0], cov[c, 7, 0]))

        def body(r, acc, c=c):
            return acc | (_pair_ge(k_hi, k_lo, cov[c, 0, r], cov[c, 1, r])
                          & _pair_lt(k_hi, k_lo, cov[c, 2, r], cov[c, 3, r]))

        in_cov = jax.lax.fori_loop(0, cov.shape[2], body,
                                   jnp.zeros((p,), jnp.bool_))
        hit = in_cov & in_win
        doc_hit = jnp.zeros((num_docs,), jnp.int32) \
            .at[safe_rows].max(hit.astype(jnp.int32), mode="drop")
        out = out & (doc_hit > 0)
        if need_first:
            # lexicographic (hi, lo) min in two passes: min the hi words,
            # then min the lo words among points matching that hi — exact
            # because the second pass only sees the argmin-hi candidates
            fh_hi = jnp.full((num_docs + 1,), sent, jnp.uint32) \
                .at[safe_rows].min(jnp.where(hit, t_hi, sent), mode="drop")
            at_min = hit & (t_hi == fh_hi[safe_rows])
            fh_lo = jnp.full((num_docs + 1,), sent, jnp.uint32) \
                .at[safe_rows].min(jnp.where(at_min, t_lo, sent),
                                   mode="drop")
            fh_his.append(fh_hi[:num_docs])
            fh_los.append(fh_lo[:num_docs])
        if with_analytics:
            # last-hit dual: lexicographic (hi, lo) max with a (0, 0)
            # no-hit sentinel — exact for the same argmax-hi reason
            lh_hi = jnp.zeros((num_docs + 1,), jnp.uint32) \
                .at[safe_rows].max(jnp.where(hit, t_hi, 0), mode="drop")
            at_max = hit & (t_hi == lh_hi[safe_rows])
            lh_lo = jnp.zeros((num_docs + 1,), jnp.uint32) \
                .at[safe_rows].max(jnp.where(at_max, t_lo, 0), mode="drop")
            cnt = jnp.zeros((num_docs + 1,), jnp.int32) \
                .at[safe_rows].add(hit.astype(jnp.int32), mode="drop")
            lh_his.append(lh_hi[:num_docs])
            lh_los.append(lh_lo[:num_docs])
            cnts.append(cnt[:num_docs])
    if with_analytics:
        return (out, jnp.stack(fh_his), jnp.stack(fh_los),
                jnp.stack(lh_his), jnp.stack(lh_los), jnp.stack(cnts))
    if with_first_hits:
        return out, jnp.stack(fh_his), jnp.stack(fh_los)
    return out


@functools.partial(jax.jit, static_argnames=("num_docs", "with_first_hits",
                                             "with_analytics"))
def refine_tracks_batched_ref(pts: jnp.ndarray, rows: jnp.ndarray,
                              cov: jnp.ndarray, num_docs: int,
                              with_first_hits: bool = False,
                              with_analytics: bool = False):
    """Wave-stacked refine: pts [S, 4, P], rows [S, P] → masks
    [S, num_docs] (every shard shares the query's constraint table);
    ``with_first_hits`` adds uint32 first-hit word tables
    [S, C, num_docs] × 2 (hi, lo); ``with_analytics`` adds last-hit word
    tables (0-sentinel) and an int32 hit-count table on top."""
    n_constraints = int(cov.shape[0])
    if pts.shape[0] == 0:
        out = jnp.zeros((0, num_docs), jnp.bool_)
        shape = (0, n_constraints, num_docs)
        if with_analytics:
            sent = jnp.uint32(_FH_SENT)
            t = jnp.full(shape, sent, jnp.uint32)
            z = jnp.zeros(shape, jnp.uint32)
            return out, t, t, z, z, jnp.zeros(shape, jnp.int32)
        if with_first_hits:
            t = jnp.full(shape, jnp.uint32(_FH_SENT), jnp.uint32)
            return out, t, t
        return out
    return jax.vmap(
        lambda pp, rr: refine_tracks_ref(pp, rr, cov, num_docs,
                                         with_first_hits,
                                         with_analytics))(pts, rows)


@functools.partial(jax.jit, static_argnames=("num_docs", "with_first_hits",
                                             "with_analytics"))
def refine_tracks_multi_ref(pts: jnp.ndarray, rows: jnp.ndarray,
                            cov: jnp.ndarray, num_docs: int,
                            with_first_hits: bool = False,
                            with_analytics: bool = False):
    """Multi-query wave refine oracle: cov [Q, C, 8, R] carries Q
    coalesced queries' constraint tables; pts [S, 4, P] / rows [S, P] are
    the wave's shared track buffers.  vmap over the query axis of the
    batched single-query oracle → masks [Q, S, num_docs]
    (+ first-hit uint32 word tables [Q, S, C, num_docs] × 2; under
    ``with_analytics`` also last-hit tables and int32 counts)."""
    n_queries, n_constraints = int(cov.shape[0]), int(cov.shape[1])
    s = pts.shape[0]
    if n_queries == 0 or s == 0:
        out = jnp.zeros((n_queries, s, num_docs), jnp.bool_)
        shape = (n_queries, s, n_constraints, num_docs)
        if with_analytics:
            t = jnp.full(shape, jnp.uint32(_FH_SENT), jnp.uint32)
            z = jnp.zeros(shape, jnp.uint32)
            return out, t, t, z, z, jnp.zeros(shape, jnp.int32)
        if with_first_hits:
            t = jnp.full(shape, jnp.uint32(_FH_SENT), jnp.uint32)
            return out, t, t
        return out
    return jax.vmap(
        lambda cc: refine_tracks_batched_ref(pts, rows, cc, num_docs,
                                             with_first_hits,
                                             with_analytics))(cov)


# --------------------------------------------------------- flash attention

def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int | None = None,
                        softcap: float | None = None,
                        scale: float | None = None):
    """Reference GQA attention.

    q [B, Hq, Sq, D]; k, v [B, Hkv, Skv, D]; Hq % Hkv == 0.
    ``window``: sliding-window size (keys within [i-window+1, i]).
    ``softcap``: tanh logit soft-capping (Gemma-style).
    Decode: Sq may be 1 with Skv = cache length (causal mask then permits
    everything up to the cache length).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    # query i sits at absolute position (skv - sq + i): supports decode
    qpos = jnp.arange(sq) + (skv - sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, cache_len, *, window=None,
                         softcap=None):
    """Single-token decode: q [B, Hq, 1, D] against a [B, Hkv, Smax, D]
    cache of which the first ``cache_len`` entries are valid."""
    b, hq, _, d = q.shape
    _, hkv, smax, _ = k_cache.shape
    group = hq // hkv
    scale = 1.0 / np.sqrt(d)
    k = jnp.repeat(k_cache, group, axis=1)
    v = jnp.repeat(v_cache, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    kpos = jnp.arange(smax)
    mask = kpos[None, :] < cache_len          # [B?, Smax] broadcast
    if window is not None:
        mask = mask & (kpos[None, :] >= cache_len - window)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


# ------------------------------------------------------------- SSM scan

def ssm_scan_ref(a, bx, h0=None):
    """Diagonal linear recurrence h_t = a_t ⊙ h_{t-1} + bx_t.

    a, bx: [B, L, D] (elementwise decay and input); returns hs [B, L, D]
    and final state [B, D].  This is the Mamba/mLSTM inner scan with the
    state dimension folded into D.
    """
    B, L, D = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), a.dtype)

    def step(h, inputs):
        a_t, bx_t = inputs
        h = a_t * h + bx_t
        return h, h

    hT, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0),
                                     jnp.moveaxis(bx, 1, 0)))
    return jnp.moveaxis(hs, 0, 1), hT
