"""FDb: column-first indexed storage for nested records (paper §4.1)."""
from .schema import (Schema, Field, BOOL, INT, UINT, FLOAT, DOUBLE, STRING,
                     MESSAGE)
from .columnar import Column, ColumnBatch
from .index import (TagIndex, RangeIndex, LocationIndex, AreaIndex,
                    bitmap_zeros, bitmap_full, bitmap_from_ids,
                    ids_from_bitmap, bitmap_count)
from .fdb import FDb, Shard, build_fdb
from .streaming import StreamingFDb

__all__ = [
    "Schema", "Field", "BOOL", "INT", "UINT", "FLOAT", "DOUBLE", "STRING",
    "MESSAGE", "Column", "ColumnBatch", "TagIndex", "RangeIndex",
    "LocationIndex", "AreaIndex", "bitmap_zeros", "bitmap_full",
    "bitmap_from_ids", "ids_from_bitmap", "bitmap_count",
    "FDb", "Shard", "build_fdb", "StreamingFDb",
]
