"""FDb: sharded, column-first, indexed storage for nested records (§4.1).

An :class:`FDb` is a manifest + N shards.  Each shard holds (a) data columns
grouped by column set and (b) the indices declared by field options on the
schema.  Index construction honours the paper's machinery:

  * a field may carry multiple indices of different kinds,
  * *virtual fields* (``Field.virtual`` = callable over the shard's columns)
    are indexed but never materialized as data,
  * ``location`` indices read companion lat/lng leaves; ``area`` indices
    expand each doc's polyline into a strip (width_m) or point into a circle
    (radius_m) and post into level-``level`` area-tree cells,
  * ``spacetime`` indices post every track point (lat/lng/t leaves) into
    (area-tree cell × time bucket) keys — the Tesseract trip index
    (:mod:`repro.tess.index`).

Storage is a directory of ``.npz`` shard files + a JSON manifest — the
"simple key-value storage abstraction" of the paper (SSTable/LevelDb there,
npz here); read-only after ingest, like the paper's ingested datasets.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field as dc_field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..geo import mercator as Mc
from ..geo.areatree import AreaTree
from .columnar import Column, ColumnBatch
from .index import (AreaIndex, LocationIndex, RangeIndex, TagIndex,
                    bitmap_full)
from .schema import MESSAGE, STRING, Schema

__all__ = ["FDb", "Shard", "build_fdb"]


@dataclass
class Shard:
    batch: ColumnBatch
    indexes: Dict[Tuple[str, str], object] = dc_field(default_factory=dict)
    # valid-doc bitmap, built once: a stable array identity lets the jax
    # backend keep it device-resident across queries (exec.device_cache)
    _all_bm: Optional[np.ndarray] = dc_field(default=None, repr=False,
                                             compare=False)

    @property
    def n(self) -> int:
        return self.batch.n

    def all_bitmap(self) -> np.ndarray:
        if self._all_bm is None:
            self._all_bm = bitmap_full(self.n)
        return self._all_bm

    def index(self, path: str, kind: str):
        return self.indexes.get((path, kind))


def _virtual_or_column(shard_batch: ColumnBatch, path: str, f) -> Tuple:
    """Returns (values, row_splits, vocab) for a leaf or virtual field."""
    if f.virtual is not None:
        raw = {p: c for p, c in shard_batch.columns.items()}
        vals = np.asarray(f.virtual(raw))
        return vals, None, None
    col = shard_batch[path]
    return col.values, col.row_splits, col.vocab


def _build_shard_indexes(schema: Schema, batch: ColumnBatch
                         ) -> Dict[Tuple[str, str], object]:
    out: Dict[Tuple[str, str], object] = {}
    n = batch.n
    for path, f in schema.indexed_paths():
        for kind in f.indexes:
            p = dict(f.index_params)
            if kind == "tag":
                vals, splits, vocab = _virtual_or_column(batch, path, f)
                out[(path, kind)] = TagIndex.build(vals, n, splits, vocab)
            elif kind == "range":
                vals, splits, _ = _virtual_or_column(batch, path, f)
                out[(path, kind)] = RangeIndex.build(vals, n, splits)
            elif kind == "location":
                lat_p = p.get("lat", path + ".lat")
                lng_p = p.get("lng", path + ".lng")
                lat, lng = batch[lat_p], batch[lng_p]
                out[(path, kind)] = LocationIndex.build(
                    lat.values, lng.values, n, lat.row_splits)
            elif kind == "area":
                lat_p = p.get("lat", path + ".lat")
                lng_p = p.get("lng", path + ".lng")
                level = int(p.get("level", 6))
                width_m = float(p.get("width_m", 20.0))
                lat, lng = batch[lat_p], batch[lng_p]
                areas: List[AreaTree] = []
                if lat.row_splits is None:   # points -> circles
                    ix, iy = Mc.latlng_to_xy(lat.values, lng.values)
                    for i in range(n):
                        mpu = float(Mc.meters_per_unit_at(lat.values[i]))
                        areas.append(AreaTree.from_circle(
                            int(ix[i]), int(iy[i]), width_m / mpu,
                            max_level=level))
                else:                         # polylines -> strips
                    ix, iy = Mc.latlng_to_xy(lat.values, lng.values)
                    sp = lat.row_splits
                    for i in range(n):
                        s, e = int(sp[i]), int(sp[i + 1])
                        if e == s:
                            areas.append(AreaTree.empty())
                            continue
                        mpu = float(Mc.meters_per_unit_at(lat.values[s]))
                        areas.append(AreaTree.from_path(
                            ix[s:e].astype(np.float64),
                            iy[s:e].astype(np.float64),
                            width_m / mpu, max_level=level))
                out[(path, kind)] = AreaIndex.build(areas, level)
            elif kind == "spacetime":
                # (cell × time-bucket) postings over a repeated track —
                # lazy import: tess sits above fdb in the layer order
                from ..tess.index import SpaceTimeIndex
                lat = batch[p.get("lat", path + ".lat")]
                lng = batch[p.get("lng", path + ".lng")]
                tt = batch[p.get("t", path + ".t")]
                out[(path, kind)] = SpaceTimeIndex.build(
                    lat.values, lng.values, tt.values, n, lat.row_splits,
                    level=int(p.get("level", 6)),
                    bucket_s=float(p.get("bucket_s", 900.0)),
                    epoch=float(p.get("epoch", 0.0)))
            else:  # pragma: no cover
                raise ValueError(f"unknown index kind {kind!r}")
    return out


class FDb:
    """A named, sharded, indexed dataset."""

    def __init__(self, name: str, schema: Schema, shards: List[Shard]):
        self.name = name
        self.schema = schema
        self.shards = shards

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def num_docs(self) -> int:
        return sum(s.n for s in self.shards)

    def nbytes(self) -> int:
        return sum(s.batch.nbytes() for s in self.shards)

    # ----------------------------------------------------------------- save
    def save(self, directory: str) -> None:
        os.makedirs(directory, exist_ok=True)
        manifest = {
            "name": self.name,
            "schema": self.schema.spec_json(),
            "num_shards": self.num_shards,
            "rows": [s.n for s in self.shards],
        }
        with open(os.path.join(directory, "MANIFEST.json"), "w") as fh:
            json.dump(manifest, fh, indent=1)
        for i, shard in enumerate(self.shards):
            arrays: Dict[str, np.ndarray] = {}
            for p, c in shard.batch.columns.items():
                arrays[f"col/{p}/values"] = c.values
                if c.row_splits is not None:
                    arrays[f"col/{p}/splits"] = c.row_splits
                if c.vocab is not None:
                    arrays[f"col/{p}/vocab"] = np.array(c.vocab, dtype="U")
            arrays["__n__"] = np.array([shard.n], dtype=np.int64)
            np.savez_compressed(
                os.path.join(directory, f"shard-{i:05d}.npz"), **arrays)

    @staticmethod
    def load(directory: str, schema: Optional[Schema] = None) -> "FDb":
        """Load a saved FDb; pass ``schema`` to restore virtual-field indices
        (callables are not serializable — the paper registers structures with
        the Structure manager for the same reason)."""
        with open(os.path.join(directory, "MANIFEST.json")) as fh:
            manifest = json.load(fh)
        if schema is None:
            schema = Schema.from_spec_json(manifest["schema"])
        shards: List[Shard] = []
        for i in range(manifest["num_shards"]):
            with np.load(os.path.join(directory, f"shard-{i:05d}.npz")) as z:
                n = int(z["__n__"][0])
                cols: Dict[str, Column] = {}
                paths = {k.split("/")[1] for k in z.files if k.startswith("col/")}
                for p in paths:
                    vals = z[f"col/{p}/values"]
                    splits = z.get(f"col/{p}/splits")
                    vocab_a = z.get(f"col/{p}/vocab")
                    vocab = list(vocab_a) if vocab_a is not None else None
                    cols[p] = Column(vals, splits, vocab)
            batch = ColumnBatch(schema, cols, n)
            shards.append(Shard(batch, _build_shard_indexes(schema, batch)))
        return FDb(manifest["name"], schema, shards)

    def __repr__(self):
        return (f"FDb({self.name!r}, shards={self.num_shards}, "
                f"docs={self.num_docs}, {self.nbytes()/1e6:.1f} MB)")


def build_fdb(name: str, schema: Schema, records: Sequence[dict],
              num_shards: int = 8,
              shard_key: Optional[Callable[[dict], int]] = None) -> FDb:
    """Ingest records → sharded, indexed FDb.

    ``shard_key`` maps a record to an integer (hashed onto shards); default
    is round-robin, which balances shard sizes — the paper's sampling trick
    (run on a subset of shards) then yields an unbiased sample.
    """
    buckets: List[List[dict]] = [[] for _ in range(num_shards)]
    for i, r in enumerate(records):
        k = (shard_key(r) % num_shards) if shard_key else (i % num_shards)
        buckets[k].append(r)
    shards = []
    for bucket in buckets:
        batch = ColumnBatch.from_records(schema, bucket)
        shards.append(Shard(batch, _build_shard_indexes(schema, batch)))
    return FDb(name, schema, shards)


# -- Schema JSON round-trip (save/load support) ------------------------------
# Serializes the full field tree *including index annotations* so a loaded
# FDb rebuilds its indices; virtual-field callables are the one thing that
# cannot round-trip through JSON (pass the schema to FDb.load for those).

def _field_to_json(f) -> dict:
    return {"name": f.name, "type": f.type, "repeated": f.repeated,
            "indexes": list(f.indexes), "column_set": f.column_set,
            "index_params": f.index_params, "virtual": f.virtual is not None,
            "fields": [_field_to_json(s) for s in f.fields]}


def _field_from_json(d) -> "Field":
    from .schema import Field
    return Field(d["name"], d["type"], d["repeated"],
                 [_field_from_json(s) for s in d["fields"]],
                 tuple(ix for ix in d["indexes"] if not d["virtual"]),
                 d["column_set"], None, d["index_params"])


def _schema_spec_json(self: Schema) -> dict:
    return {"name": self.name,
            "fields": [_field_to_json(f) for f in self.fields]}


def _schema_from_spec_json(spec: dict) -> Schema:
    return Schema(spec["name"],
                  [_field_from_json(f) for f in spec["fields"]])


Schema.spec_json = _schema_spec_json
Schema.from_spec_json = staticmethod(_schema_from_spec_json)
