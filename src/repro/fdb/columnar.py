"""Column-first record batches (paper §4.1.1: FDb data layout).

FDb "stores data values organized by column sets".  A :class:`ColumnBatch`
is the in-memory unit: a dict of dotted leaf paths → :class:`Column`.

  * singular fields → dense array ``values[n]``
  * repeated fields → ragged pair ``(values[m], row_splits[n+1])``; all
    leaves under the same repeated ancestor share one row_splits array
  * strings → dictionary-encoded ``int32`` codes + per-column vocab (this is
    also what makes tag indices and device-side group-bys cheap)

Gather/concat are the two primitives the query engine needs: index-selected
reads gather only matching docs ("read column-wise from the column sets"),
and the Mixer concatenates partial results.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field
from typing import Dict, List, Optional, Sequence

import numpy as np

from .schema import Schema, MESSAGE, STRING, BOOL, INT, UINT, FLOAT, DOUBLE

_DTYPES = {BOOL: np.bool_, INT: np.int64, UINT: np.uint64,
           FLOAT: np.float32, DOUBLE: np.float64, STRING: np.int32}

__all__ = ["Column", "ColumnBatch", "dtype_for", "span_indices"]


def dtype_for(ftype: str):
    return _DTYPES[ftype]


def span_indices(starts: np.ndarray, ends: np.ndarray) -> np.ndarray:
    """Concatenate integer ranges ``[starts[i], ends[i])`` into one flat
    int64 index array, fully vectorized (no per-span Python loop).

    This is the spans-concatenate gather behind every CSR read: ragged
    column gathers, postings-list unions, and candidate track slicing.
    """
    starts = np.asarray(starts, dtype=np.int64)
    lens = np.asarray(ends, dtype=np.int64) - starts
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    offsets = np.cumsum(lens) - lens               # flat start of each span
    return np.repeat(starts - offsets, lens) + np.arange(total,
                                                         dtype=np.int64)


@dataclass
class Column:
    values: np.ndarray
    row_splits: Optional[np.ndarray] = None        # int64 [n+1] if repeated
    vocab: Optional[List[str]] = None              # strings only

    @property
    def is_repeated(self) -> bool:
        return self.row_splits is not None

    @property
    def num_rows(self) -> int:
        if self.row_splits is not None:
            return self.row_splits.size - 1
        return self.values.shape[0]

    # ------------------------------------------------------------- strings
    def decode(self):
        """Materialize strings (host-side display/collect only)."""
        if self.vocab is None:
            return self.values
        v = np.asarray(self.vocab, dtype=object)
        return v[self.values]

    # -------------------------------------------------------------- gather
    def gather(self, ids: np.ndarray) -> "Column":
        ids = np.asarray(ids, dtype=np.int64)
        if not self.is_repeated:
            return Column(self.values[ids], None, self.vocab)
        starts = self.row_splits[ids]
        ends = self.row_splits[ids + 1]
        new_splits = np.zeros(ids.size + 1, dtype=np.int64)
        np.cumsum(ends - starts, out=new_splits[1:])
        flat = span_indices(starts, ends)          # kept elements, in order
        return Column(self.values[flat], new_splits, self.vocab)

    @staticmethod
    def concat(cols: Sequence["Column"]) -> "Column":
        cols = [c for c in cols]
        if not cols:
            raise ValueError("concat of zero columns")
        rep = cols[0].is_repeated
        if any(c.is_repeated != rep for c in cols):
            raise ValueError("mixed cardinality in concat")
        if cols[0].vocab is not None:
            # Merge vocabs, remap codes.
            merged: Dict[str, int] = {}
            parts = []
            for c in cols:
                remap = np.array([merged.setdefault(s, len(merged))
                                  for s in c.vocab], dtype=np.int32) \
                    if c.vocab else np.zeros(0, dtype=np.int32)
                parts.append(remap[c.values] if c.values.size else c.values)
            vocab = [None] * len(merged)
            for s, i in merged.items():
                vocab[i] = s
            values = np.concatenate(parts) if parts else np.zeros(0, np.int32)
        else:
            vocab = None
            values = np.concatenate([c.values for c in cols])
        if not rep:
            return Column(values, None, vocab)
        offsets = np.cumsum([0] + [c.values.shape[0] for c in cols])
        splits = np.concatenate(
            [np.asarray([0], dtype=np.int64)]
            + [c.row_splits[1:] + off for c, off in zip(cols, offsets)])
        return Column(values, splits, vocab)

    @staticmethod
    def from_strings(strings: Sequence[str],
                     row_splits: Optional[np.ndarray] = None) -> "Column":
        table: Dict[str, int] = {}
        codes = np.array([table.setdefault(s, len(table)) for s in strings],
                         dtype=np.int32)
        vocab = [None] * len(table)
        for s, i in table.items():
            vocab[i] = s
        return Column(codes, row_splits, vocab)


class ColumnBatch:
    """n rows of a schema, stored column-first."""

    def __init__(self, schema: Schema, columns: Dict[str, Column], n: int):
        self.schema = schema
        self.columns = columns
        self.n = int(n)
        for p, c in columns.items():
            if c.num_rows != self.n:
                raise ValueError(f"column {p!r} has {c.num_rows} rows, "
                                 f"batch has {self.n}")

    # ------------------------------------------------------------- access
    def __getitem__(self, path: str) -> Column:
        return self.columns[path]

    def __contains__(self, path: str) -> bool:
        return path in self.columns

    def paths(self) -> List[str]:
        return sorted(self.columns)

    def gather(self, ids: np.ndarray) -> "ColumnBatch":
        return ColumnBatch(self.schema,
                           {p: c.gather(ids) for p, c in self.columns.items()},
                           len(ids))

    def select_paths(self, paths: Sequence[str]) -> "ColumnBatch":
        return ColumnBatch(self.schema.minimal_viable(paths),
                           {p: self.columns[p] for p in paths}, self.n)

    @staticmethod
    def concat(batches: Sequence["ColumnBatch"]) -> "ColumnBatch":
        batches = list(batches)
        if not batches:
            raise ValueError("concat of zero batches")
        if len(batches) == 1:
            return batches[0]
        paths = batches[0].paths()
        cols = {p: Column.concat([b[p] for b in batches]) for p in paths}
        return ColumnBatch(batches[0].schema, cols,
                           sum(b.n for b in batches))

    def nbytes(self) -> int:
        tot = 0
        for c in self.columns.values():
            tot += c.values.nbytes
            if c.row_splits is not None:
                tot += c.row_splits.nbytes
        return tot

    # ------------------------------------------------------ records <-> cols
    @staticmethod
    def from_records(schema: Schema, records: Sequence[dict]) -> "ColumnBatch":
        n = len(records)
        cols: Dict[str, Column] = {}
        splits_cache: Dict[str, np.ndarray] = {}

        def rep_root(path: str) -> Optional[str]:
            parts = path.split(".")
            for i in range(1, len(parts) + 1):
                pre = ".".join(parts[:i])
                if schema.field(pre).repeated:
                    return pre
            return None

        def get(rec: dict, path: str):
            node = rec
            for part in path.split("."):
                if node is None:
                    return None
                if isinstance(node, list):
                    node = [x.get(part) if isinstance(x, dict) else None
                            for x in node]
                else:
                    node = node.get(part) if isinstance(node, dict) else None
            return node

        for path in schema.leaf_paths():
            f = schema.field(path)
            if f.virtual is not None:
                continue
            root = rep_root(path)
            if root is None:
                raw = [get(r, path) for r in records]
                if f.type == STRING:
                    cols[path] = Column.from_strings(
                        ["" if v is None else str(v) for v in raw])
                else:
                    fill = False if f.type == BOOL else 0
                    arr = np.array([fill if v is None else v for v in raw],
                                   dtype=_DTYPES[f.type])
                    cols[path] = Column(arr)
            else:
                flat: list = []
                lens = np.zeros(n, dtype=np.int64)
                for i, r in enumerate(records):
                    v = get(r, path)
                    if v is None:
                        v = []
                    elif not isinstance(v, list):
                        v = [v]
                    lens[i] = len(v)
                    flat.extend(v)
                if root not in splits_cache:
                    sp = np.zeros(n + 1, dtype=np.int64)
                    np.cumsum(lens, out=sp[1:])
                    splits_cache[root] = sp
                sp = splits_cache[root]
                if int(sp[-1]) != len(flat):
                    raise ValueError(
                        f"ragged mismatch under repeated field {root!r} "
                        f"at leaf {path!r}")
                if f.type == STRING:
                    cols[path] = Column.from_strings(
                        [str(x) for x in flat], sp)
                else:
                    arr = np.array(flat, dtype=_DTYPES[f.type]) if flat \
                        else np.zeros(0, dtype=_DTYPES[f.type])
                    cols[path] = Column(arr, sp)
        return ColumnBatch(schema, cols, n)

    def to_records(self) -> List[dict]:
        out: List[dict] = [dict() for _ in range(self.n)]

        def put(rec: dict, path: str, value):
            parts = path.split(".")
            node = rec
            for part in parts[:-1]:
                node = node.setdefault(part, {})
            node[parts[-1]] = value

        for path, c in self.columns.items():
            vals = c.decode()
            if c.is_repeated:
                for i in range(self.n):
                    seg = vals[c.row_splits[i]:c.row_splits[i + 1]]
                    put(out[i], path, list(seg.tolist()))
            else:
                for i in range(self.n):
                    v = vals[i]
                    put(out[i], path,
                        v.item() if isinstance(v, np.generic) else v)
        return out

    def __repr__(self):
        return (f"ColumnBatch({self.schema.name!r}, n={self.n}, "
                f"cols={len(self.columns)})")
