"""Read-write (streaming) FDb (paper §4.1.1).

The paper implements read-write FDbs on Bigtable "for streaming FDbs,
including for query profiling and data ingestion logs".  We reproduce the
abstraction on the same key-value contract: an append memtable that flushes
into immutable indexed shards; readers see memtable + flushed shards merged.
WarpFlow itself uses this for its query-profiling log (exec.adhoc writes one
record per query stage).
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence

from .columnar import ColumnBatch
from .fdb import FDb, Shard, _build_shard_indexes
from .schema import Schema

__all__ = ["StreamingFDb"]


class StreamingFDb:
    def __init__(self, name: str, schema: Schema,
                 flush_threshold: int = 4096):
        self.name = name
        self.schema = schema
        self.flush_threshold = int(flush_threshold)
        self._memtable: List[dict] = []
        self._shards: List[Shard] = []
        self._lock = threading.Lock()

    # ------------------------------------------------------------- writes
    def append(self, record: dict) -> None:
        with self._lock:
            self._memtable.append(record)
            if len(self._memtable) >= self.flush_threshold:
                self._flush_locked()

    def extend(self, records: Sequence[dict]) -> None:
        with self._lock:
            self._memtable.extend(records)
            while len(self._memtable) >= self.flush_threshold:
                self._flush_locked()

    def flush(self) -> None:
        with self._lock:
            if self._memtable:
                self._flush_locked()

    def _flush_locked(self) -> None:
        chunk = self._memtable[:self.flush_threshold]
        self._memtable = self._memtable[self.flush_threshold:]
        batch = ColumnBatch.from_records(self.schema, chunk)
        self._shards.append(Shard(batch,
                                  _build_shard_indexes(self.schema, batch)))

    # -------------------------------------------------------------- reads
    def snapshot(self) -> FDb:
        """Immutable read view: flushed shards + memtable as a final shard."""
        with self._lock:
            shards = list(self._shards)
            if self._memtable:
                batch = ColumnBatch.from_records(self.schema, self._memtable)
                shards.append(
                    Shard(batch, _build_shard_indexes(self.schema, batch)))
        return FDb(self.name, self.schema, shards)

    @property
    def num_docs(self) -> int:
        with self._lock:
            return (sum(s.n for s in self._shards) + len(self._memtable))
