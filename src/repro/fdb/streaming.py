"""Read-write (streaming) FDb (paper §4.1.1) with LSM-style delta shards.

The paper implements read-write FDbs on Bigtable "for streaming FDbs,
including for query profiling and data ingestion logs".  We reproduce the
abstraction on the same key-value contract, extended to first-class live
ingestion (ROADMAP Open item 3; CheetahGIS is the reference architecture):

  * **memtable** — raw appended records.  ``append``/``extend`` buffer
    here; crossing ``flush_threshold`` triggers a flush.
  * **delta shards** — each flush freezes one memtable chunk into an
    immutable :class:`~repro.fdb.fdb.Shard` and builds that shard's
    indexes — tag/range/area *and* ``spacetime`` postings — right there,
    **incrementally**: ingesting new data never re-indexes sealed data.
  * **sealed shards** — an LSM-style compaction policy: once
    ``compact_threshold`` small delta shards accumulate, they merge
    (``ColumnBatch.concat`` + one index build) into a single larger
    sealed shard.  Compaction preserves row order (sealed = deltas in
    flush order), so reader views stay byte-stable across a compaction.
    ``compact_threshold=0`` disables the policy (useful when delta
    shards should stay time-partitioned, e.g. for shard-pruning demos);
    :meth:`compact` forces a merge on demand.

    The merge runs on a **background worker**, never on the appending
    thread: crossing the threshold signals a lazily-started daemon, the
    expensive concat + index build happens outside the writer lock, and
    the result commits under the lock by replacing exactly the delta
    prefix it merged — appends land freely during the merge (asserted by
    ``tests/test_streaming.py::test_appends_never_block_on_compaction``).
    ``compact_async=False`` restores the legacy inline-at-flush merge;
    :meth:`drain_compaction` blocks until the policy is satisfied (tests
    and shutdown), :meth:`close` stops the worker.

**Concurrency model.**  All mutation and snapshot state is guarded by one
re-entrant lock; writers (any number of threads) serialize on it, so no
append is lost and a flush boundary never tears a record.  Readers never
hold the lock across query execution: :meth:`snapshot` materializes an
immutable :class:`~repro.fdb.fdb.FDb` view (sealed + delta shards + the
memtable as a tail shard) and hands it out.  Snapshots are cached per
**generation** — a counter bumped by every mutation — so repeated reads
of an unchanged FDb return the *same object*: downstream identity-keyed
machinery (the jax backend's device-buffer priming, the serve tier's
``ResultCache`` FDb tokens) sees one stable identity per generation, and
a query plan that pins its snapshot (``Plan.db``) is immune to appends
landing mid-query — it reads either the pre-append or the post-append
view, never a torn mix.

**Invalidation hook.**  :meth:`add_listener` registers a callback invoked
after every mutation with the now-stale snapshot (the generation readers
may still hold keys against); :meth:`bind_cache` wires that straight into
:meth:`repro.serve.result_cache.ResultCache.invalidate`, so a live
``QueryServer`` can never serve a pre-append cached result once the hook
fires.  Listeners run outside the lock and their errors are swallowed —
ingestion never fails because an observer did.

WarpFlow itself uses this class for its query-profiling log (exec.adhoc
writes one record per query stage).
"""
from __future__ import annotations

import threading
import time
import weakref
from typing import Callable, Dict, List, Optional, Sequence

from .columnar import ColumnBatch
from .fdb import FDb, Shard, _build_shard_indexes
from .schema import Schema

__all__ = ["StreamingFDb"]


class StreamingFDb:
    def __init__(self, name: str, schema: Schema,
                 flush_threshold: int = 4096,
                 compact_threshold: int = 8,
                 compact_async: bool = True):
        self.name = name
        self.schema = schema
        self.flush_threshold = int(flush_threshold)
        #: delta-shard count that triggers an automatic merge into one
        #: sealed shard; 0 disables auto-compaction
        self.compact_threshold = int(compact_threshold)
        #: run threshold-triggered merges on the background worker; False
        #: restores the legacy inline merge on the flushing thread
        self.compact_async = bool(compact_async)
        self._memtable: List[dict] = []
        self._sealed: List[Shard] = []       # large compacted shards
        self._delta: List[Shard] = []        # small recent flushed shards
        self._lock = threading.RLock()
        self._generation = 0
        self._snap: Optional[tuple] = None   # (generation, FDb) cache
        self._listeners: List[Callable[[FDb], None]] = []
        self._compactions = 0
        #: serializes merges (background worker vs forced ``compact()``);
        #: held across the whole merge, while ``_lock`` is only held for
        #: the short prefix-snapshot and commit sections
        self._merge_lock = threading.Lock()
        self._compact_event: Optional[threading.Event] = None
        self._compact_thread: Optional[threading.Thread] = None
        self._closed = False
        #: test seam: called at merge start (outside the writer lock) —
        #: the slow-compaction test injects a sleep here to prove appends
        #: never block on a merge
        self._compact_hook: Optional[Callable[[], None]] = None

    # ----------------------------------------------------------- internals
    @property
    def _shards(self) -> List[Shard]:
        """Flushed shards, sealed-first (back-compat view for tests)."""
        with self._lock:
            return self._sealed + self._delta

    def _stale_snap_locked(self) -> Optional[FDb]:
        """The snapshot a mutation is about to invalidate, if one is
        current (readers may hold cache keys against it)."""
        if self._snap is not None and self._snap[0] == self._generation:
            return self._snap[1]
        return None

    def _notify(self, stale: Optional[FDb]) -> None:
        """Fire mutation listeners (outside the lock) with the now-stale
        snapshot.  Observer failures never fail ingestion."""
        if stale is None:
            return
        for fn in list(self._listeners):
            try:
                fn(stale)
            except Exception:
                pass

    # ------------------------------------------------------------- writes
    def append(self, record: dict) -> None:
        with self._lock:
            stale = self._stale_snap_locked()
            self._memtable.append(record)
            if len(self._memtable) >= self.flush_threshold:
                self._flush_locked()
            self._generation += 1
        self._notify(stale)

    def extend(self, records: Sequence[dict]) -> None:
        with self._lock:
            stale = self._stale_snap_locked()
            self._memtable.extend(records)
            while len(self._memtable) >= self.flush_threshold:
                self._flush_locked()
            self._generation += 1
        self._notify(stale)

    def flush(self) -> None:
        """Freeze the memtable into a delta shard (incremental index
        build included); no-op on an empty memtable."""
        stale = None
        with self._lock:
            if self._memtable:
                stale = self._stale_snap_locked()
                self._flush_locked()
                self._generation += 1
        self._notify(stale)

    def _flush_locked(self) -> None:
        chunk = self._memtable[:self.flush_threshold]
        self._memtable = self._memtable[self.flush_threshold:]
        batch = ColumnBatch.from_records(self.schema, chunk)
        # incremental indexing: only this delta's postings are built —
        # sealed/older delta shards are untouched
        self._delta.append(Shard(batch,
                                 _build_shard_indexes(self.schema, batch)))
        if self.compact_threshold and \
                len(self._delta) >= self.compact_threshold:
            if self.compact_async:
                self._signal_compactor_locked()
            else:
                self._compact_locked()

    # --------------------------------------------------------- compaction
    def compact(self) -> bool:
        """Merge all delta shards into one sealed shard now (synchronous:
        returns after the merge committed).  The merge itself runs
        outside the writer lock, so concurrent appends still land while
        it builds.  Returns True when a merge happened."""
        return self._merge_delta_prefix(min_deltas=2)

    def _compact_locked(self) -> None:
        """Legacy inline merge (``compact_async=False``): runs under the
        writer lock on the flushing thread."""
        batch = ColumnBatch.concat([sh.batch for sh in self._delta])
        self._sealed.append(Shard(batch,
                                  _build_shard_indexes(self.schema, batch)))
        self._delta = []
        self._compactions += 1

    def _signal_compactor_locked(self) -> None:
        """Wake (lazily starting) the background merge worker.  The
        worker holds only a weakref — a collected StreamingFDb (e.g. a
        per-engine query-profile log) is never pinned by its compactor,
        and the thread exits on its next poll."""
        if self._compact_event is None:
            self._compact_event = threading.Event()
            self._compact_thread = threading.Thread(
                target=_compaction_worker,
                args=(weakref.ref(self), self._compact_event),
                name=f"warpflow-compact-{self.name}", daemon=True)
            self._compact_thread.start()
        self._compact_event.set()

    def _merge_delta_prefix(self, min_deltas: int) -> bool:
        """The merge step both the worker and ``compact()`` run: snapshot
        the current delta list under the lock, build the merged shard
        with NO lock held (appends land meanwhile), then commit under the
        lock by replacing exactly the snapshotted prefix — new deltas
        flushed during the merge only ever *extend* the list, so the
        prefix is stable by construction."""
        with self._merge_lock:
            with self._lock:
                to_merge = list(self._delta)
            if len(to_merge) < min_deltas:
                return False
            if self._compact_hook is not None:
                self._compact_hook()
            batch = ColumnBatch.concat([sh.batch for sh in to_merge])
            merged = Shard(batch,
                           _build_shard_indexes(self.schema, batch))
            with self._lock:
                assert self._delta[:len(to_merge)] == to_merge
                stale = self._stale_snap_locked()
                self._sealed.append(merged)
                del self._delta[:len(to_merge)]
                self._compactions += 1
                self._generation += 1
        self._notify(stale)
        return True

    def _compaction_due_locked(self) -> bool:
        return bool(self.compact_threshold
                    and len(self._delta) >= self.compact_threshold)

    def drain_compaction(self, timeout: float = 10.0) -> None:
        """Block until the compaction policy is satisfied and no merge is
        in flight — the deterministic point tests (and shutdown) wait on
        now that threshold merges happen off the appending thread."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                due = self._compaction_due_locked()
            if not due and not self._merge_lock.locked():
                return
            time.sleep(0.002)
        raise TimeoutError(f"compaction of {self.name!r} did not drain "
                           f"within {timeout}s")

    def close(self) -> None:
        """Stop the background compactor (idempotent).  Pending merges
        are abandoned; data is never lost — deltas simply stay unmerged."""
        with self._lock:
            self._closed = True
            ev = self._compact_event
        if ev is not None:
            ev.set()

    # -------------------------------------------------------------- reads
    def snapshot(self) -> FDb:
        """Immutable read view: sealed + delta shards + memtable as a
        final shard.  Cached per generation — unchanged data returns the
        same ``FDb`` object, so device priming and result-cache tokens
        stay stable between mutations."""
        with self._lock:
            if self._snap is not None and self._snap[0] == self._generation:
                return self._snap[1]
            shards = self._sealed + self._delta
            if self._memtable:
                batch = ColumnBatch.from_records(self.schema, self._memtable)
                shards = shards + [
                    Shard(batch, _build_shard_indexes(self.schema, batch))]
            db = FDb(self.name, self.schema, shards)
            self._snap = (self._generation, db)
            return db

    @property
    def generation(self) -> int:
        """Mutation counter; a snapshot is valid while this is unchanged."""
        with self._lock:
            return self._generation

    @property
    def num_docs(self) -> int:
        with self._lock:
            return (sum(s.n for s in self._sealed)
                    + sum(s.n for s in self._delta) + len(self._memtable))

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"generation": self._generation,
                    "sealed_shards": len(self._sealed),
                    "delta_shards": len(self._delta),
                    "memtable_rows": len(self._memtable),
                    "compactions": self._compactions,
                    "docs": (sum(s.n for s in self._sealed)
                             + sum(s.n for s in self._delta)
                             + len(self._memtable))}

    # ---------------------------------------------------------- listeners
    def add_listener(self, fn: Callable[[FDb], None]) -> None:
        """Register ``fn(stale_snapshot)`` to run after every mutation
        that invalidates a live snapshot."""
        with self._lock:
            self._listeners.append(fn)

    def bind_cache(self, cache) -> None:
        """Invalidate ``cache`` entries keyed on a snapshot whenever new
        data lands — the generation-token hook that keeps a live
        ``QueryServer`` from serving pre-append results."""
        invalidate = getattr(cache, "invalidate", None)
        if invalidate is not None:
            self.add_listener(invalidate)


def _compaction_worker(ref: "weakref.ref[StreamingFDb]",
                       event: threading.Event) -> None:
    """Background merge loop: wait for a threshold signal, merge, repeat.
    Holds the StreamingFDb only through a weakref between polls so the
    owner stays collectable; exits when the owner is collected or closed."""
    while True:
        event.wait(timeout=0.5)
        db = ref()
        if db is None:
            return
        try:
            if db._closed:
                return
            if event.is_set():
                event.clear()
                with db._lock:
                    due = db._compaction_due_locked()
                if due:
                    try:
                        db._merge_delta_prefix(
                            min_deltas=max(2, db.compact_threshold))
                    except Exception:
                        pass   # a failed merge never kills ingestion
        finally:
            del db             # never pin across the idle wait
