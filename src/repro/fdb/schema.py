"""Schemas: the Dynamic-Protocol-Buffers analog (paper §4.1.2, §4.3.3).

The paper annotates Protocol Buffers fields with *field options* to declare
indices and column sets, creates schemas dynamically at every pipeline stage
(Dynamic Protocol Buffers), and prunes million-node schema trees down to the
*minimal viable schema* a query touches.

We reproduce the descriptor layer: a :class:`Schema` is a tree of
:class:`Field` descriptors with types ``{bool,int,uint,float,double,string,
message}`` × cardinality ``{singular,repeated}`` plus options:

  * ``index=`` one of ``tag | range | location | area | spacetime`` (and a
    field may carry several indices — "a single field can have multiple
    indices of different types"),
  * ``column_set=`` the column family the field is stored with,
  * ``virtual=`` an expression evaluated at ingest to produce index-only
    values that are never materialized as data columns.

Nested message fields are addressed with dotted paths (``loc.lat``).
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

BOOL, INT, UINT, FLOAT, DOUBLE, STRING, MESSAGE = (
    "bool", "int", "uint", "float", "double", "string", "message")
SCALAR_TYPES = (BOOL, INT, UINT, FLOAT, DOUBLE, STRING)
INDEX_KINDS = ("tag", "range", "location", "area", "spacetime")

__all__ = ["Field", "Schema", "BOOL", "INT", "UINT", "FLOAT", "DOUBLE",
           "STRING", "MESSAGE", "SCALAR_TYPES", "INDEX_KINDS"]


@dataclass
class Field:
    name: str
    type: str
    repeated: bool = False
    fields: List["Field"] = dc_field(default_factory=list)   # for MESSAGE
    indexes: Tuple[str, ...] = ()
    column_set: str = "default"
    virtual: Optional[Callable] = None       # columns-dict -> np array
    index_params: dict = dc_field(default_factory=dict)

    def __post_init__(self):
        if self.type not in SCALAR_TYPES + (MESSAGE,):
            raise ValueError(f"unknown field type {self.type!r}")
        for ix in self.indexes:
            if ix not in INDEX_KINDS:
                raise ValueError(f"unknown index kind {ix!r}")
        if self.type == MESSAGE and self.virtual is not None:
            raise ValueError("virtual fields must be scalar")

    def walk(self, prefix: str = ""):
        path = f"{prefix}{self.name}"
        yield path, self
        for sub in self.fields:
            yield from sub.walk(path + ".")


class Schema:
    """A named tree of fields; the unit registered with the Structure manager."""

    def __init__(self, name: str, fields: Sequence[Field]):
        self.name = name
        self.fields = list(fields)
        self._by_path: Dict[str, Field] = dict(self.walk())
        seen = set()
        for p in self._by_path:
            if p in seen:
                raise ValueError(f"duplicate field path {p!r}")
            seen.add(p)

    # ------------------------------------------------------------- access
    def walk(self):
        for f in self.fields:
            yield from f.walk()

    def field(self, path: str) -> Field:
        try:
            return self._by_path[path]
        except KeyError:
            raise KeyError(f"{self.name} has no field {path!r}; known: "
                           f"{sorted(self._by_path)[:20]}") from None

    def has(self, path: str) -> bool:
        return path in self._by_path

    def leaf_paths(self) -> List[str]:
        return [p for p, f in self._by_path.items() if f.type != MESSAGE]

    def indexed_paths(self) -> List[Tuple[str, Field]]:
        return [(p, f) for p, f in self._by_path.items() if f.indexes]

    def column_sets(self) -> Dict[str, List[str]]:
        out: Dict[str, List[str]] = {}
        for p, f in self._by_path.items():
            if f.type != MESSAGE and f.virtual is None:
                out.setdefault(f.column_set, []).append(p)
        return out

    def node_count(self) -> int:
        return len(self._by_path)

    # ------------------------------------------- minimal viable schema (§4.3.3)
    def minimal_viable(self, paths: Iterable[str]) -> "Schema":
        """Prune to the smallest field tree covering ``paths``.

        The paper: "generates the minimal viable schema by pruning the
        original structure tree to the smallest set of nodes needed for the
        query at hand (tens of nodes as opposed to millions)".
        """
        want = set()
        for p in paths:
            if not self.has(p):
                raise KeyError(f"unknown field {p!r} in schema {self.name}")
            parts = p.split(".")
            for i in range(1, len(parts) + 1):
                want.add(".".join(parts[:i]))

        def prune(fields: List[Field], prefix: str) -> List[Field]:
            out = []
            for f in fields:
                path = prefix + f.name
                if path in want:
                    if f.type == MESSAGE:
                        kept = prune(f.fields, path + ".")
                        out.append(Field(f.name, f.type, f.repeated, kept,
                                         f.indexes, f.column_set, f.virtual,
                                         f.index_params))
                    else:
                        out.append(f)
                elif any(w.startswith(path + ".") for w in want):
                    kept = prune(f.fields, path + ".")
                    out.append(Field(f.name, f.type, f.repeated, kept,
                                     f.indexes, f.column_set, f.virtual,
                                     f.index_params))
            return out

        return Schema(self.name + "#mvs", prune(self.fields, ""))

    # ------------------------------------------------ dynamic schemas (§4.3.3)
    @staticmethod
    def dynamic(name: str, spec: Dict[str, object]) -> "Schema":
        """Create a schema at runtime from ``{path: type | (type, repeated)}``.

        This is how every WFL pipeline stage gets its implicit output schema
        — the Dynamic Protocol Buffers mechanism.  Dotted paths create nested
        message fields on the fly.
        """
        root: dict = {}
        for path, t in spec.items():
            repeated = False
            if isinstance(t, tuple):
                t, repeated = t
            parts = path.split(".")
            node = root
            for part in parts[:-1]:
                node = node.setdefault(part, {})
                if not isinstance(node, dict):
                    raise ValueError(f"field conflict at {part!r} in {path!r}")
            node[parts[-1]] = (t, repeated)

        def build(node: dict) -> List[Field]:
            out = []
            for fname, val in node.items():
                if isinstance(val, dict):
                    out.append(Field(fname, MESSAGE, fields=build(val)))
                else:
                    t, rep = val
                    out.append(Field(fname, t, repeated=rep))
            return out

        return Schema(name, build(root))

    def spec(self) -> Dict[str, object]:
        """Inverse of :meth:`dynamic` (leaf paths only)."""
        return {p: (f.type, f.repeated) for p, f in self._by_path.items()
                if f.type != MESSAGE}

    def __repr__(self):
        return f"Schema({self.name!r}, {self.node_count()} nodes)"
