"""FDb secondary indices (paper §4.1.2) with bitmap postings.

Each shard carries fine-grained indices mapping index values → document ids
*within the shard*, so queries "selectively access the relevant data records
without first having to load the partitions".  Postings are surfaced as
fixed-width bitmaps (uint32 words over the shard's docs) because bitmap
AND/OR/ANDNOT is the query-time hot loop — that is the Pallas ``bitset``
kernel's job on device; numpy here is the host/build-side reference.

Index kinds:
  * ``tag``      — inverted index for discrete values (strings/ints)
  * ``range``    — sorted values + doc ids for numeric BETWEEN / comparisons
  * ``location`` — sorted 60-bit Morton keys; selected by AreaTree ranges
  * ``area``     — cell → docs postings over area-tree cells at a fixed
                   level; selects docs whose *geometry* (path/region)
                   intersects a query region (paper Fig. 5)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..geo import mercator as M
from ..geo.areatree import AreaTree

__all__ = [
    "bitmap_zeros", "bitmap_full", "bitmap_from_ids", "ids_from_bitmap",
    "mask_from_bitmap", "bitmap_stack", "popcount_words",
    "bitmap_and", "bitmap_or", "bitmap_andnot", "bitmap_not", "bitmap_count",
    "TagIndex", "RangeIndex", "LocationIndex", "AreaIndex",
]


# --------------------------------------------------------------------------
# Bitmaps (uint32 words).  Device-side equivalents live in repro.kernels.
# --------------------------------------------------------------------------

def _nwords(n: int) -> int:
    return (n + 31) // 32


def bitmap_zeros(n: int) -> np.ndarray:
    return np.zeros(_nwords(n), dtype=np.uint32)


def bitmap_full(n: int) -> np.ndarray:
    bm = np.full(_nwords(n), 0xFFFFFFFF, dtype=np.uint32)
    tail = n % 32
    if tail and bm.size:
        bm[-1] = np.uint32((1 << tail) - 1)
    return bm


def bitmap_from_ids(ids: np.ndarray, n: int) -> np.ndarray:
    bm = bitmap_zeros(n)
    ids = np.asarray(ids, dtype=np.int64)
    if ids.size:
        np.bitwise_or.at(bm, ids >> 5,
                         (np.uint32(1) << (ids & 31).astype(np.uint32)))
    return bm


def mask_from_bitmap(bm: np.ndarray, n: int) -> np.ndarray:
    """Word bitmap → per-doc bool mask [n] (compaction-kernel input)."""
    return np.unpackbits(bm.view(np.uint8), bitorder="little")[:n] \
        .view(np.bool_)


def ids_from_bitmap(bm: np.ndarray, n: int) -> np.ndarray:
    return np.nonzero(mask_from_bitmap(bm, n))[0].astype(np.int64)


def bitmap_stack(bitmaps: Sequence[np.ndarray]) -> np.ndarray:
    """Stack K same-length bitmaps into one C-contiguous [K, W] uint32
    buffer — the exact word-level layout ``kernels.ops.bitmap_intersect``
    consumes, so device dispatch needs no per-bit expansion or re-copy."""
    if not bitmaps:
        raise ValueError("bitmap_stack of zero bitmaps")
    return np.stack(bitmaps).astype(np.uint32, copy=False)


def bitmap_and(a, b):
    return a & b


def bitmap_or(a, b):
    return a | b


def bitmap_andnot(a, b):
    return a & ~b


def bitmap_not(a, n: int):
    return bitmap_full(n) & ~a


_POP8 = np.array([bin(i).count("1") for i in range(256)], dtype=np.uint8)


def popcount_words(bm: np.ndarray) -> int:
    """Set bits of a uint32 word array, without per-bit expansion."""
    return int(_POP8[bm.view(np.uint8)].sum())


def bitmap_count(bm: np.ndarray) -> int:
    return popcount_words(bm)


# --------------------------------------------------------------------------
# Tag index
# --------------------------------------------------------------------------

@dataclass
class TagIndex:
    """Inverted index: discrete value → sorted doc ids."""

    keys: np.ndarray          # sorted unique int64 keys (string hash or int)
    splits: np.ndarray        # int64 [K+1] CSR into doc_ids
    doc_ids: np.ndarray       # int64 [total]
    n_docs: int
    vocab: Optional[Dict[str, int]] = None   # for string tags: str -> key

    @staticmethod
    def build(values: np.ndarray, n_docs: int,
              row_splits: Optional[np.ndarray] = None,
              vocab: Optional[List[str]] = None) -> "TagIndex":
        values = np.asarray(values)
        if row_splits is not None:
            docs = np.repeat(np.arange(n_docs, dtype=np.int64),
                             np.diff(row_splits))
        else:
            docs = np.arange(n_docs, dtype=np.int64)
        keys = values.astype(np.int64)
        order = np.lexsort((docs, keys))
        keys_s, docs_s = keys[order], docs[order]
        uniq, starts = np.unique(keys_s, return_index=True)
        splits = np.concatenate([starts, [keys_s.size]]).astype(np.int64)
        vmap = {s: i for i, s in enumerate(vocab)} if vocab is not None else None
        return TagIndex(uniq, splits, docs_s, n_docs, vmap)

    def _key_of(self, value) -> Optional[int]:
        if self.vocab is not None:
            if not isinstance(value, str):
                value = str(value)
            if value not in self.vocab:
                return None
            return self.vocab[value]
        return int(value)

    def lookup(self, value) -> np.ndarray:
        k = self._key_of(value)
        if k is None:
            return bitmap_zeros(self.n_docs)
        i = np.searchsorted(self.keys, k)
        if i >= self.keys.size or self.keys[i] != k:
            return bitmap_zeros(self.n_docs)
        ids = self.doc_ids[self.splits[i]:self.splits[i + 1]]
        return bitmap_from_ids(ids, self.n_docs)

    def lookup_any(self, values: Sequence) -> np.ndarray:
        bm = bitmap_zeros(self.n_docs)
        for v in values:
            bm |= self.lookup(v)
        return bm


# --------------------------------------------------------------------------
# Range index
# --------------------------------------------------------------------------

@dataclass
class RangeIndex:
    sorted_values: np.ndarray
    doc_ids: np.ndarray
    n_docs: int

    @staticmethod
    def build(values: np.ndarray, n_docs: int,
              row_splits: Optional[np.ndarray] = None) -> "RangeIndex":
        values = np.asarray(values)
        if row_splits is not None:
            docs = np.repeat(np.arange(n_docs, dtype=np.int64),
                             np.diff(row_splits))
        else:
            docs = np.arange(n_docs, dtype=np.int64)
        order = np.argsort(values, kind="stable")
        return RangeIndex(values[order], docs[order], n_docs)

    def lookup(self, lo=None, hi=None, lo_incl=True, hi_incl=True
               ) -> np.ndarray:
        v = self.sorted_values
        a = 0 if lo is None else int(
            np.searchsorted(v, lo, side="left" if lo_incl else "right"))
        b = v.size if hi is None else int(
            np.searchsorted(v, hi, side="right" if hi_incl else "left"))
        if b <= a:
            return bitmap_zeros(self.n_docs)
        return bitmap_from_ids(self.doc_ids[a:b], self.n_docs)


# --------------------------------------------------------------------------
# Location index
# --------------------------------------------------------------------------

@dataclass
class LocationIndex:
    """Sorted Morton keys of point locations → docs; selected by area ranges."""

    sorted_keys: np.ndarray    # uint64
    doc_ids: np.ndarray
    n_docs: int

    @staticmethod
    def build(lat: np.ndarray, lng: np.ndarray, n_docs: int,
              row_splits: Optional[np.ndarray] = None) -> "LocationIndex":
        keys = M.latlng_to_morton(lat, lng)
        if row_splits is not None:
            docs = np.repeat(np.arange(n_docs, dtype=np.int64),
                             np.diff(row_splits))
        else:
            docs = np.arange(n_docs, dtype=np.int64)
        order = np.argsort(keys, kind="stable")
        return LocationIndex(keys[order], docs[order], n_docs)

    def lookup(self, area: AreaTree) -> np.ndarray:
        """Docs whose location lies inside ``area`` (bbox or region, §4.1.2)."""
        if area.is_empty:
            return bitmap_zeros(self.n_docs)
        starts = np.searchsorted(self.sorted_keys, area.lo, side="left")
        ends = np.searchsorted(self.sorted_keys, area.hi, side="left")
        total = int(np.sum(ends - starts))
        if total == 0:
            return bitmap_zeros(self.n_docs)
        ids = np.concatenate([self.doc_ids[a:b]
                              for a, b in zip(starts, ends) if b > a])
        return bitmap_from_ids(ids, self.n_docs)


# --------------------------------------------------------------------------
# Area index
# --------------------------------------------------------------------------

@dataclass
class AreaIndex:
    """Cell → docs postings over area-tree cells at a fixed level.

    Indexes *geometries* (paths expanded to strips, regions, points expanded
    to circles — paper §4.1.2/Fig. 5).  A doc posts into every level-``level``
    cell its representative area touches; a query region selects the union of
    postings of the cells it covers → "all areas that intersect this region".
    """

    level: int
    cells: np.ndarray        # sorted unique uint64 cell indices (not aligned)
    splits: np.ndarray       # CSR into doc_ids
    doc_ids: np.ndarray
    n_docs: int

    @staticmethod
    def build(doc_areas: Sequence[AreaTree], level: int) -> "AreaIndex":
        shift = np.uint64(6 * (M.MAX_LEVEL - level))
        cell_list: List[np.ndarray] = []
        doc_list: List[np.ndarray] = []
        one = np.uint64(1)
        for doc, area in enumerate(doc_areas):
            if area.is_empty:
                continue
            c0 = area.lo >> shift
            c1 = (area.hi - one) >> shift
            counts = (c1 - c0 + one).astype(np.int64)
            total = int(counts.sum())
            base = np.repeat(c0, counts)
            offs = (np.arange(total, dtype=np.uint64)
                    - np.repeat(np.cumsum(counts) - counts, counts)
                    .astype(np.uint64))
            cs = np.unique(base + offs)
            cell_list.append(cs)
            doc_list.append(np.full(cs.size, doc, dtype=np.int64))
        if not cell_list:
            z = np.zeros(0, dtype=np.uint64)
            return AreaIndex(level, z, np.zeros(1, dtype=np.int64),
                             np.zeros(0, dtype=np.int64), len(doc_areas))
        cells = np.concatenate(cell_list)
        docs = np.concatenate(doc_list)
        order = np.lexsort((docs, cells))
        cells, docs = cells[order], docs[order]
        uniq, starts = np.unique(cells, return_index=True)
        splits = np.concatenate([starts, [cells.size]]).astype(np.int64)
        return AreaIndex(level, uniq, splits, docs, len(doc_areas))

    def lookup_region(self, region: AreaTree) -> np.ndarray:
        """All docs whose indexed area intersects ``region``."""
        if region.is_empty or self.cells.size == 0:
            return bitmap_zeros(self.n_docs)
        shift = np.uint64(6 * (M.MAX_LEVEL - self.level))
        one = np.uint64(1)
        c0 = region.lo >> shift
        c1 = (region.hi - one) >> shift
        bm = bitmap_zeros(self.n_docs)
        for lo, hi in zip(c0, c1):
            a = int(np.searchsorted(self.cells, lo, side="left"))
            b = int(np.searchsorted(self.cells, hi, side="right"))
            if b > a:
                ids = self.doc_ids[self.splits[a]:self.splits[b]]
                bm |= bitmap_from_ids(ids, self.n_docs)
        return bm

    def lookup_points(self, lat, lng) -> np.ndarray:
        """All docs whose indexed area covers any of the given points."""
        keys = M.latlng_to_morton(np.asarray(lat), np.asarray(lng))
        shift = np.uint64(6 * (M.MAX_LEVEL - self.level))
        cells = np.unique(keys >> shift)
        bm = bitmap_zeros(self.n_docs)
        idx = np.searchsorted(self.cells, cells)
        for i, c in zip(idx, cells):
            if i < self.cells.size and self.cells[i] == c:
                ids = self.doc_ids[self.splits[i]:self.splits[i + 1]]
                bm |= bitmap_from_ids(ids, self.n_docs)
        return bm
