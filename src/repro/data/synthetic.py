"""Synthetic spatiotemporal world generator (paper §6 datasets).

Deterministic generators for the three datasets the paper's experiments
revolve around: road segments (with polyline geometry), traffic-speed
observations (a time series per segment with rush-hour structure), and
route requests (paths over roads with actual travel times).  Scales from
unit-test size to benchmark size with one ``scale`` knob.

Each road gets a *true* speed profile: base speed, rush-hour dip, and a
per-road variability level — so the paper's "coefficient of variation"
query (Q1–Q5) has real signal to find, and the §5 ML workflow can learn
to predict speeds from (road, hour) features.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from ..fdb.schema import (DOUBLE, INT, MESSAGE, STRING, Field, Schema)

__all__ = ["roads_schema", "observations_schema", "route_requests_schema",
           "generate_world", "CITIES"]

# city → (lat0, lng0, lat_span, lng_span); SF-bay-like layout
CITIES: Dict[str, Tuple[float, float, float, float]] = {
    "SF": (37.70, -122.52, 0.11, 0.12),
    "Berkeley": (37.85, -122.30, 0.06, 0.06),
    "SouthBay": (37.23, -122.05, 0.15, 0.25),
    "Fremont": (37.50, -122.05, 0.08, 0.10),
    "Sacramento": (38.45, -121.55, 0.15, 0.20),
    "LA": (33.90, -118.40, 0.30, 0.40),
}
BAY_AREA = ("SF", "Berkeley", "SouthBay", "Fremont")


def roads_schema() -> Schema:
    return Schema("Roads", [
        Field("id", INT, indexes=("tag",)),
        Field("city", STRING, indexes=("tag",)),
        Field("loc", MESSAGE, fields=[Field("lat", DOUBLE),
                                      Field("lng", DOUBLE)],
              indexes=("location",)),
        Field("polyline", MESSAGE, fields=[
            Field("lat", DOUBLE, repeated=True),
            Field("lng", DOUBLE, repeated=True)],
            indexes=("area",), index_params={"level": 6, "width_m": 25.0},
            column_set="geometry"),
        Field("speed_limit", DOUBLE, indexes=("range",)),
        Field("base_speed", DOUBLE),
        Field("variability", DOUBLE),
    ])


def observations_schema() -> Schema:
    return Schema("SpeedObservations", [
        Field("road_id", INT, indexes=("tag",)),
        Field("loc", MESSAGE, fields=[Field("lat", DOUBLE),
                                      Field("lng", DOUBLE)],
              indexes=("location",)),
        Field("hour", INT, indexes=("range",)),
        Field("dow", INT, indexes=("range",)),         # 0=Mon … 6=Sun
        Field("month", INT, indexes=("range",)),
        Field("speed", DOUBLE),
        Field("accuracy_m", DOUBLE),
    ])


def route_requests_schema() -> Schema:
    return Schema("RouteRequests", [
        Field("id", INT, indexes=("tag",)),
        Field("start_loc", MESSAGE, fields=[Field("lat", DOUBLE),
                                            Field("lng", DOUBLE)],
              indexes=("location",)),
        Field("end_loc", MESSAGE, fields=[Field("lat", DOUBLE),
                                          Field("lng", DOUBLE)],
              indexes=("location",)),
        Field("hour", INT, indexes=("range",)),
        Field("route", MESSAGE, fields=[
            Field("id", INT, repeated=True)]),          # road segment ids
        Field("time_s", DOUBLE),
    ])


def _road_speed(base: float, var: float, hour: int, rng) -> float:
    """True speed model: rush-hour dips + per-road variability noise."""
    rush = 1.0
    if 7 <= hour <= 9:
        rush = 0.55 + 0.1 * np.cos(hour - 8)
    elif 16 <= hour <= 18:
        rush = 0.6
    elif 0 <= hour <= 5:
        rush = 1.15
    return max(3.0, base * rush + rng.normal(0.0, var))


def generate_world(scale: float = 1.0, seed: int = 0):
    """Returns dict of record lists + schemas; sizes scale linearly."""
    rng = np.random.default_rng(seed)
    n_roads = max(20, int(600 * scale))
    n_obs = max(100, int(20_000 * scale))
    n_req = max(20, int(1_500 * scale))

    cities = list(CITIES)
    weights = np.array([4.0, 1.0, 2.0, 1.0, 1.5, 3.0])
    weights = weights / weights.sum()

    roads: List[dict] = []
    for i in range(n_roads):
        city = cities[int(rng.choice(len(cities), p=weights))]
        lat0, lng0, dlat, dlng = CITIES[city]
        lat = lat0 + rng.uniform(0, dlat)
        lng = lng0 + rng.uniform(0, dlng)
        npts = int(rng.integers(2, 6))
        step = rng.uniform(2e-4, 8e-4, size=(npts, 2)) \
            * rng.choice([-1, 1], size=(npts, 2))
        pts = np.cumsum(np.vstack([[0, 0], step[:-1]]), axis=0) \
            + [lat, lng]
        base = float(rng.uniform(20, 100))
        roads.append({
            "id": i, "city": city,
            "loc": {"lat": lat, "lng": lng},
            "polyline": {"lat": pts[:, 0].tolist(),
                         "lng": pts[:, 1].tolist()},
            "speed_limit": float(np.ceil(base / 10) * 10),
            "base_speed": base,
            "variability": float(rng.uniform(0.5, 12.0)),
        })

    obs: List[dict] = []
    for _ in range(n_obs):
        r = roads[int(rng.integers(0, n_roads))]
        hour = int(np.clip(rng.normal(12, 5.5), 0, 23))
        obs.append({
            "road_id": r["id"],
            "loc": {"lat": r["loc"]["lat"] + rng.normal(0, 1e-4),
                    "lng": r["loc"]["lng"] + rng.normal(0, 1e-4)},
            "hour": hour,
            "dow": int(rng.integers(0, 7)),
            "month": int(rng.integers(1, 7)),
            "speed": _road_speed(r["base_speed"], r["variability"], hour,
                                 rng),
            "accuracy_m": float(np.abs(rng.normal(8, 6)) + 3),
        })

    reqs: List[dict] = []
    for i in range(n_req):
        k = int(rng.integers(2, 8))
        seg_ids = rng.integers(0, n_roads, size=k).tolist()
        start = roads[seg_ids[0]]["loc"]
        end = roads[seg_ids[-1]]["loc"]
        hour = int(np.clip(rng.normal(9, 4), 0, 23))
        t = 0.0
        for sid in seg_ids:
            r = roads[sid]
            speed = _road_speed(r["base_speed"], r["variability"], hour,
                                rng)
            t += 120.0 * r["speed_limit"] / max(speed, 1.0)
        reqs.append({
            "id": i, "start_loc": dict(start), "end_loc": dict(end),
            "hour": hour, "route": {"id": [int(s) for s in seg_ids]},
            "time_s": t,
        })

    return {
        "roads": roads, "observations": obs, "route_requests": reqs,
        "roads_schema": roads_schema(),
        "observations_schema": observations_schema(),
        "route_requests_schema": route_requests_schema(),
    }
