"""Synthetic spatiotemporal world generator (paper §6 datasets).

Deterministic generators for the datasets the paper's experiments revolve
around: road segments (with polyline geometry), traffic-speed observations
(a time series per segment with rush-hour structure), route requests (paths
over roads with actual travel times), and trips (variable-length point
tracks with timestamps — the §2 Tesseract workload).  Scales from
unit-test size to benchmark size with one ``scale`` knob.

Each road gets a *true* speed profile: base speed, rush-hour dip, and a
per-road variability level — so the paper's "coefficient of variation"
query (Q1–Q5) has real signal to find, and the §5 ML workflow can learn
to predict speeds from (road, hour) features.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from ..fdb.schema import (DOUBLE, INT, MESSAGE, STRING, Field, Schema)

__all__ = ["roads_schema", "observations_schema", "route_requests_schema",
           "trips_schema", "generate_world", "city_region", "CITIES"]

# city → (lat0, lng0, lat_span, lng_span); SF-bay-like layout
CITIES: Dict[str, Tuple[float, float, float, float]] = {
    "SF": (37.70, -122.52, 0.11, 0.12),
    "Berkeley": (37.85, -122.30, 0.06, 0.06),
    "SouthBay": (37.23, -122.05, 0.15, 0.25),
    "Fremont": (37.50, -122.05, 0.08, 0.10),
    "Sacramento": (38.45, -121.55, 0.15, 0.20),
    "LA": (33.90, -118.40, 0.30, 0.40),
}
BAY_AREA = ("SF", "Berkeley", "SouthBay", "Fremont")

# inter-city trip destinations: geographically plausible neighbors
NEIGHBORS: Dict[str, Tuple[str, ...]] = {
    "SF": ("Berkeley", "SouthBay", "Fremont", "LA"),
    "Berkeley": ("SF", "Fremont", "Sacramento"),
    "SouthBay": ("SF", "Fremont", "LA"),
    "Fremont": ("SouthBay", "Berkeley", "SF"),
    "Sacramento": ("Berkeley", "SF"),
    "LA": ("SF", "SouthBay"),
}


def roads_schema() -> Schema:
    return Schema("Roads", [
        Field("id", INT, indexes=("tag",)),
        Field("city", STRING, indexes=("tag",)),
        Field("loc", MESSAGE, fields=[Field("lat", DOUBLE),
                                      Field("lng", DOUBLE)],
              indexes=("location",)),
        Field("polyline", MESSAGE, fields=[
            Field("lat", DOUBLE, repeated=True),
            Field("lng", DOUBLE, repeated=True)],
            indexes=("area",), index_params={"level": 6, "width_m": 25.0},
            column_set="geometry"),
        Field("speed_limit", DOUBLE, indexes=("range",)),
        Field("base_speed", DOUBLE),
        Field("variability", DOUBLE),
    ])


def observations_schema() -> Schema:
    return Schema("SpeedObservations", [
        Field("road_id", INT, indexes=("tag",)),
        Field("loc", MESSAGE, fields=[Field("lat", DOUBLE),
                                      Field("lng", DOUBLE)],
              indexes=("location",)),
        Field("hour", INT, indexes=("range",)),
        Field("dow", INT, indexes=("range",)),         # 0=Mon … 6=Sun
        Field("month", INT, indexes=("range",)),
        Field("speed", DOUBLE),
        Field("accuracy_m", DOUBLE),
    ])


def route_requests_schema() -> Schema:
    return Schema("RouteRequests", [
        Field("id", INT, indexes=("tag",)),
        Field("start_loc", MESSAGE, fields=[Field("lat", DOUBLE),
                                            Field("lng", DOUBLE)],
              indexes=("location",)),
        Field("end_loc", MESSAGE, fields=[Field("lat", DOUBLE),
                                          Field("lng", DOUBLE)],
              indexes=("location",)),
        Field("hour", INT, indexes=("range",)),
        Field("route", MESSAGE, fields=[
            Field("id", INT, repeated=True)]),          # road segment ids
        Field("time_s", DOUBLE),
    ])


def city_region(*names: str, max_level: int = 6):
    """Union of city bounding boxes → selection :class:`AreaTree`.

    The canonical query-region builder for this world, shared by the
    benchmark queries, the Tesseract tests, and the examples.  Level 6
    ≈ 150 m cells: city-scale selection with ~100× fewer Morton ranges
    than level 7 (probe cost ∝ ranges).
    """
    from ..geo import mercator as M
    from ..geo.areatree import AreaTree
    area = AreaTree.empty()
    for c in names:
        lat0, lng0, dlat, dlng = CITIES[c]
        ix, iy = M.latlng_to_xy(np.array([lat0, lat0 + dlat]),
                                np.array([lng0, lng0 + dlng]))
        area = area | AreaTree.from_box(int(ix[0]), int(iy[1]),
                                        int(ix[1]), int(iy[0]),
                                        max_level=max_level)
    return area


def trips_schema() -> Schema:
    """Trips: variable-length space-time tracks (the Tesseract workload).

    The ``track`` message carries the repeated (lat, lng, t) point stream
    and a ``spacetime`` index — (level-6 area-tree cell × 15-min bucket)
    postings built at ingest (see :mod:`repro.tess.index`).  ``t`` is
    seconds since the synthetic week's epoch (``day * 86400 + sec``).
    """
    return Schema("Trips", [
        Field("id", INT, indexes=("tag",)),
        Field("vehicle", INT, indexes=("tag",)),
        Field("day", INT, indexes=("range",)),         # 0=Mon … 6=Sun
        Field("start_hour", INT, indexes=("range",)),
        Field("track", MESSAGE, fields=[
            Field("lat", DOUBLE, repeated=True),
            Field("lng", DOUBLE, repeated=True),
            Field("t", DOUBLE, repeated=True)],
            indexes=("spacetime",),
            index_params={"level": 6, "bucket_s": 900.0, "epoch": 0.0},
            column_set="track"),
        Field("duration_s", DOUBLE, indexes=("range",)),
    ])


def _road_speed(base: float, var: float, hour: int, rng) -> float:
    """True speed model: rush-hour dips + per-road variability noise."""
    rush = 1.0
    if 7 <= hour <= 9:
        rush = 0.55 + 0.1 * np.cos(hour - 8)
    elif 16 <= hour <= 18:
        rush = 0.6
    elif 0 <= hour <= 5:
        rush = 1.15
    return max(3.0, base * rush + rng.normal(0.0, var))


def generate_world(scale: float = 1.0, seed: int = 0):
    """Returns dict of record lists + schemas; sizes scale linearly."""
    rng = np.random.default_rng(seed)
    n_roads = max(20, int(600 * scale))
    n_obs = max(100, int(20_000 * scale))
    n_req = max(20, int(1_500 * scale))

    cities = list(CITIES)
    weights = np.array([4.0, 1.0, 2.0, 1.0, 1.5, 3.0])
    weights = weights / weights.sum()

    roads: List[dict] = []
    for i in range(n_roads):
        city = cities[int(rng.choice(len(cities), p=weights))]
        lat0, lng0, dlat, dlng = CITIES[city]
        lat = lat0 + rng.uniform(0, dlat)
        lng = lng0 + rng.uniform(0, dlng)
        npts = int(rng.integers(2, 6))
        step = rng.uniform(2e-4, 8e-4, size=(npts, 2)) \
            * rng.choice([-1, 1], size=(npts, 2))
        pts = np.cumsum(np.vstack([[0, 0], step[:-1]]), axis=0) \
            + [lat, lng]
        base = float(rng.uniform(20, 100))
        roads.append({
            "id": i, "city": city,
            "loc": {"lat": lat, "lng": lng},
            "polyline": {"lat": pts[:, 0].tolist(),
                         "lng": pts[:, 1].tolist()},
            "speed_limit": float(np.ceil(base / 10) * 10),
            "base_speed": base,
            "variability": float(rng.uniform(0.5, 12.0)),
        })

    obs: List[dict] = []
    for _ in range(n_obs):
        r = roads[int(rng.integers(0, n_roads))]
        hour = int(np.clip(rng.normal(12, 5.5), 0, 23))
        obs.append({
            "road_id": r["id"],
            "loc": {"lat": r["loc"]["lat"] + rng.normal(0, 1e-4),
                    "lng": r["loc"]["lng"] + rng.normal(0, 1e-4)},
            "hour": hour,
            "dow": int(rng.integers(0, 7)),
            "month": int(rng.integers(1, 7)),
            "speed": _road_speed(r["base_speed"], r["variability"], hour,
                                 rng),
            "accuracy_m": float(np.abs(rng.normal(8, 6)) + 3),
        })

    reqs: List[dict] = []
    for i in range(n_req):
        k = int(rng.integers(2, 8))
        seg_ids = rng.integers(0, n_roads, size=k).tolist()
        start = roads[seg_ids[0]]["loc"]
        end = roads[seg_ids[-1]]["loc"]
        hour = int(np.clip(rng.normal(9, 4), 0, 23))
        t = 0.0
        for sid in seg_ids:
            r = roads[sid]
            speed = _road_speed(r["base_speed"], r["variability"], hour,
                                rng)
            t += 120.0 * r["speed_limit"] / max(speed, 1.0)
        reqs.append({
            "id": i, "start_loc": dict(start), "end_loc": dict(end),
            "hour": hour, "route": {"id": [int(s) for s in seg_ids]},
            "time_s": t,
        })

    # -- trips: space-time tracks over the road world (Tesseract workload).
    # Drawn *after* the other datasets so their streams stay byte-identical
    # for a given (scale, seed).  ~1/3 of trips are inter-city (first half
    # of the track in city A, second half in a NEIGHBORS[a] city) so
    # two-constraint region-A-then-region-B queries have real answers;
    # start times follow a commute-shaped (bimodal) distribution over a
    # 7-day week.
    n_trips = max(40, int(1_200 * scale))
    by_city: Dict[str, List[dict]] = {}
    for r in roads:
        by_city.setdefault(r["city"], []).append(r)
    trips: List[dict] = []
    for i in range(n_trips):
        a = cities[int(rng.choice(len(cities), p=weights))]
        b = a
        if rng.random() < 0.35:
            nbrs = NEIGHBORS[a]
            b = nbrs[int(rng.integers(0, len(nbrs)))]
        k = int(rng.integers(3, 9))
        k1 = k if b == a else max(1, k // 2)
        pool_a = by_city.get(a) or roads
        pool_b = by_city.get(b) or roads
        segs = [pool_a[int(rng.integers(0, len(pool_a)))]
                for _ in range(k1)] + \
               [pool_b[int(rng.integers(0, len(pool_b)))]
                for _ in range(k - k1)]
        day = int(rng.integers(0, 7))
        u = rng.random()
        if u < 0.40:
            hour = float(np.clip(rng.normal(8.0, 1.2), 0.0, 23.5))
        elif u < 0.75:
            hour = float(np.clip(rng.normal(17.5, 1.3), 0.0, 23.5))
        else:
            hour = float(rng.uniform(0.0, 23.5))
        t = day * 86400.0 + hour * 3600.0
        lats: List[float] = []
        lngs: List[float] = []
        ts: List[float] = []
        for seg in segs:
            for la, ln in zip(seg["polyline"]["lat"],
                              seg["polyline"]["lng"]):
                lats.append(float(la))
                lngs.append(float(ln))
                ts.append(t)
                t += float(rng.uniform(20.0, 90.0))
        trips.append({
            "id": i,
            "vehicle": int(rng.integers(0, max(16, n_trips // 8))),
            "day": day, "start_hour": int(hour),
            "track": {"lat": lats, "lng": lngs, "t": ts},
            "duration_s": ts[-1] - ts[0],
        })

    return {
        "roads": roads, "observations": obs, "route_requests": reqs,
        "trips": trips,
        "roads_schema": roads_schema(),
        "observations_schema": observations_schema(),
        "route_requests_schema": route_requests_schema(),
        "trips_schema": trips_schema(),
    }
