"""Deterministic, checkpointable LM token pipeline.

The training driver's input side: synthetic token streams generated from a
counter-based PRNG, so the pipeline's *entire* state is (seed, step) —
restartable exactly at any step with no log replay (the data half of the
fault-tolerance story: checkpoint saves (seed, step) alongside params).

Host-side prefetch runs one batch ahead on a thread.  The WFL-fed variant
(:class:`WflBatcher`) draws batches from a WarpFlow query result, which is
how §5 "time-to-trained-model" is served: data selection happens in the
query engine, batching here.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["TokenPipeline", "TrainingDataset", "WflBatcher"]


class TokenPipeline:
    """Synthetic token batches with skip-ahead restore."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int, *,
                 seed: int = 0, start_step: int = 0,
                 prefetch: int = 2, structured: bool = True):
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.step = start_step
        self.structured = structured
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    # -- deterministic batch: a counter-based stream keyed by (seed, step)
    def _make(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 32) ^ step)
        if self.structured:
            # learnable structure: markov-ish repetition so loss can fall
            base = rng.integers(0, self.vocab_size,
                                (self.batch, self.seq_len // 4 + 1))
            tok = np.repeat(base, 4, axis=1)[:, :self.seq_len]
            noise = rng.integers(0, self.vocab_size, tok.shape)
            keep = rng.random(tok.shape) < 0.85
            tok = np.where(keep, tok, noise)
        else:
            tok = rng.integers(0, self.vocab_size,
                               (self.batch, self.seq_len))
        labels = np.roll(tok, -1, axis=1)
        return {"tokens": tok.astype(np.int32),
                "labels": labels.astype(np.int32)}

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def close(self):
        self._stop.set()

    @staticmethod
    def restore(state: dict, vocab_size: int, batch: int, seq_len: int,
                **kw) -> "TokenPipeline":
        return TokenPipeline(vocab_size, batch, seq_len,
                             seed=state["seed"],
                             start_step=state["step"], **kw)


class TrainingDataset:
    """Feature matrix + target vector selected by a WFL query (§5).

    The materialized end of ``Flow.to_dataset(features=..., target=...)``:
    data selection happens in the query engine (indices, refine, fused
    waves), and this object is the hand-off into training — minibatch
    iteration via :meth:`batches`, a train/test :meth:`split`, and
    :meth:`fit`, which closes the paper's time-to-trained-model loop by
    training an :class:`repro.ml.integration.MLPRegressor` on the rows
    the query selected.
    """

    def __init__(self, features: np.ndarray, targets: np.ndarray,
                 feature_names):
        self.features = np.asarray(features, np.float32)
        self.targets = np.asarray(targets, np.float32)
        self.feature_names = list(feature_names)

    @classmethod
    def from_table(cls, table, feature_paths, target_path
                   ) -> "TrainingDataset":
        feats = np.stack([np.asarray(table.batch[p].values, np.float32)
                          for p in feature_paths], axis=-1)
        targets = np.asarray(table.batch[target_path].values, np.float32)
        return cls(feats, targets, feature_paths)

    def __len__(self) -> int:
        return int(self.features.shape[0])

    @property
    def num_features(self) -> int:
        return int(self.features.shape[1])

    def split(self, frac: float = 0.8, seed: int = 0):
        """Shuffled (train, test) split."""
        rng = np.random.default_rng(seed)
        order = rng.permutation(len(self))
        k = int(len(self) * frac)
        return (TrainingDataset(self.features[order[:k]],
                                self.targets[order[:k]],
                                self.feature_names),
                TrainingDataset(self.features[order[k:]],
                                self.targets[order[k:]],
                                self.feature_names))

    def batches(self, batch: int, seed: int = 0):
        """Endless shuffled minibatch stream of (features, targets)."""
        rng = np.random.default_rng(seed)
        while True:
            idx = rng.integers(0, len(self), batch)
            yield self.features[idx], self.targets[idx]

    def fit(self, *, hidden: int = 64, depth: int = 2, seed: int = 0,
            **train_kw):
        """Train an MLP head on this dataset → (model, losses)."""
        from ..ml.integration import MLPRegressor
        model = MLPRegressor(self.num_features, hidden=hidden, depth=depth,
                             seed=seed)
        losses = model.train(self.features, self.targets, **train_kw)
        return model, losses


class WflBatcher:
    """Batches features/targets out of a WarpFlow query result (§5)."""

    def __init__(self, table, feature_paths, target_path, batch: int,
                 seed: int = 0):
        self.features = np.stack(
            [np.asarray(table.batch[p].values, np.float32)
             for p in feature_paths], axis=-1)
        self.targets = np.asarray(table.batch[target_path].values,
                                  np.float32)
        self.batch = batch
        self.rng = np.random.default_rng(seed)

    def __next__(self):
        idx = self.rng.integers(0, self.features.shape[0], self.batch)
        return self.features[idx], self.targets[idx]

    def __iter__(self):
        return self
