"""Data substrate: synthetic world generator + training pipelines."""
from .synthetic import (generate_world, roads_schema, observations_schema,
                        route_requests_schema, CITIES, BAY_AREA)
from .pipeline import TokenPipeline, WflBatcher

__all__ = ["generate_world", "roads_schema", "observations_schema",
           "route_requests_schema", "CITIES", "BAY_AREA",
           "TokenPipeline", "WflBatcher"]
