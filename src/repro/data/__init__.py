"""Data substrate: synthetic world generator + training pipelines."""
from .synthetic import (generate_world, roads_schema, observations_schema,
                        route_requests_schema, trips_schema, city_region,
                        CITIES, BAY_AREA)
from .pipeline import TokenPipeline, WflBatcher

__all__ = ["generate_world", "roads_schema", "observations_schema",
           "route_requests_schema", "trips_schema", "city_region",
           "CITIES", "BAY_AREA", "TokenPipeline", "WflBatcher"]
