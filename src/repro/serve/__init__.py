"""Query-serving subsystem: multi-query coalescing, admission, caching.

``QueryServer`` fronts an :class:`~repro.exec.adhoc.AdHocEngine` with a
bounded admission queue, a coalescing scheduler that batches compatible
concurrent queries into single multi-query wave dispatches
(``ExecBackend.run_wave_fused_multi``), and a TTL result + postings
cache that degrades to recomputation on any fault.
"""
from .result_cache import ResultCache
from .server import QueryServer, ServerBusy

__all__ = ["QueryServer", "ServerBusy", "ResultCache"]
