"""Concurrent query serving: admission, coalescing, result caching.

WarpFlow's serving tier (paper §4.3) keeps an always-on micro-cluster
answering many clients against the same resident FDbs.  This module adds
the session-server shape on top of :class:`~repro.exec.adhoc.AdHocEngine`:

  * **Admission** — a bounded pending queue.  ``submit`` returns a
    future; when the queue is full it raises :class:`ServerBusy`
    immediately (back-pressure, never unbounded buffering).
  * **Coalescing** — a scheduler thread drains the pending queue each
    tick and groups *compatible* queries (same FDb, same shard set, no
    residual filter, no joins, at most one track refine on one path with
    ≤ 30 packed constraints) into one **multi-query wave batch**: Q queries ride a single ``run_wave_fused_multi`` dispatch
    per wave, so the whole group costs ⌈shards/wave⌉ device dispatches
    *total* instead of Q×⌈shards/wave⌉.  Queries that do not fit the
    coalesced shape — residual filters, joins, multi-refine plans —
    simply fall through to the engine's single-query path; incompatible
    never means error.
  * **Caching** — a keyed TTL result + postings cache
    (:class:`~repro.serve.result_cache.ResultCache`).  Every cache call
    is wrapped: a broken or fault-injected cache degrades the server to
    recomputation, it never fails a query.

**Concurrency model.**  One condition variable guards the pending deque,
the closed flag, and the stat counters.  ``submit`` (any client thread)
appends under it and raises :class:`ServerBusy` at ``max_pending``; the
daemon scheduler thread drains it each tick (a short
``tick_s`` sleep lets near-simultaneous submits join one batch), or
``run_pending()`` drains synchronously on the caller for deterministic
coalescing.  Execution never holds the lock: each batch plans its
queries, then runs groups through the engine's worker pool.

**Live sources.**  Every query plans against the source's current
snapshot and executes against that pin (``Plan.db``) — appends landing
between coalesced waves can never tear a result across generations; a
query sees either the pre-append or the post-append view, whole.  The
first time a batch touches a source registered live in the catalog
(a :class:`~repro.fdb.streaming.StreamingFDb`), the server wires the
streaming mutation hook into its cache
(:meth:`~repro.fdb.streaming.StreamingFDb.bind_cache`): an append both
bumps the cache's generation token and sweeps the stale snapshot's
entries, so a pre-append cached result is never served after the hook
fires — even within the old entry's TTL.

Each coalesced query's rows are byte-identical to what the single-query
path produces — the multi-query ops sit behind the same
:class:`~repro.exec.backend.ExecBackend` parity seam, with the numpy
base class as the loop-over-queries oracle.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from types import SimpleNamespace
from typing import Dict, List, Optional

from ..core.flow import AggregateOp, Flow, JoinOp
from ..core.planner import Plan, plan_flow
from ..exec.adhoc import AdHocEngine, QueryProfile, QueryResult
from ..exec.backend import ExecBackend
from ..exec.batched import (fused_enabled, partition_waves,
                            resolve_partition_plan)
from ..exec.processors import aggregate_produce_batched, run_record_ops
from ..exec.task import ShardPartial
from ..fdb.index import mask_from_bitmap
from .result_cache import ResultCache

__all__ = ["QueryServer", "ServerBusy"]


class ServerBusy(RuntimeError):
    """Admission queue full — the client should back off and retry."""


class _Pending:
    __slots__ = ("flow", "future", "plan", "key", "cache_key")

    def __init__(self, flow: Flow, future: Future):
        self.flow = flow
        self.future = future
        self.plan: Optional[Plan] = None
        self.key = None                    # coalescing compatibility key
        self.cache_key = None


class QueryServer:
    """Session server: bounded admission + coalescing scheduler + cache.

    ``cache`` is a :class:`ResultCache`, ``None`` for the default one, or
    ``False`` to serve uncached.  ``max_coalesce`` bounds the query axis
    of one multi-query dispatch; ``max_pending`` bounds admission.
    """

    def __init__(self, engine: Optional[AdHocEngine] = None,
                 catalog=None, backend=None, *,
                 config=None,
                 max_pending: int = 64, max_coalesce: int = 16,
                 cache=None, tick_s: float = 0.001, start: bool = True):
        if engine is None:
            engine = AdHocEngine(catalog=catalog, backend=backend,
                                 config=config)
        self.engine = engine
        self.max_pending = int(max_pending)
        self.max_coalesce = max(1, int(max_coalesce))
        self.tick_s = float(tick_s)
        self.cache = (ResultCache() if cache is None
                      else (cache or None))
        self._cv = threading.Condition()
        self._pending: "deque[_Pending]" = deque()
        self._closed = False
        self._watched: set = set()      # live sources wired into the cache
        self._stats = {"admitted": 0, "rejected": 0, "served": 0,
                       "coalesced_queries": 0, "coalesced_batches": 0,
                       "fallback_queries": 0, "cache_hits": 0,
                       "cache_errors": 0}
        self._thread = threading.Thread(target=self._loop,
                                        name="repro-serve-scheduler",
                                        daemon=True)
        if start:
            self._thread.start()

    # ------------------------------------------------------------- public
    def submit(self, flow: Flow) -> Future:
        """Admit ``flow``; returns a future resolving to its
        :class:`QueryResult`.  Raises :class:`ServerBusy` when the
        pending queue is at capacity."""
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("QueryServer is closed")
            if len(self._pending) >= self.max_pending:
                self._stats["rejected"] += 1
                raise ServerBusy(
                    f"admission queue full ({self.max_pending} pending)")
            self._pending.append(_Pending(flow, fut))
            self._stats["admitted"] += 1
            self._cv.notify()
        return fut

    def collect(self, flow: Flow, timeout: Optional[float] = None
                ) -> QueryResult:
        """Blocking convenience: ``submit(flow).result(timeout)``."""
        return self.submit(flow).result(timeout)

    def run_pending(self) -> int:
        """Drain and serve everything pending, synchronously, on the
        calling thread.  With ``start=False`` this makes coalescing
        deterministic — submit Q queries, then serve them as one batch —
        which is what the launch-contract tests and the serve benchmark
        rely on."""
        with self._cv:
            batch = list(self._pending)
            self._pending.clear()
        if batch:
            self._serve_batch(batch)
        return len(batch)

    def stats(self) -> Dict[str, int]:
        with self._cv:
            out = dict(self._stats)
            out["pending"] = len(self._pending)
        if self.cache is not None:
            try:
                out["cache"] = self.cache.stats()
            except Exception:
                pass
        return out

    def close(self, timeout: float = 10.0) -> None:
        """Stop admitting, drain in-flight work, join the scheduler."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
        if self._thread.is_alive():
            self._thread.join(timeout)

    def __enter__(self) -> "QueryServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---------------------------------------------------------- scheduler
    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait(timeout=0.25)
                if not self._pending:
                    if self._closed:
                        return
                    continue
                batch = list(self._pending)
                self._pending.clear()
            # a short tick lets near-simultaneous submits join this batch
            if self.tick_s > 0 and len(batch) < self.max_coalesce:
                time.sleep(self.tick_s)
                with self._cv:
                    while self._pending and len(batch) < 4 * self.max_coalesce:
                        batch.append(self._pending.popleft())
            try:
                self._serve_batch(batch)
            except Exception as e:                 # defensive: never die
                for p in batch:
                    if not p.future.done():
                        p.future.set_exception(e)

    def _serve_batch(self, batch: List[_Pending]) -> None:
        groups: Dict[tuple, List[_Pending]] = {}
        singles: List[_Pending] = []
        for p in batch:
            try:
                p.plan = plan_flow(p.flow, self.engine.catalog)
            except Exception as e:
                p.future.set_exception(e)
                continue
            self._watch_live(p.plan.source)
            if self._cache_get(p):
                continue
            p.key = self._compat_key(p.plan)
            if p.key is None:
                singles.append(p)
            else:
                groups.setdefault(p.key, []).append(p)
        for key, grp in groups.items():
            for i in range(0, len(grp), self.max_coalesce):
                chunk = grp[i:i + self.max_coalesce]
                if len(chunk) == 1:
                    singles.extend(chunk)
                    continue
                try:
                    self._run_group(chunk)
                except Exception:
                    # coalesced execution is an optimization, never a
                    # correctness risk: re-run each query solo
                    singles.extend(c for c in chunk if not c.future.done())
        for p in singles:
            self._run_single(p)

    def _run_single(self, p: _Pending) -> None:
        try:
            res = self.engine.collect(p.flow)
            self._cache_put(p, res)
            # stats land before the future resolves, so a client that has
            # its result also sees it counted
            with self._cv:
                self._stats["fallback_queries"] += 1
                self._stats["served"] += 1
            p.future.set_result(res)
        except Exception as e:
            p.future.set_exception(e)

    def _watch_live(self, source: str) -> None:
        """First touch of a live (streaming) source: wire its mutation
        hook into this server's cache, so appends invalidate eagerly."""
        if self.cache is None or source in self._watched:
            return
        self._watched.add(source)
        try:
            live = getattr(self.engine.catalog, "live", None)
            sdb = live(source) if live is not None else None
            if sdb is not None:
                sdb.bind_cache(self.cache)
        except Exception:
            with self._cv:
                self._stats["cache_errors"] += 1

    # -------------------------------------------------------- coalescing
    @staticmethod
    def _compat_key(plan: Plan):
        """Grouping key for plans one multi-query dispatch can carry, or
        ``None`` (single-query path).  Residual filters need host work
        before selection completes, joins need a recursive broadcast
        collect; multi-refine and over-budget constraint sets exceed the
        kernel's packed table.  The pinned snapshot's identity is part of
        the key: two queries planned astride a streaming append must not
        share one dispatch over mixed generations."""
        if plan.residual is not None or \
                any(isinstance(op, JoinOp) for op in plan.server_ops):
            return None
        if len(plan.refines) > 1:
            return None
        refine_path = None
        if plan.refines:
            rf = plan.refines[0]
            if not (1 <= len(rf.constraints) <= 30):
                return None
            refine_path = rf.path
        return (plan.source, id(plan.db), tuple(plan.shard_ids),
                refine_path)

    def _probe_bitmaps(self, db, plan: Plan, sid: int, shard):
        """Host probe bitmaps for one (plan, shard) — served from the
        postings cache when possible."""
        key = None
        if self.cache is not None:
            try:
                key = self.cache.key_for(
                    db, SimpleNamespace(source=plan.source,
                                        probes=plan.probes),
                    kind="postings", extra=(sid,))
                hit = self.cache.get("postings", key)
                if hit is not None:
                    return list(hit)
            except Exception:
                with self._cv:
                    self._stats["cache_errors"] += 1
                key = None
        bms = [p.run(shard) for p in plan.probes]
        if key is not None:
            try:
                self.cache.put("postings", key, list(bms))
            except Exception:
                with self._cv:
                    self._stats["cache_errors"] += 1
        return bms

    @staticmethod
    def _select_wave(backend, shards, probes, refine):
        """Per-primitive selection for one query over one wave — the
        never-declining fallback when the multi dispatch declines."""
        bms = backend.probe_shards([sh.all_bitmap() for sh in shards],
                                   probes)
        masks = [mask_from_bitmap(bm, sh.n) for bm, sh in zip(bms, shards)]
        n_cands = [int(m.sum()) for m in masks]
        if refine is not None:
            masks = backend.refine_tracks_batched(
                [sh.batch for sh in shards], refine.path,
                refine.constraints, masks, edges=refine.edges,
                min_counts=getattr(refine, "min_counts", None),
                dwells=getattr(refine, "dwells", None))
        return n_cands, backend.compact_masks(masks)

    def _run_group(self, chunk: List[_Pending]) -> None:
        """Q compatible queries through shared waves: one multi-query
        fused dispatch per wave, then per-query gather + mixer tails.
        The selection dispatch stays one launch per wave; the per-(query,
        shard) gather tails fan out over the engine's server slots (they
        dominate wall time otherwise — the single-query path gets the
        same parallelism from its per-wave worker threads)."""
        engine = self.engine
        backend = engine.backend
        plans = [p.plan for p in chunk]
        # execute against the snapshot pinned at plan time — a streaming
        # append between planning and this wave must not swap the data
        db = plans[0].db if plans[0].db is not None \
            else engine.catalog.get(plans[0].source)
        backend.prime_fdb(db)
        shard_ids = list(plans[0].shard_ids)
        # the coalesced dispatch rides the same partition layer as the
        # single-query engines: waves form *within* each partition and
        # dispatch under its partition context, so Q coalesced queries
        # cost sum over partitions of ceil(shards_p/wave) multi
        # dispatches.  The per-query tails below gather host-side, so
        # this path keeps the host AggPartial merge (partition-invariant
        # — partials are assembled in shard-id order per query).
        pplan = resolve_partition_plan(getattr(engine, "partitions", None),
                                       backend, plans[0])
        subs = []
        for pi, part in enumerate(pplan.parts):
            pw = partition_waves(part, engine.wave)
            for j, w in enumerate(pw):
                subs.append((pi, w, pw[j + 1] if j + 1 < len(pw)
                             else None))
        refines = [pl.refines[0] if pl.refines else None for pl in plans]
        grant = engine.catalog.resources.acquire(
            min(max(len(shard_ids), 1), engine.num_servers))
        t0 = time.perf_counter()

        def gather_tail(pl, qi, sid, sh, ids, n_cand):
            paths = [c for c in pl.source_paths
                     if c in sh.batch.columns] or sh.batch.paths()
            # the coalesced tail issues Q×S *small* gathers; the host
            # gather is byte-identical by the seam contract (selection by
            # row index) and its cost is linear in gathered bytes, not in
            # per-call device-dispatch overhead
            gb = ExecBackend.gather_columns(backend, sh.batch, paths, ids)
            part = ShardPartial(shard_id=sid, rows_scanned=sh.n,
                                rows_selected=n_cand,
                                bytes_read=gb.nbytes())
            return (qi, sid), (part, gb)

        tail_futs = []
        try:
            with ThreadPoolExecutor(max_workers=grant) as pool:
                for pi, wave_sids, nxt in subs:
                    shards = [db.shards[s] for s in wave_sids]
                    probes_multi = [
                        [self._probe_bitmaps(db, pl, sid, sh)
                         for sid, sh in zip(wave_sids, shards)]
                        for pl in plans]
                    pre = [db.shards[s] for s in nxt] if nxt else None
                    out = None
                    cfg = getattr(self.engine, "config", None)
                    if fused_enabled(cfg.fused if cfg is not None
                                     else None) \
                            and getattr(backend, "batched_dispatch",
                                        False):
                        with backend.partition_context(
                                pi, pplan.num_partitions):
                            out = backend.run_wave_fused_multi(
                                shards, probes_multi, refines,
                                prefetch_shards=pre)
                    if out is None:
                        out = [self._select_wave(backend, shards, probes,
                                                 rf)
                               for probes, rf in zip(probes_multi,
                                                     refines)]
                    # wave k's gathers overlap wave k+1's dispatch
                    for qi, (pl, (n_cands, ids_list)) in enumerate(
                            zip(plans, out)):
                        for sid, sh, ids, n_cand in zip(wave_sids, shards,
                                                        ids_list, n_cands):
                            tail_futs.append(pool.submit(
                                gather_tail, pl, qi, sid, sh, ids, n_cand))
                by_key = dict(f.result() for f in tail_futs)
        finally:
            engine.catalog.resources.release(grant)
        per_query = [[by_key[(qi, sid)] for sid in shard_ids]
                     for qi in range(len(plans))]

        results = []
        for p, pl, pairs in zip(chunk, plans, per_query):
            parts = [part for part, _ in pairs]
            batches = [run_record_ops(gb, pl.server_ops, engine.catalog,
                                      None, backend=backend)
                       for _, gb in pairs]
            if pl.mixer_ops and isinstance(pl.mixer_ops[0], AggregateOp):
                aggs = aggregate_produce_batched(
                    batches, pl.mixer_ops[0].spec, backend)
                for part, agg in zip(parts, aggs):
                    part.agg = agg
            else:
                for part, gb in zip(parts, batches):
                    part.batch = gb
            profile = QueryProfile(source=pl.source,
                                   shards_total=len(shard_ids),
                                   shards_done=len(parts))
            for part in parts:
                profile.rows_scanned += part.rows_scanned
                profile.rows_selected += part.rows_selected
                profile.bytes_read += part.bytes_read
            batch = engine._mixer(pl, parts, profile)
            profile.exec_ms = (time.perf_counter() - t0) * 1e3
            engine.profile_log.append(profile.record())
            results.append((p, QueryResult(batch, profile, pl)))
        # every query finalized — count the batch, then resolve futures,
        # so a client that has its result also sees it counted
        with self._cv:
            self._stats["coalesced_batches"] += 1
            self._stats["coalesced_queries"] += len(results)
            self._stats["served"] += len(results)
        for p, res in results:
            self._cache_put(p, res)
            p.future.set_result(res)

    # ------------------------------------------------------------- cache
    def _cache_get(self, p: _Pending) -> bool:
        if self.cache is None:
            return False
        try:
            db = p.plan.db if p.plan.db is not None \
                else self.engine.catalog.get(p.plan.source)
            p.cache_key = self.cache.key_for(db, p.plan, kind="result")
            hit = self.cache.get("result", p.cache_key)
        except Exception:
            with self._cv:
                self._stats["cache_errors"] += 1
            p.cache_key = None
            return False
        if hit is None:
            return False
        with self._cv:
            self._stats["cache_hits"] += 1
            self._stats["served"] += 1
        p.future.set_result(hit)
        return True

    def _cache_put(self, p: _Pending, res: QueryResult) -> None:
        if self.cache is None:
            return
        try:
            if p.cache_key is None:
                db = p.plan.db if p.plan.db is not None \
                    else self.engine.catalog.get(p.plan.source)
                p.cache_key = self.cache.key_for(db, p.plan, kind="result")
            self.cache.put("result", p.cache_key, res)
        except Exception:
            with self._cv:
                self._stats["cache_errors"] += 1
