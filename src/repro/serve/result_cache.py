"""Keyed result + postings cache for the query-serving layer.

Serving traffic repeats itself — dashboards refresh the same Tesseract,
sessions re-run a refined flow against the same resident FDb — so the
server memoizes two kinds of derived values:

  * ``"result"``  — a finished :class:`~repro.exec.adhoc.QueryResult`
    for one (FDb, plan) pair,
  * ``"postings"`` — the host-built probe bitmaps for one
    (FDb, plan, shard) triple (the index lookups the coalescer runs
    before every wave dispatch).

Keys are SHA-256 digests over a **canonical byte encoding** of the plan
(regions by their cover-range words, windows and paths by value — never
object identity), prefixed with a per-FDb **generation token** drawn
from a ``WeakKeyDictionary``: a rebuilt FDb under the same name gets a
fresh token, so stale entries can never alias a new dataset.  The token
rides *outside* the digest (``b"<token>|<sha256>"``), which is what
makes :meth:`ResultCache.invalidate` possible: when a live
``StreamingFDb`` appends, its mutation hook
(:meth:`repro.fdb.streaming.StreamingFDb.bind_cache`) calls
``invalidate(stale_snapshot)`` — the token is bumped (future lookups
can never match) and every entry carrying the old token prefix is
swept eagerly.  A plan containing something the canonicalizer does not
understand simply is not cacheable (``key_for`` returns ``None``) —
unknown ≠ equal is the safe direction.

Entries carry a per-kind TTL against an **injectable clock** (tests pin
time), and the cache holds an LRU byte budget over the values' reported
sizes.

**Concurrency model.**  One re-entrant lock guards the entry map, byte
accounting, the token table, and the stat counters; every public method
takes it, so the scheduler thread, worker-pool gather tails, and
streaming mutation listeners can call in concurrently.  Every public
entry point also swallows its own errors: a broken cache degrades the
server to recomputation, it never fails a query — the server
additionally wraps its calls, so even a cache object whose methods
raise (fault-injection tests do exactly that) cannot surface.
"""
from __future__ import annotations

import hashlib
import itertools
import threading
import time
import weakref
from collections import OrderedDict
from typing import Any, Dict, Optional

import numpy as np

__all__ = ["ResultCache", "DEFAULT_TTL_S", "DEFAULT_MAX_BYTES"]

DEFAULT_TTL_S = {"result": 30.0, "postings": 300.0}
DEFAULT_MAX_BYTES = 64 << 20


def _canon(obj, out) -> None:
    """Append a canonical byte encoding of ``obj`` to ``out``.

    Raises ``TypeError`` on anything it cannot canonicalize — the caller
    treats that plan as uncacheable rather than guessing at equality.
    """
    if obj is None:
        out.append(b"N")
    elif isinstance(obj, bool):
        out.append(b"b1" if obj else b"b0")
    elif isinstance(obj, (int, np.integer)):
        out.append(b"i" + str(int(obj)).encode())
    elif isinstance(obj, (float, np.floating)):
        out.append(b"f" + np.float64(obj).tobytes())
    elif isinstance(obj, str):
        out.append(b"s" + obj.encode("utf-8") + b"\x00")
    elif isinstance(obj, bytes):
        out.append(b"y" + obj + b"\x00")
    elif isinstance(obj, np.ndarray):
        out.append(b"a" + obj.dtype.str.encode()
                   + str(obj.shape).encode() + np.ascontiguousarray(obj)
                   .tobytes())
    elif isinstance(obj, (list, tuple)):
        out.append(b"[")
        for e in obj:
            _canon(e, out)
        out.append(b"]")
    elif isinstance(obj, dict):
        out.append(b"{")
        for k in sorted(obj, key=str):
            _canon(str(k), out)
            _canon(obj[k], out)
        out.append(b"}")
    elif hasattr(obj, "lo") and hasattr(obj, "hi") \
            and isinstance(getattr(obj, "lo"), np.ndarray):
        # AreaTree-shaped region: its cover ranges ARE its query meaning
        out.append(b"R")
        _canon(obj.lo, out)
        _canon(obj.hi, out)
    elif hasattr(obj, "__dict__") and type(obj).__module__.startswith(
            "repro."):
        # plan nodes (IndexProbe, RefineSpec, ops, exprs): canonicalize by
        # type name + instance fields; anything exotic inside raises
        out.append(b"O" + type(obj).__qualname__.encode() + b"\x00")
        _canon(vars(obj), out)
    else:
        raise TypeError(f"uncacheable plan element: {type(obj)!r}")


class ResultCache:
    """Hash-keyed TTL + LRU-byte-budget cache (see module docstring)."""

    def __init__(self, ttl_s: Optional[Dict[str, float]] = None,
                 max_bytes: int = DEFAULT_MAX_BYTES,
                 clock=time.monotonic):
        self.ttl_s = dict(DEFAULT_TTL_S)
        if ttl_s:
            self.ttl_s.update(ttl_s)
        self.max_bytes = int(max_bytes)
        self.clock = clock
        self._lock = threading.RLock()
        # key → (value, expires_at, nbytes); move-to-end on hit (LRU)
        self._entries: "OrderedDict[bytes, tuple]" = OrderedDict()
        self._nbytes = 0
        self._tokens: "weakref.WeakKeyDictionary" = \
            weakref.WeakKeyDictionary()
        self._next_token = itertools.count(1)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.errors = 0
        self.invalidations = 0

    # ------------------------------------------------------------- keying
    def key_for(self, db, plan, kind: str = "result",
                extra=()) -> Optional[bytes]:
        """Cache key for ``plan`` against ``db``, or ``None`` when the
        plan cannot be canonicalized (→ not cacheable, never wrong)."""
        try:
            with self._lock:
                token = self._tokens.get(db)
                if token is None:
                    token = next(self._next_token)
                    self._tokens[db] = token
            out = [kind.encode(), b"\x00"]
            _canon([getattr(plan, "source", None),
                    list(getattr(plan, "shard_ids", ())),
                    getattr(plan, "probes", ()),
                    getattr(plan, "refines", ()),
                    getattr(plan, "residual", None),
                    getattr(plan, "server_ops", ()),
                    getattr(plan, "mixer_ops", ()),
                    list(extra)], out)
            # token outside the digest → invalidate() can sweep by prefix
            return (str(token).encode() + b"|"
                    + hashlib.sha256(b"".join(out)).digest())
        except Exception:
            with self._lock:
                self.errors += 1
            return None

    def invalidate(self, db) -> int:
        """Expire every entry keyed against ``db``'s current generation
        token and issue a fresh token, so no future ``key_for(db, …)``
        can match a pre-invalidation entry.  This is the streaming-append
        hook (:meth:`repro.fdb.streaming.StreamingFDb.bind_cache`).
        Returns the number of entries swept (0 when ``db`` was never
        keyed)."""
        try:
            with self._lock:
                self.invalidations += 1
                old = self._tokens.get(db)
                self._tokens[db] = next(self._next_token)
                if old is None:
                    return 0
                prefix = str(old).encode() + b"|"
                dead = [k for k in self._entries if k.startswith(prefix)]
                for k in dead:
                    _, _, nbytes = self._entries.pop(k)
                    self._nbytes -= nbytes
                return len(dead)
        except Exception:
            with self._lock:
                self.errors += 1
            return 0

    # ------------------------------------------------------------ get/put
    def get(self, kind: str, key: Optional[bytes]):
        """Live value for ``key`` or ``None`` (expired entries evict)."""
        if key is None:
            return None
        try:
            with self._lock:
                ent = self._entries.get(key)
                if ent is None:
                    self.misses += 1
                    return None
                value, expires_at, nbytes = ent
                if self.clock() >= expires_at:
                    del self._entries[key]
                    self._nbytes -= nbytes
                    self.misses += 1
                    return None
                self._entries.move_to_end(key)
                self.hits += 1
                return value
        except Exception:
            with self._lock:
                self.errors += 1
            return None

    def put(self, kind: str, key: Optional[bytes], value,
            nbytes: Optional[int] = None) -> None:
        if key is None:
            return
        try:
            if nbytes is None:
                nbytes = self._sizeof(value)
            ttl = float(self.ttl_s.get(kind, self.ttl_s.get("result", 30.0)))
            expires_at = self.clock() + ttl
            with self._lock:
                old = self._entries.pop(key, None)
                if old is not None:
                    self._nbytes -= old[2]
                self._entries[key] = (value, expires_at, int(nbytes))
                self._nbytes += int(nbytes)
                while self._nbytes > self.max_bytes and len(self._entries) > 1:
                    _, (_, _, nb) = self._entries.popitem(last=False)
                    self._nbytes -= nb
                    self.evictions += 1
                if self._nbytes > self.max_bytes:      # lone oversize entry
                    self._entries.popitem(last=False)
                    self._nbytes = 0
                    self.evictions += 1
        except Exception:
            with self._lock:
                self.errors += 1

    @staticmethod
    def _sizeof(value) -> int:
        batch = getattr(value, "batch", None)
        if batch is not None and hasattr(batch, "nbytes"):
            return int(batch.nbytes())
        if isinstance(value, np.ndarray):
            return int(value.nbytes)
        if isinstance(value, (list, tuple)):
            return sum(int(a.nbytes) for sub in value
                       for a in (sub if isinstance(sub, (list, tuple))
                                 else [sub])
                       if isinstance(a, np.ndarray)) or 64
        return 64

    # -------------------------------------------------------------- admin
    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._nbytes = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"entries": len(self._entries), "nbytes": self._nbytes,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions, "errors": self.errors,
                    "invalidations": self.invalidations}
