"""Tesseract: space-time trip indexing and multi-constraint queries.

The subsystem behind the paper's headline workload — "all trips passing
through region A during time window T1 and region B during T2" (§2, §6):

  * :class:`SpaceTimeIndex` — per-shard (area-tree cell × time bucket)
    postings bitmaps over repeated track fields, built at ``build_fdb``
    time next to ``TagIndex``/``RangeIndex`` (declare
    ``indexes=("spacetime",)`` on the track message field),
  * :class:`Tesseract` — the constraint builder whose predicate compiles
    to stacked bitmap AND work on the ``ExecBackend`` seam plus the exact
    refine pass, itself a fused device op (``refine_tracks_batched`` →
    the Pallas ``refine`` kernel over the shard's resident CSR track
    buffers; see ``Flow.tesseract``, ``repro.core.planner`` and
    ``repro.exec.refine``).  ``then()`` / ``before()`` add *ordering*
    edges (A **then** B), resolved in the same fused pass via
    per-constraint first-hit timestamps,
  * :func:`tesseract_stats` — index-probe candidates vs. exact survivors,
    the pruning-ratio report the benchmarks track.
"""
from .index import SpaceTimeIndex
from .query import Tesseract, tesseract_stats

__all__ = ["SpaceTimeIndex", "Tesseract", "tesseract_stats"]
