"""Space-time postings index for trip tracks (paper §2, §6: Tesseract).

The paper's headline workload asks for "all trips passing through region A
during time window T1 *and* region B during T2" over petabyte-scale track
data.  Per shard we build one :class:`SpaceTimeIndex` per indexed track
field: every track point posts into a **(area-tree cell × time bucket)**
key, and postings are surfaced as the same uint32-word bitmaps the rest of
the query hot loop uses — so a Tesseract constraint probe is a bitmap OR
over matching keys, and a multi-constraint query is a stacked bitmap AND
handled by the ``bitset`` kernel through the ``ExecBackend`` seam.

Key layout: ``(cell_index << TIME_BITS) | bucket`` with the cell index the
6·level-bit Morton prefix of the point (the same level-``level`` cells the
``area`` index and :func:`repro.geo.areatree.cover` produce) and the bucket
``floor((t - epoch) / bucket_s)``.  Keys of one cell are contiguous, so a
region cover (disjoint Morton ranges) translates into a few ``searchsorted``
spans with a post-filter on the bucket field — no per-cell probing.

The index also keeps each doc's ``[t_min, t_max]`` track span and prunes
docs whose span misses the query window with the same offset-overlap test
:class:`repro.core.sketches.IntervalSet` uses (overlap ⇔ ``t_min ≤ q_hi``
and ``t_max ≥ q_lo``) — cheap, and it removes the cell-granularity false
positives of trips that pass the region at a different time of day.

Probes are **conservative**: a returned doc's track touches a covered cell
during an overlapping bucket, which is a superset of exactly passing through
the region during the window.  The planner therefore keeps the constraint in
the residual filter; the exact point-in-cover × time-window pass runs behind
the backend's ``compact_mask`` (see ``repro.core.planner``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from ..fdb.index import bitmap_from_ids, bitmap_zeros
from ..geo import mercator as M
from ..geo.areatree import AreaTree

__all__ = ["SpaceTimeIndex", "TIME_BITS", "MAX_BUCKET"]

TIME_BITS = 20                        # buckets per key: 2^20 ≈ 18 years @ 15 min
MAX_BUCKET = (1 << TIME_BITS) - 1
_TB = np.uint64(TIME_BITS)
_BMASK = np.uint64(MAX_BUCKET)
_ONE = np.uint64(1)


@dataclass
class SpaceTimeIndex:
    """(cell × time-bucket) → docs postings over one repeated track field."""

    level: int                 # area-tree cell level of the spatial key part
    bucket_s: float            # time bucket width, seconds
    epoch: float               # t of bucket 0
    keys: np.ndarray           # sorted unique uint64 (cell << TIME_BITS) | bucket
    splits: np.ndarray         # int64 [K+1] CSR into doc_ids
    doc_ids: np.ndarray        # int64 [total]
    t_min: np.ndarray          # float64 [n_docs]; +inf for empty tracks
    t_max: np.ndarray          # float64 [n_docs]; -inf for empty tracks
    n_docs: int

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(lat: np.ndarray, lng: np.ndarray, t: np.ndarray, n_docs: int,
              row_splits: Optional[np.ndarray] = None, *,
              level: int = 6, bucket_s: float = 900.0,
              epoch: float = 0.0) -> "SpaceTimeIndex":
        if not 0 < level <= (64 - TIME_BITS) // 6:
            # the packed key is (6·level cell bits) << TIME_BITS | bucket;
            # beyond level 7 it would overflow uint64 and silently corrupt
            # lookups, so reject at build time
            raise ValueError(f"spacetime index level must be in "
                             f"[1, {(64 - TIME_BITS) // 6}], got {level}")
        if bucket_s <= 0:
            raise ValueError("bucket_s must be positive")
        lat = np.asarray(lat, dtype=np.float64)
        lng = np.asarray(lng, dtype=np.float64)
        t = np.asarray(t, dtype=np.float64)
        if row_splits is not None:
            docs = np.repeat(np.arange(n_docs, dtype=np.int64),
                             np.diff(row_splits))
        else:
            docs = np.arange(n_docs, dtype=np.int64)[: lat.size]
        t_min = np.full(n_docs, np.inf)
        t_max = np.full(n_docs, -np.inf)
        if t.size:
            np.minimum.at(t_min, docs, t)
            np.maximum.at(t_max, docs, t)
        if lat.size == 0:
            return SpaceTimeIndex(level, bucket_s, epoch,
                                  np.zeros(0, dtype=np.uint64),
                                  np.zeros(1, dtype=np.int64),
                                  np.zeros(0, dtype=np.int64),
                                  t_min, t_max, n_docs)
        shift = np.uint64(6 * (M.MAX_LEVEL - level))
        cell = M.latlng_to_morton(lat, lng) >> shift
        bucket = np.clip(np.floor((t - epoch) / bucket_s),
                         0, MAX_BUCKET).astype(np.uint64)
        ck = (cell << _TB) | bucket
        order = np.lexsort((docs, ck))
        ck_s, docs_s = ck[order], docs[order]
        # dedupe (key, doc) pairs — a track may linger in one cell+bucket
        keep = np.ones(ck_s.size, dtype=bool)
        keep[1:] = (ck_s[1:] != ck_s[:-1]) | (docs_s[1:] != docs_s[:-1])
        ck_s, docs_s = ck_s[keep], docs_s[keep]
        uniq, starts = np.unique(ck_s, return_index=True)
        splits = np.concatenate([starts, [ck_s.size]]).astype(np.int64)
        return SpaceTimeIndex(level, bucket_s, epoch, uniq, splits, docs_s,
                              t_min, t_max, n_docs)

    # ----------------------------------------------------------------- lookup
    def _bucket_range(self, t0: float, t1: float) -> Tuple[int, int]:
        b0 = int(np.clip(np.floor((t0 - self.epoch) / self.bucket_s),
                         0, MAX_BUCKET))
        b1 = int(np.clip(np.floor((t1 - self.epoch) / self.bucket_s),
                         0, MAX_BUCKET))
        return b0, b1

    def lookup(self, region: AreaTree, t0: float, t1: float) -> np.ndarray:
        """Candidate docs with a track point in a cell covering ``region``
        during a bucket overlapping ``[t0, t1]`` (superset of exact)."""
        if region.is_empty or t1 < t0 or self.keys.size == 0:
            return bitmap_zeros(self.n_docs)
        shift = np.uint64(6 * (M.MAX_LEVEL - self.level))
        c0 = region.lo >> shift
        c1 = (region.hi - _ONE) >> shift          # inclusive cell ranges
        b0, b1 = self._bucket_range(t0, t1)
        parts = []
        for lo, hi in zip(c0, c1):
            a = int(np.searchsorted(self.keys, (lo << _TB) | np.uint64(b0),
                                    side="left"))
            b = int(np.searchsorted(self.keys, (hi << _TB) | np.uint64(b1),
                                    side="right"))
            if b <= a:
                continue
            span = self.keys[a:b]
            bk = span & _BMASK
            for i in np.nonzero((bk >= b0) & (bk <= b1))[0] + a:
                parts.append(self.doc_ids[self.splits[i]:self.splits[i + 1]])
        if not parts:
            return bitmap_zeros(self.n_docs)
        bm = bitmap_from_ids(np.concatenate(parts), self.n_docs)
        # IntervalSet-style span prune: drop docs whose whole track misses
        # the window (kills same-place-different-time false positives).
        overlap = (self.t_min <= t1) & (self.t_max >= t0)
        return bm & bitmap_from_ids(
            np.nonzero(overlap)[0].astype(np.int64), self.n_docs)

    def num_keys(self) -> int:
        return int(self.keys.size)

    def __repr__(self):
        return (f"SpaceTimeIndex(level={self.level}, "
                f"bucket_s={self.bucket_s}, keys={self.keys.size}, "
                f"docs={self.n_docs})")
