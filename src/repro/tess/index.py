"""Space-time postings index for trip tracks (paper §2, §6: Tesseract).

The paper's headline workload asks for "all trips passing through region A
during time window T1 *and* region B during T2" over petabyte-scale track
data.  Per shard we build one :class:`SpaceTimeIndex` per indexed track
field: every track point posts into a **(area-tree cell × time bucket)**
key, and postings are surfaced as the same uint32-word bitmaps the rest of
the query hot loop uses — so a Tesseract constraint probe is a bitmap OR
over matching keys, and a multi-constraint query is a stacked bitmap AND
handled by the ``bitset`` kernel through the ``ExecBackend`` seam.

Key layout: ``(cell_index << TIME_BITS) | bucket`` with the cell index the
6·level-bit Morton prefix of the point (the same level-``level`` cells the
``area`` index and :func:`repro.geo.areatree.cover` produce) and the bucket
``floor((t - epoch) / bucket_s)``.  Keys of one cell are contiguous, so a
region cover (disjoint Morton ranges) translates into a few ``searchsorted``
spans with a post-filter on the bucket field — no per-cell probing.

The index also keeps each doc's ``[t_min, t_max]`` track span and prunes
docs whose span misses the query window with the same offset-overlap test
:class:`repro.core.sketches.IntervalSet` uses (overlap ⇔ ``t_min ≤ q_hi``
and ``t_max ≥ q_lo``) — cheap, and it removes the cell-granularity false
positives of trips that pass the region at a different time of day.

Probes are **conservative**: a returned doc's track touches a covered cell
during an overlapping bucket, which is a superset of exactly passing through
the region during the window.  The planner therefore also compiles the
constraint into a ``RefineSpec``: the exact point-in-cover × time-window
pass runs *on device* behind the backend's ``refine_tracks`` /
``refine_tracks_batched`` ops (the Pallas ``refine`` kernel over the
shard's resident CSR track buffers; see ``repro.core.planner`` and
``repro.exec.refine``), and its per-doc hit mask feeds the selection
compaction.

Time is bucketed relative to ``epoch``: build clamps points outside
``[epoch, epoch + 2^20·bucket_s)`` into the boundary buckets — pick
``epoch`` ≤ the dataset's earliest timestamp for time discrimination.
Query windows entirely outside the representable range return no
candidates when nothing was clamped on that side (the common case; they
must not alias onto unrelated bucket-0 / MAX_BUCKET postings), and
collapse onto the boundary bucket when build did clamp points there —
conservative either way, so ``find()`` always agrees with the exact
``filter()`` semantics.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from ..fdb.columnar import span_indices
from ..fdb.index import bitmap_from_ids, bitmap_zeros
from ..geo import mercator as M
from ..geo.areatree import AreaTree

__all__ = ["SpaceTimeIndex", "TIME_BITS", "MAX_BUCKET"]

TIME_BITS = 20                        # buckets per key: 2^20 ≈ 18 years @ 15 min
MAX_BUCKET = (1 << TIME_BITS) - 1
_TB = np.uint64(TIME_BITS)
_BMASK = np.uint64(MAX_BUCKET)
_ONE = np.uint64(1)


@dataclass
class SpaceTimeIndex:
    """(cell × time-bucket) → docs postings over one repeated track field."""

    level: int                 # area-tree cell level of the spatial key part
    bucket_s: float            # time bucket width, seconds
    epoch: float               # t of bucket 0
    keys: np.ndarray           # sorted unique uint64 (cell << TIME_BITS) | bucket
    splits: np.ndarray         # int64 [K+1] CSR into doc_ids
    doc_ids: np.ndarray        # int64 [total]
    t_min: np.ndarray          # float64 [n_docs]; +inf for empty tracks
    t_max: np.ndarray          # float64 [n_docs]; -inf for empty tracks
    n_docs: int
    #: build saw points clamped into the boundary buckets (t < epoch /
    #: past bucket 2^20−1) — out-of-range query windows must then stay
    #: conservative and probe the boundary bucket instead of short-
    #: circuiting to empty
    clamped_lo: bool = False
    clamped_hi: bool = False

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(lat: np.ndarray, lng: np.ndarray, t: np.ndarray, n_docs: int,
              row_splits: Optional[np.ndarray] = None, *,
              level: int = 6, bucket_s: float = 900.0,
              epoch: float = 0.0) -> "SpaceTimeIndex":
        if not 0 < level <= (64 - TIME_BITS) // 6:
            # the packed key is (6·level cell bits) << TIME_BITS | bucket;
            # beyond level 7 it would overflow uint64 and silently corrupt
            # lookups, so reject at build time
            raise ValueError(f"spacetime index level must be in "
                             f"[1, {(64 - TIME_BITS) // 6}], got {level}")
        if bucket_s <= 0:
            raise ValueError("bucket_s must be positive")
        lat = np.asarray(lat, dtype=np.float64)
        lng = np.asarray(lng, dtype=np.float64)
        t = np.asarray(t, dtype=np.float64)
        if row_splits is not None:
            docs = np.repeat(np.arange(n_docs, dtype=np.int64),
                             np.diff(row_splits))
        else:
            docs = np.arange(n_docs, dtype=np.int64)[: lat.size]
        t_min = np.full(n_docs, np.inf)
        t_max = np.full(n_docs, -np.inf)
        if t.size:
            np.minimum.at(t_min, docs, t)
            np.maximum.at(t_max, docs, t)
        if lat.size == 0:
            return SpaceTimeIndex(level, bucket_s, epoch,
                                  np.zeros(0, dtype=np.uint64),
                                  np.zeros(1, dtype=np.int64),
                                  np.zeros(0, dtype=np.int64),
                                  t_min, t_max, n_docs)
        shift = np.uint64(6 * (M.MAX_LEVEL - level))
        cell = M.latlng_to_morton(lat, lng) >> shift
        # Build-side clamp: points before ``epoch`` post into bucket 0 and
        # points past bucket 2^20−1 into MAX_BUCKET, so out-of-range data
        # stays discoverable by windows that reach (or overshoot toward)
        # the boundary buckets.  ``epoch`` should be ≤ the dataset's
        # earliest t (and the bucket width wide enough for its span) for
        # the index to discriminate in time; the ``clamped_lo``/
        # ``clamped_hi`` flags remember that the clamp fired, so
        # :meth:`_bucket_range` only short-circuits out-of-range windows
        # to empty when no clamped postings exist to alias onto.
        raw_bucket = np.floor((t - epoch) / bucket_s)
        clamped_lo = bool(np.any(raw_bucket < 0))
        clamped_hi = bool(np.any(raw_bucket > MAX_BUCKET))
        bucket = np.clip(raw_bucket, 0, MAX_BUCKET).astype(np.uint64)
        ck = (cell << _TB) | bucket
        order = np.lexsort((docs, ck))
        ck_s, docs_s = ck[order], docs[order]
        # dedupe (key, doc) pairs — a track may linger in one cell+bucket
        keep = np.ones(ck_s.size, dtype=bool)
        keep[1:] = (ck_s[1:] != ck_s[:-1]) | (docs_s[1:] != docs_s[:-1])
        ck_s, docs_s = ck_s[keep], docs_s[keep]
        uniq, starts = np.unique(ck_s, return_index=True)
        splits = np.concatenate([starts, [ck_s.size]]).astype(np.int64)
        return SpaceTimeIndex(level, bucket_s, epoch, uniq, splits, docs_s,
                              t_min, t_max, n_docs, clamped_lo, clamped_hi)

    # ----------------------------------------------------------------- lookup
    def _bucket_range(self, t0: float, t1: float
                      ) -> Optional[Tuple[int, int]]:
        """Bucket span of ``[t0, t1]``, or ``None`` when the window misses
        every posted bucket.

        Build time clamps out-of-range *points* into the boundary buckets
        (0 / ``MAX_BUCKET``), which keeps probes conservative for windows
        that reach a boundary.  A window that ends before ``epoch`` or
        starts past bucket 2^20−1 must NOT be clamped the same way when no
        such points exist — that would alias it onto the boundary buckets
        and probe unrelated postings — so it reports no intersection
        instead.  When build *did* clamp points on that side
        (``clamped_lo``/``clamped_hi``), the window collapses onto the
        boundary bucket: those postings are a genuine superset of the
        window's matches, preserving the conservative contract even when
        ``epoch`` was chosen inside the data's time span.
        """
        b0 = np.floor((t0 - self.epoch) / self.bucket_s)
        b1 = np.floor((t1 - self.epoch) / self.bucket_s)
        if b1 < 0 and not self.clamped_lo:
            return None
        if b0 > MAX_BUCKET and not self.clamped_hi:
            return None
        return (int(np.clip(b0, 0, MAX_BUCKET)),
                int(np.clip(b1, 0, MAX_BUCKET)))

    def lookup(self, region: AreaTree, t0: float, t1: float,
               backend=None) -> np.ndarray:
        """Candidate docs with a track point in a cell covering ``region``
        during a bucket overlapping ``[t0, t1]`` (superset of exact).

        The postings OR is a single spans-concatenate gather: per cover
        range, ``searchsorted`` bounds the key span; matching keys across
        *all* ranges are collected at once (bucket post-filter included)
        and their CSR doc lists concatenated without any per-key Python
        loop — the key-fan-out cost is one vectorized gather.

        ``backend`` (an ``ExecBackend``) lowers the tail — the doc-id OR
        into a word bitmap plus the ``[t_min, t_max]`` span prune — behind
        the exec seam (``postings_bitmap``), running it on device over the
        primed span buffers; ``None`` keeps the host math.
        """
        if region.is_empty or t1 < t0 or self.keys.size == 0:
            return bitmap_zeros(self.n_docs)
        br = self._bucket_range(t0, t1)
        if br is None:                 # window outside representable range
            return bitmap_zeros(self.n_docs)
        b0, b1 = br
        shift = np.uint64(6 * (M.MAX_LEVEL - self.level))
        c0 = region.lo >> shift
        c1 = (region.hi - _ONE) >> shift          # inclusive cell ranges
        a = np.searchsorted(self.keys, (c0 << _TB) | np.uint64(b0),
                            side="left")
        b = np.searchsorted(self.keys, (c1 << _TB) | np.uint64(b1),
                            side="right")
        kidx = span_indices(a, b)                 # key slots, all ranges
        if kidx.size == 0:
            return bitmap_zeros(self.n_docs)
        bk = self.keys[kidx] & _BMASK
        kidx = kidx[(bk >= b0) & (bk <= b1)]      # bucket post-filter
        if kidx.size == 0:
            return bitmap_zeros(self.n_docs)
        ids = self.doc_ids[span_indices(self.splits[kidx],
                                        self.splits[kidx + 1])]
        if backend is not None:
            return backend.postings_bitmap(ids, self.t_min, self.t_max,
                                           t0, t1, self.n_docs)
        bm = bitmap_from_ids(ids, self.n_docs)
        # IntervalSet-style span prune: drop docs whose whole track misses
        # the window (kills same-place-different-time false positives).
        overlap = (self.t_min <= t1) & (self.t_max >= t0)
        return bm & bitmap_from_ids(
            np.nonzero(overlap)[0].astype(np.int64), self.n_docs)

    def span(self) -> Optional[Tuple[float, float]]:
        """Time span ``(lo, hi)`` covered by any track point in this
        shard, or ``None`` when unknown (no docs, or no doc has points).

        This is the shard-level partition statistic behind the planner's
        time-partitioned shard pruning: a query window ``[t0, t1]`` with
        ``t1 < lo`` or ``t0 > hi`` cannot match any doc here — docs with
        points all miss the window (their per-doc ``[t_min, t_max]``
        spans lie inside ``[lo, hi]``), and docs without points match no
        space-time constraint at all.  ``None`` means "keep the shard"
        (unknown is never grounds to prune)."""
        if self.n_docs == 0 or self.t_min.size == 0:
            return None
        lo = float(np.min(self.t_min))
        hi = float(np.max(self.t_max))
        if not (np.isfinite(lo) and np.isfinite(hi)):
            return None                       # every track empty
        return lo, hi

    def num_keys(self) -> int:
        return int(self.keys.size)

    def __repr__(self):
        return (f"SpaceTimeIndex(level={self.level}, "
                f"bucket_s={self.bucket_s}, keys={self.keys.size}, "
                f"docs={self.n_docs})")
