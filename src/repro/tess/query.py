"""Tesseract queries: multi-constraint space-time trip selection (paper §2).

The paper's motivating workload: *"all trips passing through region A during
time window T1 and region B during T2"*.  A :class:`Tesseract` is the
constraint builder —

    tess = Tesseract(region_a, t0, t1).also(region_b, t2, t3)
    trips = fdb("Trips").tesseract(tess).collect()

Each constraint becomes one :class:`~repro.core.exprs.InSpaceTime` conjunct.
The planner compiles every conjunct into a ``spacetime`` index probe *and*
keeps it in the residual filter: per shard, all constraint postings bitmaps
are stacked into **one** batched ``bitset`` kernel launch through the
``ExecBackend`` seam (``probe_shard`` → ``intersect_bitmaps``), and the
surviving candidates are refined exactly (point-in-cover × time-window)
behind the backend's ``compact_mask``.

:func:`tesseract_stats` mirrors that hot path outside an engine, reporting
index-probe candidate counts vs. exact-refine counts per shard — the
pruning-ratio evidence the benchmarks track.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.exprs import (EvalContext, Expr, ExprProxy, FieldRef,
                          InSpaceTime, eval_expr)
from ..geo.areatree import AreaTree

__all__ = ["Tesseract", "tesseract_stats"]


class Tesseract:
    """Immutable builder of space-time constraints (AND semantics)."""

    def __init__(self, region: AreaTree, t0: float, t1: float,
                 field: str = "track"):
        if t1 < t0:
            raise ValueError("Tesseract window with t1 < t0")
        self.field = field
        self.constraints: Tuple[Tuple[AreaTree, float, float], ...] = (
            (region, float(t0), float(t1)),)

    def also(self, region: AreaTree, t0: float, t1: float) -> "Tesseract":
        """Add another constraint: ... AND through ``region`` during
        ``[t0, t1]``."""
        if t1 < t0:
            raise ValueError("Tesseract window with t1 < t0")
        out = Tesseract.__new__(Tesseract)
        out.field = self.field
        out.constraints = self.constraints + ((region, float(t0),
                                               float(t1)),)
        return out

    def expr(self, field: Optional[str] = None) -> ExprProxy:
        """The WFL predicate: AND of per-constraint ``InSpaceTime`` nodes —
        usable directly in ``find()`` and composable with other conjuncts."""
        fr = FieldRef(field or self.field)
        out: Optional[ExprProxy] = None
        for region, t0, t1 in self.constraints:
            e = ExprProxy(InSpaceTime(fr, region, t0, t1))
            out = e if out is None else (out & e)
        return out

    def __repr__(self):
        return (f"Tesseract({self.field!r}, "
                f"{len(self.constraints)} constraints)")


def tesseract_stats(db, tess: Tesseract, backend=None) -> Dict[str, Any]:
    """Per-shard index-probe candidates vs. exact-refine survivors.

    Runs the same per-shard hot loop the engines run — one stacked
    ``intersect_bitmaps`` over all constraint postings, then the exact
    refine behind ``compact_mask`` — and reports the pruning ratio
    (fraction of docs the index never touched).
    """
    from ..exec.backend import as_backend     # lazy: exec imports core
    be = as_backend(backend)
    pred: Expr = tess.expr()._expr
    per_shard: List[Dict[str, int]] = []
    docs = candidates = refined = 0
    for sid, shard in enumerate(db.shards):
        idx = shard.index(tess.field, "spacetime")
        if idx is None:
            raise RuntimeError(f"{db.name}.{tess.field} has no spacetime "
                               f"index")
        bms = [idx.lookup(region, t0, t1)
               for region, t0, t1 in tess.constraints]
        bm = be.intersect_bitmaps(shard.all_bitmap(), bms)
        ids = be.select_ids(bm, shard.n)
        sub = shard.batch.gather(ids)
        v = eval_expr(pred, EvalContext(sub))
        mask = np.asarray(v.values, dtype=bool)
        if mask.ndim == 0:
            mask = np.broadcast_to(mask, (sub.n,))
        keep = be.compact_mask(mask)
        per_shard.append({"shard": sid, "docs": shard.n,
                          "candidates": int(ids.size),
                          "refined": int(keep.size)})
        docs += shard.n
        candidates += int(ids.size)
        refined += int(keep.size)
    return {"docs": docs, "candidates": candidates, "refined": refined,
            "pruning": 1.0 - (candidates / docs if docs else 0.0),
            "per_shard": per_shard}
