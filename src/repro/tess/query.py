"""Tesseract queries: multi-constraint space-time trip selection (paper §2).

The paper's motivating workload: *"all trips passing through region A during
time window T1 and region B during T2"*.  A :class:`Tesseract` is the
constraint builder —

    tess = Tesseract(region_a, t0, t1).also(region_b, t2, t3)
    trips = fdb("Trips").tesseract(tess).collect()

and ``then()`` / ``before()`` add *ordering* edges — "through region A
during T1 **and then** region B during T2" — which ride the same refine
pass: the kernel also min-reduces a per-(doc × constraint) **first-hit**
packed timestamp, and the ordering DAG is a strict-less compare over that
table, applied device-side before the mask feeds ``compact_masks``.

The same one-hot compare pass carries the whole reduction family at zero
extra launches: :meth:`Tesseract.at_least` counts a constraint's hits
("≥ k points in A"), and :meth:`Tesseract.dwell` max-reduces a last-hit
table next to the first-hit one and requires ``last − first >= min_s``
seconds in the region.  Constraints can be named (``also(...,
label="work")``) and ordering edges then read ``before("home", "work")``
— the int-index form keeps working.

Each unordered constraint becomes one
:class:`~repro.core.exprs.InSpaceTime` conjunct (ordered builders — and
any builder carrying count/dwell reductions — compile to a single
:class:`~repro.core.exprs.InSpaceTimeSeq` node).
The planner compiles every conjunct into a ``spacetime`` index probe *and*
a :class:`~repro.core.planner.RefineSpec`: per shard, all constraint
postings bitmaps are stacked into **one** batched ``bitset`` kernel launch
through the ``ExecBackend`` seam (``probe_shards`` → ``intersect_bitmaps``),
and the exact pass (point-in-cover × time-window over the ragged track)
runs as **one** fused device launch per wave behind the backend's
``refine_tracks_batched`` op — the Pallas ``refine`` kernel on the jax
backend, a vectorized numpy oracle on the host backend — whose per-doc hit
masks feed the existing ``compact_masks`` selection.  Nothing about the
exact pass runs per-shard on the host anymore; the residual filter is only
used for non-Tesseract conjuncts.

:func:`tesseract_stats` mirrors that hot path outside an engine, reporting
index-probe candidate counts vs. exact-refine counts per shard — the
pruning-ratio evidence the benchmarks track.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple, Union

from ..core.exprs import ExprProxy, FieldRef, InSpaceTime, InSpaceTimeSeq
from ..geo.areatree import AreaTree

__all__ = ["Tesseract", "tesseract_stats"]


class Tesseract:
    """Immutable builder of space-time constraints (AND semantics).

    ``also()`` adds an unordered constraint; ``then()`` adds a *sequenced*
    one — the trip's first hit of the previous constraint must be strictly
    before its first hit of the new one (A **then** B).  ``before(i, j)``
    is the general form: an ordering edge between any two constraints by
    index *or label*, so arbitrary ordering DAGs compose on top of
    ``also()``.  ``at_least(k)`` / ``dwell(min_s)`` attach count/dwell
    reductions to a constraint (the most recent by default).
    """

    def __init__(self, region: AreaTree, t0: float, t1: float,
                 field: str = "track", label: Optional[str] = None):
        if t1 < t0:
            raise ValueError("Tesseract window with t1 < t0")
        self.field = field
        self.constraints: Tuple[Tuple[AreaTree, float, float], ...] = (
            (region, float(t0), float(t1)),)
        self.order_edges: Tuple[Tuple[int, int], ...] = ()
        self._labels: Tuple[Optional[str], ...] = (label,)
        self._min_counts: Tuple[int, ...] = (1,)
        self._dwells: Tuple[Optional[float], ...] = (None,)

    def _copy(self) -> "Tesseract":
        out = Tesseract.__new__(Tesseract)
        out.field = self.field
        out.constraints = self.constraints
        out.order_edges = self.order_edges
        out._labels = self._labels
        out._min_counts = self._min_counts
        out._dwells = self._dwells
        return out

    # ------------------------------------------------------------ reductions
    @property
    def min_counts(self) -> Optional[Tuple[int, ...]]:
        """Per-constraint hit-count thresholds, or ``None`` when every
        constraint keeps the default any-hit (k = 1) verdict."""
        if all(k == 1 for k in self._min_counts):
            return None
        return self._min_counts

    @property
    def dwells(self) -> Optional[Tuple[Optional[float], ...]]:
        """Per-constraint dwell thresholds (seconds), or ``None`` when no
        constraint carries one."""
        if all(d is None for d in self._dwells):
            return None
        return self._dwells

    @property
    def labels(self) -> Tuple[Optional[str], ...]:
        return self._labels

    def _resolve(self, c: Union[int, str], what: str) -> int:
        """Constraint selector → index: ints pass through (bounds-checked),
        strings resolve against the labels given to ``also(label=...)``."""
        n = len(self.constraints)
        if isinstance(c, str):
            try:
                return self._labels.index(c)
            except ValueError:
                known = [x for x in self._labels if x is not None]
                raise ValueError(
                    f"{what}: no constraint labelled {c!r} "
                    f"(labels: {known})") from None
        i = int(c)
        if not (0 <= i < n):
            raise ValueError(f"{what}({c}) with {n} constraints")
        return i

    def at_least(self, k: int,
                 constraint: Union[int, str, None] = None) -> "Tesseract":
        """Require ≥ ``k`` track points satisfying a constraint (the most
        recently added one by default; pick another by index or label).
        ``k = 1`` is the plain any-hit verdict; ``k = 0`` makes the
        constraint vacuous — it stops filtering (and the planner drops its
        index probe so un-hit docs survive to the exact pass)."""
        k = int(k)
        if k < 0:
            raise ValueError(f"at_least({k}): count must be >= 0")
        i = len(self.constraints) - 1 if constraint is None \
            else self._resolve(constraint, "at_least")
        out = self._copy()
        mc = list(out._min_counts)
        mc[i] = k
        out._min_counts = tuple(mc)
        return out

    def dwell(self, min_s: float,
              constraint: Union[int, str, None] = None) -> "Tesseract":
        """Require the trip to have *dwelled* ≥ ``min_s`` seconds in a
        constraint (the most recently added one by default): at least one
        hit, and ``t(last hit) − t(first hit) >= min_s`` — inclusive at
        the threshold, so a pair of hits exactly ``min_s`` apart passes
        and a single hit satisfies only ``min_s = 0``.  Rides the same
        refine dispatch as the hit mask (a last-hit max-reduce next to the
        first-hit min-reduce)."""
        min_s = float(min_s)
        if min_s < 0:
            raise ValueError(f"dwell({min_s}): seconds must be >= 0")
        i = len(self.constraints) - 1 if constraint is None \
            else self._resolve(constraint, "dwell")
        out = self._copy()
        dw = list(out._dwells)
        dw[i] = min_s
        out._dwells = tuple(dw)
        return out

    # ----------------------------------------------------------- constraints
    def also(self, region: AreaTree, t0: float, t1: float,
             label: Optional[str] = None) -> "Tesseract":
        """Add another constraint: ... AND through ``region`` during
        ``[t0, t1]`` (no ordering between this and other constraints).
        ``label`` names the constraint for ``before()`` / ``at_least()`` /
        ``dwell()`` selectors."""
        if t1 < t0:
            raise ValueError("Tesseract window with t1 < t0")
        if label is not None and label in self._labels:
            raise ValueError(f"duplicate constraint label {label!r}")
        out = self._copy()
        out.constraints = self.constraints + ((region, float(t0),
                                               float(t1)),)
        out._labels = self._labels + (label,)
        out._min_counts = self._min_counts + (1,)
        out._dwells = self._dwells + (None,)
        return out

    def then(self, region: AreaTree, t0: float, t1: float,
             label: Optional[str] = None) -> "Tesseract":
        """Add a *sequenced* constraint: ... AND THEN through ``region``
        during ``[t0, t1]`` — the trip's first hit of the previous
        constraint must be strictly before its first hit of this one.
        Equal first-hit timestamps do not count as before (tie ⇒ no
        match).  Chains compose: ``A.then(B).then(C)`` requires
        first(A) < first(B) < first(C)."""
        out = self.also(region, t0, t1, label=label)
        k = len(out.constraints) - 1
        out.order_edges = self.order_edges + ((k - 1, k),)
        return out

    def before(self, i: Union[int, str], j: Union[int, str]) -> "Tesseract":
        """Ordering edge between two existing constraints, by index or by
        the label given to ``also(label=...)``: the first hit of
        constraint ``i`` must be strictly before the first hit of ``j`` —
        ``then()`` is sugar for ``also(...).before(k-1, k)``."""
        ii = self._resolve(i, "before")
        jj = self._resolve(j, "before")
        if ii == jj:
            raise ValueError("before() needs two distinct constraints")
        out = self._copy()
        out.order_edges = self.order_edges + ((ii, jj),)
        return out

    def expr(self, field: Optional[str] = None) -> ExprProxy:
        """The WFL predicate — usable directly in ``find()`` and composable
        with other conjuncts.  Unordered, reduction-free constraints
        compile to an AND of per-constraint ``InSpaceTime`` nodes; any
        ordering edge or count/dwell reduction promotes the whole builder
        to a single ``InSpaceTimeSeq`` node so edges and reduction tuples
        travel with the constraint list into the planner."""
        fr = FieldRef(field or self.field)
        if self.order_edges or self.min_counts is not None \
                or self.dwells is not None:
            return ExprProxy(InSpaceTimeSeq(fr, self.constraints,
                                            self.order_edges,
                                            self.min_counts, self.dwells))
        out: Optional[ExprProxy] = None
        for region, t0, t1 in self.constraints:
            e = ExprProxy(InSpaceTime(fr, region, t0, t1))
            out = e if out is None else (out & e)
        return out

    def __repr__(self):
        extras = []
        if self.order_edges:
            extras.append(f"{len(self.order_edges)} ordering edges")
        if self.min_counts is not None:
            extras.append("counts")
        if self.dwells is not None:
            extras.append("dwell")
        tail = (", " + ", ".join(extras)) if extras else ""
        return (f"Tesseract({self.field!r}, "
                f"{len(self.constraints)} constraints{tail})")


def tesseract_stats(db, tess: Tesseract, backend=None,
                    wave: Optional[int] = None) -> Dict[str, Any]:
    """Per-shard index-probe candidates vs. exact-refine survivors.

    Runs the same hot loop the engines run, through the batched seam: per
    wave of shards, one stacked ``probe_shards`` launch ANDs every
    constraint's postings bitmaps, one ``refine_tracks_batched`` launch
    evaluates the exact point-in-cover × time-window pass on device over
    the resident ragged tracks, and one ``compact_masks`` launch per mask
    set turns the bitmaps into candidate/survivor ids.  Reports the
    pruning ratio (fraction of docs the index never touched); 0.0 on an
    empty FDb (an index over zero docs has pruned nothing).  Constraints
    made vacuous with ``at_least(0)`` skip their index probe (their
    postings are not a superset of "always true").
    """
    from ..exec.backend import as_backend     # lazy: exec imports core
    from ..exec.batched import partition_waves, wave_size
    from ..fdb.index import mask_from_bitmap
    be = as_backend(backend)
    be.prime_fdb(db)
    mins = tess.min_counts
    probe_cs = [c for c in range(len(tess.constraints))
                if mins is None or mins[c] != 0]
    per_shard: List[Dict[str, int]] = []
    docs = candidates = refined = 0
    for sids in partition_waves(range(db.num_shards), wave_size(wave, be)):
        shards = [db.shards[sid] for sid in sids]
        idxs = [sh.index(tess.field, "spacetime") for sh in shards]
        if any(ix is None for ix in idxs):
            raise RuntimeError(f"{db.name}.{tess.field} has no spacetime "
                               f"index")
        bms = be.probe_shards(
            [sh.all_bitmap() for sh in shards],
            [[ix.lookup(*tess.constraints[c]) for c in probe_cs]
             for ix in idxs])
        cand_masks = [mask_from_bitmap(bm, sh.n)
                      for bm, sh in zip(bms, shards)]
        ids_list = be.compact_masks(cand_masks)
        refined_masks = be.refine_tracks_batched(
            [sh.batch for sh in shards], tess.field, tess.constraints,
            cand_masks, edges=tess.order_edges,
            min_counts=mins, dwells=tess.dwells)
        keeps = be.compact_masks(refined_masks)
        for sid, sh, ids, keep in zip(sids, shards, ids_list, keeps):
            per_shard.append({"shard": sid, "docs": sh.n,
                              "candidates": int(ids.size),
                              "refined": int(keep.size)})
            docs += sh.n
            candidates += int(ids.size)
            refined += int(keep.size)
    return {"docs": docs, "candidates": candidates, "refined": refined,
            "pruning": (1.0 - candidates / docs) if docs else 0.0,
            "per_shard": per_shard}
