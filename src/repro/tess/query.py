"""Tesseract queries: multi-constraint space-time trip selection (paper §2).

The paper's motivating workload: *"all trips passing through region A during
time window T1 and region B during T2"*.  A :class:`Tesseract` is the
constraint builder —

    tess = Tesseract(region_a, t0, t1).also(region_b, t2, t3)
    trips = fdb("Trips").tesseract(tess).collect()

Each constraint becomes one :class:`~repro.core.exprs.InSpaceTime` conjunct.
The planner compiles every conjunct into a ``spacetime`` index probe *and*
a :class:`~repro.core.planner.RefineSpec`: per shard, all constraint
postings bitmaps are stacked into **one** batched ``bitset`` kernel launch
through the ``ExecBackend`` seam (``probe_shards`` → ``intersect_bitmaps``),
and the exact pass (point-in-cover × time-window over the ragged track)
runs as **one** fused device launch per wave behind the backend's
``refine_tracks_batched`` op — the Pallas ``refine`` kernel on the jax
backend, a vectorized numpy oracle on the host backend — whose per-doc hit
masks feed the existing ``compact_masks`` selection.  Nothing about the
exact pass runs per-shard on the host anymore; the residual filter is only
used for non-Tesseract conjuncts.

:func:`tesseract_stats` mirrors that hot path outside an engine, reporting
index-probe candidate counts vs. exact-refine counts per shard — the
pruning-ratio evidence the benchmarks track.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from ..core.exprs import ExprProxy, FieldRef, InSpaceTime
from ..geo.areatree import AreaTree

__all__ = ["Tesseract", "tesseract_stats"]


class Tesseract:
    """Immutable builder of space-time constraints (AND semantics)."""

    def __init__(self, region: AreaTree, t0: float, t1: float,
                 field: str = "track"):
        if t1 < t0:
            raise ValueError("Tesseract window with t1 < t0")
        self.field = field
        self.constraints: Tuple[Tuple[AreaTree, float, float], ...] = (
            (region, float(t0), float(t1)),)

    def also(self, region: AreaTree, t0: float, t1: float) -> "Tesseract":
        """Add another constraint: ... AND through ``region`` during
        ``[t0, t1]``."""
        if t1 < t0:
            raise ValueError("Tesseract window with t1 < t0")
        out = Tesseract.__new__(Tesseract)
        out.field = self.field
        out.constraints = self.constraints + ((region, float(t0),
                                               float(t1)),)
        return out

    def expr(self, field: Optional[str] = None) -> ExprProxy:
        """The WFL predicate: AND of per-constraint ``InSpaceTime`` nodes —
        usable directly in ``find()`` and composable with other conjuncts."""
        fr = FieldRef(field or self.field)
        out: Optional[ExprProxy] = None
        for region, t0, t1 in self.constraints:
            e = ExprProxy(InSpaceTime(fr, region, t0, t1))
            out = e if out is None else (out & e)
        return out

    def __repr__(self):
        return (f"Tesseract({self.field!r}, "
                f"{len(self.constraints)} constraints)")


def tesseract_stats(db, tess: Tesseract, backend=None,
                    wave: Optional[int] = None) -> Dict[str, Any]:
    """Per-shard index-probe candidates vs. exact-refine survivors.

    Runs the same hot loop the engines run, through the batched seam: per
    wave of shards, one stacked ``probe_shards`` launch ANDs every
    constraint's postings bitmaps, one ``refine_tracks_batched`` launch
    evaluates the exact point-in-cover × time-window pass on device over
    the resident ragged tracks, and one ``compact_masks`` launch per mask
    set turns the bitmaps into candidate/survivor ids.  Reports the
    pruning ratio (fraction of docs the index never touched); 0.0 on an
    empty FDb (an index over zero docs has pruned nothing).
    """
    from ..exec.backend import as_backend     # lazy: exec imports core
    from ..exec.batched import partition_waves, wave_size
    from ..fdb.index import mask_from_bitmap
    be = as_backend(backend)
    be.prime_fdb(db)
    per_shard: List[Dict[str, int]] = []
    docs = candidates = refined = 0
    for sids in partition_waves(range(db.num_shards), wave_size(wave, be)):
        shards = [db.shards[sid] for sid in sids]
        idxs = [sh.index(tess.field, "spacetime") for sh in shards]
        if any(ix is None for ix in idxs):
            raise RuntimeError(f"{db.name}.{tess.field} has no spacetime "
                               f"index")
        bms = be.probe_shards(
            [sh.all_bitmap() for sh in shards],
            [[ix.lookup(region, t0, t1)
              for region, t0, t1 in tess.constraints] for ix in idxs])
        cand_masks = [mask_from_bitmap(bm, sh.n)
                      for bm, sh in zip(bms, shards)]
        ids_list = be.compact_masks(cand_masks)
        refined_masks = be.refine_tracks_batched(
            [sh.batch for sh in shards], tess.field, tess.constraints,
            cand_masks)
        keeps = be.compact_masks(refined_masks)
        for sid, sh, ids, keep in zip(sids, shards, ids_list, keeps):
            per_shard.append({"shard": sid, "docs": sh.n,
                              "candidates": int(ids.size),
                              "refined": int(keep.size)})
            docs += sh.n
            candidates += int(ids.size)
            refined += int(keep.size)
    return {"docs": docs, "candidates": candidates, "refined": refined,
            "pruning": (1.0 - candidates / docs) if docs else 0.0,
            "per_shard": per_shard}
