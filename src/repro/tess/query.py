"""Tesseract queries: multi-constraint space-time trip selection (paper §2).

The paper's motivating workload: *"all trips passing through region A during
time window T1 and region B during T2"*.  A :class:`Tesseract` is the
constraint builder —

    tess = Tesseract(region_a, t0, t1).also(region_b, t2, t3)
    trips = fdb("Trips").tesseract(tess).collect()

and ``then()`` / ``before()`` add *ordering* edges — "through region A
during T1 **and then** region B during T2" — which ride the same refine
pass: the kernel also min-reduces a per-(doc × constraint) **first-hit**
packed timestamp, and the ordering DAG is a strict-less compare over that
table, applied device-side before the mask feeds ``compact_masks``.

Each unordered constraint becomes one
:class:`~repro.core.exprs.InSpaceTime` conjunct (ordered builders compile
to a single :class:`~repro.core.exprs.InSpaceTimeSeq` node).
The planner compiles every conjunct into a ``spacetime`` index probe *and*
a :class:`~repro.core.planner.RefineSpec`: per shard, all constraint
postings bitmaps are stacked into **one** batched ``bitset`` kernel launch
through the ``ExecBackend`` seam (``probe_shards`` → ``intersect_bitmaps``),
and the exact pass (point-in-cover × time-window over the ragged track)
runs as **one** fused device launch per wave behind the backend's
``refine_tracks_batched`` op — the Pallas ``refine`` kernel on the jax
backend, a vectorized numpy oracle on the host backend — whose per-doc hit
masks feed the existing ``compact_masks`` selection.  Nothing about the
exact pass runs per-shard on the host anymore; the residual filter is only
used for non-Tesseract conjuncts.

:func:`tesseract_stats` mirrors that hot path outside an engine, reporting
index-probe candidate counts vs. exact-refine counts per shard — the
pruning-ratio evidence the benchmarks track.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from ..core.exprs import ExprProxy, FieldRef, InSpaceTime, InSpaceTimeSeq
from ..geo.areatree import AreaTree

__all__ = ["Tesseract", "tesseract_stats"]


class Tesseract:
    """Immutable builder of space-time constraints (AND semantics).

    ``also()`` adds an unordered constraint; ``then()`` adds a *sequenced*
    one — the trip's first hit of the previous constraint must be strictly
    before its first hit of the new one (A **then** B).  ``before(i, j)``
    is the general form: an ordering edge between any two constraints by
    index, so arbitrary ordering DAGs compose on top of ``also()``.
    """

    def __init__(self, region: AreaTree, t0: float, t1: float,
                 field: str = "track"):
        if t1 < t0:
            raise ValueError("Tesseract window with t1 < t0")
        self.field = field
        self.constraints: Tuple[Tuple[AreaTree, float, float], ...] = (
            (region, float(t0), float(t1)),)
        self.order_edges: Tuple[Tuple[int, int], ...] = ()

    def _copy(self) -> "Tesseract":
        out = Tesseract.__new__(Tesseract)
        out.field = self.field
        out.constraints = self.constraints
        out.order_edges = self.order_edges
        return out

    def also(self, region: AreaTree, t0: float, t1: float) -> "Tesseract":
        """Add another constraint: ... AND through ``region`` during
        ``[t0, t1]`` (no ordering between this and other constraints)."""
        if t1 < t0:
            raise ValueError("Tesseract window with t1 < t0")
        out = self._copy()
        out.constraints = self.constraints + ((region, float(t0),
                                               float(t1)),)
        return out

    def then(self, region: AreaTree, t0: float, t1: float) -> "Tesseract":
        """Add a *sequenced* constraint: ... AND THEN through ``region``
        during ``[t0, t1]`` — the trip's first hit of the previous
        constraint must be strictly before its first hit of this one.
        Equal first-hit timestamps do not count as before (tie ⇒ no
        match).  Chains compose: ``A.then(B).then(C)`` requires
        first(A) < first(B) < first(C)."""
        out = self.also(region, t0, t1)
        k = len(out.constraints) - 1
        out.order_edges = self.order_edges + ((k - 1, k),)
        return out

    def before(self, i: int, j: int) -> "Tesseract":
        """Ordering edge between two existing constraints by index: the
        first hit of constraint ``i`` must be strictly before the first
        hit of constraint ``j`` — ``then()`` is sugar for
        ``also(...).before(k-1, k)``."""
        n = len(self.constraints)
        if not (0 <= i < n and 0 <= j < n):
            raise ValueError(f"before({i}, {j}) with {n} constraints")
        if i == j:
            raise ValueError("before() needs two distinct constraints")
        out = self._copy()
        out.order_edges = self.order_edges + ((int(i), int(j)),)
        return out

    def expr(self, field: Optional[str] = None) -> ExprProxy:
        """The WFL predicate — usable directly in ``find()`` and composable
        with other conjuncts.  Unordered constraints compile to an AND of
        per-constraint ``InSpaceTime`` nodes; any ordering edge promotes
        the whole builder to a single ``InSpaceTimeSeq`` node so the edges
        travel with the constraint list into the planner."""
        fr = FieldRef(field or self.field)
        if self.order_edges:
            return ExprProxy(InSpaceTimeSeq(fr, self.constraints,
                                            self.order_edges))
        out: Optional[ExprProxy] = None
        for region, t0, t1 in self.constraints:
            e = ExprProxy(InSpaceTime(fr, region, t0, t1))
            out = e if out is None else (out & e)
        return out

    def __repr__(self):
        return (f"Tesseract({self.field!r}, "
                f"{len(self.constraints)} constraints, "
                f"{len(self.order_edges)} ordering edges)")


def tesseract_stats(db, tess: Tesseract, backend=None,
                    wave: Optional[int] = None) -> Dict[str, Any]:
    """Per-shard index-probe candidates vs. exact-refine survivors.

    Runs the same hot loop the engines run, through the batched seam: per
    wave of shards, one stacked ``probe_shards`` launch ANDs every
    constraint's postings bitmaps, one ``refine_tracks_batched`` launch
    evaluates the exact point-in-cover × time-window pass on device over
    the resident ragged tracks, and one ``compact_masks`` launch per mask
    set turns the bitmaps into candidate/survivor ids.  Reports the
    pruning ratio (fraction of docs the index never touched); 0.0 on an
    empty FDb (an index over zero docs has pruned nothing).
    """
    from ..exec.backend import as_backend     # lazy: exec imports core
    from ..exec.batched import partition_waves, wave_size
    from ..fdb.index import mask_from_bitmap
    be = as_backend(backend)
    be.prime_fdb(db)
    per_shard: List[Dict[str, int]] = []
    docs = candidates = refined = 0
    for sids in partition_waves(range(db.num_shards), wave_size(wave, be)):
        shards = [db.shards[sid] for sid in sids]
        idxs = [sh.index(tess.field, "spacetime") for sh in shards]
        if any(ix is None for ix in idxs):
            raise RuntimeError(f"{db.name}.{tess.field} has no spacetime "
                               f"index")
        bms = be.probe_shards(
            [sh.all_bitmap() for sh in shards],
            [[ix.lookup(region, t0, t1)
              for region, t0, t1 in tess.constraints] for ix in idxs])
        cand_masks = [mask_from_bitmap(bm, sh.n)
                      for bm, sh in zip(bms, shards)]
        ids_list = be.compact_masks(cand_masks)
        refined_masks = be.refine_tracks_batched(
            [sh.batch for sh in shards], tess.field, tess.constraints,
            cand_masks, edges=tess.order_edges)
        keeps = be.compact_masks(refined_masks)
        for sid, sh, ids, keep in zip(sids, shards, ids_list, keeps):
            per_shard.append({"shard": sid, "docs": sh.n,
                              "candidates": int(ids.size),
                              "refined": int(keep.size)})
            docs += sh.n
            candidates += int(ids.size)
            refined += int(keep.size)
    return {"docs": docs, "candidates": candidates, "refined": refined,
            "pruning": (1.0 - candidates / docs) if docs else 0.0,
            "per_shard": per_shard}
