"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM (arXiv 2405.04517 §2.3) is a linear-attention-style cell with
exponential input gates and matrix memory C ∈ ℝ^{dh×dh} per head.  Training
and prefill use the *chunked* parallel form: intra-chunk decayed attention
(quadratic within a small chunk) + inter-chunk state carry — sub-quadratic
overall, the same structure as our Mamba path.  Decode is the O(1)
recurrence.  Gates use sigmoid forget + clipped-exp input (the paper's
stabilized exponential gating, with the running-max stabilizer folded into
the per-chunk log-space cumulative sums).

sLSTM (§2.2) has scalar memory with recurrent (block-diagonal per-head)
connections — inherently sequential, computed with ``lax.scan`` over time;
the paper itself notes it is not parallelizable (their GPU kernel
parallelizes over heads, which the vectorized scan body gives us for free).
A gated pf=4/3 MLP follows, per the paper's block layout.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, silu
from .sharding import constrain

__all__ = ["mlstm_init", "mlstm_apply", "mlstm_decode", "mlstm_cache_init",
           "slstm_init", "slstm_apply", "slstm_decode", "slstm_cache_init"]

_I_CLIP = 5.0


# ===========================================================================
# mLSTM
# ===========================================================================

def mlstm_init(key, d: int, num_heads: int, *, pf: int = 2,
               dtype=jnp.float32):
    di = pf * d
    dh = di // num_heads
    ks = jax.random.split(key, 8)

    def headwise(k):
        # per-head block-diagonal projection (paper: q/k/v per head)
        return (jax.random.normal(k, (num_heads, dh, dh), jnp.float32)
                / jnp.sqrt(dh)).astype(dtype)

    return {
        "w_upA": dense_init(ks[0], d, di, dtype),     # cell input path
        "w_upB": dense_init(ks[1], d, di, dtype),     # output gate path
        "wq": headwise(ks[2]),
        "wk": headwise(ks[3]),
        "wv": headwise(ks[4]),
        "wi": dense_init(ks[5], di, num_heads, jnp.float32),
        "wf": dense_init(ks[6], di, num_heads, jnp.float32),
        "out_proj": dense_init(ks[7], di, d, dtype),
    }


def _headwise_proj(u, w, num_heads):
    """u [B, S, dI] × w [H, dh, dh] → [B, H, S, dh]."""
    b, s, di = u.shape
    dh = di // num_heads
    uh = u.reshape(b, s, num_heads, dh)
    return jnp.einsum("bshd,hde->bhse", uh, w.astype(u.dtype))


def _mlstm_gates(u, p):
    """u [B, S, dI] → log_f, log_i [B, S, H] (stabilized)."""
    f_raw = u.astype(jnp.float32) @ p["wf"]
    i_raw = u.astype(jnp.float32) @ p["wi"]
    log_f = jax.nn.log_sigmoid(f_raw)
    log_i = jnp.clip(i_raw, -_I_CLIP, _I_CLIP)
    return log_f, log_i


def mlstm_apply(x, p, num_heads: int, *, chunk: int = None,
                return_state: bool = False):
    """x [B, S, D] → [B, S, D] via chunked decayed linear attention."""
    import os
    chunk = chunk or int(os.environ.get("REPRO_SSM_CHUNK", 256))
    b, s, d = x.shape
    u = silu(x @ p["w_upA"].astype(x.dtype))
    og = silu(x @ p["w_upB"].astype(x.dtype))
    di = u.shape[-1]
    dh = di // num_heads
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    q = _headwise_proj(u, p["wq"], num_heads).astype(jnp.float32) * scale
    k = _headwise_proj(u, p["wk"], num_heads).astype(jnp.float32)
    v = _headwise_proj(u, p["wv"], num_heads).astype(jnp.float32)
    log_f, log_i = _mlstm_gates(u, p)                     # [B, S, H]
    log_f = log_f.transpose(0, 2, 1)                      # [B, H, S]
    log_i = log_i.transpose(0, 2, 1)

    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        log_f = jnp.pad(log_f, ((0, 0), (0, 0), (0, pad)))
        log_i = jnp.pad(log_i, ((0, 0), (0, 0), (0, pad)),
                        constant_values=-_I_CLIP)
    nc = (s + pad) // c

    def split_chunks(t, tail):
        return jnp.moveaxis(t.reshape(b, num_heads, nc, c, *tail), 2, 0)

    qc = split_chunks(q, (dh,))
    kc = split_chunks(k, (dh,))
    vc = split_chunks(v, (dh,))
    fc = split_chunks(log_f, ())
    ic = split_chunks(log_i, ())

    @jax.checkpoint
    def chunk_body(carry, inp):
      # kernel_interior: the decay matrices/scores live in VMEM on the
      # chunked Pallas path (ssm_scan kernel family) — bucketed by the
      # roofline analyzer like flash_interior
      with jax.named_scope("kernel_interior"):
        C, n = carry                           # [B,H,dh,dh], [B,H,dh]
        qq, kk, vv, lf, li = inp
        Lf = jnp.cumsum(lf, axis=-1)           # [B,H,c] inclusive
        # intra-chunk decay matrix (log space, lower triangular)
        dmat = Lf[..., :, None] - Lf[..., None, :] + li[..., None, :]
        tri = jnp.tril(jnp.ones((c, c), bool))
        dmat = jnp.where(tri, dmat, -jnp.inf)
        w = jnp.exp(dmat)                      # [B,H,c,c]
        scores = jnp.einsum("bhtd,bhsd->bhts", qq, kk) * w
        intra = jnp.einsum("bhts,bhsd->bhtd", scores, vv)
        n_intra = jnp.einsum("bhts,bhsd->bhtd", w *
                             jnp.ones_like(scores), kk)
        # inter-chunk contribution
        decay_t = jnp.exp(Lf)[..., None]       # [B,H,c,1]
        inter = jnp.einsum("bhtd,bhde->bhte", qq * decay_t, C)
        n_inter = decay_t * n[:, :, None, :]
        num = intra + inter
        n_tot = n_intra + n_inter
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bhtd,bhtd->bht", qq, n_tot))[..., None],
            1.0)
        h = num / denom
        # carry update
        decay_end = jnp.exp(Lf[..., -1:] - Lf)            # [B,H,c]
        ki = kk * jnp.exp(li)[..., None] * decay_end[..., None]
        C_new = jnp.exp(Lf[..., -1])[..., None, None] * C + \
            jnp.einsum("bhsd,bhse->bhde", ki, vv)
        n_new = jnp.exp(Lf[..., -1])[..., None] * n + ki.sum(axis=2)
        # pin carry sharding: GSPMD loop-carry propagation replicates the
        # [B,H,dh,dv] matrix memory otherwise (observed: 4 GiB/chunk repl.)
        C_new = constrain(C_new, ("batch", None, None, "model"))
        n_new = constrain(n_new, ("batch", None, "model"))
        return (C_new, n_new), h

    C0 = jnp.zeros((b, num_heads, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, num_heads, dh), jnp.float32)
    (CT, nT), hs = jax.lax.scan(chunk_body, (C0, n0), (qc, kc, vc, fc, ic))
    h = jnp.moveaxis(hs, 0, 2).reshape(b, num_heads, nc * c, dh)[:, :, :s]
    h = h.transpose(0, 2, 1, 3).reshape(b, s, di).astype(x.dtype)
    out = (h * og) @ p["out_proj"].astype(h.dtype)
    if return_state:
        # exact: padded steps have log_f = 0 (no decay) and k = v = 0
        # (no contribution), so (CT, nT) is the state after position s.
        return out, {"C": CT, "n": nT}
    return out


def mlstm_cache_init(batch: int, d: int, num_heads: int, pf: int = 2):
    di = pf * d
    dh = di // num_heads
    return {"C": jnp.zeros((batch, num_heads, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, num_heads, dh), jnp.float32)}


def mlstm_decode(x, p, num_heads: int, cache):
    """x [B, 1, D] → (y [B, 1, D], cache) — O(1) recurrent update."""
    b, _, d = x.shape
    u = silu(x[:, 0] @ p["w_upA"].astype(x.dtype))
    og = silu(x[:, 0] @ p["w_upB"].astype(x.dtype))
    di = u.shape[-1]
    dh = di // num_heads
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    q = _headwise_proj(u[:, None], p["wq"], num_heads)[:, :, 0].astype(
        jnp.float32) * scale
    k = _headwise_proj(u[:, None], p["wk"], num_heads)[:, :, 0].astype(
        jnp.float32)
    v = _headwise_proj(u[:, None], p["wv"], num_heads)[:, :, 0].astype(
        jnp.float32)
    log_f, log_i = _mlstm_gates(u[:, None], p)
    f = jnp.exp(log_f[:, 0])[..., None]                   # [B,H,1]
    i = jnp.exp(log_i[:, 0])[..., None]
    C = f[..., None] * cache["C"] + i[..., None] * \
        jnp.einsum("bhd,bhe->bhde", k, v)
    n = f * cache["n"] + i * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n))[..., None],
                        1.0)
    h = (num / denom).reshape(b, di).astype(x.dtype)
    return ((h * og) @ p["out_proj"].astype(h.dtype))[:, None], \
        {"C": C, "n": n}


# ===========================================================================
# sLSTM
# ===========================================================================

def slstm_init(key, d: int, num_heads: int, dtype=jnp.float32):
    dh = d // num_heads
    ks = jax.random.split(key, 10)
    p = {"out_proj": dense_init(ks[8], d, d, dtype),
         "mlp": {"w_gate": dense_init(ks[9], d, d * 4 // 3, dtype),
                 "w_up": dense_init(jax.random.fold_in(ks[9], 1), d,
                                    d * 4 // 3, dtype),
                 "w_down": dense_init(jax.random.fold_in(ks[9], 2),
                                      d * 4 // 3, d, dtype)}}
    for j, g in enumerate(("i", "f", "z", "o")):
        p[f"w{g}"] = dense_init(ks[j], d, d, dtype)
        p[f"r{g}"] = (jax.random.normal(ks[4 + j],
                                        (num_heads, dh, dh), jnp.float32)
                      / jnp.sqrt(dh)).astype(dtype)
        p[f"b{g}"] = jnp.zeros((d,), jnp.float32)
    return p


def _slstm_step(p, num_heads, state, xw_t):
    """state: (c, n, h, m) each [B, D]; xw_t = precomputed input
    projections (xi, xf, xz, xo), each [B, D].

    §Perf iteration (cell C): the input GEMMs are hoisted out of the time
    scan — per-step fusions were re-reading all four [D, D] gate matrices
    (67–134 MB × S steps = 99% of the memory term); only the [H, dh, dh]
    head-block recurrences (VMEM-resident in a fused TPU kernel —
    kernel_interior scope) remain sequential.
    """
    c, n, h, m = state
    xi, xf_, xz, xo = xw_t
    b, d = xi.shape
    dh = d // num_heads

    with jax.named_scope("kernel_interior"):
        def rec(h_prev, r):
            hh = h_prev.reshape(b, num_heads, dh)
            return jnp.einsum("bhd,hde->bhe", hh, r.astype(jnp.float32)
                              ).reshape(b, d)

        hi = xi + rec(h, p["ri"]) + p["bi"]
        hf = xf_ + rec(h, p["rf"]) + p["bf"]
        hz = xz + rec(h, p["rz"]) + p["bz"]
        ho = xo + rec(h, p["ro"]) + p["bo"]
        # stabilized exponential gating (paper eq. 15–17)
        m_new = jnp.maximum(hf + m, hi)
        i_g = jnp.exp(hi - m_new)
        f_g = jnp.exp(hf + m - m_new)
        z = jnp.tanh(hz)
        o = jax.nn.sigmoid(ho)
        c_new = f_g * c + i_g * z
        n_new = f_g * n + i_g
        h_new = o * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new


def _slstm_inputs(x, p):
    """Batched input projections for all gates: [B, S, D] × 4 (one GEMM
    each over the whole sequence — time-parallel, MXU-friendly)."""
    xf32 = x.astype(jnp.float32)
    return tuple(xf32 @ p[w].astype(jnp.float32)
                 for w in ("wi", "wf", "wz", "wo"))


def slstm_apply(x, p, num_heads: int, *, return_state: bool = False):
    """x [B, S, D] → [B, S, D] (sequential scan over time)."""
    b, s, d = x.shape
    z0 = jnp.zeros((b, d), jnp.float32)
    state0 = (z0, z0, z0, z0)
    xw = tuple(jnp.moveaxis(t, 1, 0) for t in _slstm_inputs(x, p))
    # checkpointed: backward recomputes the per-step gate activations
    # instead of saving 4 × [B, D] f32 per time step
    step = jax.checkpoint(lambda st, xt: _slstm_step(p, num_heads, st, xt))
    stT, hs = jax.lax.scan(step, state0, xw)
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    out = h @ p["out_proj"].astype(h.dtype)
    mlp = p["mlp"]
    dt = out.dtype
    out = out + (silu(out @ mlp["w_gate"].astype(dt))
                 * (out @ mlp["w_up"].astype(dt))) @ mlp["w_down"].astype(dt)
    if return_state:
        return out, {"c": stT[0], "n": stT[1], "h": stT[2], "m": stT[3]}
    return out


def slstm_cache_init(batch: int, d: int):
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def slstm_decode(x, p, num_heads: int, cache):
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    xw = tuple(t[:, 0] for t in _slstm_inputs(x, p))
    state, h = _slstm_step(p, num_heads, state, xw)
    h = h.astype(x.dtype)
    out = h @ p["out_proj"].astype(h.dtype)
    mlp = p["mlp"]
    dt = out.dtype
    out = out + (silu(out @ mlp["w_gate"].astype(dt))
                 * (out @ mlp["w_up"].astype(dt))) @ mlp["w_down"].astype(dt)
    return out[:, None], {"c": state[0], "n": state[1], "h": state[2],
                          "m": state[3]}
