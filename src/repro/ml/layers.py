"""Shared neural layers (pure JAX, parameter pytrees).

Everything is written against *logical* shapes; sharding comes from
``repro.ml.sharding`` path rules at pjit time.  Initializers return nested
dicts so ``jax.eval_shape`` gives the dry-run parameter tree without
allocation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["rms_norm", "layer_norm", "dense_init", "rope", "mrope",
           "mlp_init", "mlp_apply", "norm_init", "embed_init", "gelu",
           "silu"]


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> jnp.ndarray:
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


def norm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(x, p, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * p["scale"].astype(jnp.float32)
            ).astype(dt)


def layer_norm(x, p, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(dt)


def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


# ------------------------------------------------------------------ RoPE

def _rope_angles(positions, dim: int, theta: float):
    """positions [...,] → cos/sin [..., dim/2]."""
    half = dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding. x [B, H, S, D], positions [B, S] (absolute)."""
    b, h, s, d = x.shape
    cos, sin = _rope_angles(positions, d, theta)        # [B, S, D/2]
    cos = cos[:, None, :, :]
    sin = sin[:, None, :, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def mrope(x, positions3, theta: float = 10000.0,
          sections: Tuple[int, int, int] = (2, 1, 1)):
    """Multimodal RoPE (Qwen2-VL §3.1): head_dim split into temporal/
    height/width sections with separate position streams.

    x [B, H, S, D]; positions3 [3, B, S] (equal streams ⇒ plain RoPE on
    text).  ``sections`` are relative weights over D/2 frequency slots.
    """
    b, h, s, d = x.shape
    half = d // 2
    total = sum(sections)
    sizes = [half * w // total for w in sections]
    sizes[-1] = half - sum(sizes[:-1])
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # per-frequency-slot stream selection
    sel = jnp.concatenate([jnp.full((sz,), i, jnp.int32)
                           for i, sz in enumerate(sizes)])
    # gather: ang[b, s, f] = positions3[sel[f], b, s] * freqs[f]
    p_sel = positions3[sel, :, :]                        # [half, B, S]
    ang = jnp.moveaxis(p_sel, 0, -1).astype(jnp.float32) * freqs  # [B,S,half]
    cos = jnp.cos(ang)[:, None, :, :]
    sin = jnp.sin(ang)[:, None, :, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- MLP

def mlp_init(key, d: int, f: int, *, gated: bool = True,
             dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if gated:
        return {"w_gate": dense_init(ks[0], d, f, dtype),
                "w_up": dense_init(ks[1], d, f, dtype),
                "w_down": dense_init(ks[2], f, d, dtype)}
    return {"w_up": dense_init(ks[0], d, f, dtype),
            "w_down": dense_init(ks[1], f, d, dtype)}


def mlp_apply(x, p, act: str = "silu"):
    a = {"silu": silu, "gelu": gelu}[act]
    wg = p.get("w_gate")
    wu = p["w_up"].astype(x.dtype)
    wd = p["w_down"].astype(x.dtype)
    if wg is not None:
        h = a(x @ wg.astype(x.dtype)) * (x @ wu)
    else:
        h = a(x @ wu)
    return h @ wd
