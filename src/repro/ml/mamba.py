"""Mamba (selective SSM) block — Jamba's recurrent layer.

Training/prefill use a *chunked* selective scan: within a chunk the
diagonal recurrence is solved by an associative scan (the same algorithm
as the Pallas ``ssm_scan`` kernel — the kernel is the TPU-target fast path
for the flattened inner scan), and chunks are threaded sequentially via a
[B, dI, N] carry.  Live memory is O(B·chunk·dI·N) instead of
O(B·S·dI·N), which is what makes seq=512k lowerable.

Decode keeps O(1) state: {h: [B, dI, N], conv: [B, K-1, dI]}.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, silu
from .sharding import constrain

__all__ = ["mamba_init", "mamba_apply", "mamba_decode", "mamba_cache_init"]


def mamba_init(key, d: int, *, expand: int = 2, state: int = 16,
               conv: int = 4, dt_rank: Optional[int] = None,
               dtype=jnp.float32):
    di = expand * d
    r = dt_rank or max(1, d // 16)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (di, conv), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, r + 2 * state, dtype),
        "dt_proj": dense_init(ks[3], r, di, dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, state + 1,
                                             dtype=jnp.float32), (di, 1))),
        "D_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv: x [B, S, dI], w [dI, K]."""
    k = w.shape[1]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + pad[:, i:i + x.shape[1], :] * w[:, i]
    return out + b


def _ssm_params(x1, p):
    """x1 [B, S, dI] → (delta, B_ssm, C_ssm) with A from A_log."""
    r = p["dt_proj"].shape[0]
    n = (p["x_proj"].shape[1] - r) // 2
    x_dbl = x1 @ p["x_proj"].astype(x1.dtype)
    dt_raw, b_ssm, c_ssm = jnp.split(x_dbl, [r, r + n], axis=-1)
    delta = jax.nn.softplus(dt_raw @ p["dt_proj"].astype(dt_raw.dtype)
                            + p["dt_bias"].astype(dt_raw.dtype))
    return delta, b_ssm, c_ssm


def mamba_apply(x, p, *, chunk: int = None, return_state: bool = False):
    """x [B, S, D] → [B, S, D] (training / prefill).

    ``return_state`` additionally returns the decode cache
    {h: [B, dI, N], conv: [B, K-1, dI]} after the last position.
    """
    import os
    chunk = chunk or int(os.environ.get("REPRO_SSM_CHUNK", 256))
    b, s, d = x.shape
    di = p["conv_w"].shape[0]
    n = p["A_log"].shape[1]
    xz = x @ p["in_proj"].astype(x.dtype)
    x1, z = jnp.split(xz, 2, axis=-1)
    x1 = silu(_causal_conv(x1, p["conv_w"], p["conv_b"]))
    delta, b_ssm, c_ssm = _ssm_params(x1, p)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # [dI, N]

    c = min(chunk, s)
    pad = (-s) % c
    # chunk inputs stored bf16 (the scan saves them as backward residuals;
    # all recurrence math upcasts to f32 inside the chunk)
    x1h = x1.astype(jnp.bfloat16)
    dh_ = delta.astype(jnp.bfloat16)
    bh = b_ssm.astype(jnp.bfloat16)
    ch = c_ssm.astype(jnp.bfloat16)
    if pad:
        x1p = jnp.pad(x1h, ((0, 0), (0, pad), (0, 0)))
        dp = jnp.pad(dh_, ((0, 0), (0, pad), (0, 0)))
        bp = jnp.pad(bh, ((0, 0), (0, pad), (0, 0)))
        cp = jnp.pad(ch, ((0, 0), (0, pad), (0, 0)))
    else:
        x1p, dp, bp, cp = x1h, dh_, bh, ch
    nc = (s + pad) // c

    @jax.checkpoint
    def chunk_body(h, inp):
      with jax.named_scope("kernel_interior"):   # VMEM on the Pallas path
        xc, dc, bc, cc = inp        # [B, c, dI], [B, c, dI], [B,c,N], [B,c,N]
        dc = dc.astype(jnp.float32)
        a = jnp.exp(dc[..., None] * A)                          # [B,c,dI,N]
        bx = (dc * xc.astype(jnp.float32))[..., None] * \
            bc.astype(jnp.float32)[:, :, None, :]               # [B,c,dI,N]

        def comb(u, w):
            return u[0] * w[0], w[1] + w[0] * u[1]

        a_sc, b_sc = jax.lax.associative_scan(comb, (a, bx), axis=1)
        hs = b_sc + a_sc * h[:, None]                           # [B,c,dI,N]
        y = jnp.einsum("bcdn,bcn->bcd", hs, cc.astype(jnp.float32))
        return constrain(hs[:, -1], ("batch", "model", None)), y

    h0 = jnp.zeros((b, di, n), jnp.float32)
    to_chunks = lambda t: jnp.moveaxis(
        t.reshape(b, nc, c, t.shape[-1]), 1, 0)
    hT, ys = jax.lax.scan(chunk_body, h0,
                          (to_chunks(x1p), to_chunks(dp), to_chunks(bp),
                           to_chunks(cp)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * c, di)[:, :s]
    y = y + p["D_skip"] * x1
    y = y.astype(x.dtype) * silu(z)
    out = y @ p["out_proj"].astype(y.dtype)
    if return_state:
        # NOTE: padding chunks have a=exp(0·A)=1, bx=0 ⇒ they do NOT decay
        # or perturb the carry, so hT is exact for the s real positions.
        k = p["conv_w"].shape[1]
        x1_raw = jnp.split(x @ p["in_proj"].astype(x.dtype), 2, axis=-1)[0]
        pre = jnp.pad(x1_raw, ((0, 0), (k - 1, 0), (0, 0)))[:, -(k - 1):]
        return out, {"h": hT, "conv": pre.astype(jnp.float32)}
    return out


def mamba_cache_init(batch: int, p, dtype=jnp.float32):
    di, k = p["conv_w"].shape
    n = p["A_log"].shape[1]
    return {"h": jnp.zeros((batch, di, n), jnp.float32),
            "conv": jnp.zeros((batch, k - 1, di), jnp.float32)}


def mamba_decode(x, p, cache):
    """Single token: x [B, 1, D] → (y [B, 1, D], cache)."""
    b = x.shape[0]
    xz = x[:, 0] @ p["in_proj"].astype(x.dtype)
    x1, z = jnp.split(xz, 2, axis=-1)                      # [B, dI]
    conv_buf = jnp.concatenate([cache["conv"], x1[:, None]], axis=1)
    w = p["conv_w"]
    k = w.shape[1]
    x1c = jnp.einsum("bkd,dk->bd", conv_buf[:, -k:], w) + p["conv_b"]
    x1c = silu(x1c)
    delta, b_ssm, c_ssm = _ssm_params(x1c[:, None], p)
    delta, b_ssm, c_ssm = delta[:, 0], b_ssm[:, 0], c_ssm[:, 0]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(delta.astype(jnp.float32)[..., None] * A)  # [B, dI, N]
    bx = (delta * x1c).astype(jnp.float32)[..., None] * \
        b_ssm.astype(jnp.float32)[:, None, :]
    h = a * cache["h"] + bx
    y = jnp.einsum("bdn,bn->bd", h, c_ssm.astype(jnp.float32))
    y = y + p["D_skip"] * x1c
    y = y.astype(x.dtype) * silu(z)
    out = (y @ p["out_proj"].astype(y.dtype))[:, None]
    return out, {"h": h, "conv": conv_buf[:, 1:]}
