"""Sharding rules: logical parameter/activation axes → mesh axes.

Mesh: ``(data, model)`` single-pod (16×16) or ``(pod, data, model)``
multi-pod (2×16×16).  Batch shards over (pod, data); tensor-parallel dims
shard over model:

  * attention QKV out-dim and O in-dim → model (Megatron col/row split)
  * MLP hidden dim → model
  * vocab dim of embedding & lm_head → model
  * MoE expert dim → model (expert parallelism)
  * KV caches: batch → data, kv-heads → model (GSPMD pads when the head
    count does not divide the axis)

Rules are *path-based*: ``param_specs`` walks the params pytree and matches
leaf path names, so every architecture (dense / MoE / SSM / hybrid) gets
specs without per-arch plumbing.  ``zero1`` additionally shards optimizer
state over the data axis (ZeRO-1).
"""
from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["batch_axes", "param_specs", "act_spec", "cache_specs",
           "NONE_SPEC", "zero1_specs", "extend_specs", "constrain",
           "active_mesh", "set_active_mesh"]

NONE_SPEC = P()

# Ambient mesh for activation-sharding constraints inside model code.
# Set by ModelBundle during lowering; None in CPU tests (constraints no-op).
_ACTIVE_MESH: list = [None]


def set_active_mesh(mesh):
    _ACTIVE_MESH[0] = mesh


def active_mesh():
    return _ACTIVE_MESH[0]


def constrain(x, dims):
    """Pin an intermediate's sharding: ``dims`` per-axis ∈ {None, "batch",
    "model"}.  No-op without an active mesh; axes that don't divide are
    dropped.  This is how recurrent scan carries (mLSTM C, mamba h) stay
    sharded when GSPMD's fixed-point propagation gives up on loop carries.
    """
    mesh = _ACTIVE_MESH[0]
    if mesh is None:
        return x
    spec = []
    for size, d in zip(x.shape, dims):
        if d == "batch":
            ax = batch_axes(mesh)
            n = int(np.prod([mesh.shape[a] for a in ax])) if ax else 1
            spec.append(ax if n > 1 and size % n == 0 else None)
        elif d == "model" and "model" in mesh.axis_names:
            n = mesh.shape["model"]
            spec.append("model" if size % n == 0 and size >= n else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes the global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# Leaf-name patterns → (sharded_dim_from_end, description).
# Dims are indexed from the end so stacked (scan-over-layers) params with a
# leading group dim match the same rules.
_RULES = [
    (r"\bembed\b",        2, "vocab"),          # [V, D] → V on model
    (r"\blm_head\b",      1, "vocab"),          # [D, V] → V on model
    # NOTE: ordered — the experts rule must precede w_gate/w_up/w_down,
    # or expert FFN weights match the dense-FFN rules and EP never engages
    (r"\bexperts?\.",     3, "experts"),        # [E, ., .] → E on model
    (r"\bw(q|k|v)\b",     1, "heads"),          # [D, H*hd] → out on model
    (r"\bw(q|k|v)_bias\b", 1, "heads"),
    (r"\bwo\b",           2, "heads"),          # [H*hd, D] → in on model
    (r"\bw_gate\b",       1, "ffn"),            # [D, F]
    (r"\bw_up\b",         1, "ffn"),
    (r"\bw_down\b",       2, "ffn"),            # [F, D]
    (r"\brouter\b",       1, "experts"),        # [D, E]
    (r"\bin_proj\b",      1, "ssm_inner"),      # [D, 2*dI]
    (r"\bout_proj\b",     2, "ssm_inner"),      # [dI, D]
    (r"\bx_proj\b",       2, "ssm_inner"),      # [dI, R]
    (r"\bdt_proj\b",      1, "ssm_inner"),      # [R, dI] → dI on model
    (r"\bconv_w\b",       2, "ssm_inner"),      # [dI, K]
    (r"\bA_log\b",        2, "ssm_inner"),      # [dI, N]
    (r"\bD_skip\b",       1, "ssm_inner"),      # [dI]
    (r"\b(wi|wf|wo_gate)\b", 1, "heads"),       # xlstm gate projections
    (r"\bw_upA\b",        1, "ffn"),
    (r"\bw_upB\b",        1, "ffn"),
]


def _leaf_path(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        else:
            parts.append(str(p))
    return ".".join(parts)


def _spec_for(path: str, ndim: int, shape, model_size: int,
              model_axis: str = "model") -> P:
    for pat, dim_from_end, _ in _RULES:
        if re.search(pat, path):
            if ndim >= dim_from_end:
                d = ndim - dim_from_end
                if shape[d] % model_size == 0:
                    axes: list = [None] * ndim
                    axes[d] = model_axis
                    return P(*axes)
                # primary dim not divisible (e.g. 8 kv heads on a 16-way
                # axis): fall back to the largest divisible dim, else
                # replicate — pjit rejects uneven shards outright.
                order = sorted(range(ndim), key=lambda i: -shape[i])
                for d2 in order:
                    if shape[d2] % model_size == 0 and shape[d2] >= \
                            model_size:
                        axes = [None] * ndim
                        axes[d2] = model_axis
                        return P(*axes)
                return P()
    return P()   # replicated (norms, small biases, scalars)


def param_specs(params_shape, mesh: Mesh):
    """Params (or eval_shape thereof) → matching PartitionSpec pytree."""
    model_axis = "model" if "model" in mesh.axis_names else None
    model_size = mesh.shape.get("model", 1)

    def fn(path, leaf):
        if model_axis is None:
            return P()
        return _spec_for(_leaf_path(path), len(leaf.shape), leaf.shape,
                         model_size)

    return jax.tree_util.tree_map_with_path(fn, params_shape)


def act_spec(mesh: Mesh, *more_axes) -> P:
    """Activation spec: batch over (pod, data), then given axes."""
    return P(batch_axes(mesh), *more_axes)


def cache_specs(mesh: Mesh):
    """KV cache spec: [B, Hkv, S, hd] → batch on (pod,data), heads on model."""
    return P(batch_axes(mesh), "model", None, None)


def extend_specs(specs, mesh: Mesh, params_shape, axis: str = "data"):
    """Shard each leaf's largest unsharded divisible dim over ``axis``.

    Applied to optimizer moments this is **ZeRO-1**; applied to the
    parameters themselves it is **FSDP** (weights gathered per layer
    inside the step, stored 1/data-fraction per device).
    """
    size = mesh.shape.get(axis, 1)

    def fn(spec, leaf):
        if size <= 1 or not hasattr(leaf, "shape") or len(leaf.shape) == 0:
            return spec
        cur = list(spec) + [None] * (len(leaf.shape) - len(spec))
        # choose the largest dim not already sharded & divisible by axis
        order = np.argsort([-s for s in leaf.shape])
        for d in order:
            if cur[d] is None and leaf.shape[d] % size == 0 \
                    and leaf.shape[d] >= size:
                cur[d] = axis
                return P(*cur)
        return spec

    return jax.tree_util.tree_map(fn, specs, params_shape)


def zero1_specs(specs, mesh: Mesh, params_shape):
    return extend_specs(specs, mesh, params_shape, "data")
