"""Mixture-of-Experts layer (GShard-style dense dispatch, EP over model).

Top-k routing with capacity.  Tokens are reshaped to [G, S, E-agnostic]
groups with the *group axis sharded over the data mesh axes* (no lax.map —
a scanned axis cannot stay sharded under GSPMD), dispatch/combine tensors
[G, S, E, C] are built in bf16 with cumulative-position one-hots, and the
expert FFNs run as batched einsums with the expert dim sharded over
``model`` when the expert count divides it (EP; otherwise the FFN dim
shards — tensor-parallel experts).  GSPMD inserts the token all-to-alls
around the sharded-expert einsums.

Capacity C = max(k, f·S·k/E) per group: S·E·C ∝ f·k·S², so ``group_size``
bounds the dispatch tensor — 1024 keeps it ≈ S·E·C·2B ≈ 5 MB/group at
k=2, E=8.

Aux losses: load-balance (Switch) + router z-loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, silu, gelu
from .sharding import constrain, active_mesh

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, d: int, f: int, num_experts: int, dtype=jnp.float32):
    ks = jax.random.split(key, 4)

    def e_init(k, din, dout):
        return jax.vmap(lambda kk: dense_init(kk, din, dout, dtype))(
            jax.random.split(k, num_experts))

    return {
        "router": dense_init(ks[0], d, num_experts, jnp.float32),
        "experts": {
            "w_gate": e_init(ks[1], d, f),
            "w_up": e_init(ks[2], d, f),
            "w_down": e_init(ks[3], f, d),
        },
    }


def moe_apply(x, p, *, top_k: int, capacity_factor: float = 1.25,
              act: str = "silu", group_size: int = 1024
              ) -> Tuple[jnp.ndarray, dict]:
    """x [B, S, D] → (out [B, S, D], aux losses)."""
    b, s, d = x.shape
    e = p["router"].shape[1]
    act_fn = {"silu": silu, "gelu": gelu}[act]
    cdt = x.dtype                                    # compute dtype

    tokens = x.reshape(b * s, d)
    t = tokens.shape[0]
    sg = min(group_size, t)
    while t % sg:
        sg -= 1
    g = t // sg
    cap = int(max(top_k, capacity_factor * sg * top_k / e))
    tok = tokens.reshape(g, sg, d)                   # G sharded over data

    # router in mixed precision: bf16 matmul, f32 accumulation — never
    # materialize an f32 copy of the [G, S, D] token tensor
    logits = jax.lax.dot_general(
        tok.astype(cdt), p["router"].astype(cdt),
        (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)          # [G, S, E] f32 (small)

    combine = jnp.zeros((g, sg, e, cap), cdt)
    used = jnp.zeros((g, e), jnp.float32)            # capacity slots used
    gk = gates
    for _ in range(top_k):
        idx = jnp.argmax(gk, axis=-1)                          # [G, S]
        gval = jnp.take_along_axis(gk, idx[..., None], -1)[..., 0]
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)     # [G, S, E]
        pos = (jnp.cumsum(onehot, axis=1) - onehot
               + used[:, None, :])                             # [G, S, E]
        in_cap = pos < cap
        posc = jnp.clip(pos, 0, cap - 1).astype(jnp.int32)
        disp = onehot * in_cap                                 # [G, S, E]
        # the [G,S,E,C] slot one-hot is built directly in compute dtype —
        # an f32 copy here is ~2× the whole layer's activation budget
        combine = combine + ((disp * gval[..., None]).astype(cdt)[..., None]
                             * jax.nn.one_hot(posc, cap, dtype=cdt))
        used = used + disp.sum(axis=1)
        gk = gk * (1.0 - onehot)

    dispatch = (combine > 0).astype(cdt)

    # pin expert parallelism: groups over data; experts over model when E
    # divides it (EP — the token all-to-all appears exactly here), else
    # the feature dim shards (TP experts, e.g. Mixtral's 8e on 16-way).
    # GSPMD's propagation otherwise leaves [G,E,C,D] unsharded on E.
    mesh = active_mesh()
    n_model = mesh.shape.get("model", 1) if mesh is not None else 1
    ep = e % n_model == 0 and e >= n_model

    def pin(t):
        return constrain(t, ("batch", "model", None, None) if ep
                         else ("batch", None, None, "model"))

    # §Perf iteration 2: dispatch/combine are ALSO E-sharded under EP, so
    # the final combine einsum contracts local experts + all-reduces the
    # [G,S,D] output instead of all-gathering [G,E,C,D] over E (measured:
    # collective term ↓ on llama4 prefill — see EXPERIMENTS §Perf).
    def pin_sc(t):                     # [G,S,E,C]
        return constrain(t, ("batch", None, "model", None) if ep
                         else ("batch", None, None, None))

    dispatch = pin_sc(dispatch)
    combine = pin_sc(combine)
    ex_in = pin(jnp.einsum("gsec,gsd->gecd", dispatch, tok.astype(cdt)))
    we = p["experts"]
    h = act_fn(jnp.einsum("gecd,edf->gecf", ex_in,
                          we["w_gate"].astype(cdt)))
    h = pin(h * jnp.einsum("gecd,edf->gecf", ex_in,
                           we["w_up"].astype(cdt)))
    ex_out = pin(jnp.einsum("gecf,efd->gecd", h, we["w_down"].astype(cdt)))
    out = jnp.einsum("gsec,gecd->gsd", combine, ex_out)

    # aux stats (Switch LB + z-loss), averaged over groups
    me = gates.mean(axis=1)                                    # [G, E]
    ce = dispatch.astype(jnp.float32).sum(axis=(1, 3)) / sg    # [G, E]
    lb = e * jnp.sum(me * ce, axis=-1).mean() / top_k
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return out.reshape(b, s, d), {"load_balance": lb, "router_z": z}
