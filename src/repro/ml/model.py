"""Model bundle: arch config → pjit train/prefill/decode programs.

This is the layer ``launch/`` drives: it owns parameter/optimizer/cache
sharding (via ``repro.ml.sharding`` rules), the training step (chunked CE
loss, MoE aux losses, clipping, AdamW, optional ZeRO-1 / int8-EF grad
compression), and the serving steps — plus ``input_specs`` returning
ShapeDtypeStruct stand-ins for every (arch × shape) cell of the dry-run.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig
from . import sharding as sh
from .sharding import set_active_mesh
from .losses import chunked_lm_loss
from .optim import (adamw_init, adamw_update, clip_by_global_norm,
                    compress_ef, cosine_schedule, ef_init)
from .transformer import LM, MAX_LEARNED_POS

__all__ = ["ModelBundle", "input_specs"]


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = tok
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    elif shape.kind == "prefill":
        out["tokens"] = tok
    else:  # decode
        out["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    if cfg.frontend == "audio_stub" and shape.kind != "decode":
        out["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                             jnp.bfloat16)
    if cfg.mrope and shape.kind == "train":
        out["positions"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    return out


def _cache_spec_leaf(path, leaf, mesh: Mesh) -> P:
    """Per-leaf cache specs: batch → (pod,data); heads/channels → model.

    When the batch is too small for the data axes (long_500k has B=1),
    KV caches switch to *sequence-parallel* layout: the cache length
    shards over (pod, data) — context parallelism — and GSPMD reduces the
    attention softmax across the seq shards.
    """
    name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    batch = sh.batch_axes(mesh)
    n_batch = int(np.prod([mesh.shape[a] for a in batch])) if batch else 1
    n_model = mesh.shape.get("model", 1)
    nd = len(leaf.shape)
    b = leaf.shape[1] if nd >= 2 else 1
    seq_parallel = b < n_batch

    def div(i):
        return leaf.shape[i] % n_model == 0 and leaf.shape[i] >= n_model

    if name in ("k", "v", "cross_k", "cross_v"):    # [G,B,H,S,hd]
        # heads over model when the count divides (qwen1.5 kv=16);
        # otherwise shard the cache length over model (flash-decode style
        # context parallelism — GSPMD reduces the softmax across shards).
        head_ax = "model" if div(2) else None
        seq_model = None if head_ax else "model"
        if seq_parallel:
            seq = tuple(a for a in (batch if isinstance(batch, tuple)
                                    else (batch,)) if a) + \
                ((seq_model,) if seq_model else ())
            return P(None, None, head_ax, tuple(x for x in seq if x), None)
        return P(None, batch, head_ax,
                 seq_model if seq_model and div(3) else None, None)
    bspec = None if seq_parallel else batch
    if name == "h" and nd == 4:                     # mamba [G,B,dI,N]
        return P(None, bspec, "model" if div(2) else None, None)
    if name == "conv":                              # [G,B,K-1,dI]
        return P(None, bspec, None, "model" if div(3) else None)
    if name == "C":                                 # mlstm [G,B,H,dk,dv]
        return P(None, bspec, "model" if div(2) else None,
                 "model" if not div(2) and div(3) else None, None)
    if name == "n" and nd == 4:
        return P(None, bspec, "model" if div(2) else None,
                 "model" if not div(2) and div(3) else None)
    if nd >= 2:                                     # slstm scalars [G,B,D]
        return P(None, bspec)
    return P()


@dataclass
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    clip_norm: float = 1.0
    weight_decay: float = 0.1
    loss_chunk: Optional[int] = 2048
    moe_lb_weight: float = 0.01
    moe_z_weight: float = 1e-3
    zero1: bool = False
    fsdp: bool = False              # shard weights over data (gather/layer)
    param_dtype: str = "float32"    # bfloat16 = mixed precision (f32 moments)
    seq_parallel: bool = True       # shard activations' seq dim over model
    compress_grads: bool = False
    remat: str = "dots"             # none | dots | full


class ModelBundle:
    def __init__(self, cfg: ArchConfig, mesh: Mesh, *,
                 impl: str = "reference",
                 train_cfg: Optional[TrainConfig] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.train_cfg = train_cfg or TrainConfig()
        self.lm = LM(cfg, impl=impl, remat=self.train_cfg.remat,
                     mesh=mesh, seq_parallel=self.train_cfg.seq_parallel)

    # ------------------------------------------------------------ shapes
    def init_params(self, key):
        return self._cast_params(self.lm.init(key))

    def _cast_params(self, params):
        if self.train_cfg.param_dtype == "float32":
            return params
        dt = jnp.dtype(self.train_cfg.param_dtype)

        def cast(x):
            # matrices → bf16 (matmul sites cast activations to match);
            # vectors (norms, biases, A_log, …) stay f32 for stability
            return x.astype(dt) if getattr(x, "ndim", 0) >= 2 and                 x.dtype == jnp.float32 else x

        return jax.tree_util.tree_map(cast, params)

    def params_shape(self):
        return jax.eval_shape(self.init_params, jax.random.key(0))

    def param_shardings(self):
        params_shape = self.params_shape()
        specs = sh.param_specs(params_shape, self.mesh)
        if self.train_cfg.fsdp:
            specs = sh.extend_specs(specs, self.mesh, params_shape, "data")
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), specs)

    def opt_shardings(self, params_shape):
        specs = sh.param_specs(params_shape, self.mesh)
        if self.train_cfg.fsdp:
            specs = sh.extend_specs(specs, self.mesh, params_shape, "data")
        elif self.train_cfg.zero1:
            specs = sh.zero1_specs(specs, self.mesh, params_shape)
        m = jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), specs)
        return {"m": m, "v": m,
                "step": NamedSharding(self.mesh, P())}

    def cache_shardings(self, caches_shape):
        return jax.tree_util.tree_map_with_path(
            lambda p, l: NamedSharding(self.mesh,
                                       _cache_spec_leaf(p, l, self.mesh)),
            caches_shape)

    def _data_sharding(self, ndim: int, batch_dim: int = 0,
                       batch_size: Optional[int] = None):
        axes: list = [None] * ndim
        baxes = sh.batch_axes(self.mesh)
        n_batch = int(np.prod([self.mesh.shape[a] for a in baxes])) \
            if baxes else 1
        if batch_size is None or batch_size % n_batch == 0:
            axes[batch_dim] = baxes
        # else: replicate (tiny-batch decode; cache is seq-parallel instead)
        return NamedSharding(self.mesh, P(*axes))

    # ------------------------------------------------------------- train
    def make_train_step(self):
        cfg, tc, lm = self.cfg, self.train_cfg, self.lm
        lr_fn = cosine_schedule(tc.lr, tc.warmup, tc.total_steps)

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                hid, aux = lm.hidden(p, batch["tokens"],
                                     batch.get("positions"),
                                     batch.get("frames"))
                loss = chunked_lm_loss(hid, lm.head(p), batch["labels"],
                                       chunk=tc.loss_chunk)
                total = loss + tc.moe_lb_weight * aux["load_balance"] \
                    + tc.moe_z_weight * aux["router_z"]
                return total, (loss, aux)

            (total, (loss, aux)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            if tc.compress_grads:
                grads, new_err = compress_ef(grads, opt_state["ef"])
            grads, gnorm = clip_by_global_norm(grads, tc.clip_norm)
            lr = lr_fn(opt_state["adam"]["step"] + 1)   # 1-indexed schedule
            new_params, new_adam = adamw_update(
                params, grads, opt_state["adam"], lr,
                weight_decay=tc.weight_decay)
            new_opt = {"adam": new_adam}
            if tc.compress_grads:
                new_opt["ef"] = new_err
            metrics = {"loss": loss, "total_loss": total,
                       "grad_norm": gnorm, "lr": lr,
                       "moe_lb": aux["load_balance"]}
            return new_params, new_opt, metrics

        return train_step

    def init_opt_state(self, params):
        opt = {"adam": adamw_init(params)}
        if self.train_cfg.compress_grads:
            opt["ef"] = ef_init(params)
        return opt

    def lower_train(self, shape: ShapeConfig):
        set_active_mesh(self.mesh)
        """.lower() the pjit train step for a shape cell (dry-run entry)."""
        mesh = self.mesh
        params_shape = self.params_shape()
        p_shard = self.param_shardings()
        opt_shape = jax.eval_shape(self.init_opt_state, params_shape)
        o_shard = self.opt_shardings(params_shape)
        if self.train_cfg.compress_grads:
            o_shard = {"adam": o_shard,
                       "ef": self.opt_shardings(params_shape)["m"]}
        else:
            o_shard = {"adam": o_shard}
        specs = input_specs(self.cfg, shape)
        b_shard = {k: self._data_sharding(
            len(v.shape), 1 if k == "positions" else 0,
            batch_size=v.shape[1 if k == "positions" else 0])
            for k, v in specs.items()}
        step = self.make_train_step()
        with mesh:
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            return jitted.lower(params_shape, opt_shape, specs)

    # ------------------------------------------------------------- serve
    def make_prefill(self):
        lm = self.lm

        def prefill(params, batch):
            return lm.prefill(params, batch["tokens"],
                              frames=batch.get("frames"))

        return prefill

    def make_decode_step(self):
        lm = self.lm

        def serve_step(params, caches, tokens, pos):
            logits, caches = lm.decode_step(params, tokens, caches, pos)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_tok, caches

        return serve_step

    def lower_prefill(self, shape: ShapeConfig):
        set_active_mesh(self.mesh)
        mesh = self.mesh
        params_shape = self.params_shape()
        p_shard = self.param_shardings()
        specs = input_specs(self.cfg, shape)
        b_shard = {k: self._data_sharding(len(v.shape),
                                          batch_size=v.shape[0])
                   for k, v in specs.items()}
        fn = self.make_prefill()
        with mesh:
            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
            return jitted.lower(params_shape, specs)

    def lower_decode(self, shape: ShapeConfig):
        set_active_mesh(self.mesh)
        mesh = self.mesh
        cfg = self.cfg
        b = shape.global_batch
        params_shape = self.params_shape()
        p_shard = self.param_shardings()
        enc_len = shape.seq_len if cfg.encoder_layers > 0 else None
        caches_shape = jax.eval_shape(
            functools.partial(self.lm.init_caches, b, shape.seq_len,
                              enc_len=enc_len))
        c_shard = self.cache_shardings(caches_shape)
        tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        fn = self.make_decode_step()
        with mesh:
            jitted = jax.jit(
                fn,
                in_shardings=(p_shard, c_shard,
                              self._data_sharding(2, batch_size=b), None),
                out_shardings=(self._data_sharding(2, batch_size=b),
                               c_shard),
                donate_argnums=(1,))
            return jitted.lower(params_shape, caches_shape, tok,
                                jax.ShapeDtypeStruct((), jnp.int32))
