"""ML stack: model zoo, training/serving steps, WFL integration (§5)."""
from .transformer import LM, cycle_len
from .model import ModelBundle, TrainConfig, input_specs
from .integration import ColumnModel, MLPRegressor

__all__ = ["LM", "cycle_len", "ModelBundle", "TrainConfig", "input_specs",
           "ColumnModel", "MLPRegressor"]
