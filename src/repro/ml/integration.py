"""WFL ↔ JAX model integration (paper §5).

The paper exposes TensorFlow model loading/application as WFL operators so
pipelines can "run large-scale inference and annotate datasets".  Here any
JAX callable becomes a flow operator via :class:`ColumnModel`, which
adapts ``{column name: np array}`` batches to the model and is what
``Flow.model_apply`` and expression-level ``ModelApply`` call.

``SavedModel``-style persistence: ``save``/``load`` round-trip params +
feature spec through npz (the paper's SavedModel-compat surface).
"""
from __future__ import annotations

import json
import os
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ColumnModel", "MLPRegressor"]


class ColumnModel:
    """Adapter: named numpy columns → JAX model → numpy column."""

    def __init__(self, apply_fn: Callable, params, feature_order: List[str],
                 batch_size: int = 8192):
        self.apply_fn = apply_fn
        self.params = params
        self.feature_order = feature_order
        self.batch_size = batch_size
        self._jitted = jax.jit(apply_fn)

    def apply_columns(self, cols: Dict[str, np.ndarray]) -> np.ndarray:
        feats = np.stack([np.asarray(cols[f], dtype=np.float32)
                          for f in self.feature_order], axis=-1)
        outs = []
        for i in range(0, feats.shape[0], self.batch_size):
            chunk = feats[i:i + self.batch_size]
            outs.append(np.asarray(self._jitted(self.params,
                                                jnp.asarray(chunk))))
        return np.concatenate(outs) if outs else np.zeros((0,), np.float32)


class MLPRegressor:
    """Small MLP head — the paper's road-speed model stand-in (§6).

    Trained inside ``examples/ml_workflow.py`` on features extracted by a
    WFL query; applied at scale back through WFL ``model_apply``.
    """

    def __init__(self, num_features: int, hidden: int = 64, depth: int = 2,
                 seed: int = 0):
        self.num_features = num_features
        key = jax.random.key(seed)
        dims = [num_features] + [hidden] * depth + [1]
        layers = []
        for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
            key, k = jax.random.split(key)
            layers.append({
                "w": jax.random.normal(k, (a, b), jnp.float32)
                / jnp.sqrt(a),
                "b": jnp.zeros((b,), jnp.float32)})
        # feature/target standardization lives IN the params so the model
        # is self-contained through save/load and WFL application
        self.params = {"layers": layers,
                       "x_mu": jnp.zeros((num_features,), jnp.float32),
                       "x_sd": jnp.ones((num_features,), jnp.float32),
                       "y_mu": jnp.zeros((), jnp.float32),
                       "y_sd": jnp.ones((), jnp.float32)}

    @staticmethod
    def apply(params, x):
        h = (x - params["x_mu"]) / params["x_sd"]
        layers = params["layers"]
        for i, layer in enumerate(layers):
            h = h @ layer["w"] + layer["b"]
            if i < len(layers) - 1:
                h = jax.nn.relu(h)
        return h[..., 0] * params["y_sd"] + params["y_mu"]

    def train(self, feats: np.ndarray, targets: np.ndarray, *,
              steps: int = 500, lr: float = 1e-2, batch: int = 1024,
              seed: int = 0):
        x = jnp.asarray(feats, jnp.float32)
        y = jnp.asarray(targets, jnp.float32)
        self.params["x_mu"] = x.mean(axis=0)
        self.params["x_sd"] = x.std(axis=0) + 1e-6
        self.params["y_mu"] = y.mean()
        self.params["y_sd"] = y.std() + 1e-6

        def loss_fn(p, xb, yb):
            # normalized-space loss: keeps gradient scale O(1) regardless
            # of target units (raw-space loss diverges: grads ∝ y_sd²)
            pred_n = (MLPRegressor.apply(p, xb) - p["y_mu"]) / p["y_sd"]
            yn = (yb - p["y_mu"]) / p["y_sd"]
            return jnp.mean((pred_n - yn) ** 2)

        @jax.jit
        def step(p, key):
            idx = jax.random.randint(key, (min(batch, x.shape[0]),), 0,
                                     x.shape[0])
            l, g = jax.value_and_grad(loss_fn)(p, x[idx], y[idx])
            p = {**jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g),
                 "x_mu": p["x_mu"], "x_sd": p["x_sd"],
                 "y_mu": p["y_mu"], "y_sd": p["y_sd"]}
            return p, l

        key = jax.random.key(seed)
        p = self.params
        losses = []
        for _ in range(steps):
            key, k = jax.random.split(key)
            p, l = step(p, k)
            losses.append(float(l))
        self.params = p
        return losses

    def as_column_model(self, feature_order: List[str]) -> ColumnModel:
        return ColumnModel(MLPRegressor.apply, self.params, feature_order)

    # SavedModel-style persistence (§5)
    def save(self, directory: str, feature_order: List[str]) -> None:
        os.makedirs(directory, exist_ok=True)
        arrays = {"x_mu": np.asarray(self.params["x_mu"]),
                  "x_sd": np.asarray(self.params["x_sd"]),
                  "y_mu": np.asarray(self.params["y_mu"]),
                  "y_sd": np.asarray(self.params["y_sd"])}
        for i, layer in enumerate(self.params["layers"]):
            arrays[f"w{i}"] = np.asarray(layer["w"])
            arrays[f"b{i}"] = np.asarray(layer["b"])
        np.savez(os.path.join(directory, "params.npz"), **arrays)
        with open(os.path.join(directory, "model.json"), "w") as fh:
            json.dump({"features": feature_order,
                       "num_features": self.num_features}, fh)

    @staticmethod
    def load(directory: str) -> "ColumnModel":
        with open(os.path.join(directory, "model.json")) as fh:
            meta = json.load(fh)
        z = np.load(os.path.join(directory, "params.npz"))
        layers = []
        i = 0
        while f"w{i}" in z:
            layers.append({"w": jnp.asarray(z[f"w{i}"]),
                           "b": jnp.asarray(z[f"b{i}"])})
            i += 1
        params = {"layers": layers,
                  "x_mu": jnp.asarray(z["x_mu"]),
                  "x_sd": jnp.asarray(z["x_sd"]),
                  "y_mu": jnp.asarray(z["y_mu"]),
                  "y_sd": jnp.asarray(z["y_sd"])}
        return ColumnModel(MLPRegressor.apply, params, meta["features"])
