"""Optimizer stack: AdamW + global-norm clip + schedules + int8
error-feedback gradient compression (no optax — built in raw JAX).

Distributed-optimization features:
  * **ZeRO-1** — optimizer moments take the params' TP specs *plus* a
    data-axis shard on their largest free dim (``sharding.zero1_specs``);
    pjit then keeps each data shard's slice of m/v resident only once.
  * **int8 error-feedback compression** — ``compress_ef`` quantizes grads
    to int8 with a per-tensor scale, carrying the quantization error into
    the next step (error feedback keeps AdamW convergence); on a mesh,
    ``compressed_psum`` (shard_map) moves int8 over the wire (all-gather +
    local reduce) instead of fp32 all-reduce — 4× fewer collective bytes,
    visible in the §Roofline collective term.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["adamw_init", "adamw_update", "clip_by_global_norm",
           "cosine_schedule", "compress_ef", "ef_init", "compressed_psum"]


# ------------------------------------------------------------------ AdamW

def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        update = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
        if p.ndim >= 2:     # decay matrices only (norms/bias exempt)
            update = update + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m2, v2

    out = jax.tree_util.tree_map(upd, params, grads, state["m"],
                                 state["v"])
    flat, treedef = jax.tree_util.tree_flatten(out,
                                               is_leaf=lambda x:
                                               isinstance(x, tuple))
    new_p = jax.tree_util.tree_unflatten(treedef, [x[0] for x in flat])
    new_m = jax.tree_util.tree_unflatten(treedef, [x[1] for x in flat])
    new_v = jax.tree_util.tree_unflatten(treedef, [x[2] for x in flat])
    return new_p, {"m": new_m, "v": new_v, "step": step}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(np.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


# ----------------------------------------------- int8 error-feedback EF21

def ef_init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def _quant_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_ef(grads, err):
    """Quantize grads+carried error to int8; return (deq grads, new err).

    Error feedback: e' = (g + e) − deq(quant(g + e)); the residual is
    re-injected next step, preserving convergence under 4× compression.
    """
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quant_int8(x)
        deq = q.astype(jnp.float32) * scale
        return deq, x - deq

    out = jax.tree_util.tree_map(one, grads, err)
    flat, treedef = jax.tree_util.tree_flatten(
        out, is_leaf=lambda x: isinstance(x, tuple))
    deq = jax.tree_util.tree_unflatten(treedef, [x[0] for x in flat])
    new_err = jax.tree_util.tree_unflatten(treedef, [x[1] for x in flat])
    return deq, new_err


def compressed_psum(x, axis_name: str):
    """Mean over a mesh axis moving int8 on the wire (inside shard_map).

    all-gather of (int8 payload, fp32 scale) + local dequant-reduce:
    wire bytes ≈ N·R·1B vs 2·N·4B for ring all-reduce — the §Perf
    cross-pod gradient-compression lever.
    """
    q, scale = _quant_int8(x.astype(jnp.float32))
    qs = jax.lax.all_gather(q, axis_name)                 # [R, ...] int8
    ss = jax.lax.all_gather(scale, axis_name)             # [R]
    deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * x.ndim)
    return deq.mean(axis=0)
