"""Unified LM: dense / MoE / SSM / hybrid / enc-dec from one ArchConfig.

Layers are stacked into *pattern groups* and scanned: a group is one cycle
of ``block_pattern`` × ``attention_pattern`` (e.g. Gemma-3's 5 local + 1
global, Jamba's 7 mamba + 1 attn); parameters carry a leading [G] dim and
``lax.scan`` runs the G groups — one traced copy of the cycle regardless
of depth, which keeps 512-device HLO small and compile times sane.

Entry points per shape kind:
  * ``apply``       — training forward → logits [B, S, V]
  * ``encode``      — whisper encoder over frame embeddings
  * ``prefill``     — forward over a prompt, returns last-token logits +
                      filled caches (KV for attn, state for SSM)
  * ``decode_step`` — one token against caches (scan over groups carrying
                      the hidden state, caches as scan xs/ys)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import attention as A
from . import mamba as Mb
from . import xlstm as X
from .layers import (embed_init, dense_init, layer_norm, mlp_apply,
                     mlp_init, norm_init, rms_norm)
from .moe import moe_apply, moe_init

__all__ = ["LM", "cycle_len"]

MAX_LEARNED_POS = 32768


def cycle_len(cfg: ArchConfig) -> int:
    import math
    a, b = len(cfg.block_pattern), len(cfg.attention_pattern)
    return a * b // math.gcd(a, b)


def _norm(cfg):
    return rms_norm if cfg.norm == "rmsnorm" else layer_norm


def _slot_info(cfg: ArchConfig, slot: int, *, decoder: bool = True):
    kind = cfg.block_pattern[slot % len(cfg.block_pattern)]
    attn_kind = cfg.attention_pattern[slot % len(cfg.attention_pattern)]
    window = cfg.window if attn_kind == "local" else None
    spec = A.AttnSpec(cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd,
                      qkv_bias=cfg.qkv_bias, window=window,
                      softcap=cfg.logit_softcap,
                      rope_theta=cfg.rope_theta, mrope=cfg.mrope,
                      causal=decoder)
    is_moe = cfg.layer_is_moe(slot)
    return kind, spec, is_moe, window


# ---------------------------------------------------------------- init

def _block_init(key, cfg: ArchConfig, slot: int, *, cross: bool = False,
                decoder: bool = True):
    kind, spec, is_moe, _ = _slot_info(cfg, slot, decoder=decoder)
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"norm1": norm_init(cfg.d_model)}
    if cfg.norm == "layernorm":
        p["norm1"]["bias"] = jnp.zeros((cfg.d_model,))
    if kind == "attn":
        p["attn"] = A.attn_init(ks[0], spec)
    elif kind == "mamba":
        p["mamba"] = Mb.mamba_init(ks[0], cfg.d_model,
                                   expand=cfg.ssm_expand,
                                   state=cfg.ssm_state, conv=cfg.ssm_conv)
    elif kind == "mlstm":
        p["cell"] = X.mlstm_init(ks[0], cfg.d_model, cfg.num_heads)
    elif kind == "slstm":
        p["cell"] = X.slstm_init(ks[0], cfg.d_model, cfg.num_heads)
    else:
        raise ValueError(kind)
    if cross and kind == "attn":
        p["normx"] = norm_init(cfg.d_model)
        p["xattn"] = A.attn_init(ks[1], spec)
    if cfg.d_ff > 0 and kind in ("attn", "mamba"):
        p["norm2"] = norm_init(cfg.d_model)
        if cfg.norm == "layernorm":
            p["norm2"]["bias"] = jnp.zeros((cfg.d_model,))
        if is_moe:
            p["moe"] = moe_init(ks[2], cfg.d_model, cfg.d_ff,
                                cfg.moe_experts)
        else:
            p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff,
                                gated=(cfg.act == "silu"))
    return p


# ---------------------------------------------------------------- apply

def _positions_for(cfg: ArchConfig, b: int, s: int, offset=0):
    pos = jnp.arange(s, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (b, s))
    if cfg.mrope:
        return jnp.broadcast_to(pos[None], (3, b, s))
    return pos


def _project_cross_kv(enc_out, p_attn, spec):
    """Project encoder states with a block's wk/wv → [B, Hkv, Se, hd]."""
    be, se, _ = enc_out.shape
    kx = (enc_out @ p_attn["wk"].astype(enc_out.dtype)).reshape(be, se, spec.num_kv_heads,
                                          spec.head_dim).transpose(0, 2, 1, 3)
    vx = (enc_out @ p_attn["wv"].astype(enc_out.dtype)).reshape(be, se, spec.num_kv_heads,
                                          spec.head_dim).transpose(0, 2, 1, 3)
    return kx, vx


def _block_apply(cfg: ArchConfig, slot: int, x, p, positions, *,
                 enc_out=None, impl: str, decoder: bool = True,
                 return_state: bool = False):
    """Full-sequence forward for one layer.

    Returns (x, aux, extras): extras is (k, v[, cross_k, cross_v]) for attn
    layers or the final recurrent state for SSM layers (when
    ``return_state``), feeding prefill cache construction.
    """
    kind, spec, is_moe, _ = _slot_info(cfg, slot, decoder=decoder)
    nrm = _norm(cfg)
    aux = {"load_balance": jnp.zeros((), jnp.float32),
           "router_z": jnp.zeros((), jnp.float32)}
    in_dtype = x.dtype
    h = nrm(x, p["norm1"], cfg.norm_eps)
    extras = None
    if kind == "attn":
        rope_pos = positions if cfg.pos == "rope" else None
        q, k, v = A._project_qkv(h, p["attn"], spec, rope_pos)
        out = A._attention(q, k, v, causal=spec.causal, window=spec.window,
                           softcap=spec.softcap, scale=None, impl=impl)
        b_, s_ = h.shape[0], h.shape[1]
        out = out.transpose(0, 2, 1, 3).reshape(b_, s_, -1)
        x = x + out @ p["attn"]["wo"].astype(out.dtype)
        extras = {"k": k, "v": v}
        if enc_out is not None and "xattn" in p:
            hx = nrm(x, p["normx"], cfg.norm_eps)
            qx, _, _ = A._project_qkv(hx, p["xattn"], spec, None)
            kx, vx = _project_cross_kv(enc_out, p["xattn"], spec)
            xo = A._attention(qx, kx, vx, causal=False, window=None,
                              softcap=None, scale=None, impl=impl)
            xo = xo.transpose(0, 2, 1, 3).reshape(b_, s_, -1)
            x = x + xo @ p["xattn"]["wo"].astype(xo.dtype)
            extras["cross_k"] = kx
            extras["cross_v"] = vx
    elif kind == "mamba":
        if return_state:
            y, extras = Mb.mamba_apply(h, p["mamba"], return_state=True)
        else:
            y = Mb.mamba_apply(h, p["mamba"])
        x = x + y
    elif kind == "mlstm":
        if return_state:
            y, extras = X.mlstm_apply(h, p["cell"], cfg.num_heads,
                                      return_state=True)
        else:
            y = X.mlstm_apply(h, p["cell"], cfg.num_heads)
        x = x + y
    elif kind == "slstm":
        if return_state:
            y, extras = X.slstm_apply(h, p["cell"], cfg.num_heads,
                                      return_state=True)
        else:
            y = X.slstm_apply(h, p["cell"], cfg.num_heads)
        x = x + y
    if "mlp" in p or "moe" in p:
        h2 = nrm(x, p["norm2"], cfg.norm_eps)
        if "moe" in p:
            mo, a = moe_apply(h2, p["moe"], top_k=cfg.moe_top_k,
                              capacity_factor=cfg.moe_capacity_factor,
                              act=cfg.act,
                              group_size=cfg.moe_group_size)
            aux = a
            x = x + mo
        else:
            x = x + mlp_apply(h2, p["mlp"], cfg.act)
    return x.astype(in_dtype), aux, extras


def _block_decode(cfg: ArchConfig, slot: int, x, p, cache, pos, *,
                  enc_out=None):
    """Single-token step; returns (x, new_cache)."""
    kind, spec, is_moe, window = _slot_info(cfg, slot)
    nrm = _norm(cfg)
    in_dtype = x.dtype
    h = nrm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        b = x.shape[0]
        rolling = window is not None
        if cfg.pos == "rope":
            positions = jnp.broadcast_to(
                jnp.asarray(pos, jnp.int32)[None, None], (x.shape[0], 1))
            if cfg.mrope:
                positions = jnp.broadcast_to(positions[None], (3, b, 1))
        else:
            positions = None
        q, k, v = A._project_qkv(h, p["attn"], spec, positions)
        smax = cache["k"].shape[2]
        slot_pos = (pos % smax) if rolling else pos
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, slot_pos, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, slot_pos, 0))
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = ck, cv
        out = A.decode_attention(
            q, {"k": ck, "v": cv, "len": jnp.asarray(pos + 1, jnp.int32)},
            window=window, softcap=spec.softcap, rolling=rolling)
        out = out.transpose(0, 2, 1, 3).reshape(x.shape[0], 1, -1)
        x = x + out @ p["attn"]["wo"].astype(out.dtype)
        if "cross_k" in cache and "xattn" in p:
            hx = nrm(x, p["normx"], cfg.norm_eps)
            qx, _, _ = A._project_qkv(hx, p["xattn"], spec, None)
            xo = A.decode_attention(
                qx, {"k": cache["cross_k"], "v": cache["cross_v"],
                     "len": jnp.asarray(cache["cross_k"].shape[2],
                                        jnp.int32)})
            xo = xo.transpose(0, 2, 1, 3).reshape(x.shape[0], 1, -1)
            x = x + xo @ p["xattn"]["wo"].astype(xo.dtype)
        cache = new_cache
    elif kind == "mamba":
        y, cache = Mb.mamba_decode(h, p["mamba"], cache)
        x = x + y
    elif kind == "mlstm":
        y, cache = X.mlstm_decode(h, p["cell"], cfg.num_heads, cache)
        x = x + y
    elif kind == "slstm":
        y, cache = X.slstm_decode(h, p["cell"], cfg.num_heads, cache)
        x = x + y
    if "mlp" in p or "moe" in p:
        h2 = nrm(x, p["norm2"], cfg.norm_eps)
        if "moe" in p:
            mo, _ = moe_apply(h2, p["moe"], top_k=cfg.moe_top_k,
                              capacity_factor=cfg.moe_capacity_factor,
                              act=cfg.act,
                              group_size=cfg.moe_group_size)
            x = x + mo
        else:
            x = x + mlp_apply(h2, p["mlp"], cfg.act)
    return x.astype(in_dtype), cache


# ---------------------------------------------------------------- model

class LM:
    def __init__(self, cfg: ArchConfig, *, impl: str = "reference",
                 remat: str = "none", mesh=None, seq_parallel: bool = True):
        self.cfg = cfg
        self.impl = impl
        self.remat = remat
        self.mesh = mesh            # enables activation sharding constraints
        self.seq_parallel = seq_parallel
        self.cyc = cycle_len(cfg)
        assert cfg.num_layers % self.cyc == 0, \
            f"{cfg.name}: layers {cfg.num_layers} not divisible by " \
            f"pattern cycle {self.cyc}"
        self.groups = cfg.num_layers // self.cyc
        self.enc_groups = cfg.encoder_layers  # encoder: uniform layers

    # ------------------------------------------------------------- init
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        p: Dict[str, Any] = {"embed": embed_init(ks[0], cfg.vocab_size,
                                                 cfg.d_model)}
        if cfg.pos == "learned":
            p["pos_embed"] = (jax.random.normal(
                ks[1], (MAX_LEARNED_POS, cfg.d_model), jnp.float32)
                * 0.02)
        cross = cfg.encoder_layers > 0

        def stack_group(key, init_one):
            keys = jax.random.split(key, self.groups)
            return jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs),
                *[init_one(k) for k in keys])

        p["blocks"] = {}
        for s in range(self.cyc):
            p["blocks"][f"slot{s}"] = stack_group(
                jax.random.fold_in(ks[2], s),
                lambda k, s=s: _block_init(k, cfg, s, cross=cross))
        if cfg.encoder_layers > 0:
            enc_cfg = cfg
            keys = jax.random.split(ks[3], cfg.encoder_layers)
            p["enc_blocks"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs),
                *[_block_init(k, enc_cfg, 0, decoder=False) for k in keys])
            p["enc_norm"] = norm_init(cfg.d_model)
            p["enc_in"] = dense_init(ks[4], cfg.d_model, cfg.d_model)
        p["final_norm"] = norm_init(cfg.d_model)
        if cfg.norm == "layernorm":
            p["final_norm"]["bias"] = jnp.zeros((cfg.d_model,))
            if "enc_norm" in p:
                p["enc_norm"]["bias"] = jnp.zeros((cfg.d_model,))
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(ks[5], cfg.d_model, cfg.vocab_size)
        return p

    # --------------------------------------------------------- helpers
    def _embed(self, p, tokens, positions):
        cfg = self.cfg
        adt = jnp.dtype(cfg.act_dtype)
        x = jnp.take(p["embed"], tokens, axis=0).astype(adt)
        if cfg.pos == "learned":
            pos = positions if positions.ndim == 2 else positions[0]
            x = x + jnp.take(p["pos_embed"], pos, axis=0).astype(adt)
        return x

    def _logits(self, p, x):
        cfg = self.cfg
        head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
        return jax.lax.dot_general(
            x, head.astype(x.dtype), (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    def _seq_constraint(self, x):
        """Sequence-parallel activation constraint (MaxText-style).

        Between blocks, activations shard [batch → (pod,data), seq →
        model]; the layer-scan's saved carries then occupy 1/model of the
        memory, at the cost of per-layer seq all-gather/reduce-scatter —
        the classic sequence-parallelism trade, measured in §Perf.
        """
        if self.mesh is None or "model" not in self.mesh.axis_names \
                or not self.seq_parallel:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        from .sharding import batch_axes
        b, s, _ = x.shape
        n_model = self.mesh.shape["model"]
        baxes = batch_axes(self.mesh)
        n_b = 1
        for a in baxes:
            n_b *= self.mesh.shape[a]
        bspec = baxes if b % n_b == 0 else None
        sspec = "model" if s % n_model == 0 and s >= n_model else None
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(bspec, sspec, None)))

    def _scan_blocks(self, p, x, positions, enc_out=None):
        cfg = self.cfg
        impl = self.impl

        remat = self.remat

        def body(carry, grp):
            x = carry
            aux_tot = jnp.zeros((2,), jnp.float32)
            for s in range(self.cyc):
                def one(x, gp, s=s):
                    y, aux, _ = _block_apply(cfg, s, x, gp, positions,
                                             enc_out=enc_out, impl=impl)
                    return y, (aux["load_balance"], aux["router_z"])

                if remat != "none":
                    # nested remat: during the group's backward recompute
                    # only ONE layer's internals are ever live
                    one = jax.checkpoint(
                        one, policy=jax.checkpoint_policies.dots_saveable
                        if remat == "dots" else
                        jax.checkpoint_policies.nothing_saveable)
                x, (lb, rz) = one(x, grp[f"slot{s}"])
                aux_tot = aux_tot + jnp.stack([lb, rz])
            return self._seq_constraint(x), aux_tot

        if self.remat != "none":
            policy = (jax.checkpoint_policies.dots_saveable
                      if self.remat == "dots" else
                      jax.checkpoint_policies.nothing_saveable)
            body = jax.checkpoint(body, policy=policy)
        x, auxs = jax.lax.scan(body, x, p["blocks"])
        return x, auxs.sum(axis=0)

    # ------------------------------------------------------------ apply
    def hidden(self, p, tokens, positions=None, frames=None):
        """Training forward up to the final norm → (hidden, aux dict)."""
        cfg = self.cfg
        b, s = tokens.shape
        positions = (_positions_for(cfg, b, s) if positions is None
                     else positions)
        x = self._embed(p, tokens, positions)
        enc_out = None
        if cfg.encoder_layers > 0:
            if frames is None:
                raise ValueError(f"{cfg.name} needs frame embeddings")
            enc_out = self.encode(p, frames)
        x, aux2 = self._scan_blocks(p, x, positions, enc_out=enc_out)
        x = _norm(cfg)(x, p["final_norm"], cfg.norm_eps)
        return x, {"load_balance": aux2[0], "router_z": aux2[1]}

    def head(self, p):
        return p["embed"].T if self.cfg.tie_embeddings else p["lm_head"]

    def apply(self, p, tokens, positions=None, frames=None):
        """Training forward → (logits [B,S,V] fp32, aux dict)."""
        x, aux = self.hidden(p, tokens, positions, frames)
        return self._logits(p, x), aux

    def encode(self, p, frames):
        """Whisper encoder over precomputed frame embeddings [B, S, D]."""
        cfg = self.cfg
        adt = jnp.dtype(cfg.act_dtype)
        x = (frames.astype(adt) @ p["enc_in"].astype(adt))
        b, s, _ = x.shape
        positions = _positions_for(cfg, b, s)
        if cfg.pos == "learned":
            x = x + jnp.take(p["pos_embed"], positions, axis=0
                             ).astype(jnp.bfloat16)

        def body(x, lp):
            x, _, _ = _block_apply(cfg, 0, x, lp, positions,
                                   impl=self.impl, decoder=False)
            return x, None

        x, _ = jax.lax.scan(body, x, p["enc_blocks"])
        return layer_norm(x, p["enc_norm"], cfg.norm_eps) \
            if cfg.norm == "layernorm" else rms_norm(x, p["enc_norm"],
                                                     cfg.norm_eps)

    # ---------------------------------------------------------- serving
    def init_caches(self, batch: int, max_len: int,
                    enc_len: Optional[int] = None):
        """Stacked per-slot caches [G, ...] matching the block scan."""
        cfg = self.cfg
        caches = {}
        for s in range(self.cyc):
            kind, spec, _, window = _slot_info(cfg, s)
            if kind == "attn":
                size = min(window, max_len) if window else max_len
                c = {"k": jnp.zeros((batch, cfg.num_kv_heads, size,
                                     cfg.hd), jnp.bfloat16),
                     "v": jnp.zeros((batch, cfg.num_kv_heads, size,
                                     cfg.hd), jnp.bfloat16)}
                if cfg.encoder_layers > 0:
                    el = enc_len or max_len
                    c["cross_k"] = jnp.zeros((batch, cfg.num_kv_heads, el,
                                              cfg.hd), jnp.bfloat16)
                    c["cross_v"] = jnp.zeros((batch, cfg.num_kv_heads, el,
                                              cfg.hd), jnp.bfloat16)
            elif kind == "mamba":
                di = cfg.ssm_expand * cfg.d_model
                c = {"h": jnp.zeros((batch, di, cfg.ssm_state),
                                    jnp.float32),
                     "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di),
                                       jnp.float32)}
            elif kind == "mlstm":
                c = X.mlstm_cache_init(batch, cfg.d_model, cfg.num_heads)
            elif kind == "slstm":
                c = X.slstm_cache_init(batch, cfg.d_model)
            caches[f"slot{s}"] = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (self.groups,) + x.shape),
                c)
        return caches

    def decode_step(self, p, tokens, caches, pos):
        """tokens [B, 1], caches (stacked), pos scalar → (logits, caches)."""
        cfg = self.cfg
        b = tokens.shape[0]
        positions = _positions_for(cfg, b, 1, offset=pos)
        x = self._embed(p, tokens, positions)

        def body(x, inp):
            grp, gcache = inp
            new_c = {}
            for s in range(self.cyc):
                x, c = _block_decode(cfg, s, x, grp[f"slot{s}"],
                                     gcache[f"slot{s}"], pos)
                new_c[f"slot{s}"] = c
            return x, new_c

        x, new_caches = jax.lax.scan(body, x, (p["blocks"], caches))
        x = _norm(cfg)(x, p["final_norm"], cfg.norm_eps)
        return self._logits(p, x[:, -1:, :]), new_caches

    def prefill(self, p, tokens, frames=None):
        """Prompt forward → (last-token logits, filled caches)."""
        cfg = self.cfg
        b, s = tokens.shape
        positions = _positions_for(cfg, b, s)
        x = self._embed(p, tokens, positions)
        enc_out = None
        if cfg.encoder_layers > 0:
            enc_out = self.encode(p, frames)
        caches = {}

        def body(carry, grp):
            x = carry
            extras_out = {}
            for sl in range(self.cyc):
                x, _, extras = _block_apply(
                    cfg, sl, x, grp[f"slot{sl}"], positions,
                    enc_out=enc_out, impl=self.impl, return_state=True)
                extras_out[f"slot{sl}"] = extras
            return x, extras_out

        x, extras = jax.lax.scan(body, x, p["blocks"])
        x = _norm(cfg)(x, p["final_norm"], cfg.norm_eps)
        logits = self._logits(p, x[:, -1:, :])
        caches = self._caches_from_prefill(extras, s, b, enc_out)
        return logits, caches

    def _caches_from_prefill(self, extras, s, b, enc_out,
                             decode_budget: int = 1024):
        """extras [G-stacked per slot] → decode caches.

        Rolling (windowed) caches are laid out so that slot == abs_pos %
        window, matching the modulo writes of ``decode_step``.
        """
        cfg = self.cfg
        caches = self.init_caches(b, max_len=s + decode_budget,
                                  enc_len=enc_out.shape[1]
                                  if enc_out is not None else None)
        for sl in range(self.cyc):
            key = f"slot{sl}"
            kind = cfg.layer_kind(sl)
            ex = extras[key]
            if kind == "attn":
                k, v = ex["k"], ex["v"]          # [G, B, Hq?, S, hd]
                window = _slot_info(cfg, sl)[3]
                if window and s >= window:
                    k = k[..., s - window:s, :]
                    v = v[..., s - window:s, :]
                    shift = s % window
                    k = jnp.roll(k, shift, axis=-2)
                    v = jnp.roll(v, shift, axis=-2)
                caches[key]["k"] = jax.lax.dynamic_update_slice(
                    caches[key]["k"], k.astype(jnp.bfloat16),
                    (0, 0, 0, 0, 0))
                caches[key]["v"] = jax.lax.dynamic_update_slice(
                    caches[key]["v"], v.astype(jnp.bfloat16),
                    (0, 0, 0, 0, 0))
                if "cross_k" in ex:
                    caches[key]["cross_k"] = ex["cross_k"].astype(
                        jnp.bfloat16)
                    caches[key]["cross_v"] = ex["cross_v"].astype(
                        jnp.bfloat16)
            elif ex is not None:
                caches[key].update(ex)
        return caches
