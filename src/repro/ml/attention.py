"""Attention blocks: GQA with RoPE/M-RoPE, SWA, local:global, softcap.

Three execution paths share one semantic definition:

  * ``kernels.ops.flash_attention`` — the Pallas TPU kernel (training /
    prefill on the real target),
  * :func:`chunked_attention` — a pure-jnp *flash-structured* fallback
    (lax.scan over KV blocks, online softmax) whose memory is O(S·block)
    instead of O(S²); this is what the 512-device dry-run lowers for long
    contexts, keeping memory_analysis honest,
  * :func:`decode_attention` — single-token attention against a KV cache
    (optionally a rolling window cache).

KV caches: dict(k, v [B, Hkv, Smax, hd], len scalar int32).  Rolling caches
(SWA / local layers) store only ``window`` positions and are written
modulo-window; absolute positions are reconstructed for RoPE and masking.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .layers import dense_init, mrope, rope
from .sharding import active_mesh, constrain

__all__ = ["attn_init", "attn_apply", "chunked_attention",
           "decode_attention", "init_cache", "AttnSpec"]


# --------------------------------------------------------------------------
# Pure-jnp chunked flash attention (compile-time memory ∝ S·block)
# --------------------------------------------------------------------------

def chunked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      scale: Optional[float] = None,
                      block_k: int = 1024):
    """q [B,Hq,Sq,D], k/v [B,Hkv,Skv,D] → [B,Hq,Sq,D], online softmax."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    bk = min(block_k, skv)
    nblk = (skv + bk - 1) // bk
    pad = nblk * bk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(b, hkv, nblk, bk, d)
    vb = v.reshape(b, hkv, nblk, bk, d)
    # grouped GQA layout [B, Hkv, g, Sq, D]: K/V contract directly against
    # their query group — no repeat, no f32 cache copy (§Perf iteration)
    qg = q.reshape(b, hkv, group, sq, d)
    # §Perf iteration: pin q and the online-softmax carry. GSPMD leaves
    # scan carries replicated, which forced a full-accumulator all-reduce
    # per KV chunk (measured 2 TiB/device on llama4 prefill). Heads shard
    # over model when divisible; otherwise the query sequence does.
    mesh = active_mesh()
    n_model = mesh.shape.get("model", 1) if mesh is not None else 1
    if hkv % n_model == 0 and hkv >= n_model:
        _pin = ("batch", "model", None, None, None)
    elif sq % n_model == 0 and sq >= n_model:
        _pin = ("batch", None, None, "model", None)
    else:
        _pin = ("batch", None, None, None, None)
    qg = constrain(qg, _pin)
    q_pos = jnp.arange(sq) + (skv - sq)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(carry, inp):
        # checkpointed: backward recomputes the [.., bq, bk] probabilities
        # per block instead of saving them — keeps training memory at
        # O(S·block), the same contract as the Pallas flash kernel.
        # named_scope marks the kernel-interior ops: everything inside
        # stays in VMEM on the Pallas TPU path, and the roofline analyzer
        # buckets these bytes separately (flash_interior).
        with jax.named_scope("flash_interior"):
            m, l, acc = carry
            kc, vc, ki = inp                  # [B,Hkv,bk,D], ..., scalar
            logits = jax.lax.dot_general(
                qg.astype(kc.dtype), kc,
                (((4,), (3,)), ((0, 1), (0, 1))),
                preferred_element_type=jnp.float32) * scale  # [B,Hkv,g,Sq,bk]
            if softcap is not None:
                logits = softcap * jnp.tanh(logits / softcap)
            k_pos = ki * bk + jnp.arange(bk)
            mask = k_pos[None, :] < skv
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            if window is not None:
                mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(axis=-1, keepdims=True))
            p = jnp.exp(logits - m_new)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1, keepdims=True)
            pv = jax.lax.dot_general(
                p.astype(vc.dtype), vc,
                (((4,), (2,)), ((0, 1), (0, 1))),
                preferred_element_type=jnp.float32)          # [B,Hkv,g,Sq,D]
            acc_new = acc * corr + pv
            return (constrain(m_new, _pin), constrain(l_new, _pin),
                    constrain(acc_new, _pin)), None

    m0 = constrain(jnp.full((b, hkv, group, sq, 1), -1e30, jnp.float32),
                   _pin)
    l0 = constrain(jnp.zeros((b, hkv, group, sq, 1), jnp.float32), _pin)
    a0 = constrain(jnp.zeros((b, hkv, group, sq, d), jnp.float32), _pin)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0),
         jnp.arange(nblk)))
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def _attention(q, k, v, *, causal, window, softcap, scale, impl):
    if impl in ("pallas", "interpret"):
        return kops.flash_attention(q, k, v, causal=causal, window=window,
                                    softcap=softcap, scale=scale, impl=impl)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale)


# --------------------------------------------------------------------------
# Decode against KV cache
# --------------------------------------------------------------------------

def init_cache(batch: int, num_kv_heads: int, max_len: int, head_dim: int,
               dtype=jnp.bfloat16):
    return {"k": jnp.zeros((batch, num_kv_heads, max_len, head_dim), dtype),
            "v": jnp.zeros((batch, num_kv_heads, max_len, head_dim), dtype),
            "len": jnp.zeros((), jnp.int32)}


def decode_attention(q, cache, *, window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     rolling: bool = False):
    """q [B,Hq,1,D] vs cache (already containing the current token).

    GQA without materializing repeated K/V: q reshapes to
    [B, Hkv, group, D] and contracts the *raw* bf16 cache with f32
    accumulation — §Perf iteration: the old ``repeat``+f32-cast path
    copied the whole cache ×group×2 per step (measured 24× HBM blowup on
    command-r decode); this formulation is what a flash-decode kernel
    streams in VMEM.
    """
    b, hq, _, d = q.shape
    k, v = cache["k"], cache["v"]
    _, hkv, smax, _ = k.shape
    group = hq // hkv
    scale = 1.0 / np.sqrt(d)
    qg = q[:, :, 0, :].reshape(b, hkv, group, d)
    logits = jax.lax.dot_general(
        qg.astype(k.dtype), k, (((3,), (3,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32) * scale    # [B,Hkv,g,S]
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    kpos = jnp.arange(smax)
    if rolling:
        valid = kpos[None, :] < jnp.minimum(cache["len"], smax)
    else:
        valid = kpos[None, :] < cache["len"]
        if window is not None:
            valid = valid & (kpos[None, :] >= cache["len"] - window)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jax.lax.dot_general(
        p.astype(v.dtype), v, (((3,), (2,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32)            # [B,Hkv,g,D]
    return out.reshape(b, hq, 1, d).astype(q.dtype)


def cache_update(cache, k_new, v_new, *, rolling: bool = False):
    """Append one position (k/v [B,Hkv,1,hd]) at cache['len'] (mod window
    when rolling)."""
    smax = cache["k"].shape[2]
    pos = cache["len"] % smax if rolling else cache["len"]
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(
        cache["k"].dtype), (0, 0, pos, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(
        cache["v"].dtype), (0, 0, pos, 0))
    return {"k": k, "v": v, "len": cache["len"] + 1}


# --------------------------------------------------------------------------
# Full GQA block
# --------------------------------------------------------------------------

class AttnSpec:
    """Static attention configuration for one layer."""

    def __init__(self, d_model: int, num_heads: int, num_kv_heads: int,
                 head_dim: int, *, qkv_bias=False, window=None,
                 softcap=None, rope_theta=10000.0, mrope=False,
                 causal=True, query_scale: Optional[float] = None):
        self.d_model = d_model
        self.num_heads = num_heads
        self.num_kv_heads = num_kv_heads
        self.head_dim = head_dim
        self.qkv_bias = qkv_bias
        self.window = window
        self.softcap = softcap
        self.rope_theta = rope_theta
        self.mrope = mrope
        self.causal = causal
        self.query_scale = query_scale


def attn_init(key, spec: AttnSpec, dtype=jnp.float32):
    d, h, hkv, hd = (spec.d_model, spec.num_heads, spec.num_kv_heads,
                     spec.head_dim)
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, h * hd, dtype),
         "wk": dense_init(ks[1], d, hkv * hd, dtype),
         "wv": dense_init(ks[2], d, hkv * hd, dtype),
         "wo": dense_init(ks[3], h * hd, d, dtype)}
    if spec.qkv_bias:
        p["wq_bias"] = jnp.zeros((h * hd,), dtype)
        p["wk_bias"] = jnp.zeros((hkv * hd,), dtype)
        p["wv_bias"] = jnp.zeros((hkv * hd,), dtype)
    return p


def _project_qkv(x, p, spec: AttnSpec, positions):
    b, s, _ = x.shape
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if spec.qkv_bias:
        q = q + p["wq_bias"].astype(x.dtype)
        k = k + p["wk_bias"].astype(x.dtype)
        v = v + p["wv_bias"].astype(x.dtype)
    q = q.reshape(b, s, spec.num_heads, spec.head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, spec.num_kv_heads, spec.head_dim
                  ).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, spec.num_kv_heads, spec.head_dim
                  ).transpose(0, 2, 1, 3)
    if positions is not None:
        if spec.mrope:
            q = mrope(q, positions, spec.rope_theta)
            k = mrope(k, positions, spec.rope_theta)
        else:
            q = rope(q, positions, spec.rope_theta)
            k = rope(k, positions, spec.rope_theta)
    return q, k, v


def attn_apply(x, p, spec: AttnSpec, positions, *,
               kv: Optional[Tuple] = None,           # cross-attention K/V src
               cache: Optional[dict] = None, rolling: bool = False,
               impl: str = "reference"):
    """Returns (out [B,S,D], updated cache or None).

    Training/prefill: cache None → full attention over x (or ``kv`` for
    cross-attention).  Decode: S==1 with a cache → append + attend.
    """
    b, s, _ = x.shape
    q, k, v = _project_qkv(x, p, spec, positions)
    if kv is not None:                       # cross-attention (enc-dec)
        k, v = kv
    if cache is not None:
        cache = cache_update(cache, k, v, rolling=rolling)
        out = decode_attention(q, cache, window=spec.window,
                               softcap=spec.softcap, rolling=rolling)
    else:
        out = _attention(q, k, v, causal=spec.causal, window=spec.window,
                         softcap=spec.softcap, scale=spec.query_scale,
                         impl=impl)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return out @ p["wo"], cache
