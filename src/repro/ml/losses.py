"""Losses: cross-entropy with optional sequence-chunked logits.

For large-vocab models the [B, S, V] logits tensor dominates activation
memory (gemma3 train_4k: 1M tokens × 262k vocab ≈ 1 TB fp32 global).  The
chunked path never materializes it: a scan over sequence chunks computes
``hidden_chunk @ head`` → softmax-CE → scalar, keeping live memory at
B·chunk·V.  This is one of the §Perf memory levers.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["cross_entropy", "chunked_lm_loss"]


def cross_entropy(logits, labels, mask=None):
    """logits [..., V] fp32, labels [...] int — mean NLL over mask."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(nll.dtype)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def chunked_lm_loss(hidden, head, labels, mask=None,
                    chunk: Optional[int] = None):
    """hidden [B, S, D] (any dtype), head [D, V] → mean NLL.

    ``chunk=None`` materializes full logits (small models); otherwise a
    scan over ⌈S/chunk⌉ chunks bounds live logits memory.
    """
    b, s, d = hidden.shape
    headc = head.astype(hidden.dtype)
    if chunk is None or chunk >= s:
        logits = jax.lax.dot_general(
            hidden, headc, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return cross_entropy(logits, labels, mask)
    c = chunk
    pad = (-s) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        m = jnp.pad(mask if mask is not None
                    else jnp.ones((b, s), jnp.float32),
                    ((0, 0), (0, pad)))
    else:
        m = mask if mask is not None else jnp.ones((b, s), jnp.float32)
    nc = (s + pad) // c
    hs = jnp.moveaxis(hidden.reshape(b, nc, c, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)
    ms = jnp.moveaxis(m.reshape(b, nc, c), 1, 0)

    @jax.checkpoint
    def body(acc, inp):
        # checkpointed: the [B, chunk, V] logits recompute in backward
        # instead of being saved per chunk
        h, l, mm = inp
        logits = jax.lax.dot_general(
            h, headc, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mm.astype(jnp.float32)
        return (acc[0] + nll.sum(), acc[1] + mm.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
