"""Advanced analysis structures (paper §4.2.2).

WFL "provides advanced structures such as HyperLogLog sketches for
cardinality estimation of big data, Bloom filters for membership tests, and
interval trees for windowing queries."  All three are mergeable across
shards, which is what makes them usable as distributed aggregates: servers
build partials, the Mixer merges.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field as dc_field
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = ["splitmix64", "hash_values", "hll_register_rows", "HyperLogLog",
           "BloomFilter", "IntervalSet"]

_U = np.uint64


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 — the workhorse 64-bit mixer."""
    x = np.asarray(x).astype(np.uint64)
    x = (x + _U(0x9E3779B97F4A7C15))
    x = (x ^ (x >> _U(30))) * _U(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> _U(27))) * _U(0x94D049BB133111EB)
    return x ^ (x >> _U(31))


def hash_values(values, vocab: Optional[Sequence[str]] = None) -> np.ndarray:
    """64-bit hashes for a column: ints are mixed; string codes hash their
    vocab entry (stable across shards, unlike per-shard codes)."""
    values = np.asarray(values)
    if vocab is not None:
        vh = np.array([int.from_bytes(
            hashlib.blake2b(s.encode(), digest_size=8).digest(), "little")
            for s in vocab], dtype=np.uint64)
        return vh[values]
    if values.dtype.kind == "f":
        values = values.view(np.uint64 if values.dtype.itemsize == 8
                             else np.uint32)
    return splitmix64(values)


# --------------------------------------------------------------------------
# HyperLogLog (Flajolet et al. 2007), dense registers, mergeable.
# --------------------------------------------------------------------------

def hll_register_rows(h: np.ndarray, p: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-hash HLL register updates: 64-bit hashes → (register index
    [n] int64, rank [n] uint8).  A sketch is the per-register **max** of
    these rows (zero = empty register), which is what lets grouped sketch
    building run as one segment-max through the execution backend —
    commutative and idempotent, hence partition- and order-invariant."""
    h = np.asarray(h, dtype=np.uint64)
    idx = (h >> _U(64 - p)).astype(np.int64)
    rest = (h << _U(p)) | _U((1 << p) - 1)
    # rank = leading zeros of the remaining 64-p bits, +1
    lz = np.zeros(h.shape, dtype=np.uint8)
    cur = rest.copy()
    for shift in (32, 16, 8, 4, 2, 1):
        mask = cur < (_U(1) << _U(64 - shift))
        lz = np.where(mask, lz + shift, lz)
        cur = np.where(mask, cur << _U(shift), cur)
    rank = np.minimum(lz + 1, 64 - p + 1).astype(np.uint8)
    return idx, rank


@dataclass
class HyperLogLog:
    p: int = 12
    registers: np.ndarray = None  # uint8 [2^p]

    def __post_init__(self):
        if self.registers is None:
            self.registers = np.zeros(1 << self.p, dtype=np.uint8)

    def add_hashes(self, h: np.ndarray) -> "HyperLogLog":
        idx, rank = hll_register_rows(h, self.p)
        np.maximum.at(self.registers, idx, rank)
        return self

    def add(self, values, vocab=None) -> "HyperLogLog":
        return self.add_hashes(hash_values(values, vocab))

    def merge(self, other: "HyperLogLog") -> "HyperLogLog":
        assert self.p == other.p
        np.maximum(self.registers, other.registers, out=self.registers)
        return self

    def estimate(self) -> float:
        m = float(1 << self.p)
        alpha = 0.7213 / (1.0 + 1.079 / m)
        inv = np.ldexp(1.0, -self.registers.astype(np.int64))
        e = alpha * m * m / inv.sum()
        zeros = int((self.registers == 0).sum())
        if e <= 2.5 * m and zeros:
            return m * np.log(m / zeros)     # linear counting
        return float(e)


# --------------------------------------------------------------------------
# Bloom filter (Bloom 1970), double hashing, mergeable.
# --------------------------------------------------------------------------

@dataclass
class BloomFilter:
    num_bits: int = 1 << 16
    num_hashes: int = 5
    bits: np.ndarray = None    # uint32 words

    def __post_init__(self):
        if self.bits is None:
            self.bits = np.zeros((self.num_bits + 31) // 32, dtype=np.uint32)

    def _positions(self, h: np.ndarray) -> np.ndarray:
        h1 = h & _U(0xFFFFFFFF)
        h2 = h >> _U(32)
        ks = np.arange(self.num_hashes, dtype=np.uint64)
        return ((h1[:, None] + ks[None, :] * h2[:, None])
                % _U(self.num_bits)).astype(np.int64)

    def add(self, values, vocab=None) -> "BloomFilter":
        pos = self._positions(hash_values(values, vocab)).ravel()
        np.bitwise_or.at(self.bits, pos >> 5,
                         np.uint32(1) << (pos & 31).astype(np.uint32))
        return self

    def contains(self, values, vocab=None) -> np.ndarray:
        pos = self._positions(hash_values(values, vocab))
        word = self.bits[pos >> 5]
        bit = (word >> (pos & 31).astype(np.uint32)) & np.uint32(1)
        return bit.astype(bool).all(axis=1)

    def merge(self, other: "BloomFilter") -> "BloomFilter":
        assert self.num_bits == other.num_bits
        np.bitwise_or(self.bits, other.bits, out=self.bits)
        return self


# --------------------------------------------------------------------------
# Interval set for windowing queries (CLRS interval trees, vectorized form).
# --------------------------------------------------------------------------

class IntervalSet:
    """Static interval collection with stabbing/overlap queries.

    Stored sorted by start with an augmented running-max of ends — the flat
    (cache-friendly) equivalent of a CLRS interval tree.  ``overlapping``
    returns, for each query window, whether any interval overlaps it;
    ``count_overlaps`` returns how many (via offset counting:
    #overlaps = #starts ≤ q_end − #ends < q_start).
    """

    def __init__(self, starts, ends):
        starts = np.asarray(starts, dtype=np.float64)
        ends = np.asarray(ends, dtype=np.float64)
        if np.any(ends < starts):
            raise ValueError("interval with end < start")
        order = np.argsort(starts, kind="stable")
        self.starts = starts[order]
        self.ends = ends[order]
        self.sorted_ends = np.sort(ends)
        self.max_end_prefix = (np.maximum.accumulate(self.ends)
                               if len(self.ends) else self.ends)

    def __len__(self):
        return self.starts.size

    def count_overlaps(self, q_start, q_end) -> np.ndarray:
        q_start = np.asarray(q_start, dtype=np.float64)
        q_end = np.asarray(q_end, dtype=np.float64)
        n_start_le = np.searchsorted(self.starts, q_end, side="right")
        n_end_lt = np.searchsorted(self.sorted_ends, q_start, side="left")
        return (n_start_le - n_end_lt).astype(np.int64)

    def overlapping(self, q_start, q_end) -> np.ndarray:
        return self.count_overlaps(q_start, q_end) > 0

    def stab(self, q) -> np.ndarray:
        return self.overlapping(q, q)
