"""Query sessions (paper §3.1).

"Query sessions to incrementally build and run queries with partial context
kept in the cluster while the user refines the query.  Also, full
auto-complete support … not just for the language but also for the
structure of the data, and the data values themselves."

A :class:`Session` keeps named intermediate results (collected tables) so a
REPL user can refine a pipeline without re-running earlier stages, and
offers structure- and value-aware completion:

  * ``complete("Roads.")``       → field paths of the Roads schema
  * ``complete("Roads.city=S")`` → values of the city column starting "S"
    (served from the shard tag indices — no data scan)
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from ..fdb.schema import MESSAGE
from .exprs import CollectedTable
from .flow import Flow, fdb as _fdb

__all__ = ["Session"]


class Session:
    def __init__(self, engine=None, catalog=None, backend=None,
                 config=None):
        """``config`` is an :class:`repro.exec.ExecConfig` bundling the
        execution knobs (backend/wave/partitions/fused/profile) when no
        explicit engine is supplied; the legacy ``backend`` kwarg
        ("numpy", "jax", or an ExecBackend instance) remains as a shim."""
        if engine is None:
            if backend is not None or config is not None:
                from ..exec.adhoc import AdHocEngine
                engine = AdHocEngine(catalog=catalog, backend=backend,
                                     config=config)
            else:
                from ..exec.adhoc import default_engine
                engine = default_engine()
        self.engine = engine
        self.catalog = catalog or engine.catalog
        self.vars: Dict[str, Any] = {}

    # ---------------------------------------------------------------- flows
    def fdb(self, name: str) -> Flow:
        return _fdb(name, session=self)

    def run(self, flow: Flow, name: Optional[str] = None, **kw
            ) -> CollectedTable:
        """Collect and (optionally) keep the result in session context."""
        res = flow.collect(engine=self.engine, **kw)
        if name is not None:
            self.vars[name] = res
        return res

    def __getitem__(self, name: str) -> Any:
        return self.vars[name]

    def serve(self, **kw):
        """A :class:`~repro.serve.QueryServer` bound to this session's
        engine: concurrent submits against the session's resident FDbs
        coalesce into shared multi-query wave dispatches, with admission
        bounds and a TTL result cache (see :mod:`repro.serve`)."""
        from ..serve import QueryServer
        return QueryServer(engine=self.engine, **kw)

    # ---------------------------------------------------------- completion
    def complete(self, text: str, limit: int = 20) -> List[str]:
        # value completion: "Db.path=prefix"
        if "=" in text:
            lhs, prefix = text.split("=", 1)
            db_name, _, path = lhs.partition(".")
            db = self.catalog.get(db_name)
            out: set = set()
            for shard in db.shards:
                idx = shard.index(path, "tag")
                if idx is not None and idx.vocab is not None:
                    out.update(v for v in idx.vocab
                               if v.startswith(prefix))
                elif path in shard.batch.columns:
                    col = shard.batch[path]
                    if col.vocab is not None:
                        out.update(v for v in col.vocab
                                   if v.startswith(prefix))
                if len(out) >= limit:
                    break
            return sorted(out)[:limit]
        # structure completion: "Db.pre" → field paths
        if "." in text:
            db_name, _, prefix = text.partition(".")
            if db_name in self.catalog.names():
                schema = self.catalog.schema_of(db_name)
                return sorted(p for p, f in schema.walk()
                              if p.startswith(prefix))[:limit]
        # dataset completion
        return sorted(n for n in self.catalog.names()
                      if n.startswith(text))[:limit]
