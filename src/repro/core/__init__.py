"""WFL: the WarpFlow language core — expressions, flows, planning, sessions."""
from .exprs import (P, proto, IN, BETWEEN, vsum, vmin, vmax, vcount, vmean,
                    where, func, group, CollectedTable, AggSpec)
from .flow import Flow, fdb
from .planner import plan_flow, split_find_pred
from .session import Session
from .sketches import HyperLogLog, BloomFilter, IntervalSet

__all__ = [
    "P", "proto", "IN", "BETWEEN", "vsum", "vmin", "vmax", "vcount",
    "vmean", "where", "func", "group", "CollectedTable", "AggSpec",
    "Flow", "fdb", "plan_flow", "split_find_pred", "Session",
    "HyperLogLog", "BloomFilter", "IntervalSet",
]
