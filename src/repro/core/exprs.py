"""WFL expression IR (paper §4.2).

WFL transformations are "expressions composed of data types, operators and
higher-order functions".  We embed WFL in Python: flow operators take
lambdas over a record proxy ``p``; evaluating the lambda *traces* an
expression tree (this module), which the engine then

  * type-checks / schema-infers (→ Dynamic Protocol Buffers, §4.3.3),
  * scans for index-usable conjuncts (``find()`` planning, §4.3.4),
  * evaluates vectorized over column batches — singular fields are scalars,
    repeated fields are vectors, and every operator broadcasts over repeated
    operands exactly as §4.2.2 specifies ("the operation is extended to
    every single element within the operand").

The final statement of a WFL body is its return value; in Python that is
simply the lambda's return expression.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..fdb.columnar import Column, ColumnBatch
from ..fdb.schema import (BOOL, DOUBLE, FLOAT, INT, MESSAGE, STRING, UINT,
                          Schema)
from ..geo.areatree import AreaTree
from ..geo import mercator as Mc
from .sketches import BloomFilter, IntervalSet

__all__ = [
    "Expr", "FieldRef", "Lit", "External", "BinOp", "UnOp", "Between",
    "InRegion", "InSet", "InSpaceTime", "InSpaceTimeSeq", "Reduce",
    "GetField", "TableLookup",
    "Func",
    "MakeProto", "ModelApply", "P", "proto", "IN", "BETWEEN",
    "vsum", "vmin", "vmax", "vcount", "vmean", "where",
    "CollectedTable", "Val", "EvalContext", "eval_expr", "required_paths",
    "infer_spec", "group", "AggSpec",
]


# ===========================================================================
# IR nodes
# ===========================================================================

class Expr:
    def children(self) -> Tuple["Expr", ...]:
        return ()


@dataclass(frozen=True)
class FieldRef(Expr):
    path: str


@dataclass(frozen=True)
class Lit(Expr):
    value: Any


@dataclass(frozen=True)
class External(Expr):
    """A captured host object: AreaTree, CollectedTable, BloomFilter, …"""
    obj: Any = dc_field(hash=False)


@dataclass(frozen=True)
class BinOp(Expr):
    op: str
    a: Expr
    b: Expr

    def children(self):
        return (self.a, self.b)


@dataclass(frozen=True)
class UnOp(Expr):
    op: str
    a: Expr

    def children(self):
        return (self.a,)


@dataclass(frozen=True)
class Between(Expr):
    a: Expr
    lo: Any
    hi: Any

    def children(self):
        return (self.a,)


@dataclass(frozen=True)
class InRegion(Expr):
    field: Expr            # FieldRef to a location (message with lat/lng)
    region: Any = dc_field(hash=False)            # AreaTree

    def children(self):
        return (self.field,)


@dataclass(frozen=True)
class InSet(Expr):
    a: Expr
    values: tuple

    def children(self):
        return (self.a,)


@dataclass(frozen=True)
class InSpaceTime(Expr):
    """One Tesseract constraint: the track passes through ``region`` during
    ``[t0, t1]`` — true iff *some* track point is inside the region's cover
    and time window.  Singular (any-reduced) over the repeated track."""
    field: Expr            # FieldRef to a track (repeated lat/lng/t leaves)
    region: Any = dc_field(hash=False)            # AreaTree
    t0: float = 0.0
    t1: float = 0.0

    def children(self):
        return (self.field,)


@dataclass(frozen=True)
class InSpaceTimeSeq(Expr):
    """Ordered Tesseract constraints over one track field (A **then** B).

    Every ``(region, t0, t1)`` constraint must hit (some track point inside
    the region's cover during the window — the plain ``InSpaceTime`` AND),
    and for each ``(i, j)`` ordering edge the track's **first hit** of
    constraint ``i`` (minimum timestamp among its satisfying points) must be
    *strictly* before its first hit of constraint ``j``.  Equal first-hit
    timestamps do not count as before (tie ⇒ edge fails).  Singular
    (any-reduced) over the repeated track, like ``InSpaceTime``.

    Per-constraint **reductions** generalize the any-hit verdict from the
    same one-hot pass: ``min_counts[c] = k`` requires ≥ k satisfying points
    (k = 0 is vacuously true — the constraint stops filtering);
    ``dwells[c] = d`` requires ≥ 1 hit and ``t(last hit) − t(first hit)
    >= d`` seconds (inclusive at the threshold).  ``None`` in either slot
    (or the whole tuple) keeps the plain any-hit semantics.
    """
    field: Expr            # FieldRef to a track (repeated lat/lng/t leaves)
    constraints: Tuple[Tuple[Any, float, float], ...] = \
        dc_field(hash=False, default=())      # [(AreaTree, t0, t1), …]
    edges: Tuple[Tuple[int, int], ...] = ()   # (i, j): first_i < first_j
    min_counts: Optional[Tuple[int, ...]] = None     # "≥ k hits" per slot
    dwells: Optional[Tuple[Optional[float], ...]] = None  # seconds per slot

    def children(self):
        return (self.field,)


@dataclass(frozen=True)
class Reduce(Expr):
    op: str                # sum|min|max|mean|count
    a: Expr

    def children(self):
        return (self.a,)


@dataclass(frozen=True)
class GetField(Expr):
    base: Expr
    name: str

    def children(self):
        return (self.base,)


@dataclass(frozen=True)
class TableLookup(Expr):
    table: Any = dc_field(hash=False)     # CollectedTable
    key: Expr = None

    def children(self):
        return (self.key,)


@dataclass(frozen=True)
class Func(Expr):
    name: str
    args: tuple

    def children(self):
        return tuple(a for a in self.args if isinstance(a, Expr))


@dataclass(frozen=True)
class MakeProto(Expr):
    fields: tuple          # ((name, Expr), ...)

    def children(self):
        return tuple(e for _, e in self.fields)


@dataclass(frozen=True)
class ModelApply(Expr):
    model: Any = dc_field(hash=False)
    inputs: tuple = ()     # ((name, Expr), ...)

    def children(self):
        return tuple(e for _, e in self.inputs)


def _wrap(x) -> Expr:
    if isinstance(x, ExprProxy):
        return x._expr
    if isinstance(x, Expr):
        return x
    if isinstance(x, (bool, int, float, str, np.generic)):
        return Lit(x)
    return External(x)


# ===========================================================================
# Tracing proxies — the `p` in `flow.map(p => ...)`
# ===========================================================================

class ExprProxy:
    __array_priority__ = 1000   # win binops against numpy scalars

    def __init__(self, expr: Expr):
        object.__setattr__(self, "_expr", expr)

    # field access ----------------------------------------------------------
    def __getattr__(self, name: str) -> "ExprProxy":
        if name.startswith("_"):
            raise AttributeError(name)
        e = self._expr
        if isinstance(e, FieldRef):
            return ExprProxy(FieldRef(f"{e.path}.{name}" if e.path else name))
        return ExprProxy(GetField(e, name))

    def __getitem__(self, key) -> "ExprProxy":
        # roads[p.route.id] — dictionary lookup with vector keys
        e = self._expr
        if isinstance(e, External) and isinstance(e.obj, CollectedTable):
            return ExprProxy(TableLookup(e.obj, _wrap(key)))
        raise TypeError("subscript only supported on collected dicts")

    # operators --------------------------------------------------------------
    def _bin(self, op, other, swap=False):
        a, b = _wrap(self), _wrap(other)
        if swap:
            a, b = b, a
        return ExprProxy(BinOp(op, a, b))

    __add__ = lambda s, o: s._bin("add", o)
    __radd__ = lambda s, o: s._bin("add", o, True)
    __sub__ = lambda s, o: s._bin("sub", o)
    __rsub__ = lambda s, o: s._bin("sub", o, True)
    __mul__ = lambda s, o: s._bin("mul", o)
    __rmul__ = lambda s, o: s._bin("mul", o, True)
    __truediv__ = lambda s, o: s._bin("div", o)
    __rtruediv__ = lambda s, o: s._bin("div", o, True)
    __mod__ = lambda s, o: s._bin("mod", o)
    __pow__ = lambda s, o: s._bin("pow", o)
    __eq__ = lambda s, o: s._bin("eq", o)        # type: ignore[assignment]
    __ne__ = lambda s, o: s._bin("ne", o)        # type: ignore[assignment]
    __lt__ = lambda s, o: s._bin("lt", o)
    __le__ = lambda s, o: s._bin("le", o)
    __gt__ = lambda s, o: s._bin("gt", o)
    __ge__ = lambda s, o: s._bin("ge", o)
    __and__ = lambda s, o: s._bin("and", o)
    __rand__ = lambda s, o: s._bin("and", o, True)
    __or__ = lambda s, o: s._bin("or", o)
    __ror__ = lambda s, o: s._bin("or", o, True)
    __neg__ = lambda s: ExprProxy(UnOp("neg", _wrap(s)))
    __invert__ = lambda s: ExprProxy(UnOp("not", _wrap(s)))
    __abs__ = lambda s: ExprProxy(UnOp("abs", _wrap(s)))
    __hash__ = None   # type: ignore[assignment]

    def in_(self, what) -> "ExprProxy":
        return IN(self, what)

    def between(self, lo, hi) -> "ExprProxy":
        return BETWEEN(self, lo, hi)

    def __bool__(self):
        raise TypeError(
            "WFL expressions are lazy; use &, | instead of and/or, "
            "and IN()/BETWEEN() instead of `in`.")


#: The record proxy — `P.field` inside flow lambdas.
P = ExprProxy(FieldRef(""))


def proto(**fields) -> ExprProxy:
    """``proto(a=expr, b=expr)`` — construct the stage's output record."""
    flat: List[Tuple[str, Expr]] = []
    for name, v in fields.items():
        e = _wrap(v)
        if isinstance(e, MakeProto):   # nested proto → dotted paths
            for sub, se in e.fields:
                flat.append((f"{name}.{sub}", se))
        else:
            flat.append((name, e))
    return ExprProxy(MakeProto(tuple(flat)))


def IN(a, what) -> ExprProxy:
    a = _wrap(a)
    if isinstance(what, AreaTree):
        if not isinstance(a, FieldRef):
            raise TypeError("IN(region) requires a location field")
        return ExprProxy(InRegion(a, what))
    if isinstance(what, BloomFilter):
        return ExprProxy(Func("bloom_contains", (a, External(what))))
    if isinstance(what, (list, tuple, set, frozenset)):
        return ExprProxy(InSet(a, tuple(what)))
    raise TypeError(f"IN: unsupported container {type(what).__name__}")


def BETWEEN(a, lo, hi) -> ExprProxy:
    return ExprProxy(Between(_wrap(a), lo, hi))


def vsum(a) -> ExprProxy:
    return ExprProxy(Reduce("sum", _wrap(a)))


def vmin(a) -> ExprProxy:
    return ExprProxy(Reduce("min", _wrap(a)))


def vmax(a) -> ExprProxy:
    return ExprProxy(Reduce("max", _wrap(a)))


def vmean(a) -> ExprProxy:
    return ExprProxy(Reduce("mean", _wrap(a)))


def vcount(a) -> ExprProxy:
    return ExprProxy(Reduce("count", _wrap(a)))


def where(cond, a, b) -> ExprProxy:
    return ExprProxy(Func("where", (_wrap(cond), _wrap(a), _wrap(b))))


def func(name, *args) -> ExprProxy:
    return ExprProxy(Func(name, tuple(_wrap(a) for a in args)))


# ===========================================================================
# Collected tables (`collect().to_dict(key)`)
# ===========================================================================

class CollectedTable:
    """Materialized flow results; supports record access + dict lookups."""

    def __init__(self, batch: ColumnBatch):
        self.batch = batch
        self._key_path: Optional[str] = None
        self._sorted_keys: Optional[np.ndarray] = None
        self._sorted_rows: Optional[np.ndarray] = None
        self._key_vocab_map: Optional[Dict[str, int]] = None

    @property
    def n(self) -> int:
        return self.batch.n

    def to_records(self) -> List[dict]:
        return self.batch.to_records()

    def to_dict(self, key_path) -> "CollectedTable":
        """Index by a key column for ``table[keys]`` lookups in expressions."""
        if isinstance(key_path, ExprProxy):
            assert isinstance(key_path._expr, FieldRef)
            key_path = key_path._expr.path
        col = self.batch[key_path]
        if col.is_repeated:
            raise TypeError("to_dict key must be singular")
        keys = col.values
        if col.vocab is not None:
            self._key_vocab_map = {s: i for i, s in enumerate(col.vocab)}
        order = np.argsort(keys, kind="stable")
        self._key_path = key_path
        self._sorted_keys = keys[order]
        self._sorted_rows = order.astype(np.int64)
        return self

    def __getitem__(self, key):
        """Fig. 1 syntax: ``roads[p.route.id]`` inside a WFL expression."""
        if isinstance(key, (ExprProxy, Expr)):
            return ExprProxy(TableLookup(self, _wrap(key)))
        raise TypeError("collected-table lookup takes a WFL expression key")

    def lookup_rows(self, keys: np.ndarray,
                    key_vocab: Optional[List[str]] = None) -> np.ndarray:
        """Row ids per key (−1 = missing), vectorized."""
        if self._sorted_keys is None:
            raise RuntimeError("call .to_dict(key) before lookups")
        keys = np.asarray(keys)
        if key_vocab is not None:
            if self._key_vocab_map is None:
                raise TypeError("string keys against non-string dict")
            remap = np.array([self._key_vocab_map.get(s, -1)
                              for s in key_vocab], dtype=np.int64)
            keys = remap[keys]
        pos = np.searchsorted(self._sorted_keys, keys)
        pos_c = np.minimum(pos, self._sorted_keys.size - 1)
        hit = (self._sorted_keys.size > 0) & \
            (self._sorted_keys[pos_c] == keys) & (keys >= 0 if key_vocab else True)
        return np.where(hit, self._sorted_rows[pos_c], -1)

    def __repr__(self):
        return f"CollectedTable(n={self.n}, key={self._key_path!r})"


# ===========================================================================
# Evaluation
# ===========================================================================

@dataclass
class Val:
    """A vectorized value over the batch's rows.

    ``splits`` set ⇒ repeated (ragged).  ``table``+``rows`` set ⇒ this is a
    vector of *records* (rows into a CollectedTable) — field access gathers.
    """
    values: np.ndarray = None
    splits: Optional[np.ndarray] = None
    vocab: Optional[List[str]] = None
    table: Optional[CollectedTable] = None

    @property
    def is_repeated(self):
        return self.splits is not None


@dataclass
class EvalContext:
    batch: ColumnBatch
    meters_per_unit: float = 0.06   # local Mercator scale hint

    @property
    def n(self):
        return self.batch.n


def _broadcast(a: Val, b: Val, n: int) -> Tuple[np.ndarray, np.ndarray,
                                                Optional[np.ndarray]]:
    """Align two vals: returns flat arrays + common splits (None=singular)."""
    if a.is_repeated and b.is_repeated:
        if a.splits is not b.splits and not np.array_equal(a.splits, b.splits):
            raise ValueError("binary op on differently-shaped vectors")
        return a.values, b.values, a.splits
    if a.is_repeated:
        lens = np.diff(a.splits)
        bv = b.values if b.values.ndim else np.broadcast_to(b.values, (n,))
        return a.values, np.repeat(bv, lens), a.splits
    if b.is_repeated:
        lens = np.diff(b.splits)
        av = a.values if a.values.ndim else np.broadcast_to(a.values, (n,))
        return np.repeat(av, lens), b.values, b.splits
    return a.values, b.values, None


_BINOPS: Dict[str, Callable] = {
    "add": np.add, "sub": np.subtract, "mul": np.multiply,
    "div": np.divide, "mod": np.mod, "pow": np.power,
    "eq": np.equal, "ne": np.not_equal, "lt": np.less, "le": np.less_equal,
    "gt": np.greater, "ge": np.greater_equal,
    "and": np.logical_and, "or": np.logical_or,
}

_UNOPS: Dict[str, Callable] = {
    "neg": np.negative, "not": np.logical_not, "abs": np.abs,
    "sqrt": np.sqrt, "log": np.log, "exp": np.exp,
    "floor": np.floor, "ceil": np.ceil,
}


def _str_code(lit, vocab: List[str]):
    try:
        return vocab.index(str(lit))
    except ValueError:
        return -1


def eval_expr(expr: Expr, ctx: EvalContext) -> Val:
    n = ctx.n
    if isinstance(expr, FieldRef):
        col = ctx.batch[expr.path]
        return Val(col.values, col.row_splits, col.vocab)
    if isinstance(expr, Lit):
        return Val(np.asarray(expr.value))
    if isinstance(expr, External):
        return Val(values=expr.obj)
    if isinstance(expr, BinOp):
        a = eval_expr(expr.a, ctx)
        b = eval_expr(expr.b, ctx)
        # string comparison: map literal onto vocab codes
        if a.vocab is not None and b.values is not None and b.values.ndim == 0:
            b = Val(np.asarray(_str_code(b.values.item(), a.vocab)))
        elif b.vocab is not None and a.values is not None and a.values.ndim == 0:
            a = Val(np.asarray(_str_code(a.values.item(), b.vocab)))
        fa, fb, sp = _broadcast(a, b, n)
        if expr.op == "div":
            fa = np.asarray(fa, dtype=np.float64)
        return Val(_BINOPS[expr.op](fa, fb), sp)
    if isinstance(expr, UnOp):
        a = eval_expr(expr.a, ctx)
        return Val(_UNOPS[expr.op](a.values), a.splits, None)
    if isinstance(expr, Between):
        a = eval_expr(expr.a, ctx)
        return Val((a.values >= expr.lo) & (a.values <= expr.hi), a.splits)
    if isinstance(expr, InSet):
        a = eval_expr(expr.a, ctx)
        if a.vocab is not None:
            codes = {_str_code(v, a.vocab) for v in expr.values}
            return Val(np.isin(a.values, list(codes)), a.splits)
        return Val(np.isin(a.values, list(expr.values)), a.splits)
    if isinstance(expr, InRegion):
        lat = ctx.batch[expr.field.path + ".lat"]
        lng = ctx.batch[expr.field.path + ".lng"]
        keys = Mc.latlng_to_morton(lat.values, lng.values)
        return Val(expr.region.contains(keys), lat.row_splits)
    if isinstance(expr, InSpaceTime):
        # exact Tesseract constraint: the 1-constraint/no-edges case of
        # the ordered evaluation below (one source of the hit semantics)
        return eval_expr(InSpaceTimeSeq(
            expr.field, ((expr.region, expr.t0, expr.t1),)), ctx)
    if isinstance(expr, InSpaceTimeSeq):
        # ordered Tesseract: AND of every constraint's any-hit (some track
        # point in-cover AND in-window), plus strict first-hit ordering
        # per edge.  First hit = min timestamp among the doc's points
        # satisfying the constraint (+inf when none — such docs already
        # fail the hit AND, so edges never resurrect them); float min
        # order-matches the packed uint64 sort-key min the refine ops use
        # for every non-NaN timestamp.
        lat = ctx.batch[expr.field.path + ".lat"]
        lng = ctx.batch[expr.field.path + ".lng"]
        tt = ctx.batch[expr.field.path + ".t"]
        keys = Mc.latlng_to_morton(lat.values, lng.values)
        mins = expr.min_counts
        dwells = expr.dwells
        any_dwell = dwells is not None and any(d is not None for d in dwells)
        need_first = bool(expr.edges) or any_dwell
        first = np.full((n, len(expr.constraints)), np.inf) \
            if need_first else None
        last = np.full((n, len(expr.constraints)), -np.inf) \
            if any_dwell else None
        count = np.zeros((n, len(expr.constraints)), dtype=np.int64) \
            if mins is not None else None
        out = np.ones(n, dtype=bool)
        row_of = None if lat.row_splits is None else \
            np.repeat(np.arange(n), np.diff(lat.row_splits))
        for c, (region, t0, t1) in enumerate(expr.constraints):
            hit = region.contains(keys) \
                & (tt.values >= t0) & (tt.values <= t1)
            if row_of is None:                  # singular location + t
                doc_hit = np.asarray(hit, dtype=bool)
                if first is not None:
                    first[:, c] = np.where(hit, tt.values, np.inf)
                if last is not None:
                    last[:, c] = np.where(hit, tt.values, -np.inf)
                if count is not None:
                    count[:, c] = doc_hit.astype(np.int64)
            else:
                doc_hit = np.zeros(n, dtype=bool)
                if hit.size:
                    np.logical_or.at(doc_hit, row_of, hit)
                    if first is not None:
                        np.minimum.at(first[:, c], row_of,
                                      np.where(hit, tt.values, np.inf))
                    if last is not None:
                        np.maximum.at(last[:, c], row_of,
                                      np.where(hit, tt.values, -np.inf))
                    if count is not None:
                        np.add.at(count[:, c], row_of, hit)
            ok = doc_hit
            if mins is not None and int(mins[c]) != 1:
                k = int(mins[c])
                ok = np.ones(n, dtype=bool) if k <= 0 else count[:, c] >= k
            if dwells is not None and dwells[c] is not None:
                # + 0.0 normalizes −0.0, matching the packed sort-key
                # round-trip the device reductions difference
                span = (last[:, c] + 0.0) - (first[:, c] + 0.0)
                ok = ok & doc_hit & (span >= float(dwells[c]))
            out &= ok
        for i, j in expr.edges:
            out &= first[:, i] < first[:, j]
        return Val(out)
    if isinstance(expr, Reduce):
        a = eval_expr(expr.a, ctx)
        if not a.is_repeated:
            raise TypeError(f"{expr.op}() over a singular field")
        lens = np.diff(a.splits)
        if expr.op == "count":
            return Val(lens.astype(np.int64))
        vals = np.asarray(a.values, dtype=np.float64)
        starts = a.splits[:-1]
        if expr.op == "sum":
            out = np.add.reduceat(vals, starts) if vals.size else \
                np.zeros(n)
            out = np.where(lens > 0, out, 0.0)
        elif expr.op == "mean":
            s = np.add.reduceat(vals, starts) if vals.size else np.zeros(n)
            out = np.where(lens > 0, s / np.maximum(lens, 1), np.nan)
        elif expr.op == "min":
            out = np.minimum.reduceat(vals, starts) if vals.size else \
                np.full(n, np.nan)
            out = np.where(lens > 0, out, np.nan)
        elif expr.op == "max":
            out = np.maximum.reduceat(vals, starts) if vals.size else \
                np.full(n, np.nan)
            out = np.where(lens > 0, out, np.nan)
        else:
            raise ValueError(expr.op)
        # reduceat quirk: empty segments copy the next element; fixed by the
        # `where` masks above (out is only trusted where lens > 0).
        return Val(out)
    if isinstance(expr, GetField):
        base = eval_expr(expr.base, ctx)
        if base.table is None:
            raise TypeError(f"field access .{expr.name} on non-record value")
        col = base.table.batch[_resolve_col(base.table, expr.name)]
        rows = base.values
        safe = np.maximum(rows, 0)
        if col.is_repeated:
            raise TypeError("nested repeated lookup not supported")
        vals = col.values[safe]
        if col.vocab is None:
            vals = np.where(rows >= 0, vals, 0)
        return Val(vals, base.splits, col.vocab)
    if isinstance(expr, TableLookup):
        key = eval_expr(expr.key, ctx)
        rows = expr.table.lookup_rows(key.values, key.vocab)
        return Val(rows, key.splits, table=expr.table)
    if isinstance(expr, MakeProto):
        raise TypeError("proto() must be the top-level map() result")
    if isinstance(expr, ModelApply):
        cols = {name: eval_expr(e, ctx).values for name, e in expr.inputs}
        return Val(np.asarray(expr.model.apply_columns(cols)))
    if isinstance(expr, Func):
        return _eval_func(expr, ctx)
    raise TypeError(f"cannot evaluate {type(expr).__name__}")


def _resolve_col(table: CollectedTable, name: str) -> str:
    if name in table.batch.columns:
        return name
    # allow bare leaf names for nested paths
    cands = [p for p in table.batch.columns if p.split(".")[-1] == name]
    if len(cands) == 1:
        return cands[0]
    raise KeyError(f"ambiguous or missing field {name!r} in collected table")


def _eval_func(expr: Func, ctx: EvalContext) -> Val:
    name = expr.name
    if name == "where":
        c = eval_expr(expr.args[0], ctx)
        a = eval_expr(expr.args[1], ctx)
        b = eval_expr(expr.args[2], ctx)
        fa, fb, sp = _broadcast(a, b, ctx.n)
        fc, _, sp2 = _broadcast(c, a, ctx.n)
        return Val(np.where(fc, fa, fb), sp or sp2)
    if name == "distance":
        # distance(p.polyline): ground length in meters from repeated lat/lng
        f = expr.args[0]
        assert isinstance(f, FieldRef), "distance() needs a polyline field"
        lat = ctx.batch[f.path + ".lat"]
        lng = ctx.batch[f.path + ".lng"]
        sp = lat.row_splits
        if sp is None:
            raise TypeError("distance() needs a repeated lat/lng polyline")
        out = np.zeros(ctx.n, dtype=np.float64)
        if lat.values.size >= 2:
            ix, iy = Mc.latlng_to_xy(lat.values, lng.values)
            x = ix.astype(np.float64)
            y = iy.astype(np.float64)
            seg = np.hypot(np.diff(x), np.diff(y)) \
                * Mc.meters_per_unit_at(lat.values[:-1])
            # diff j joins flat elements j, j+1 — valid iff same row
            lens = np.diff(sp)
            row_of = np.repeat(np.arange(ctx.n), lens)          # [m]
            valid = row_of[:-1] == row_of[1:]
            np.add.at(out, row_of[:-1][valid], seg[valid])
        return Val(out)
    if name == "bloom_contains":
        a = eval_expr(expr.args[0], ctx)
        bf: BloomFilter = expr.args[1].obj
        return Val(bf.contains(a.values, a.vocab), a.splits)
    if name == "interval_overlaps":
        iv: IntervalSet = expr.args[0].obj
        lo = eval_expr(expr.args[1], ctx)
        hi = eval_expr(expr.args[2], ctx)
        return Val(iv.overlapping(lo.values, hi.values), lo.splits)
    if name == "clip":
        a = eval_expr(expr.args[0], ctx)
        lo = expr.args[1].value if isinstance(expr.args[1], Lit) else expr.args[1]
        hi = expr.args[2].value if isinstance(expr.args[2], Lit) else expr.args[2]
        return Val(np.clip(a.values, lo, hi), a.splits)
    raise KeyError(f"unknown WFL function {name!r}")


# ===========================================================================
# Static analysis: required paths + output schema inference
# ===========================================================================

def required_paths(expr: Expr, schema: Schema) -> List[str]:
    """Leaf paths a query touches → minimal viable schema (§4.3.3)."""
    out: set = set()

    def visit(e: Expr):
        if isinstance(e, FieldRef):
            if schema.has(e.path) and schema.field(e.path).type == MESSAGE:
                for p, f in schema.field(e.path).walk(
                        e.path.rsplit(".", 1)[0] + "."
                        if "." in e.path else ""):
                    if f.type != MESSAGE:
                        out.add(p)
            elif schema.has(e.path):
                out.add(e.path)
        if isinstance(e, InRegion):
            out.add(e.field.path + ".lat")
            out.add(e.field.path + ".lng")
            return
        if isinstance(e, (InSpaceTime, InSpaceTimeSeq)):
            out.add(e.field.path + ".lat")
            out.add(e.field.path + ".lng")
            out.add(e.field.path + ".t")
            return
        if isinstance(e, Func) and e.name == "distance":
            f = e.args[0]
            out.add(f.path + ".lat")
            out.add(f.path + ".lng")
            return
        for c in e.children():
            visit(c)

    visit(expr)
    return sorted(p for p in out if schema.has(p))


_NUMERIC_RESULT = {"add", "sub", "mul", "div", "mod", "pow"}
_BOOL_RESULT = {"eq", "ne", "lt", "le", "gt", "ge", "and", "or"}


def infer_spec(expr: Expr, schema: Optional[Schema]) -> Tuple[str, bool]:
    """Infer (type, repeated) — Dynamic Protocol Buffers schema derivation."""
    if isinstance(expr, FieldRef):
        if schema is not None and schema.has(expr.path):
            f = schema.field(expr.path)
            return f.type, f.repeated
        return DOUBLE, False
    if isinstance(expr, Lit):
        v = expr.value
        if isinstance(v, bool):
            return BOOL, False
        if isinstance(v, int):
            return INT, False
        if isinstance(v, str):
            return STRING, False
        return DOUBLE, False
    if isinstance(expr, BinOp):
        ta, ra = infer_spec(expr.a, schema)
        tb, rb = infer_spec(expr.b, schema)
        rep = ra or rb
        if expr.op in _BOOL_RESULT:
            return BOOL, rep
        if expr.op == "div":
            return DOUBLE, rep
        if ta == tb:
            return ta, rep
        return DOUBLE, rep
    if isinstance(expr, UnOp):
        t, r = infer_spec(expr.a, schema)
        return (BOOL, r) if expr.op == "not" else (t if expr.op in
                                                   ("neg", "abs") else DOUBLE, r)
    if isinstance(expr, (InSpaceTime, InSpaceTimeSeq)):
        return BOOL, False            # any-reduced over the track
    if isinstance(expr, (Between, InSet, InRegion)):
        _, r = infer_spec(expr.children()[0], schema)
        return BOOL, r
    if isinstance(expr, Reduce):
        if expr.op == "count":
            return INT, False
        return DOUBLE, False
    if isinstance(expr, GetField):
        base = expr.base
        if isinstance(base, TableLookup):
            tb = base.table.batch
            col_path = _resolve_col(base.table, expr.name)
            col = tb[col_path]
            t = STRING if col.vocab is not None else (
                BOOL if col.values.dtype == np.bool_
                else INT if col.values.dtype.kind in "iu" else DOUBLE)
            _, rep = infer_spec(base.key, schema) if base.key else (None, False)
            return t, rep
        return DOUBLE, False
    if isinstance(expr, TableLookup):
        _, rep = infer_spec(expr.key, schema)
        return INT, rep
    if isinstance(expr, ModelApply):
        return DOUBLE, False
    if isinstance(expr, Func):
        if expr.name in ("bloom_contains", "interval_overlaps"):
            return BOOL, infer_spec(expr.args[0] if expr.name ==
                                    "bloom_contains" else expr.args[1],
                                    schema)[1]
        if expr.name == "where":
            return infer_spec(expr.args[1], schema)
        return DOUBLE, False
    raise TypeError(f"cannot infer type of {type(expr).__name__}")


# ===========================================================================
# Aggregation specs (paper Table 1: aggregate)
# ===========================================================================

class AggSpec:
    """Built by ``group(keys...).count(...).avg(name=expr)...`` chains."""

    def __init__(self, keys: Sequence = ()):
        self.keys: List[Tuple[str, Expr]] = []
        for i, k in enumerate(keys):
            e = _wrap(k)
            name = e.path.replace(".", "_") if isinstance(e, FieldRef) \
                else f"key{i}"
            self.keys.append((name, e))
        self.aggs: List[Tuple[str, str, Optional[Expr]]] = []

    def _add(self, kind, name=None, expr=None, **kw):
        if kw:
            (name, expr), = kw.items()
        if name is None:
            name = kind
        self.aggs.append((kind, name, _wrap(expr) if expr is not None
                          else None))
        return self

    def count(self, name: str = "count"):
        return self._add("count", name)

    def sum(self, name=None, expr=None, **kw):
        return self._add("sum", name, expr, **kw)

    def avg(self, name=None, expr=None, **kw):
        return self._add("avg", name, expr, **kw)

    def std_dev(self, name=None, expr=None, **kw):
        return self._add("std_dev", name, expr, **kw)

    def min(self, name=None, expr=None, **kw):
        return self._add("min", name, expr, **kw)

    def max(self, name=None, expr=None, **kw):
        return self._add("max", name, expr, **kw)

    def approx_distinct(self, name=None, expr=None, **kw):
        """HyperLogLog cardinality (paper §4.2.2)."""
        return self._add("approx_distinct", name, expr, **kw)


def group(*keys) -> AggSpec:
    return AggSpec(keys)
