"""WFL flows (paper §3, §4.2, Table 1).

A pipeline is ``fdb('Name').find(...).map(...).aggregate(...).collect()`` —
a lazily-built DAG of operators over a *flow* of records.  Nothing executes
until a materializing operator (``collect``/``save``) hands the DAG to an
execution engine (Warp:AdHoc or Warp:Flume, §4.3).

Operator vocabulary is the paper's Table 1: map, filter, flatten, sort_asc/
sort_desc, limit, distinct, aggregate, join, sub_flow, collect, save — plus
``sample`` (the paper's "querying over a sample to quickly slice through
huge datasets", realized as shard-subset selection) and ``model_apply`` (the
§5 TensorFlow-operator analog, applying a JAX model to flow columns).

Every stage's output schema is derived automatically (Dynamic Protocol
Buffers, §4.3.3): see :meth:`Flow.schema_after`.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..fdb.schema import DOUBLE, INT, STRING, BOOL, Schema
from .exprs import (AggSpec, Expr, ExprProxy, FieldRef, MakeProto, P,
                    infer_spec, _wrap)

__all__ = ["Flow", "fdb", "Op", "FindOp", "MapOp", "FilterOp", "FlattenOp",
           "SortOp", "LimitOp", "DistinctOp", "AggregateOp", "JoinOp",
           "SubFlowOp", "SampleOp", "ModelApplyOp"]


def _trace(fn_or_expr) -> Expr:
    if callable(fn_or_expr) and not isinstance(fn_or_expr, ExprProxy):
        fn_or_expr = fn_or_expr(P)
    return _wrap(fn_or_expr)


# --------------------------------------------------------------------- ops

class Op:
    pass


@dataclass
class FindOp(Op):
    pred: Expr


@dataclass
class MapOp(Op):
    make: MakeProto


@dataclass
class FilterOp(Op):
    pred: Expr


@dataclass
class FlattenOp(Op):
    path: str


@dataclass
class SortOp(Op):
    expr: Expr
    descending: bool = False


@dataclass
class LimitOp(Op):
    k: int


@dataclass
class DistinctOp(Op):
    expr: Optional[Expr] = None


@dataclass
class AggregateOp(Op):
    spec: AggSpec


@dataclass
class JoinOp(Op):
    right: "Flow"
    left_key: Expr
    right_key: Expr
    alias: str = "r"
    strategy: str = "auto"      # auto | broadcast | shuffle


@dataclass
class SubFlowOp(Op):
    """Index join (paper Table 1 ``sub_flow``): per record, probe the other
    FDb's *index* on the key instead of materializing + hashing it."""
    right_fdb: str
    key: Expr
    index_path: str
    alias: str = "r"


@dataclass
class SampleOp(Op):
    fraction: float


@dataclass
class ModelApplyOp(Op):
    model: Any
    inputs: Tuple[Tuple[str, Expr], ...]
    output: str = "prediction"


# -------------------------------------------------------------------- flow

class Flow:
    def __init__(self, source: str, ops: Sequence[Op] = (),
                 session: Optional[Any] = None):
        self.source = source
        self.ops: List[Op] = list(ops)
        self.session = session

    def _push(self, op: Op) -> "Flow":
        return Flow(self.source, self.ops + [op], self.session)

    # -- Table 1 operators --------------------------------------------------
    def find(self, pred) -> "Flow":
        return self._push(FindOp(_trace(pred)))

    def map(self, fn) -> "Flow":
        e = _trace(fn)
        if not isinstance(e, MakeProto):
            raise TypeError("map() must return proto(...)")
        return self._push(MapOp(e))

    def filter(self, pred) -> "Flow":
        return self._push(FilterOp(_trace(pred)))

    def flatten(self, path) -> "Flow":
        if isinstance(path, ExprProxy):
            path = path._expr.path
        return self._push(FlattenOp(path))

    def sort_asc(self, expr) -> "Flow":
        return self._push(SortOp(_trace(expr), False))

    def sort_desc(self, expr) -> "Flow":
        return self._push(SortOp(_trace(expr), True))

    def limit(self, k: int) -> "Flow":
        return self._push(LimitOp(int(k)))

    def distinct_approx(self, expr, name: str = "distinct_approx") -> "Flow":
        """Approximate distinct count of ``expr`` over the whole flow
        (paper §4.2.2 HyperLogLog): one-row result column ``name``.  The
        sketch is register-maxed per partition and merged by the Mixer, so
        the estimate is partition-invariant by contract."""
        spec = AggSpec(())
        spec.approx_distinct(name, expr=_trace(expr))
        return self._push(AggregateOp(spec))

    def distinct(self, expr=None) -> "Flow":
        return self._push(DistinctOp(_trace(expr) if expr is not None
                                     else None))

    def aggregate(self, spec) -> "Flow":
        if callable(spec) and not isinstance(spec, AggSpec):
            spec = spec(P)
        if not isinstance(spec, AggSpec):
            raise TypeError("aggregate() takes group(...).agg(...) spec")
        return self._push(AggregateOp(spec))

    def join(self, right: "Flow", left_key, right_key=None, alias="r",
             strategy="auto") -> "Flow":
        right_key = right_key if right_key is not None else left_key
        return self._push(JoinOp(right, _trace(left_key), _trace(right_key),
                                 alias, strategy))

    def sub_flow(self, right_fdb: str, key, index_path: str,
                 alias="r") -> "Flow":
        return self._push(SubFlowOp(right_fdb, _trace(key), index_path,
                                    alias))

    def tesseract(self, tess, field: str = None) -> "Flow":
        """Space-time trip selection (paper §2 Tesseract queries).

        ``tess`` is a :class:`repro.tess.Tesseract`; its constraints become
        ``InSpaceTime`` conjuncts of a leading ``find()`` (a single
        ``InSpaceTimeSeq`` conjunct when the builder carries ``then()`` /
        ``before()`` ordering edges), which the planner compiles to stacked
        ``spacetime``-index bitmap probes plus the exact point-in-cover ×
        time-window refine — ordering resolved there via per-constraint
        first-hit timestamps.  Compose with other predicates via
        ``find(tess.expr() & ...)`` instead when needed.
        """
        return self._push(FindOp(_trace(tess.expr(field))))

    def sample(self, fraction: float) -> "Flow":
        if not 0.0 < fraction <= 1.0:
            raise ValueError("sample fraction in (0, 1]")
        return self._push(SampleOp(float(fraction)))

    def model_apply(self, model, output="prediction", **inputs) -> "Flow":
        """Apply a JAX model to flow columns (paper §5 TF-operator analog)."""
        ins = tuple((k, _trace(v)) for k, v in inputs.items())
        return self._push(ModelApplyOp(model, ins, output))

    def to_dataset(self, features, target, engine=None, **kw):
        """Materialize this flow as ML training data (paper §5).

        ``features`` is a ``{name: expr}`` mapping (or a sequence of field
        refs), ``target`` an expression; the query executes like any other
        flow — selection rides indices and the fused refine pass — and the
        resulting columns land in a :class:`repro.data.pipeline.
        TrainingDataset`, whose ``fit()`` trains an ``MLPRegressor`` on
        exactly the rows the query selected (time-to-trained-model).
        """
        from ..data.pipeline import TrainingDataset
        if isinstance(features, dict):
            items = [(n, _trace(e)) for n, e in features.items()]
        else:
            items = []
            for i, f in enumerate(features):
                e = _trace(f)
                name = (e.path.replace(".", "_")
                        if isinstance(e, FieldRef) else f"f{i}")
                items.append((name, e))
        te = _trace(target)
        t_name = (te.path.replace(".", "_")
                  if isinstance(te, FieldRef) else "target")
        if t_name in {n for n, _ in items}:
            t_name = "__target"
        flow = self._push(MapOp(MakeProto(tuple(items) + ((t_name, te),))))
        table = flow.collect(engine, **kw)
        return TrainingDataset.from_table(table, [n for n, _ in items],
                                          t_name)

    # -- materialization ------------------------------------------------------
    def collect(self, engine=None, **kw):
        eng = engine or (self.session.engine if self.session else None)
        if eng is None:
            from ..exec.adhoc import default_engine
            eng = default_engine()
        return eng.collect(self, **kw)

    def save(self, name: str, engine=None, **kw):
        eng = engine or (self.session.engine if self.session else None)
        if eng is None:
            from ..exec.adhoc import default_engine
            eng = default_engine()
        return eng.save(self, name, **kw)

    # -- dynamic schema derivation (§4.3.3) -----------------------------------
    def schema_after(self, catalog) -> Schema:
        schema = catalog.schema_of(self.source)
        for op in self.ops:
            schema = _apply_schema(op, schema, catalog)
        return schema

    def __repr__(self):
        names = [type(o).__name__.replace("Op", "").lower() for o in self.ops]
        return f"Flow({self.source!r} | {' | '.join(names)})"


def _apply_schema(op: Op, schema: Schema, catalog) -> Schema:
    if isinstance(op, (FindOp, FilterOp, SampleOp, SortOp, LimitOp,
                       DistinctOp)):
        return schema
    if isinstance(op, MapOp):
        spec = {name: infer_spec(e, schema) for name, e in op.make.fields}
        return Schema.dynamic(schema.name + "#map", spec)
    if isinstance(op, FlattenOp):
        spec = {}
        for p, (t, rep) in schema.spec().items():
            if p == op.path or p.startswith(op.path + "."):
                spec[p] = (t, False)
            else:
                spec[p] = (t, rep)
        return Schema.dynamic(schema.name + "#flat", spec)
    if isinstance(op, AggregateOp):
        spec: Dict[str, tuple] = {}
        for name, e in op.spec.keys:
            spec[name] = infer_spec(e, schema)
        for kind, name, e in op.spec.aggs:
            spec[name] = (INT, False) if kind in ("count",) else (DOUBLE,
                                                                  False)
        return Schema.dynamic(schema.name + "#agg", spec)
    if isinstance(op, JoinOp):
        spec = dict(schema.spec())
        rschema = op.right.schema_after(catalog)
        for p, s in rschema.spec().items():
            spec[f"{op.alias}.{p}"] = s
        return Schema.dynamic(schema.name + "#join", spec)
    if isinstance(op, SubFlowOp):
        spec = dict(schema.spec())
        rschema = catalog.schema_of(op.right_fdb)
        for p, s in rschema.spec().items():
            spec[f"{op.alias}.{p}"] = s
        return Schema.dynamic(schema.name + "#subflow", spec)
    if isinstance(op, ModelApplyOp):
        spec = dict(schema.spec())
        spec[op.output] = (DOUBLE, False)
        return Schema.dynamic(schema.name + "#model", spec)
    raise TypeError(f"unknown op {type(op).__name__}")


def fdb(name: str, session: Optional[Any] = None) -> Flow:
    """Start a flow from a registered FDb — ``fdb('Roads')`` (paper Fig. 1)."""
    return Flow(name, (), session)
