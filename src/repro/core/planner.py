"""Query planning (paper §4.3.4).

When a WFL query is submitted, a plan determines (i) which index probes
serve the ``find()`` predicate and what residual must be filtered after the
read, (ii) the minimal viable set of source columns to load (§4.3.3), (iii)
the split between remote (Server) stages, shuffle (Sharder) stages, and the
final Mixer stage, and (iv) the shard subset when sampling.

The planner is shared by both engines: Warp:AdHoc executes the plan
interactively; Warp:Flume translates the same plan into checkpointed batch
stages ("the logical model of data processing is maintained", §4.3).
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..fdb.fdb import FDb, Shard
from ..fdb.schema import Schema
from .exprs import (Between, BinOp, Expr, FieldRef, InRegion, InSet,
                    InSpaceTime, InSpaceTimeSeq, Lit, MakeProto,
                    required_paths)
from .flow import (AggregateOp, DistinctOp, FilterOp, FindOp, Flow,
                   FlattenOp, JoinOp, LimitOp, MapOp, ModelApplyOp, Op,
                   SampleOp, SortOp, SubFlowOp)

__all__ = ["IndexProbe", "RefineSpec", "Plan", "plan_flow",
           "split_find_pred", "probe_shard",
           "PartitionPlan", "partition_shards", "num_partitions",
           "PARTITIONS_ENV"]


# --------------------------------------------------------------------------
# Index probes
# --------------------------------------------------------------------------

@dataclass
class IndexProbe:
    path: str
    kind: str               # tag | range | location | area | spacetime
    args: tuple             # lookup arguments

    #: kinds whose postings are a *superset* of the predicate (cell/bucket
    #: granularity) — the conjunct additionally compiles to a
    #: :class:`RefineSpec`, the exact device-side pass behind the
    #: backend's ``refine_tracks`` op
    REFINE_KINDS = ("spacetime",)

    @property
    def needs_refine(self) -> bool:
        return self.kind in self.REFINE_KINDS

    def run(self, shard: Shard, backend=None) -> np.ndarray:
        """Probe bitmap for this conjunct.  ``backend`` (when given)
        lowers index tails that run behind the exec seam — currently the
        spacetime postings OR + span prune (``postings_bitmap``)."""
        idx = shard.index(self.path, self.kind)
        if idx is None:
            raise RuntimeError(f"missing index {self.kind} on {self.path}")
        if self.kind == "tag":
            vals = self.args[0]
            return idx.lookup_any(vals) if isinstance(vals, tuple) \
                else idx.lookup(vals)
        if self.kind == "range":
            lo, hi = self.args
            return idx.lookup(lo, hi)
        if self.kind == "location":
            return idx.lookup(self.args[0])
        if self.kind == "area":
            return idx.lookup_region(self.args[0])
        if self.kind == "spacetime":
            region, t0, t1 = self.args
            return idx.lookup(region, t0, t1, backend=backend)
        raise ValueError(self.kind)


def _indexable(e: Expr, schema: Schema) -> Optional[IndexProbe]:
    """Match one conjunct against the index vocabulary."""
    if isinstance(e, InRegion):
        f = e.field
        if schema.has(f.path):
            fld = schema.field(f.path)
            if "location" in fld.indexes:
                return IndexProbe(f.path, "location", (e.region,))
            if "area" in fld.indexes:
                return IndexProbe(f.path, "area", (e.region,))
        return None
    if isinstance(e, Between) and isinstance(e.a, FieldRef):
        if schema.has(e.a.path) and "range" in schema.field(e.a.path).indexes:
            return IndexProbe(e.a.path, "range", (e.lo, e.hi))
        return None
    if isinstance(e, BinOp) and e.op in ("eq", "le", "ge", "lt", "gt"):
        fr, lit = None, None
        if isinstance(e.a, FieldRef) and isinstance(e.b, Lit):
            fr, lit, op = e.a, e.b.value, e.op
        elif isinstance(e.b, FieldRef) and isinstance(e.a, Lit):
            flip = {"le": "ge", "ge": "le", "lt": "gt", "gt": "lt",
                    "eq": "eq"}
            fr, lit, op = e.b, e.a.value, flip[e.op]
        else:
            return None
        if not schema.has(fr.path):
            return None
        fld = schema.field(fr.path)
        if op == "eq" and "tag" in fld.indexes:
            return IndexProbe(fr.path, "tag", (lit,))
        if "range" in fld.indexes:
            if op == "eq":
                return IndexProbe(fr.path, "range", (lit, lit))
            if op in ("le", "lt"):
                return IndexProbe(fr.path, "range", (None, lit))
            if op in ("ge", "gt"):
                return IndexProbe(fr.path, "range", (lit, None))
        return None
    if isinstance(e, InSet) and isinstance(e.a, FieldRef):
        if schema.has(e.a.path) and "tag" in schema.field(e.a.path).indexes:
            return IndexProbe(e.a.path, "tag", (tuple(e.values),))
        return None
    if isinstance(e, InSpaceTime) and isinstance(e.field, FieldRef):
        f = e.field
        if schema.has(f.path) and \
                "spacetime" in schema.field(f.path).indexes:
            return IndexProbe(f.path, "spacetime", (e.region, e.t0, e.t1))
        return None
    return None


def _or_leaf_values(e: Expr) -> Optional[Tuple[str, tuple]]:
    """Tag-lookup leaf of a disjunction → (field path, values) or None."""
    if isinstance(e, InSet) and isinstance(e.a, FieldRef):
        return e.a.path, tuple(e.values)
    if isinstance(e, BinOp) and e.op == "eq":
        if isinstance(e.a, FieldRef) and isinstance(e.b, Lit):
            return e.a.path, (e.b.value,)
        if isinstance(e.b, FieldRef) and isinstance(e.a, Lit):
            return e.b.path, (e.a.value,)
    return None


def _indexable_or(e: Expr, schema: Schema) -> Optional[IndexProbe]:
    """Disjunction of tag lookups on one field → ``lookup_any`` bitmap OR.

    ``(p.city == 'SF') | IN(p.city, ['OAK', 'SJ'])`` compiles to one tag
    probe over the union of values — exact (tag postings are exact), so
    nothing is left for the residual filter.
    """
    if not (isinstance(e, BinOp) and e.op == "or"):
        return None
    leaves: List[Expr] = []

    def walk(x: Expr):
        if isinstance(x, BinOp) and x.op == "or":
            walk(x.a)
            walk(x.b)
        else:
            leaves.append(x)

    walk(e)
    path: Optional[str] = None
    values: List[Any] = []
    for leaf in leaves:
        got = _or_leaf_values(leaf)
        if got is None:
            return None
        p, vs = got
        if path is None:
            path = p
        elif path != p:
            return None               # mixed fields: not one bitmap OR
        values.extend(vs)
    if path is None or not schema.has(path) \
            or "tag" not in schema.field(path).indexes:
        return None
    return IndexProbe(path, "tag", (tuple(values),))


@dataclass
class RefineSpec:
    """Exact-refine stage over one ragged track field.

    AND of ``(region, t0, t1)`` space-time constraints, evaluated by the
    execution backend's ``refine_tracks`` / ``refine_tracks_batched`` op
    directly against the shard's resident CSR track buffers (one fused
    device pass), instead of a host residual-filter evaluation.

    ``edges`` is the ordering DAG over the constraint list (indices into
    ``constraints``): edge ``(i, j)`` requires the doc's *first hit* of
    constraint ``i`` — minimum timestamp among its satisfying points — to
    be strictly before its first hit of constraint ``j``.  The refine op
    evaluates edges against the per-(doc × constraint) first-hit table the
    same fused pass produces, so ordering adds no extra launches.

    ``min_counts``/``dwells`` carry the per-constraint count ("≥ k hits";
    ``k = 0`` vacuous) and dwell ("last − first ≥ d seconds") reductions —
    computed from the same one-hot compare pass's reduction tables, zero
    extra launches.  ``None`` means every constraint keeps the default
    (k = 1, no dwell) — the legacy spec shape.
    """
    path: str
    constraints: List[Tuple[Any, float, float]]
    edges: List[Tuple[int, int]] = dc_field(default_factory=list)
    min_counts: Optional[Tuple[int, ...]] = None
    dwells: Optional[Tuple[Optional[float], ...]] = None

    def vacuous(self, c: int) -> bool:
        """True when constraint ``c`` filters nothing: k = 0 and no dwell
        (a dwell forces ≥ 1 hit even under k = 0).  Vacuous windows must
        not prune shards, and their postings must not gate candidates."""
        return (self.min_counts is not None
                and int(self.min_counts[c]) <= 0
                and (self.dwells is None or self.dwells[c] is None))


def split_find_pred(pred: Expr, schema: Schema
                    ) -> Tuple[List[IndexProbe], List[RefineSpec],
                               Optional[Expr]]:
    """AND-split a find() predicate into index probes + track refines +
    residual filter.

    Conjuncts that match an index become probes (bitmap AND); everything
    else is evaluated as a post-read filter.  Two refinements:

      * a disjunction of tag lookups on one field (``IN``/``==``) compiles
        to a single ``TagIndex.lookup_any`` bitmap-OR probe instead of
        falling back to residual filtering,
      * ``InSpaceTime`` conjuncts (Tesseract constraints) compile to
        :class:`RefineSpec`\\ s — grouped per track field, evaluated exactly
        behind the backend's ``refine_tracks`` op — plus a *conservative*
        ``spacetime`` probe when the field is indexed (postings live at
        (cell × time-bucket) granularity).  They never enter the residual,
        so the exact pass runs on device instead of the host evaluator.
        ``InSpaceTimeSeq`` (ordered Tesseract) merges into the same
        per-path spec: its constraints append to the spec's list with one
        conservative probe each, and its ordering edges are offset to the
        merged indices — one fused refine launch per wave either way.
        Per-constraint count/dwell reductions ride the merged spec too;
        a ``k = 0`` (vacuous, "≥ 0 hits") constraint skips its spacetime
        probe — its postings are not a superset of "always true".
    """
    conjuncts: List[Expr] = []

    def walk(e: Expr):
        if isinstance(e, BinOp) and e.op == "and":
            walk(e.a)
            walk(e.b)
        else:
            conjuncts.append(e)

    walk(pred)
    probes: List[IndexProbe] = []
    refine_by_path: Dict[str, Tuple[List[Tuple[Any, float, float]],
                                    List[Tuple[int, int]], List[int],
                                    List[Optional[float]]]] = {}
    residual: List[Expr] = []
    for c in conjuncts:
        if isinstance(c, InSpaceTime) and isinstance(c.field, FieldRef):
            p = _indexable(c, schema)
            if p is not None:
                probes.append(p)
            cons, _, mcs, dws = refine_by_path.setdefault(
                c.field.path, ([], [], [], []))
            cons.append((c.region, c.t0, c.t1))
            mcs.append(1)
            dws.append(None)
            continue
        if isinstance(c, InSpaceTimeSeq) and isinstance(c.field, FieldRef):
            path = c.field.path
            cons, edges, mcs, dws = refine_by_path.setdefault(
                path, ([], [], [], []))
            off = len(cons)
            indexed = schema.has(path) \
                and "spacetime" in schema.field(path).indexes
            c_mcs = c.min_counts or (1,) * len(c.constraints)
            c_dws = c.dwells or (None,) * len(c.constraints)
            for ci, (region, t0, t1) in enumerate(c.constraints):
                if indexed and int(c_mcs[ci]) != 0:
                    probes.append(IndexProbe(path, "spacetime",
                                             (region, t0, t1)))
                cons.append((region, float(t0), float(t1)))
                mcs.append(int(c_mcs[ci]))
                dws.append(None if c_dws[ci] is None else float(c_dws[ci]))
            edges.extend((i + off, j + off) for i, j in c.edges)
            continue
        p = _indexable(c, schema) or _indexable_or(c, schema)
        if p is not None:
            probes.append(p)
        else:
            residual.append(c)
    res: Optional[Expr] = None
    for r in residual:
        res = r if res is None else BinOp("and", res, r)
    refines = []
    for path, (cs, edges, mcs, dws) in refine_by_path.items():
        default = all(k == 1 for k in mcs) and all(d is None for d in dws)
        refines.append(RefineSpec(
            path, cs, edges,
            min_counts=None if default else tuple(mcs),
            dwells=None if default else tuple(dws)))
    return probes, refines, res


def probe_shard(shard: Shard, probes: Sequence[IndexProbe],
                backend=None) -> np.ndarray:
    """Intersect all probe bitmaps through the execution backend.

    The numpy backend folds word-wise AND on the host; the jax backend
    stacks the probe postings into one [K, W] word buffer and AND-reduces
    them with the ``bitset`` kernel (``kernels.ops.bitmap_intersect``).
    """
    from ..exec.backend import as_backend   # lazy: exec imports this module
    be = as_backend(backend)
    return be.intersect_bitmaps(
        shard.all_bitmap(), [p.run(shard, backend=be) for p in probes])


# --------------------------------------------------------------------------
# Plans
# --------------------------------------------------------------------------

@dataclass
class Plan:
    source: str
    schema: Schema                   # source schema
    shard_ids: List[int]             # after sampling
    sample_fraction: float
    probes: List[IndexProbe]
    refines: List[RefineSpec]        # exact track refine behind the seam
    residual: Optional[Expr]
    source_paths: List[str]          # minimal viable read set
    server_ops: List[Op]             # record-parallel per shard
    mixer_ops: List[Op]              # final combine stage
    out_schema: Schema
    stats: Dict[str, Any] = dc_field(default_factory=dict)
    #: the FDb snapshot this plan was made against, pinned at plan time.
    #: Engines and the serve tier execute against *this* object — never a
    #: re-resolved ``catalog.get`` — so a streaming source appending (or
    #: compacting) between planning and execution cannot tear a query
    #: across generations: every query sees exactly one snapshot.
    db: Optional[FDb] = None

    def describe(self) -> str:
        lines = [f"plan for {self.source} "
                 f"[{len(self.shard_ids)} shards, sample={self.sample_fraction}]",
                 f"  read columns: {self.source_paths}"]
        if self.stats.get("pruned_shards"):
            lines.append(f"  time-partition pruning: "
                         f"{self.stats['pruned_shards']} shards skipped")
        for p in self.probes:
            lines.append(f"  index probe: {p.kind}({p.path})")
        for r in self.refines:
            order = f", {len(r.edges)} ordering edges" if r.edges else ""
            red = ""
            if r.min_counts is not None or r.dwells is not None:
                nk = sum(1 for k in (r.min_counts or ()) if int(k) != 1)
                nd = sum(1 for d in (r.dwells or ()) if d is not None)
                red = f", {nk} count / {nd} dwell reductions"
            lines.append(f"  track refine: {r.path} "
                         f"[{len(r.constraints)} constraints{order}{red}]")
        if self.residual is not None:
            lines.append("  residual filter: yes")
        lines.append(f"  server ops: "
                     f"{[type(o).__name__ for o in self.server_ops]}")
        lines.append(f"  mixer ops: "
                     f"{[type(o).__name__ for o in self.mixer_ops]}")
        return "\n".join(lines)


def plan_flow(flow: Flow, catalog) -> Plan:
    schema = catalog.schema_of(flow.source)
    db: FDb = catalog.get(flow.source)

    ops = list(flow.ops)

    # -- sampling: select a shard subset (paper §6: "sampling selects only a
    #    subset of shards to feed the query")
    fraction = 1.0
    kept_ops: List[Op] = []
    for op in ops:
        if isinstance(op, SampleOp):
            fraction *= op.fraction
        else:
            kept_ops.append(op)
    ops = kept_ops
    num_shards = db.num_shards
    n_keep = max(1, int(round(num_shards * fraction)))
    shard_ids = list(range(n_keep))            # round-robin ingest ⇒ unbiased

    # -- find(): split into probes + track refines + residual
    probes: List[IndexProbe] = []
    refines: List[RefineSpec] = []
    residual: Optional[Expr] = None
    if ops and isinstance(ops[0], FindOp):
        probes, refines, residual = split_find_pred(ops[0].pred, schema)
        ops = ops[1:]
    elif any(isinstance(o, FindOp) for o in ops):
        raise ValueError("find() must be the first operator on a source")

    # -- time-partitioned shard pruning (the BigQuery partitioned-table
    #    discipline): a space-time constraint window can only match docs
    #    in shards whose track time span overlaps it.  Constraints AND
    #    per doc, so a shard whose span misses *any* one window holds no
    #    possible match and is dropped from the enumeration — waves
    #    shrink, which the launch counter sees.  Shards with an unknown
    #    span (no spacetime index on the path, empty shard, every track
    #    empty) are conservatively kept.  Round-robin-built FDbs span the
    #    whole time range per shard and are never pruned; time-ordered
    #    streaming ingestion makes delta shards time-partitioned, which
    #    is where pruning bites.
    pruned_shards = 0
    if refines and shard_ids:
        kept: List[int] = []
        for sid in shard_ids:
            shard = db.shards[sid]
            drop = False
            for rf in refines:
                idx = shard.index(rf.path, "spacetime")
                span = idx.span() if idx is not None else None
                if span is None:
                    continue
                lo, hi = span
                # vacuous (k = 0, no dwell) windows filter nothing and
                # must not prune — the other constraints still can
                if any((t1 < lo or t0 > hi)
                       for ci, (_, t0, t1) in enumerate(rf.constraints)
                       if not rf.vacuous(ci)):
                    drop = True
                    break
            if not drop:
                kept.append(sid)
        pruned_shards = len(shard_ids) - len(kept)
        shard_ids = kept

    # -- server/mixer split: everything record-parallel runs on servers; the
    #    first global operator (aggregate/sort/limit/distinct without keys)
    #    and everything after it runs on the mixer over merged partials.
    server_ops: List[Op] = []
    mixer_ops: List[Op] = []
    on_server = True
    for op in ops:
        if on_server and isinstance(op, (MapOp, FilterOp, FlattenOp,
                                         ModelApplyOp, JoinOp, SubFlowOp)):
            server_ops.append(op)
        else:
            on_server = False
            mixer_ops.append(op)

    # -- minimal viable schema: source columns any server-side expression or
    #    raw-collect touches (paper §4.3.3)
    needed: set = set()
    saw_map = False
    residual_ops = [FindOp(residual)] if residual is not None else []
    for op in residual_ops + server_ops + mixer_ops:
        if saw_map:
            break           # later ops see the derived schema, not source
        exprs: List[Expr] = []
        if isinstance(op, FindOp) and op.pred is not None:
            exprs = [op.pred]
        elif isinstance(op, MapOp):
            exprs = [e for _, e in op.make.fields]
        elif isinstance(op, FilterOp):
            exprs = [op.pred]
        elif isinstance(op, SortOp):
            exprs = [op.expr]
        elif isinstance(op, DistinctOp) and op.expr is not None:
            exprs = [op.expr]
        elif isinstance(op, AggregateOp):
            exprs = [e for _, e in op.spec.keys] + \
                [e for _, _, e in op.spec.aggs if e is not None]
        elif isinstance(op, (JoinOp,)):
            exprs = [op.left_key]
        elif isinstance(op, SubFlowOp):
            exprs = [op.key]
        elif isinstance(op, ModelApplyOp):
            exprs = [e for _, e in op.inputs]
        for e in exprs:
            needed.update(required_paths(e, schema))
        if isinstance(op, (MapOp, AggregateOp)):
            saw_map = True
    for p in probes:
        # probes run on indices; location residual verification may still
        # need the columns — include them (cheap) for exactness checks
        if p.kind in ("location",):
            needed.update({p.path + ".lat", p.path + ".lng"})
    if not saw_map and not any(isinstance(o, AggregateOp)
                               for o in server_ops + mixer_ops):
        # raw collect: every stored column is semantically required
        needed.update(schema.leaf_paths())
    source_paths = sorted(x for x in needed
                          if schema.has(x)
                          and schema.field(x).virtual is None)

    out_schema = flow.schema_after(catalog)
    stats: Dict[str, Any] = {}
    if pruned_shards:
        stats["pruned_shards"] = pruned_shards
    return Plan(flow.source, schema, shard_ids, fraction, probes, refines,
                residual, source_paths, server_ops, mixer_ops, out_schema,
                stats=stats, db=db)


# --------------------------------------------------------------------------
# Partition layer: which device runs which shards
# --------------------------------------------------------------------------

#: env override for the number of execution partitions (engine arg wins).
PARTITIONS_ENV = "REPRO_EXEC_PARTITIONS"


@dataclass
class PartitionPlan:
    """Explicit shards -> P partitions assignment for one query.

    The partition layer sits between the planner (which enumerates and
    prunes ``Plan.shard_ids``) and the wave scheduler: each partition's
    shards are waved and dispatched independently (device-local under a
    mesh axis on the jax backend), and the per-shard segment-aggregate
    states are combined by a single ``merge_partials`` tail.  Partitions
    are contiguous slices of the pruned shard list, so flattening the
    per-partition results in partition order recovers global shard order
    — which is what keeps the merged aggregation bit-equal to the P=1
    sequential reference.
    """

    parts: List[List[int]]           # partition index -> shard ids

    @property
    def num_partitions(self) -> int:
        return len(self.parts)

    def sizes(self) -> List[int]:
        return [len(p) for p in self.parts]

    def wave_dispatches(self, wave: int) -> int:
        """Launch-contract helper: fused dispatches = sum over partitions
        of ceil(shards_p / wave).  Empty partitions dispatch nothing."""
        wave = max(1, int(wave))
        return sum(-(-len(p) // wave) for p in self.parts if p)

    def merge_combines(self) -> int:
        """Launch-contract helper: one ``merge_partials`` combine per
        aggregated query when more than one partition ran; the P=1 path
        is the legacy sequential merge (no combine launch)."""
        return 1 if sum(1 for p in self.parts if p) > 1 else 0


def partition_shards(shard_ids: Sequence[int], p: int) -> PartitionPlan:
    """Split an (already pruned) shard list into ``p`` contiguous
    partitions, balanced to within one shard (ragged counts allowed:
    ``p`` need not divide ``len(shard_ids)``; with fewer shards than
    partitions the tail partitions are empty)."""
    p = max(1, int(p))
    ids = list(shard_ids)
    base, extra = divmod(len(ids), p)
    parts: List[List[int]] = []
    lo = 0
    for i in range(p):
        hi = lo + base + (1 if i < extra else 0)
        parts.append(ids[lo:hi])
        lo = hi
    return PartitionPlan(parts)


def num_partitions(spec: Optional[int] = None, backend: Any = None) -> int:
    """Resolve the execution partition count: explicit engine arg >
    ``REPRO_EXEC_PARTITIONS`` > the accelerator mesh size (batched
    backends only — the host oracle defaults to a single partition)."""
    if spec is not None:
        return max(1, int(spec))
    import os

    env = os.environ.get(PARTITIONS_ENV, "").strip()
    if env:
        return max(1, int(env))
    if backend is not None and getattr(backend, "batched_dispatch", False):
        from ..launch.mesh import default_exec_partitions

        return default_exec_partitions()
    return 1
