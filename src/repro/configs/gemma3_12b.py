"""Gemma-3-12B [hf:google/gemma-3; unverified] — 5:1 local:global, 128k.

head_dim=256 (public config), sliding window 1024 on local layers, tanh
logit soft-capping.  5/6 of layers hold only a 1024-window cache ⇒ eligible
for long_500k (sub-quadratic in practice; the periodic global layer holds
the full cache — see DESIGN.md).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense",
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8,
    d_ff=15360, vocab_size=262144, head_dim=256,
    attention_pattern=("local", "local", "local", "local", "local",
                       "global"),
    window=1024, logit_softcap=50.0, rope_theta=1e6, act="gelu",
    tie_embeddings=True, sub_quadratic=True,
    source="hf:google/gemma-3-1b-pt (scaled per assignment)")
