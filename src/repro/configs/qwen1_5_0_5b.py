"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B] — dense, GQA kv=16 (MHA), QKV bias."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b", family="dense",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=2816, vocab_size=151936, qkv_bias=True, rope_theta=1e6,
    sub_quadratic=False, source="hf:Qwen/Qwen1.5-0.5B")
