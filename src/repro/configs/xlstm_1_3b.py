"""xLSTM-1.3B [arXiv:2405.04517; unverified] — sLSTM + mLSTM blocks.

xLSTM[7:1]: 7 mLSTM blocks per sLSTM block; 4 heads; no separate FFN
(d_ff=0) — projection factors live inside the blocks (mLSTM pf=2, sLSTM
pf=4/3 post-MLP).  O(1) recurrent state ⇒ long_500k runs (state cache, no
KV cache).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm",
                   "mlstm", "slstm"),
    pos="none", sub_quadratic=True, source="arXiv:2405.04517")
