"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01; unverified] — GQA, no bias."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22528, vocab_size=256000, rope_theta=8e6, tie_embeddings=True,
    sub_quadratic=False, source="hf:CohereForAI/c4ai-command-r-v01")
