"""Mixtral 8x7B [arXiv:2401.04088] — MoE 8 experts top-2, SWA(4096).

Sliding-window attention on every layer ⇒ rolling caches, sub-quadratic ⇒
long_500k runs.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    attention_pattern=("local",), window=4096,
    moe_experts=8, moe_top_k=2, moe_every=1, rope_theta=1e6,
    sub_quadratic=True, source="arXiv:2401.04088")
