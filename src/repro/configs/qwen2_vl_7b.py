"""Qwen2-VL-7B backbone [arXiv:2409.12191] — M-RoPE, dynamic resolution.

Vision frontend is a STUB: input_specs() provides token ids plus M-RoPE
position ids [3, B, S] (temporal/height/width streams; equal streams for
text).  QKV bias per the Qwen2 family.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, qkv_bias=True, mrope=True,
    frontend="vision_stub", rope_theta=1e6,
    sub_quadratic=False, source="arXiv:2409.12191")
