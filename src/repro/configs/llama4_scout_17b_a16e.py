"""Llama-4-Scout 17B-A16E [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

MoE 16 experts top-1 on every layer (public config unverified; the
chunked-attention variant is NOT assumed ⇒ treated as full attention,
long_500k skipped — see DESIGN.md).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    moe_experts=16, moe_top_k=1, moe_every=1, rope_theta=5e5,
    sub_quadratic=False, source="hf:meta-llama/Llama-4-Scout-17B-16E")
