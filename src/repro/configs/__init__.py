"""Architecture configs: one module per assigned arch + the paper's own
speed-prediction model (speed_model)."""
from .base import (ArchConfig, ShapeConfig, get_config, list_archs, SHAPES,
                   shape_cells)

__all__ = ["ArchConfig", "ShapeConfig", "get_config", "list_archs",
           "SHAPES", "shape_cells"]
