"""Whisper large-v3 backbone [arXiv:2212.04356; unverified] — enc-dec.

Conv frontend is a STUB: input_specs() provides precomputed frame
embeddings [B, S, D] for the encoder; the decoder is a standard
cross-attending transformer.  MHA (kv=20), GELU MLPs, LayerNorm, learned
positions (per the paper's architecture).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866, encoder_layers=32,
    frontend="audio_stub", pos="learned", act="gelu", norm="layernorm",
    sub_quadratic=False, source="arXiv:2212.04356")
