"""Jamba-v0.1 52B [arXiv:2403.19887] — Mamba+attention 1:7, MoE 16e top-2.

Jamba block: 8 layers with one attention layer (index 4), MoE MLP every
second layer; only 4/32 layers carry KV caches ⇒ long_500k runs.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    block_pattern=("mamba", "mamba", "mamba", "mamba", "attn", "mamba",
                   "mamba", "mamba"),
    moe_experts=16, moe_top_k=2, moe_every=2,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    pos="none",   # Jamba uses no positional encoding (Mamba provides order)
    sub_quadratic=True, source="arXiv:2403.19887")
