"""Architecture configs (`--arch <id>`): schema + registry.

Each assigned architecture gets one module in this package defining
``CONFIG``; ``get_config(name)`` resolves it.  ``reduced()`` produces the
smoke-test configuration (same family/block pattern, tiny dims) exercised
on CPU; FULL configs are touched only by the dry-run via ShapeDtypeStructs.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field as dc_field, replace
from typing import Dict, Optional, Tuple

__all__ = ["ArchConfig", "ShapeConfig", "get_config", "list_archs",
           "SHAPES", "shape_cells"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | audio | ssm | hybrid | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention features
    attention_pattern: Tuple[str, ...] = ("global",)   # cycles over layers
    window: Optional[int] = None
    qkv_bias: bool = False
    logit_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    mrope: bool = False
    # block types (cycled over layers): attn | mamba | mlstm | slstm
    block_pattern: Tuple[str, ...] = ("attn",)
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1               # MoE MLP every k-th layer (else dense)
    # capacity factor: 1.25 = GShard default (tokens may drop); set to
    # num_experts for dropless routing (exact train↔decode consistency)
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 1024       # dispatch group (S·E·C ∝ f·k·S²)
    # SSM
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # enc-dec (whisper)
    encoder_layers: int = 0
    frontend: Optional[str] = None   # audio_stub | vision_stub
    # misc
    pos: str = "rope"                # rope | learned | none
    act: str = "silu"
    act_dtype: str = "bfloat16"      # residual-stream dtype
    norm: str = "rmsnorm"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    sub_quadratic: bool = False      # eligible for long_500k
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def pattern_len(self) -> int:
        import math
        return max(len(self.block_pattern), len(self.attention_pattern)) \
            if len(self.block_pattern) % len(self.attention_pattern) == 0 \
            or len(self.attention_pattern) % len(self.block_pattern) == 0 \
            else len(self.block_pattern) * len(self.attention_pattern) // \
            math.gcd(len(self.block_pattern), len(self.attention_pattern))

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def layer_attn_kind(self, i: int) -> str:
        return self.attention_pattern[i % len(self.attention_pattern)]

    def layer_is_moe(self, i: int) -> bool:
        return self.moe_experts > 0 and (i % self.moe_every
                                         == self.moe_every - 1)

    def params_count(self) -> int:
        """Approximate parameter count N (for 6·N·D roofline math)."""
        d, f, hd = self.d_model, self.d_ff, self.hd
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                n += d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                    + self.num_heads * hd * d
            elif kind == "mamba":
                di = self.ssm_expand * d
                r = max(1, d // 16)
                n += d * 2 * di + di * (r + 2 * self.ssm_state) \
                    + r * di + di * self.ssm_conv + di * d
            elif kind == "mlstm":
                di = 2 * d
                dh_m = di // max(self.num_heads, 1)
                n += 2 * d * di + 3 * di * dh_m + di * d
            elif kind == "slstm":
                n += 4 * d * d + 4 * (d // max(self.num_heads, 1)) * d \
                    + d * d + 3 * d * (d * 4 // 3)
            if kind == "attn" or self.family in ("moe", "hybrid"):
                if self.layer_is_moe(i):
                    n += d * self.moe_experts + \
                        3 * self.moe_experts * d * f
                elif f > 0:
                    n += 3 * d * f
        for _ in range(self.encoder_layers):
            n += d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                + self.num_heads * hd * d + 2 * d * f   # gelu mlp (no gate)
        return n

    def active_params_count(self) -> int:
        """MoE: params touched per token (top-k of experts)."""
        if self.moe_experts == 0:
            return self.params_count()
        dense = replace(self, moe_experts=0, moe_top_k=0).params_count()
        moe_layers = sum(1 for i in range(self.num_layers)
                         if self.layer_is_moe(i))
        extra = moe_layers * (3 * self.d_model * self.d_ff
                              * (self.moe_top_k - 1))
        return dense + extra

    def reduced(self) -> "ArchConfig":
        """Smoke-test configuration: same family & patterns, tiny dims."""
        pat = len(self.block_pattern)
        apat = len(self.attention_pattern)
        import math
        cyc = pat * apat // math.gcd(pat, apat)
        layers = max(2 * cyc, 2)
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        return replace(
            self, num_layers=layers, d_model=64,
            num_heads=heads, num_kv_heads=kv, head_dim=16,
            d_ff=128 if self.d_ff else 0, vocab_size=256,
            moe_experts=min(self.moe_experts, 4) if self.moe_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            window=min(self.window, 16) if self.window else None,
            encoder_layers=2 if self.encoder_layers else 0,
            ssm_state=4, ssm_conv=4,
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "qwen1_5_0_5b", "gemma3_12b", "smollm_360m", "command_r_35b",
    "mixtral_8x7b", "llama4_scout_17b_a16e", "whisper_large_v3",
    "xlstm_1_3b", "jamba_v0_1_52b", "qwen2_vl_7b",
]

_ALIASES = {
    "qwen1.5-0.5b": "qwen1_5_0_5b", "gemma3-12b": "gemma3_12b",
    "smollm-360m": "smollm_360m", "command-r-35b": "command_r_35b",
    "mixtral-8x7b": "mixtral_8x7b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "whisper-large-v3": "whisper_large_v3", "xlstm-1.3b": "xlstm_1_3b",
    "jamba-v0.1-52b": "jamba_v0_1_52b", "qwen2-vl-7b": "qwen2_vl_7b",
}


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def list_archs():
    return list(ARCH_IDS)


def shape_cells(cfg: ArchConfig):
    """The (arch × shape) cells that apply (long_500k gating per DESIGN)."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(s)
    return out
