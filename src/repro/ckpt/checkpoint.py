"""Sharded, async, atomic checkpointing with elastic resharding.

The fault-tolerance contract for training at scale:

  * **sharded** — every host writes only the shards it owns (here: the
    addressable shards of each jax.Array), as ``<step>/shard-<host>.npz``;
  * **async** — ``save`` snapshots to host memory and hands the file IO to
    a background thread; training continues immediately;
  * **atomic** — writes go to ``<step>.tmp/`` and are committed with a
    single ``rename``; a crashed save can never be mistaken for a valid
    checkpoint (restore picks the newest *committed* step);
  * **elastic resharding** — restore takes the *target* shardings; arrays
    are assembled from saved pieces and re-placed with ``jax.device_put``,
    so a job can restart on a different mesh shape (scale up/down);
  * **retention** — keep-last-k GC.

The data pipeline checkpoints alongside (deterministic PRNG state), so a
restart replays no batch twice — see repro.data.pipeline.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx)
            for p in path)
        flat[key] = leaf
    return flat


def _unflatten_like(template, flat: Dict[str, Any]):
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in leaves_p:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx)
            for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        out.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, out)


def save_checkpoint(directory: str, step: int, tree, *,
                    blocking: bool = True) -> threading.Thread:
    """Write one step. Returns the writer thread (joined if blocking)."""
    tmp = os.path.join(directory, f"step-{step:08d}.tmp")
    final = os.path.join(directory, f"step-{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    # Snapshot to host memory NOW (async-safe even if arrays are donated).
    host: Dict[str, np.ndarray] = {}
    meta = {}
    for k, v in flat.items():
        arr = np.asarray(v)
        host[k.replace("/", "__")] = arr
        meta[k] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}

    def write():
        np.savez(os.path.join(tmp, "shard-00000.npz"), **host)
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as fh:
            json.dump({"step": step, "leaves": meta}, fh)
        os.replace(tmp, final)          # atomic commit

    t = threading.Thread(target=write, daemon=True)
    t.start()
    if blocking:
        t.join()
    return t


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.fullmatch(r"step-(\d+)", f))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template, *, step: Optional[int] = None,
                       shardings=None):
    """Restore into ``template``'s structure; ``shardings`` (same pytree
    structure, or None) re-places every leaf — the elastic-resharding path:
    the saved mesh shape is irrelevant, only the target's matters."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoints in {directory}")
    d = os.path.join(directory, f"step-{step:08d}")
    with np.load(os.path.join(d, "shard-00000.npz")) as z:
        flat = {k.replace("__", "/"): z[k] for k in z.files}
    tree = _unflatten_like(template, flat)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s) if s is not None else
            jax.numpy.asarray(x), tree, shardings)
    else:
        tree = jax.tree_util.tree_map(jax.numpy.asarray, tree)
    return tree, step


class CheckpointManager:
    """Async save + keep-last-k retention + restore-or-init."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._pending: List[threading.Thread] = []
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree, blocking: bool = False):
        t = save_checkpoint(self.directory, step, tree, blocking=blocking)
        self._pending.append(t)
        self._gc()
        return t

    def wait(self):
        for t in self._pending:
            t.join()
        self._pending.clear()

    def restore_or_none(self, template, shardings=None):
        if latest_step(self.directory) is None:
            return None, None
        self.wait()
        return restore_checkpoint(self.directory, template,
                                  shardings=shardings)

    def _gc(self):
        self.wait()
        steps = sorted(
            int(m.group(1)) for f in os.listdir(self.directory)
            if (m := re.fullmatch(r"step-(\d+)", f)))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step-{s:08d}"),
                          ignore_errors=True)
