"""Catalog & Structure managers (paper §4.3.1).

"*Structure manager* maintains a global repository of Protocol Buffers
structures defined statically or registered at run-time.  *Catalog manager*
maintains pointers to all registered FDbs, and maps them to Servers for
query and load distribution."

The Catalog manager here also owns *execution isolation* (§4.3.5): each
query must acquire a micro-cluster of server slots before it runs; when the
pool is exhausted, queries wait in a FIFO queue ("if resources are not
immediately available then the query waits in a queue").
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

from ..fdb.fdb import FDb
from ..fdb.schema import Schema

__all__ = ["Catalog", "StructureManager", "ResourceManager",
           "default_catalog"]


class StructureManager:
    def __init__(self):
        self._schemas: Dict[str, Schema] = {}

    def register(self, schema: Schema) -> None:
        self._schemas[schema.name] = schema

    def get(self, name: str) -> Schema:
        if name not in self._schemas:
            raise KeyError(f"schema {name!r} not registered; known: "
                           f"{sorted(self._schemas)}")
        return self._schemas[name]

    def names(self) -> List[str]:
        return sorted(self._schemas)


class ResourceManager:
    """Server-slot pool with FIFO admission (execution isolation, §4.3.5)."""

    def __init__(self, total_slots: int = 64):
        self.total_slots = total_slots
        self._free = total_slots
        self._cv = threading.Condition()
        self.stats = {"queries": 0, "waited": 0}

    def acquire(self, want: int, timeout: Optional[float] = None) -> int:
        """Blocks until ``min(want, total)`` slots are available; returns
        the grant size."""
        want = max(1, min(want, self.total_slots))
        with self._cv:
            self.stats["queries"] += 1
            if self._free < want:
                self.stats["waited"] += 1
            ok = self._cv.wait_for(lambda: self._free >= want,
                                   timeout=timeout)
            if not ok:
                raise TimeoutError("resource allocation timed out "
                                   "(query queue)")
            self._free -= want
            return want

    def release(self, n: int) -> None:
        with self._cv:
            self._free += n
            self._cv.notify_all()


class Catalog:
    """Registered FDbs + schemas + the shared server pool.

    Sources come in two flavours: **static** (a built :class:`FDb`) and
    **live** (a :class:`~repro.fdb.streaming.StreamingFDb` — anything
    with a ``snapshot()`` method).  ``get`` on a live source returns its
    current generation snapshot, so every query plans against a fresh,
    immutable view; the planner pins that snapshot into ``Plan.db`` and
    engines execute against the pin, never a re-resolve.  :meth:`live`
    exposes the mutable handle itself (the serve tier uses it to wire
    cache-invalidation listeners)."""

    def __init__(self, server_slots: int = 64):
        self._dbs: Dict[str, FDb] = {}
        self._live: Dict[str, object] = {}     # name → StreamingFDb
        self.structures = StructureManager()
        self.resources = ResourceManager(server_slots)

    def register(self, db) -> None:
        """Register a static ``FDb`` or a live streaming source (any
        object with ``name``/``schema``/``snapshot()``)."""
        if isinstance(db, FDb):
            self._dbs[db.name] = db
            self._live.pop(db.name, None)
        elif hasattr(db, "snapshot"):
            self._live[db.name] = db
            self._dbs.pop(db.name, None)
        else:
            raise TypeError(f"cannot register {type(db).__name__}: "
                            f"expected FDb or a snapshot()-able source")
        self.structures.register(db.schema)

    def get(self, name: str) -> FDb:
        live = self._live.get(name)
        if live is not None:
            return live.snapshot()
        if name not in self._dbs:
            raise KeyError(f"FDb {name!r} not registered; known: "
                           f"{sorted(set(self._dbs) | set(self._live))}")
        return self._dbs[name]

    def live(self, name: str):
        """The mutable streaming handle behind ``name``, or ``None`` for
        static (or unknown) sources."""
        return self._live.get(name)

    def schema_of(self, name: str) -> Schema:
        live = self._live.get(name)
        if live is not None:
            return live.schema
        return self.get(name).schema

    def names(self) -> List[str]:
        return sorted(set(self._dbs) | set(self._live))


_DEFAULT: Optional[Catalog] = None


def default_catalog() -> Catalog:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Catalog()
    return _DEFAULT
