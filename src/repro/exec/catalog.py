"""Catalog & Structure managers (paper §4.3.1).

"*Structure manager* maintains a global repository of Protocol Buffers
structures defined statically or registered at run-time.  *Catalog manager*
maintains pointers to all registered FDbs, and maps them to Servers for
query and load distribution."

The Catalog manager here also owns *execution isolation* (§4.3.5): each
query must acquire a micro-cluster of server slots before it runs; when the
pool is exhausted, queries wait in a FIFO queue ("if resources are not
immediately available then the query waits in a queue").
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

from ..fdb.fdb import FDb
from ..fdb.schema import Schema

__all__ = ["Catalog", "StructureManager", "ResourceManager",
           "default_catalog"]


class StructureManager:
    def __init__(self):
        self._schemas: Dict[str, Schema] = {}

    def register(self, schema: Schema) -> None:
        self._schemas[schema.name] = schema

    def get(self, name: str) -> Schema:
        if name not in self._schemas:
            raise KeyError(f"schema {name!r} not registered; known: "
                           f"{sorted(self._schemas)}")
        return self._schemas[name]

    def names(self) -> List[str]:
        return sorted(self._schemas)


class ResourceManager:
    """Server-slot pool with FIFO admission (execution isolation, §4.3.5)."""

    def __init__(self, total_slots: int = 64):
        self.total_slots = total_slots
        self._free = total_slots
        self._cv = threading.Condition()
        self.stats = {"queries": 0, "waited": 0}

    def acquire(self, want: int, timeout: Optional[float] = None) -> int:
        """Blocks until ``min(want, total)`` slots are available; returns
        the grant size."""
        want = max(1, min(want, self.total_slots))
        with self._cv:
            self.stats["queries"] += 1
            if self._free < want:
                self.stats["waited"] += 1
            ok = self._cv.wait_for(lambda: self._free >= want,
                                   timeout=timeout)
            if not ok:
                raise TimeoutError("resource allocation timed out "
                                   "(query queue)")
            self._free -= want
            return want

    def release(self, n: int) -> None:
        with self._cv:
            self._free += n
            self._cv.notify_all()


class Catalog:
    """Registered FDbs + schemas + the shared server pool."""

    def __init__(self, server_slots: int = 64):
        self._dbs: Dict[str, FDb] = {}
        self.structures = StructureManager()
        self.resources = ResourceManager(server_slots)

    def register(self, db: FDb) -> None:
        self._dbs[db.name] = db
        self.structures.register(db.schema)

    def get(self, name: str) -> FDb:
        if name not in self._dbs:
            raise KeyError(f"FDb {name!r} not registered; known: "
                           f"{sorted(self._dbs)}")
        return self._dbs[name]

    def schema_of(self, name: str) -> Schema:
        return self.get(name).schema

    def names(self) -> List[str]:
        return sorted(self._dbs)


_DEFAULT: Optional[Catalog] = None


def default_catalog() -> Catalog:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Catalog()
    return _DEFAULT
