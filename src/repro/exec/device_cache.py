"""Device-resident column buffers for the jax execution backend.

The per-shard hot loop used to ship every operand host→device on each
query.  The stable operands — a shard's :class:`~repro.fdb.columnar.Column`
value buffers, its valid-doc bitmap, and the ``spacetime`` index postings
arrays — never change after an FDb is built, so the jax backend puts them
on device **once per FDb open** (:meth:`JaxBackend.prime_fdb`) and reuses
the buffers across queries: the selective column read after filter→compact
gathers from the resident buffers instead of re-uploading the columns.

The cache is keyed by host-array identity.  A cached entry pins the host
array (so its ``id`` cannot be recycled), which is why only *priming*
inserts: transient arrays (probe bitmaps, residual masks, derived value
columns) pass through untouched.

Identity keying is also what makes priming **incremental for streaming
ingestion**: successive :meth:`~repro.fdb.streaming.StreamingFDb.snapshot`
generations share their sealed/delta ``Shard`` objects, so re-priming a
new generation re-uploads nothing that is already resident — only the
fresh delta buffers cost a host→device copy (``put`` on a known id is a
dict hit).  ``stats()["buffers"]`` therefore grows by exactly the delta
between generations, which the streaming tests assert.

Device puts run under ``jax.experimental.enable_x64`` so int64/float64/
uint64 buffers keep their width — the parity contract is byte-identical
results against the numpy oracle, and a silent f64→f32 truncation at put
time would break it.

On top of the identity-keyed buffers the cache holds **keyed derived
entries** (:meth:`put_keyed` / :meth:`get_keyed`): wave-stacked buffers the
fused pipeline derives from several primed arrays at once — stacked refine
track words per (FDb, wave partition), offset-coded group-code stacks,
value stacks, factorize results.  Keys are flat tuples whose int elements
are the ``id``s of the primed source arrays, so :meth:`drop` evicts every
derived entry alongside its sources when an FDb is collected.  Keyed
entries do not count toward ``len()`` / ``stats()["buffers"]`` — those
remain the primed-buffer census the priming tests assert.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = ["DeviceCache"]


class DeviceCache:
    """Identity-keyed host→device buffer cache (insert via :meth:`put`).

    All mutations run under one RLock: the serve layer opens and closes
    FDbs from worker threads while the scheduler primes waves, so put /
    drop / clear race without it.  The device put itself (host→device
    copy) stays outside the lock — only dict bookkeeping is guarded, and
    a duplicate concurrent put of the same array is harmless (last write
    wins; both device buffers alias the same bytes).
    """

    def __init__(self, jax_module):
        self._jax = jax_module
        self._jnp = jax_module.numpy
        self._lock = threading.RLock()
        # id(host array) → (host array pin, device buffer)
        self._buffers: Dict[int, Tuple[np.ndarray, object]] = {}
        # flat tuple key (tag, *source ids, ...) → derived stacked value
        self._keyed: Dict[tuple, object] = {}
        self.hits = 0
        self.misses = 0
        self.keyed_hits = 0
        #: buffers *eagerly* evicted on streaming snapshot turnover — a
        #: replaced generation's exclusive buffers (its memtable-tail
        #: shard) retired at re-prime time instead of waiting for the old
        #: snapshot's GC finalizer (see ``JaxBackend.prime_fdb``)
        self.retired_buffers = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._buffers)

    def nbytes(self) -> int:
        """Host-side bytes of everything resident (device mirror is 1:1)."""
        with self._lock:
            return sum(a.nbytes for a, _ in self._buffers.values())

    def put(self, arr: Optional[np.ndarray]):
        """Make ``arr`` device-resident; returns the device buffer."""
        if arr is None:
            return None
        key = id(arr)
        with self._lock:
            hit = self._buffers.get(key)
        if hit is not None:
            return hit[1]
        with self._jax.experimental.enable_x64():
            dev = self._jnp.asarray(arr)
        with self._lock:
            self._buffers[key] = (arr, dev)
        return dev

    def get(self, arr: np.ndarray):
        """Device buffer for ``arr`` if primed, else None (and count it)."""
        with self._lock:
            hit = self._buffers.get(id(arr))
            if hit is not None:
                self.hits += 1
                return hit[1]
            self.misses += 1
            return None

    def put_keyed(self, key: tuple, value) -> None:
        """Store a derived wave-stacked entry under a flat tuple key whose
        int elements are primed-source ``id``s (see module docstring)."""
        with self._lock:
            self._keyed[key] = value

    def get_keyed(self, key: tuple):
        """Derived entry for ``key`` if staged, else None (hits counted —
        the prefetch tests read ``keyed_hits``)."""
        with self._lock:
            hit = self._keyed.get(key)
            if hit is not None:
                self.keyed_hits += 1
            return hit

    def drop(self, keys, retired: bool = False) -> int:
        """Evict entries by key id (used by per-FDb finalizers so buffers
        of a collected FDb do not stay pinned forever).  Derived keyed
        entries referencing a dropped source id go with it.  Returns the
        number of buffers actually evicted; ``retired=True`` counts them
        on ``retired_buffers`` (the eager snapshot-turnover path)."""
        dropped = set(keys)
        evicted = 0
        with self._lock:
            for key in keys:
                if self._buffers.pop(key, None) is not None:
                    evicted += 1
            if self._keyed:
                self._keyed = {
                    k: v for k, v in self._keyed.items()
                    if not any(isinstance(e, int) and e in dropped for e in k)}
            if retired:
                self.retired_buffers += evicted
        return evicted

    def clear(self) -> None:
        with self._lock:
            self._buffers.clear()
            self._keyed.clear()
            self.hits = 0
            self.misses = 0
            self.keyed_hits = 0
            self.retired_buffers = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"buffers": len(self._buffers),
                    "nbytes": sum(a.nbytes
                                  for a, _ in self._buffers.values()),
                    "keyed": len(self._keyed), "hits": self.hits,
                    "misses": self.misses, "keyed_hits": self.keyed_hits,
                    "retired_buffers": self.retired_buffers}
