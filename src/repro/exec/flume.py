"""Warp:Flume — the checkpointed batch execution engine (paper §4.3.6).

The same logical plan as Warp:AdHoc, translated into batch stages with:

  * **stage-boundary checkpoints** — every shard task materializes its
    partial to disk with an atomic DONE marker; a re-run of the same job id
    skips completed tasks (auto-recovery after a crash, like Flume's
    checkpoint logs),
  * **retries with rerouting** — a persistently failing task is retried up
    to ``max_attempts`` times ("machine restarts and pipeline retries"),
  * **speculative execution** — when a task lags the median completed-task
    time by ``speculation_factor``, a backup duplicate is launched; first
    result wins (the classic MapReduce straggler mitigation),
  * **auto-scaling** — worker count per stage is sized from the number of
    tasks rather than fixed cluster size.

The paper notes ~25 % overhead versus a hand-written Flume job, bought back
5–10× in development time; ``benchmarks/bench_flume_overhead.py`` measures
our analog (stage checkpointing vs pure in-memory AdHoc).
"""
from __future__ import annotations

import hashlib
import os
import pickle
import tempfile
import threading
import time
from concurrent.futures import ThreadPoolExecutor, Future, wait, FIRST_COMPLETED
from typing import Dict, List, Optional, Set

from ..core.exprs import CollectedTable, FieldRef
from ..core.flow import AggregateOp, DistinctOp, Flow, JoinOp, LimitOp, SortOp
from ..core.planner import PartitionPlan, Plan, plan_flow
from ..fdb.columnar import ColumnBatch
from ..fdb.schema import Schema
from .adhoc import QueryProfile, QueryResult
from .backend import as_backend
from .batched import (merge_partition_partials, partition_waves,
                      resolve_partition_plan, run_wave_task, wave_size)
from .catalog import Catalog, default_catalog
from .config import ExecConfig
from .failures import FaultPlan, TaskFailure
from .processors import (aggregate_consume, aggregate_produce,
                         apply_distinct, apply_limit, apply_sort,
                         merge_agg_partials, run_record_ops)
from .task import ShardPartial, run_shard_task

__all__ = ["FlumeEngine"]


class FlumeEngine:
    def __init__(self, catalog: Optional[Catalog] = None,
                 ckpt_dir: Optional[str] = None,
                 max_workers: int = 8,
                 max_attempts: int = 4,
                 speculation: bool = True,
                 speculation_factor: float = 4.0,
                 backend=None, wave: Optional[int] = None,
                 partitions: Optional[int] = None,
                 config: Optional[ExecConfig] = None):
        self.catalog = catalog or default_catalog()
        # consolidated config (see exec.config): explicit config fields >
        # legacy kwargs (shims) > env > defaults
        self.config = (config or ExecConfig()).fill(
            backend=backend, wave=wave, partitions=partitions)
        self.backend = self.config.resolve_backend()
        self.wave = self.config.resolve_wave(self.backend)
        self.partitions = self.config.partitions
        self.ckpt_dir = ckpt_dir or os.path.join(tempfile.gettempdir(),
                                                 "warpflume")
        self.max_workers = max_workers
        self.max_attempts = max_attempts
        self.speculation = speculation
        self.speculation_factor = speculation_factor
        self.stats: Dict[str, int] = {"tasks_run": 0, "tasks_skipped": 0,
                                      "speculative_launched": 0,
                                      "speculative_won": 0, "retries": 0}

    # ----------------------------------------------------------------- api
    def collect(self, flow: Flow, fault_plan: Optional[FaultPlan] = None,
                job_id: Optional[str] = None) -> QueryResult:
        t0 = time.perf_counter()
        plan = plan_flow(flow, self.catalog)
        # pinned snapshot (see AdHocEngine.collect): never re-resolve
        db = plan.db if plan.db is not None else self.catalog.get(plan.source)
        self.backend.prime_fdb(db)          # device-resident columns
        job_id = job_id or self._job_id(flow)
        job_dir = os.path.join(self.ckpt_dir, job_id)
        os.makedirs(job_dir, exist_ok=True)

        tables: Dict[int, CollectedTable] = {}
        for op in plan.server_ops:
            if isinstance(op, JoinOp):
                rres = self.collect(op.right, fault_plan=fault_plan,
                                    job_id=job_id + "-r%08x" % (id(op) & 0xFFFFFFFF))
                if not isinstance(op.right_key, FieldRef):
                    raise TypeError("join right_key must be a field")
                tables[id(op)] = rres.to_dict(op.right_key.path)

        profile = QueryProfile(source=plan.source,
                               shards_total=len(plan.shard_ids))

        # Stage 1: shard tasks with checkpoints + speculation (auto-scaled).
        # Fault-free runs take the batched wave path (per-shard checkpoints
        # still written); with a fault plan installed the engine schedules
        # per-shard tasks so retries, rerouting, and speculation stay at
        # the simulated machine-failure boundary.
        workers = min(self.max_workers, max(1, len(plan.shard_ids)))
        # partition layer: resolve P and reroute partition-axis faults
        # before dispatch (launch.elastic); a fault plan that *only*
        # injects at the partition stage keeps the batched wave path —
        # per-shard faults still force per-shard task scheduling so
        # retries/speculation stay at the machine-failure boundary
        pplan = resolve_partition_plan(self.partitions, self.backend,
                                       plan, fault_plan, profile)
        wave_fn = None
        if fault_plan is None or fault_plan.stages() <= {"partition"}:
            def wave_fn(pi, sids, nxt=None):
                with self.backend.partition_context(pi,
                                                    pplan.num_partitions):
                    return run_wave_task(
                        db, plan, sids, tables, self.catalog, None,
                        stage="server", backend=self.backend,
                        prefetch_sids=nxt, fused=self.config.fused,
                        profile=self.config.profile)
        partials = self._run_stage(
            stage="server", job_dir=job_dir, task_ids=plan.shard_ids,
            fn=lambda sid: run_shard_task(db, plan, sid, tables,
                                          self.catalog, fault_plan,
                                          stage="server",
                                          backend=self.backend),
            workers=workers, profile=profile, wave_fn=wave_fn,
            pplan=pplan)

        # Stage 2 (Mixer): merge + finish — itself checkpointed.
        final_path = os.path.join(job_dir, "final.pkl")
        if os.path.exists(final_path):
            with open(final_path, "rb") as fh:
                batch = pickle.load(fh)
            self.stats["tasks_skipped"] += 1
        else:
            batch = self._mixer(plan, partials,
                                premerged=merge_partition_partials(
                                    db, plan, partials, self.backend,
                                    pplan))
            _atomic_pickle(batch, final_path)
        for p in partials:
            profile.rows_scanned += p.rows_scanned
            profile.rows_selected += p.rows_selected
            profile.bytes_read += p.bytes_read
            profile.cpu_ms += p.cpu_ms
            profile.io_ms += p.io_ms
        profile.shards_done = len(partials)
        profile.exec_ms = (time.perf_counter() - t0) * 1e3
        return QueryResult(batch, profile, plan)

    # --------------------------------------------------------------- stage
    def _run_stage(self, stage: str, job_dir: str, task_ids: List[int],
                   fn, workers: int, profile: QueryProfile,
                   wave_fn=None,
                   pplan: Optional[PartitionPlan] = None
                   ) -> List[ShardPartial]:
        stage_dir = os.path.join(job_dir, stage)
        os.makedirs(stage_dir, exist_ok=True)
        results: Dict[int, ShardPartial] = {}
        todo: List[int] = []
        for sid in task_ids:
            p = self._ckpt_path(stage_dir, sid)
            if os.path.exists(p):                       # auto-recovery
                with open(p, "rb") as fh:
                    results[sid] = pickle.load(fh)
                self.stats["tasks_skipped"] += 1
            else:
                todo.append(sid)

        if wave_fn is not None and todo:
            # batched pre-pass: one stacked dispatch per wave, waves run
            # concurrently on the stage's worker budget, same per-task
            # checkpoint files as the per-shard path.  A wave that errors
            # must not abort its siblings: completed waves still commit
            # their checkpoints (the point of stage-level recovery), and
            # the failed wave's shards fall through to the per-shard
            # machinery below, which retries or raises loudly.
            remaining: List[int] = []
            todo_set = set(todo)
            parts = (pplan.parts if pplan is not None else [list(todo)])
            # waves form *within* each partition (checkpointed shards
            # drop out first); the successor hint stays partition-local
            # so a fused backend prefetches onto that partition's device
            subs = []
            for pi, part in enumerate(parts):
                pw = partition_waves(
                    [sid for sid in part if sid in todo_set], self.wave)
                for j, w in enumerate(pw):
                    subs.append((pi, w, pw[j + 1] if j + 1 < len(pw)
                                 else None))
            with ThreadPoolExecutor(
                    max_workers=min(workers, len(subs))) as pool:
                futs = [(pool.submit(wave_fn, pi, wave, nxt), wave)
                        for pi, wave, nxt in subs]
                for fut, wave in futs:
                    try:
                        done, failed = fut.result()
                    except Exception:
                        remaining.extend(wave)
                        continue
                    for out in done:
                        results[out.shard_id] = out
                        _atomic_pickle(
                            out, self._ckpt_path(stage_dir, out.shard_id))
                    self.stats["tasks_run"] += len(done)
                    remaining.extend(failed)
            todo = remaining

        if not todo:
            return [results[sid] for sid in task_ids if sid in results]

        winner_lock = threading.Lock()
        done_times: List[float] = []

        def attempt(sid: int) -> ShardPartial:
            last: Optional[Exception] = None
            for k in range(self.max_attempts):
                try:
                    t0 = time.perf_counter()
                    out = fn(sid)
                    done_times.append(time.perf_counter() - t0)
                    return out
                except TaskFailure as e:   # reroute / retry with backoff
                    last = e
                    self.stats["retries"] += 1
                    profile.retries += 1
                    time.sleep(0.001 * (2 ** k))
            raise last  # type: ignore[misc]

        def commit(sid: int, out: ShardPartial, speculative: bool) -> bool:
            with winner_lock:
                if sid in results:
                    return False
                results[sid] = out
                if speculative:
                    self.stats["speculative_won"] += 1
            _atomic_pickle(out, self._ckpt_path(stage_dir, sid))
            return True

        stage_errors: List[Exception] = []
        with ThreadPoolExecutor(max_workers=workers) as pool:
            futs: Dict[Future, tuple] = {
                pool.submit(attempt, sid): (sid, False) for sid in todo}
            self.stats["tasks_run"] += len(todo)
            launched_backup: Set[int] = set()
            pending = set(futs)
            start = {sid: time.perf_counter() for sid in todo}
            while pending:
                done, pending = wait(pending, timeout=0.02,
                                     return_when=FIRST_COMPLETED)
                for f in done:
                    sid, spec = futs[f]
                    try:
                        out = f.result()
                    except Exception as e:
                        # exhausted retries: keep draining so *completed*
                        # siblings still commit their checkpoints — the
                        # whole point of stage-level recovery
                        stage_errors.append(e)
                        continue
                    commit(sid, out, spec)
                # straggler detection → speculative backups
                if self.speculation and len(done_times) >= 2:
                    med = sorted(done_times)[len(done_times) // 2]
                    now = time.perf_counter()
                    for f in list(pending):
                        sid, spec = futs[f]
                        if (not spec and sid not in launched_backup
                                and sid not in results
                                and now - start[sid]
                                > self.speculation_factor * max(med, 1e-4)):
                            launched_backup.add(sid)
                            self.stats["speculative_launched"] += 1
                            nf = pool.submit(attempt, sid)
                            futs[nf] = (sid, True)
                            pending.add(nf)
        if stage_errors:
            raise stage_errors[0]
        return [results[sid] for sid in task_ids if sid in results]

    # --------------------------------------------------------------- mixer
    def _mixer(self, plan: Plan, partials: List[ShardPartial],
               premerged=None) -> ColumnBatch:
        mixer_ops = list(plan.mixer_ops)
        if mixer_ops and isinstance(mixer_ops[0], AggregateOp):
            spec = mixer_ops[0].spec
            # ``premerged``: the partition layer's single-launch device
            # combine (see batched.merge_partition_partials)
            merged = premerged if premerged is not None else \
                merge_agg_partials(
                    [p.agg for p in partials if p.agg is not None], spec)
            batch = aggregate_consume(merged, spec)
            mixer_ops = mixer_ops[1:]
        else:
            batches = [p.batch for p in partials if p.batch is not None]
            batch = ColumnBatch.concat(batches) if batches else \
                ColumnBatch(plan.out_schema, {}, 0)
        for op in mixer_ops:
            if isinstance(op, SortOp):
                batch = apply_sort(batch, op)
            elif isinstance(op, LimitOp):
                batch = apply_limit(batch, op.k)
            elif isinstance(op, DistinctOp):
                batch = apply_distinct(batch, op.expr)
            elif isinstance(op, AggregateOp):
                batch = aggregate_consume(
                    aggregate_produce(batch, op.spec, self.backend), op.spec)
            else:
                batch = run_record_ops(batch, [op], self.catalog, None,
                                       backend=self.backend)
        return batch

    # ------------------------------------------------------------- helpers
    @staticmethod
    def _ckpt_path(stage_dir: str, sid: int) -> str:
        return os.path.join(stage_dir, f"task-{sid:05d}.done.pkl")

    @staticmethod
    def _job_id(flow: Flow) -> str:
        return hashlib.blake2b(repr(flow).encode(),
                               digest_size=8).hexdigest()


def _atomic_pickle(obj, path: str) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as fh:
        pickle.dump(obj, fh)
    os.replace(tmp, path)     # atomic commit — the DONE marker is the file
