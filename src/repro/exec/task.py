"""The per-shard server task, shared by Warp:AdHoc and Warp:Flume.

This is the unit of distribution and the unit of failure: index probe →
exact track refine (Tesseract constraints, behind the backend's
``refine_tracks`` op) → selective column read → residual filter →
record-parallel ops → (aggregate_produce | pre-sorted batch).  Both
engines schedule it; they differ only in what happens when it fails or
lags (§4.3.5 vs §4.3.6).

Healthy shards normally run through the *fused* wave path instead
(``run_wave_task`` → ``backend.run_wave_fused``, one dispatch per wave);
this per-shard task remains the retry/recovery unit and the per-primitive
oracle the fused results are parity-tested against.  The index probe here
goes through ``probe_shard``, which also lowers the spacetime postings OR
behind the backend seam (``postings_bitmap``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from ..core.exprs import CollectedTable
from ..core.flow import AggregateOp, LimitOp, SortOp
from ..core.planner import Plan, probe_shard
from ..fdb.columnar import ColumnBatch
from ..fdb.fdb import FDb
from ..fdb.index import mask_from_bitmap
from .backend import as_backend
from .failures import FaultPlan
from .processors import (AggPartial, aggregate_produce, apply_filter,
                         apply_limit, apply_sort, run_record_ops)

__all__ = ["ShardPartial", "run_shard_task"]


@dataclass
class ShardPartial:
    shard_id: int = -1
    batch: Optional[ColumnBatch] = None
    agg: Optional[AggPartial] = None
    rows_scanned: int = 0
    rows_selected: int = 0
    bytes_read: int = 0
    cpu_ms: float = 0.0
    io_ms: float = 0.0
    #: raw fused segment-aggregate state ``(uniq_keys, slots)`` for the
    #: partition layer's ``merge_partials`` combine; only the fused
    #: gather-free agg path fills it (``None`` elsewhere, including the
    #: per-shard retry path — the engines then fall back to the host
    #: AggPartial merge, which is partition-invariant by construction).
    seg: Optional[tuple] = None


def run_shard_task(db: FDb, plan: Plan, shard_id: int,
                   tables: Optional[Dict[int, CollectedTable]],
                   catalog, fault_plan: Optional[FaultPlan] = None,
                   stage: str = "server", backend=None) -> ShardPartial:
    if fault_plan is not None:
        fault_plan.check(stage, shard_id)
    backend = as_backend(backend)
    t0 = time.perf_counter()
    shard = db.shards[shard_id]
    bm = probe_shard(shard, plan.probes, backend)
    if plan.refines:
        mask = mask_from_bitmap(bm, shard.n)
        n_cand = int(mask.sum())
        for rf in plan.refines:
            mask = backend.refine_tracks(shard.batch, rf.path,
                                         rf.constraints, mask,
                                         edges=rf.edges,
                                         min_counts=rf.min_counts,
                                         dwells=rf.dwells)
        ids = backend.compact_mask(mask)
    else:
        ids = backend.select_ids(bm, shard.n)
        n_cand = len(ids)
    t1 = time.perf_counter()
    paths = [p for p in plan.source_paths if p in shard.batch.columns]
    if not paths:
        paths = shard.batch.paths()
    batch = shard.batch.select_paths(paths).gather(ids)
    t2 = time.perf_counter()
    out = ShardPartial(shard_id=shard_id, rows_scanned=shard.n,
                       rows_selected=n_cand, bytes_read=batch.nbytes(),
                       io_ms=(t2 - t1) * 1e3)
    if plan.residual is not None:
        batch = apply_filter(batch, plan.residual, backend)
    batch = run_record_ops(batch, plan.server_ops, catalog, tables,
                           backend=backend)
    if plan.mixer_ops and isinstance(plan.mixer_ops[0], AggregateOp):
        out.agg = aggregate_produce(batch, plan.mixer_ops[0].spec, backend)
    else:
        pre = batch
        if (len(plan.mixer_ops) >= 2
                and isinstance(plan.mixer_ops[0], SortOp)
                and isinstance(plan.mixer_ops[1], LimitOp)):
            pre = apply_limit(apply_sort(pre, plan.mixer_ops[0]),
                              plan.mixer_ops[1].k)
        out.batch = pre
    out.cpu_ms = (time.perf_counter() - t0) * 1e3
    return out
