"""Batched multi-shard wave execution (the stacked-shard hot path).

Per-shard dispatch pays one kernel launch per shard per primitive — the
dominant scaling cliff once shard counts reach the hundreds the paper runs
(§4–5).  This module groups a plan's shards into **waves** and drives each
wave through the backend's batched ops, so a wave costs:

  * one ``probe_shards`` launch       (stacked bitmap AND + popcount),
  * one ``refine_tracks_batched`` launch per track-refine spec (the exact
    Tesseract point-in-cover × time-window pass, fused on device),
  * one ``compact_masks`` launch      (stacked selection → doc ids),
  * one ``compact_masks`` launch      for the residual filter (if any),
  * one ``segment_aggregate_batched`` launch per aggregated value column,

instead of the same set *per shard* — ⌈shards/wave⌉ launches per primitive
per query (asserted by ``tests/test_batched.py`` / ``tests/test_refine.py``
via the kernel launch counter).  The numpy backend's batched ops loop
shard-by-shard, so the wave runner is byte-identical to the per-shard path
on both backends.

**Fused dispatch.**  When the backend amortizes batched launches and the
plan has no residual filter and at most one refine spec, the wave instead
runs through ``backend.run_wave_fused`` — probe → refine → compact →
(segment-agg) as ONE device dispatch (``kernels.fused``), tightening the
contract to ⌈shards/wave⌉ **total** launches per query.  Plans whose
aggregation is a single dense int-key group-by with only
count/sum/avg/std_dev/min/max (``fused_agg_plan``) skip the column gather
entirely: the fused dispatch returns per-group partial sums and
``_fused_agg_finalize`` reproduces the host aggregation byte-for-byte.
Other plans run the fused selection stages and keep the legacy
gather/processor tail.  ``REPRO_EXEC_FUSED=0`` forces the per-primitive
path (the CI leg that keeps it covered); a backend may also decline a
wave (``run_wave_fused`` → None) and fall back.  ``prefetch_sids`` names
the *next* wave so its stacked buffers upload while this wave computes.

Engines schedule waves onto their worker pools; shards whose fault check
trips at wave start are returned to the caller for the engine's per-shard
retry/recovery machinery (``run_shard_task``), which keeps the failure
unit a single shard.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.exprs import CollectedTable, FieldRef
from ..core.flow import AggregateOp, LimitOp, SortOp
from ..core.planner import (PartitionPlan, Plan, num_partitions,
                            partition_shards)
from ..fdb.fdb import FDb
from ..fdb.index import mask_from_bitmap
from .backend import as_backend
from .failures import FaultPlan, TaskFailure
from .processors import (AggPartial, aggregate_produce_batched, apply_limit,
                         apply_sort, predicate_mask, run_record_ops)
from .task import ShardPartial

__all__ = ["DEFAULT_WAVE", "WAVE_ENV", "FUSED_ENV", "wave_size",
           "partition_waves", "fused_enabled", "FusedAggPlan",
           "fused_agg_plan", "run_wave_task",
           "merge_partition_partials", "resolve_partition_plan"]

DEFAULT_WAVE = 8
WAVE_ENV = "REPRO_EXEC_WAVE"
FUSED_ENV = "REPRO_EXEC_FUSED"


def fused_enabled(override: Optional[bool] = None) -> bool:
    """Fused whole-wave dispatch is on unless ``REPRO_EXEC_FUSED=0``.
    An explicit ``override`` (``ExecConfig.fused``) wins over the env."""
    if override is not None:
        return bool(override)
    return os.environ.get(FUSED_ENV, "") != "0"


def wave_size(spec: Optional[int] = None, backend=None) -> int:
    """Shards per wave: explicit argument > $REPRO_EXEC_WAVE > backend
    default (``DEFAULT_WAVE`` when the backend's batched ops amortize
    kernel launches, else 1 — a loop-over-shards backend gains nothing
    from wide waves and would only lose per-shard thread parallelism)."""
    if spec is not None:
        return max(1, int(spec))
    env = os.environ.get(WAVE_ENV)
    if env:
        return max(1, int(env))
    if backend is not None and not getattr(backend, "batched_dispatch",
                                           False):
        return 1
    return DEFAULT_WAVE


def partition_waves(shard_ids: Sequence[int], wave: int) -> List[List[int]]:
    sids = list(shard_ids)
    return [sids[i:i + wave] for i in range(0, len(sids), wave)]


# --------------------------------------------------------------------------
# Fused aggregation plan — when the group-by can run inside the fused
# dispatch (no column gather at all)
# --------------------------------------------------------------------------

@dataclass
class FusedAggPlan:
    """Group-by lowered into the fused dispatch's segment stage.

    ``key_path`` is the single dense int-key column, ``value_paths`` the
    distinct aggregated columns (one segment slot each, deduplicated in
    first-use order — matching the host path's expression-level dedup),
    and ``slot_of[i]`` maps ``spec.aggs[i]`` to its value slot (``None``
    for count, which reads any slot's per-group row counts).
    """

    spec: object                       # core.exprs.AggSpec
    key_path: str
    value_paths: List[str]
    slot_of: List[Optional[int]]
    #: per value slot: True when some min/max agg reads that column, so
    #: the fused dispatch extends the slot with segment min/max planes
    minmax: Tuple[bool, ...] = ()

    def factorize(self, shard, backend=None):
        """``(group_keys, row_codes int32, num_groups)`` over the shard's
        FULL key column (``np.unique`` — sorted keys, same order the host
        path's single-int-key fast path produces).  Cached through the
        backend's DeviceCache keyed entries when the column is primed, so
        repeated queries skip the host unique."""
        kvals = shard.batch[self.key_path].values
        cache = getattr(backend, "device_cache", None)
        primed = getattr(backend, "_primed_refs", None)
        use_cache = (cache is not None and primed is not None
                     and id(kvals) in primed)
        key = ("agg_fact", id(kvals))
        if use_cache:
            hit = cache.get_keyed(key)
            if hit is not None:
                return hit
        uniq, inv = np.unique(kvals, return_inverse=True)
        out = (uniq, inv.reshape(-1).astype(np.int32), int(uniq.size))
        if use_cache:
            cache.put_keyed(key, out)
        return out


def fused_agg_plan(plan: Plan, shards) -> Optional[FusedAggPlan]:
    """Eligibility for the fused aggregation stage, or ``None``.

    Requirements (everything else falls back to the gather + host
    aggregation tail, still behind the fused *selection* stages):

      * the plan's first mixer op is the aggregate, with no server ops and
        no residual (both need gathered/derived columns host-side),
      * exactly one group key, a plain field ref to a dense non-vocab
        int-like column on every shard,
      * only count/sum/avg/std_dev/min/max aggs (approx_distinct needs the
        selected rows themselves), each over a plain field ref to a dense
        non-vocab numeric column — min/max ride as extra segment planes on
        their value slot,
      * every read-set column dense, so ``bytes_read`` stays exact without
        gathering (ragged nbytes depends on the selected rows' spans).
    """
    if plan.residual is not None or plan.server_ops:
        return None
    if not plan.mixer_ops or not isinstance(plan.mixer_ops[0], AggregateOp):
        return None
    spec = plan.mixer_ops[0].spec
    if len(spec.keys) != 1 or not isinstance(spec.keys[0][1], FieldRef):
        return None
    key_path = spec.keys[0][1].path

    def dense(path: str, int_key: bool = False) -> bool:
        for sh in shards:
            col = sh.batch.columns.get(path)
            if col is None or col.row_splits is not None \
                    or col.vocab is not None:
                return False
            if col.values.dtype.kind not in ("biu" if int_key else "biuf"):
                return False
        return True

    if not dense(key_path, int_key=True):
        return None
    value_paths: List[str] = []
    slot_of: List[Optional[int]] = []
    minmax_slots: set = set()
    for kind, _name, e in spec.aggs:
        if kind == "count" and e is None:
            slot_of.append(None)
            continue
        if kind not in ("sum", "avg", "std_dev", "min", "max") \
                or not isinstance(e, FieldRef) or not dense(e.path):
            return None
        if e.path not in value_paths:
            value_paths.append(e.path)
        slot = value_paths.index(e.path)
        slot_of.append(slot)
        if kind in ("min", "max"):
            minmax_slots.add(slot)
    for sh in shards:
        paths = [p for p in plan.source_paths if p in sh.batch.columns]
        if not paths:
            paths = sh.batch.paths()
        if any(sh.batch[p].row_splits is not None for p in paths):
            return None
    return FusedAggPlan(spec, key_path, value_paths, slot_of,
                        tuple(i in minmax_slots
                              for i in range(len(value_paths))))


def _fused_agg_finalize(agg: FusedAggPlan, uniq: np.ndarray,
                        slots) -> AggPartial:
    """Per-shard ``AggPartial`` from the fused dispatch's segment sums —
    the same accumulator formats ``processors._agg_finalize`` builds, for
    the groups with at least one selected row (the host path factorizes
    the *gathered* rows, so zero-count groups never exist there)."""
    part = AggPartial()
    if len(uniq) == 0 or not slots:
        return part
    cnt = slots[0][0]
    keep = cnt > 0
    if not keep.any():
        return part
    counts = cnt[keep]
    per_agg: List[list] = []
    for (kind, _name, _e), slot in zip(agg.spec.aggs, agg.slot_of):
        if kind == "count":
            per_agg.append([int(c) for c in counts])
            continue
        s = slots[slot][1][keep]
        if kind == "sum":
            per_agg.append([float(x) for x in s])
        elif kind == "avg":
            per_agg.append([(float(x), int(c))
                            for x, c in zip(s, counts)])
        elif kind == "min":
            per_agg.append([float(x) for x in slots[slot][3][keep]])
        elif kind == "max":
            per_agg.append([float(x) for x in slots[slot][4][keep]])
        else:                                            # std_dev
            s2 = slots[slot][2][keep]
            per_agg.append([(float(x), float(y), int(c))
                            for x, y, c in zip(s, s2, counts)])
    for g, v in enumerate(uniq[keep].tolist()):
        part.groups[(v,)] = [col[g] for col in per_agg]
    return part


def run_wave_task(db: FDb, plan: Plan, sids: Sequence[int],
                  tables: Optional[Dict[int, CollectedTable]],
                  catalog, fault_plan: Optional[FaultPlan] = None,
                  stage: str = "server", backend=None,
                  prefetch_sids: Optional[Sequence[int]] = None,
                  fused: Optional[bool] = None,
                  profile: Optional[bool] = None
                  ) -> Tuple[List[ShardPartial], List[int]]:
    """Run one wave of shard tasks through the batched backend seam.

    Returns ``(partials, failed_shard_ids)``: shards whose fault check
    trips are excluded from the wave and handed back for the engine's
    per-shard retry path.  ``prefetch_sids`` — the next wave's shard ids —
    lets a fused backend stage that wave's device buffers while this one
    computes (double-buffered upload; ignored on host backends).
    """
    backend = as_backend(backend)
    failed: List[int] = []
    live: List[int] = []
    for sid in sids:
        if fault_plan is not None:
            try:
                fault_plan.check(stage, sid)
            except TaskFailure:
                failed.append(sid)
                continue
        live.append(sid)
    if not live:
        return [], failed

    t0 = time.perf_counter()
    shards = [db.shards[sid] for sid in live]
    # probe bitmaps stay host-built (index lookups over host postings) so
    # the fused path's launch count is exactly the fused dispatches
    probe_bms = [[p.run(sh) for p in plan.probes] for sh in shards]

    # ---- fused whole-wave dispatch: probe → refine → compact → (agg) in
    # ONE launch when the backend and plan shape allow it
    fused_out = None
    fused_agg: Optional[FusedAggPlan] = None
    if (fused_enabled(fused) and getattr(backend, "batched_dispatch", False)
            and plan.residual is None and len(plan.refines) <= 1):
        fused_agg = fused_agg_plan(plan, shards)
        pre = ([db.shards[s] for s in prefetch_sids]
               if prefetch_sids else None)
        fused_out = backend.run_wave_fused(
            shards, probe_bms,
            plan.refines[0] if plan.refines else None, fused_agg,
            prefetch_shards=pre, profile=profile)
        if fused_out is None:                 # backend declined this wave
            fused_agg = None

    if fused_out is not None:
        n_cands, ids_list, seg = fused_out
        trace = getattr(backend, "trace_events", None)
        if trace is not None:
            trace.append(("wave_done", tuple(live)))
    else:
        # ---- per-primitive path: one launch per primitive per wave
        seg = None
        bms = backend.probe_shards(
            [sh.all_bitmap() for sh in shards], probe_bms)
        masks = [mask_from_bitmap(bm, sh.n) for bm, sh in zip(bms, shards)]
        # rows_selected reports the *index-selected* candidates
        # (pre-refine), matching the per-shard path and tesseract_stats'
        # candidate counts
        n_cands = [int(m.sum()) for m in masks]
        # ---- exact track refine: one fused device launch per wave per
        # spec, emitting per-doc hit masks that feed the selection compact
        for rf in plan.refines:
            masks = backend.refine_tracks_batched(
                [sh.batch for sh in shards], rf.path, rf.constraints,
                masks, edges=rf.edges, min_counts=rf.min_counts,
                dwells=rf.dwells)
        ids_list = backend.compact_masks(masks)
    t1 = time.perf_counter()

    # ---- gather-free aggregation tail: the fused dispatch already holds
    # the per-group sums; bytes_read is exact analytically because the
    # read set is all-dense (fused_agg_plan guarantees it)
    if fused_agg is not None:
        partials = []
        for i, (sid, sh, ids, n_cand) in enumerate(
                zip(live, shards, ids_list, n_cands)):
            paths = [p for p in plan.source_paths if p in sh.batch.columns]
            if not paths:
                paths = sh.batch.paths()
            nbytes = int(ids.size) * sum(
                int(sh.batch[p].values.dtype.itemsize) for p in paths)
            part = ShardPartial(shard_id=sid, rows_scanned=sh.n,
                                rows_selected=n_cand, bytes_read=nbytes)
            uniq, slots = seg[i]
            part.agg = _fused_agg_finalize(fused_agg, uniq, slots)
            part.seg = (uniq, slots)
            partials.append(part)
        io_each = (time.perf_counter() - t1) * 1e3 / len(live)
        cpu_each = (time.perf_counter() - t0) * 1e3 / len(live)
        for part in partials:
            part.io_ms = io_each
            part.cpu_ms = cpu_each
        return partials, failed

    # ---- selective column read (device-resident buffers when primed)
    partials: List[ShardPartial] = []
    batches = []
    for sid, sh, ids, n_cand in zip(live, shards, ids_list, n_cands):
        paths = [p for p in plan.source_paths if p in sh.batch.columns]
        if not paths:
            paths = sh.batch.paths()
        batch = backend.gather_columns(sh.batch, paths, ids)
        partials.append(ShardPartial(shard_id=sid, rows_scanned=sh.n,
                                     rows_selected=n_cand,
                                     bytes_read=batch.nbytes()))
        batches.append(batch)
    t2 = time.perf_counter()

    # ---- residual filter: masks host-evaluated, compacted in one launch
    if plan.residual is not None:
        keeps = backend.compact_masks(
            [predicate_mask(b, plan.residual) for b in batches])
        batches = [b.gather(k) for b, k in zip(batches, keeps)]
    batches = [run_record_ops(b, plan.server_ops, catalog, tables,
                              backend=backend) for b in batches]

    # ---- tail: wave-batched aggregation, or per-shard presort/limit
    if plan.mixer_ops and isinstance(plan.mixer_ops[0], AggregateOp):
        aggs = aggregate_produce_batched(batches, plan.mixer_ops[0].spec,
                                         backend)
        for part, agg in zip(partials, aggs):
            part.agg = agg
    else:
        presort = (len(plan.mixer_ops) >= 2
                   and isinstance(plan.mixer_ops[0], SortOp)
                   and isinstance(plan.mixer_ops[1], LimitOp))
        for part, batch in zip(partials, batches):
            pre = batch
            if presort:
                pre = apply_limit(apply_sort(pre, plan.mixer_ops[0]),
                                  plan.mixer_ops[1].k)
            part.batch = pre

    # profile attribution: wave phases are shared work, split evenly
    io_each = (t2 - t1) * 1e3 / len(live)
    cpu_each = (time.perf_counter() - t0) * 1e3 / len(live)
    for part in partials:
        part.io_ms = io_each
        part.cpu_ms = cpu_each
    return partials, failed


def resolve_partition_plan(partitions, backend, plan: Plan,
                           fault_plan: Optional[FaultPlan] = None,
                           profile=None) -> PartitionPlan:
    """Resolve P (engine arg > ``REPRO_EXEC_PARTITIONS`` > mesh size for
    batched backends) and assign the plan's pruned shard list to P
    contiguous partitions.  A partition whose FaultPlan check trips
    (stage ``"partition"``) is drained *before* dispatch and its shards
    rerouted across the surviving partitions
    (``launch.elastic.reroute_partitions``, counted on ``profile.retries``)
    — the partition-axis recovery path both engines share."""
    p = num_partitions(partitions, backend)
    pplan = partition_shards(plan.shard_ids, p)
    if fault_plan is not None and pplan.num_partitions > 1:
        failed = []
        for pi in range(pplan.num_partitions):
            try:
                fault_plan.check("partition", pi)
            except TaskFailure:
                failed.append(pi)
        if failed:
            from ..launch.elastic import reroute_partitions

            rerouted = reroute_partitions(pplan.parts, failed)
            if rerouted != pplan.parts and profile is not None:
                profile.retries += len(failed)
            pplan = PartitionPlan(rerouted)
    return pplan


def merge_partition_partials(db: FDb, plan: Plan,
                             partials: Sequence[ShardPartial],
                             backend, pplan) -> Optional[AggPartial]:
    """The partitioned Mixer combine: fold per-shard fused segment states
    into ONE pre-merged ``AggPartial`` through ``backend.merge_partials``
    (a single recorded combine launch).

    Returns ``None`` when the combine doesn't apply and the caller should
    keep the host ``merge_agg_partials`` fold — P=1 (the legacy sequential
    path *is* the reference), non-aggregate plans, fused-agg-ineligible
    plans, or any partial missing its raw ``seg`` state (e.g. a shard
    recovered through the per-shard retry path).  The host fold is
    partition-invariant anyway — engines sort partials back into shard-id
    order first — so the fallback only costs the merge launch evidence,
    never correctness.

    ``partials`` must already be sorted by shard id: partitions are
    contiguous shard slices, so shard-id order is exactly the states
    order the sequential P=1 reference accumulates in.
    """
    if pplan is None or pplan.num_partitions <= 1:
        return None
    if not partials:
        return None
    if not (plan.mixer_ops and isinstance(plan.mixer_ops[0], AggregateOp)):
        return None
    if any(p.seg is None for p in partials):
        return None
    fused_agg = fused_agg_plan(plan, [db.shards[s] for s in plan.shard_ids])
    if fused_agg is None:
        return None
    by_part = {sid: i for i, part in enumerate(pplan.parts)
               for sid in part}
    counts = [0] * pplan.num_partitions
    for p in partials:
        counts[by_part.get(p.shard_id, 0)] += 1
    uniq, slots = backend.merge_partials([p.seg for p in partials],
                                         minmax=fused_agg.minmax,
                                         parts=counts)
    return _fused_agg_finalize(fused_agg, uniq, slots)
