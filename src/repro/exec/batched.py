"""Batched multi-shard wave execution (the stacked-shard hot path).

Per-shard dispatch pays one kernel launch per shard per primitive — the
dominant scaling cliff once shard counts reach the hundreds the paper runs
(§4–5).  This module groups a plan's shards into **waves** and drives each
wave through the backend's batched ops, so a wave costs:

  * one ``probe_shards`` launch       (stacked bitmap AND + popcount),
  * one ``refine_tracks_batched`` launch per track-refine spec (the exact
    Tesseract point-in-cover × time-window pass, fused on device),
  * one ``compact_masks`` launch      (stacked selection → doc ids),
  * one ``compact_masks`` launch      for the residual filter (if any),
  * one ``segment_aggregate_batched`` launch per aggregated value column,

instead of the same set *per shard* — ⌈shards/wave⌉ launches per primitive
per query (asserted by ``tests/test_batched.py`` / ``tests/test_refine.py``
via the kernel launch counter).  The numpy backend's batched ops loop
shard-by-shard, so the wave runner is byte-identical to the per-shard path
on both backends.

Engines schedule waves onto their worker pools; shards whose fault check
trips at wave start are returned to the caller for the engine's per-shard
retry/recovery machinery (``run_shard_task``), which keeps the failure
unit a single shard.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.exprs import CollectedTable
from ..core.flow import AggregateOp, LimitOp, SortOp
from ..core.planner import Plan
from ..fdb.fdb import FDb
from ..fdb.index import mask_from_bitmap
from .backend import as_backend
from .failures import FaultPlan, TaskFailure
from .processors import (aggregate_produce_batched, apply_limit, apply_sort,
                         predicate_mask, run_record_ops)
from .task import ShardPartial

__all__ = ["DEFAULT_WAVE", "WAVE_ENV", "wave_size", "partition_waves",
           "run_wave_task"]

DEFAULT_WAVE = 8
WAVE_ENV = "REPRO_EXEC_WAVE"


def wave_size(spec: Optional[int] = None, backend=None) -> int:
    """Shards per wave: explicit argument > $REPRO_EXEC_WAVE > backend
    default (``DEFAULT_WAVE`` when the backend's batched ops amortize
    kernel launches, else 1 — a loop-over-shards backend gains nothing
    from wide waves and would only lose per-shard thread parallelism)."""
    if spec is not None:
        return max(1, int(spec))
    env = os.environ.get(WAVE_ENV)
    if env:
        return max(1, int(env))
    if backend is not None and not getattr(backend, "batched_dispatch",
                                           False):
        return 1
    return DEFAULT_WAVE


def partition_waves(shard_ids: Sequence[int], wave: int) -> List[List[int]]:
    sids = list(shard_ids)
    return [sids[i:i + wave] for i in range(0, len(sids), wave)]


def run_wave_task(db: FDb, plan: Plan, sids: Sequence[int],
                  tables: Optional[Dict[int, CollectedTable]],
                  catalog, fault_plan: Optional[FaultPlan] = None,
                  stage: str = "server", backend=None
                  ) -> Tuple[List[ShardPartial], List[int]]:
    """Run one wave of shard tasks through the batched backend seam.

    Returns ``(partials, failed_shard_ids)``: shards whose fault check
    trips are excluded from the wave and handed back for the engine's
    per-shard retry path.
    """
    backend = as_backend(backend)
    failed: List[int] = []
    live: List[int] = []
    for sid in sids:
        if fault_plan is not None:
            try:
                fault_plan.check(stage, sid)
            except TaskFailure:
                failed.append(sid)
                continue
        live.append(sid)
    if not live:
        return [], failed

    t0 = time.perf_counter()
    shards = [db.shards[sid] for sid in live]
    # ---- stacked index probe: one launch per wave
    bms = backend.probe_shards(
        [sh.all_bitmap() for sh in shards],
        [[p.run(sh) for p in plan.probes] for sh in shards])
    masks = [mask_from_bitmap(bm, sh.n) for bm, sh in zip(bms, shards)]
    # rows_selected reports the *index-selected* candidates (pre-refine),
    # matching the per-shard path and tesseract_stats' candidate counts
    n_cands = [int(m.sum()) for m in masks]
    # ---- exact track refine: one fused device launch per wave per spec,
    # emitting per-doc hit masks that feed the selection compact below
    for rf in plan.refines:
        masks = backend.refine_tracks_batched(
            [sh.batch for sh in shards], rf.path, rf.constraints, masks,
            edges=rf.edges)
    ids_list = backend.compact_masks(masks)
    t1 = time.perf_counter()

    # ---- selective column read (device-resident buffers when primed)
    partials: List[ShardPartial] = []
    batches = []
    for sid, sh, ids, n_cand in zip(live, shards, ids_list, n_cands):
        paths = [p for p in plan.source_paths if p in sh.batch.columns]
        if not paths:
            paths = sh.batch.paths()
        batch = backend.gather_columns(sh.batch, paths, ids)
        partials.append(ShardPartial(shard_id=sid, rows_scanned=sh.n,
                                     rows_selected=n_cand,
                                     bytes_read=batch.nbytes()))
        batches.append(batch)
    t2 = time.perf_counter()

    # ---- residual filter: masks host-evaluated, compacted in one launch
    if plan.residual is not None:
        keeps = backend.compact_masks(
            [predicate_mask(b, plan.residual) for b in batches])
        batches = [b.gather(k) for b, k in zip(batches, keeps)]
    batches = [run_record_ops(b, plan.server_ops, catalog, tables,
                              backend=backend) for b in batches]

    # ---- tail: wave-batched aggregation, or per-shard presort/limit
    if plan.mixer_ops and isinstance(plan.mixer_ops[0], AggregateOp):
        aggs = aggregate_produce_batched(batches, plan.mixer_ops[0].spec,
                                         backend)
        for part, agg in zip(partials, aggs):
            part.agg = agg
    else:
        presort = (len(plan.mixer_ops) >= 2
                   and isinstance(plan.mixer_ops[0], SortOp)
                   and isinstance(plan.mixer_ops[1], LimitOp))
        for part, batch in zip(partials, batches):
            pre = batch
            if presort:
                pre = apply_limit(apply_sort(pre, plan.mixer_ops[0]),
                                  plan.mixer_ops[1].k)
            part.batch = pre

    # profile attribution: wave phases are shared work, split evenly
    io_each = (t2 - t1) * 1e3 / len(live)
    cpu_each = (time.perf_counter() - t0) * 1e3 / len(live)
    for part in partials:
        part.io_ms = io_each
        part.cpu_ms = cpu_each
    return partials, failed
