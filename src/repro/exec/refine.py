"""Host-side packing + numpy oracle for the ragged track refine.

The exact Tesseract pass — "some track point inside the region's cover
during the window, for every constraint" — runs in two shapes:

  * :func:`refine_tracks_host` — the numpy oracle over raw CSR
    ``(lat, lng, t, row_splits)`` columns, semantically identical to
    ``eval_expr(InSpaceTime)`` (``repro.core.exprs``).  It optionally
    restricts work to candidate docs (the index-probe survivors) via a
    spans-concatenate gather, which is what the per-shard host path runs.
  * the device kernel (``repro.kernels.refine``), which consumes the
    *packed* integer form built here: Morton keys and order-mapped float64
    timestamps split into uint32 (hi, lo) word pairs, plus the per-point
    doc-id expansion of ``row_splits``.  Packing is a pure function of the
    stored track, so the jax backend computes it once per shard at
    ``prime_fdb`` time and keeps it device-resident.

Both shapes are exact bit/integer work on the same inputs, so backend
results are byte-identical (the parity contract the tests enforce) — and
both produce the same per-(doc × constraint) **reduction tables** from the
one-hot compare pass: the **first-hit** table (minimum packed timestamp
among a doc's points satisfying a constraint, :data:`FIRST_HIT_NONE` when
none) that ordered (A-then-B) queries compare edge-wise, the dual
**last-hit** max table (:data:`LAST_HIT_NONE` when none), and the
per-constraint **hit count** — the inputs to ``Tesseract.at_least(k)``
("≥ k points in A") and ``Tesseract.dwell(min_s)`` (last − first ≥ n
seconds, compared on the unpacked float64 values — the sort key preserves
order, not differences).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..fdb.columnar import span_indices
from ..geo import mercator as M

__all__ = ["f64_sort_key", "f64_from_sort_key", "pack_track_points",
           "pack_constraints", "pack_constraints_multi",
           "refine_tracks_host", "reduction_verdict", "FIRST_HIT_NONE",
           "LAST_HIT_NONE"]

_U32 = np.uint64(0xFFFFFFFF)
_SHIFT32 = np.uint64(32)

#: first-hit sentinel: a (cell, t) pair no finite timestamp maps to —
#: ``f64_sort_key`` reaches 0xFFFF… only for NaN payloads, and NaN
#: timestamps never satisfy a window compare, so "no hit" is unambiguous
FIRST_HIT_NONE = np.uint64(0xFFFFFFFFFFFFFFFF)

#: last-hit sentinel, the max-reduce dual: ``f64_sort_key`` reaches 0 only
#: for negative NaN payloads, which never satisfy a window compare
LAST_HIT_NONE = np.uint64(0)


def f64_sort_key(t) -> np.ndarray:
    """Map float64 → uint64 preserving order: flip all bits of negatives,
    set the sign bit of non-negatives (−0.0 is first normalized to +0.0 so
    the two zeros stay equal).  Lets the kernel compare timestamps with
    exact integer word compares instead of device float64."""
    t = np.asarray(t, dtype=np.float64) + 0.0       # −0.0 + 0.0 → +0.0
    bits = t.view(np.uint64)
    neg = bits >> np.uint64(63) != 0
    return np.where(neg, ~bits, bits | np.uint64(1) << np.uint64(63))


def f64_from_sort_key(k) -> np.ndarray:
    """Inverse of :func:`f64_sort_key`: uint64 order key → float64.

    Dwell predicates need real time *differences* — the sort key preserves
    order, not arithmetic — so last/first keys are unpacked before the
    ``last − first >= min_s`` compare.  Sentinel keys unpack to NaN
    payloads, which fail any dwell compare.
    """
    k = np.asarray(k, dtype=np.uint64)
    sign = k >> np.uint64(63) != 0
    bits = np.where(sign, k & ~(np.uint64(1) << np.uint64(63)), ~k)
    return bits.view(np.float64)


def _split_words(v: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    v = np.asarray(v, dtype=np.uint64)
    return ((v >> _SHIFT32).astype(np.uint32),
            (v & _U32).astype(np.uint32))


def pack_track_points(lat: np.ndarray, lng: np.ndarray, t: np.ndarray,
                      row_splits: Optional[np.ndarray]
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """CSR track columns → (pts uint32 [4, P], rows int32 [P]).

    pts rows are (key_hi, key_lo, t_hi, t_lo); rows is the per-point doc
    id (``row_splits`` expanded; identity for singular location fields).
    """
    keys = M.latlng_to_morton(lat, lng)
    k_hi, k_lo = _split_words(keys)
    t_hi, t_lo = _split_words(f64_sort_key(t))
    pts = np.stack([k_hi, k_lo, t_hi, t_lo]).astype(np.uint32)
    if row_splits is None:
        rows = np.arange(keys.size, dtype=np.int32)
    else:
        rows = np.repeat(
            np.arange(row_splits.size - 1, dtype=np.int32),
            np.diff(row_splits))
    return pts, rows


def pack_constraints(constraints: Sequence[Tuple[object, float, float]]
                     ) -> np.ndarray:
    """[(AreaTree, t0, t1), …] → uint32 [C, 8, R] word table.

    Slot r of constraint c holds (cover-range lo, hi) and the constraint's
    (window lo, hi), each split into (hi, lo) 32-bit words.  Range slots
    beyond the region's cover are the empty range (lo = 2^64−1, hi = 0) —
    never satisfiable — while window words fill every slot so the kernel
    and reference can read them from any slot.
    """
    n_c = len(constraints)
    r_pad = 128
    for region, _, _ in constraints:
        r_pad = max(r_pad, -(-int(region.lo.size) // 128) * 128)
    cov = np.zeros((n_c, 8, r_pad), dtype=np.uint32)
    cov[:, 0, :] = 0xFFFFFFFF                      # empty-range padding
    cov[:, 1, :] = 0xFFFFFFFF
    for c, (region, t0, t1) in enumerate(constraints):
        r = int(region.lo.size)
        if r:
            cov[c, 0, :r], cov[c, 1, :r] = _split_words(region.lo)
            cov[c, 2, :r], cov[c, 3, :r] = _split_words(region.hi)
        w0_hi, w0_lo = _split_words(f64_sort_key(t0))
        w1_hi, w1_lo = _split_words(f64_sort_key(t1))
        cov[c, 4, :] = w0_hi
        cov[c, 5, :] = w0_lo
        cov[c, 6, :] = w1_hi
        cov[c, 7, :] = w1_lo
    return cov


def pack_constraints_multi(constraints_list) -> np.ndarray:
    """Q queries' constraint lists → one uint32 ``[Q, C_max, 8, R_max]``
    table for the multi-query refine kernel.

    Per-query tables (:func:`pack_constraints`) are padded to a common
    shape: the range axis with the never-hit empty range, the constraint
    axis with **always-hit** pad constraints — one range slot covering the
    whole key space ``[0, 2^64)`` with a ``[0, 2^64)`` window, satisfied
    by any doc that has at least one point.  Padding is sound because
    every query carries ≥1 real constraint (the coalescer guarantees it):
    a doc passing its real constraints necessarily has a point, so the pad
    bit is set too; a doc with no points fails its real constraints
    anyway.  Pad constraints never appear in ordering edges.
    """
    covs = [pack_constraints(list(cons)) for cons in constraints_list]
    if not covs:
        return np.zeros((0, 0, 8, 128), dtype=np.uint32)
    c_max = max(c.shape[0] for c in covs)
    r_max = max(c.shape[2] for c in covs)
    out = np.zeros((len(covs), c_max, 8, r_max), dtype=np.uint32)
    # never-hit default for every slot of every (possibly padded) row
    out[:, :, 0, :] = 0xFFFFFFFF
    out[:, :, 1, :] = 0xFFFFFFFF
    for q, cov in enumerate(covs):
        c, _, r = cov.shape
        out[q, :c, :, :r] = cov
        # re-assert never-hit on the R pad of real constraints (the copy
        # above overwrote columns [:r] only; [r:] keeps the default) and
        # fill the C pad rows with the always-hit constraint
        for cp in range(c, c_max):
            out[q, cp, 0, 0] = 0        # key >= 0
            out[q, cp, 1, 0] = 0
            out[q, cp, 2, 0] = 0xFFFFFFFF   # key < 2^64−1 (keys are 60-bit)
            out[q, cp, 3, 0] = 0xFFFFFFFF
            out[q, cp, 4, :] = 0        # window [0, 2^64−1]: always true
            out[q, cp, 5, :] = 0
            out[q, cp, 6, :] = 0xFFFFFFFF
            out[q, cp, 7, :] = 0xFFFFFFFF
    return out


def reduction_verdict(first: np.ndarray, last: np.ndarray,
                      count: np.ndarray, edges: Sequence[Tuple[int, int]]
                      = (), min_counts: Optional[Sequence[int]] = None,
                      dwells: Optional[Sequence[Optional[float]]] = None
                      ) -> np.ndarray:
    """Per-doc verdict recomputed from host reduction tables.

    ``first``/``last`` uint64 [n_docs, C], ``count`` int [n_docs, C] —
    the tables :func:`refine_tracks_host` (or the synced kernel outputs)
    produce.  The kernel's all-constraints-hit mask can't express a
    ``k = 0`` (vacuous) constraint, so the jax backend recomputes the
    verdict from the count table whenever reductions are present:
    ``doc_hit ≡ count > 0`` exactly, byte-equal to the oracle's verdict.
    """
    n_docs, n_c = count.shape
    out = np.ones(n_docs, dtype=bool)
    for c in range(n_c):
        doc_hit = count[:, c] > 0
        k = 1 if min_counts is None else int(min_counts[c])
        if k == 1:
            ok = doc_hit
        elif k <= 0:
            ok = np.ones(n_docs, dtype=bool)
        else:
            ok = count[:, c] >= k
        d = None if dwells is None else dwells[c]
        if d is not None:
            span = f64_from_sort_key(last[:, c]) \
                - f64_from_sort_key(first[:, c])
            ok = ok & doc_hit & (span >= float(d))
        out &= ok
    for i, j in edges:
        out &= first[:, i] < first[:, j]
    return out


def refine_tracks_host(lat: np.ndarray, lng: np.ndarray, t: np.ndarray,
                       row_splits: Optional[np.ndarray], n_docs: int,
                       constraints: Sequence[Tuple[object, float, float]],
                       candidates: Optional[np.ndarray] = None,
                       edges: Sequence[Tuple[int, int]] = (),
                       with_first_hits: bool = False,
                       min_counts: Optional[Sequence[int]] = None,
                       dwells: Optional[Sequence[Optional[float]]] = None,
                       with_analytics: bool = False):
    """Numpy oracle: exact per-doc refine mask [n_docs] bool.

    ``candidates`` (bool [n_docs]) restricts evaluation to the index-probe
    survivors — docs outside it come back False, and because the per-doc
    verdict is independent of other docs, the result equals
    ``full_refine & candidates`` bit for bit.

    ``edges`` is the ordering DAG over ``constraints``: edge ``(i, j)``
    additionally requires the doc's **first hit** of constraint ``i`` to be
    strictly before its first hit of constraint ``j``, where first hit =
    the lexicographic-minimum packed timestamp (``f64_sort_key``) among the
    doc's points satisfying the constraint, or :data:`FIRST_HIT_NONE` when
    none do.  Equal first hits do not count as before.

    ``min_counts[c] = k`` replaces the "≥ 1 hit" verdict for constraint
    ``c`` with "≥ k hits" (each satisfying track point counts once).
    ``k = 0`` is vacuously true — the constraint stops filtering (the
    planner also drops its index probe so un-hit docs survive to refine).
    ``dwells[c] = d`` additionally requires the doc to have spent at least
    ``d`` seconds in the constraint: ≥ 1 hit and
    ``t(last hit) − t(first hit) >= d`` on the unpacked float64 values
    (inclusive at the threshold; a single hit satisfies only ``d <= 0``).

    ``with_first_hits`` returns ``(mask, first)`` with ``first`` the
    uint64 ``[n_docs, C]`` first-hit table (sentinel outside ``candidates``
    when restricted); ``with_analytics`` returns
    ``(mask, first, last, count)`` adding the uint64 last-hit table
    (:data:`LAST_HIT_NONE` when no hit) and int64 hit-count table — the
    parity surfaces the jax kernel must match byte for byte.
    """
    n_c = len(constraints)
    edges = list(edges)
    any_dwell = dwells is not None and any(d is not None for d in dwells)
    need_first = bool(edges) or with_first_hits or with_analytics or any_dwell
    need_last = with_analytics or any_dwell
    need_count = with_analytics or min_counts is not None
    first = np.full((n_docs, n_c), FIRST_HIT_NONE, dtype=np.uint64) \
        if need_first else None
    last = np.full((n_docs, n_c), LAST_HIT_NONE, dtype=np.uint64) \
        if need_last else None
    count = np.zeros((n_docs, n_c), dtype=np.int64) if need_count else None

    def ok_of(c, doc_hit):
        ok = doc_hit
        if min_counts is not None and int(min_counts[c]) != 1:
            k = int(min_counts[c])
            ok = np.ones(n_docs, dtype=bool) if k <= 0 else count[:, c] >= k
        if dwells is not None and dwells[c] is not None:
            span = f64_from_sort_key(last[:, c]) \
                - f64_from_sort_key(first[:, c])
            ok = ok & doc_hit & (span >= float(dwells[c]))
        return ok

    def finish(out):
        for i, j in edges:
            out &= first[:, i] < first[:, j]
        if with_analytics:
            return out, first, last, count
        return (out, first) if with_first_hits else out

    if n_docs == 0:
        return finish(np.zeros(0, dtype=bool))
    if row_splits is None:                         # singular location + t
        keys = M.latlng_to_morton(lat, lng)
        cand = None if candidates is None \
            else np.asarray(candidates, dtype=bool)
        out = np.ones(n_docs, dtype=bool) if cand is None else cand.copy()
        tkey = f64_sort_key(t) if (need_first or need_last) else None
        for c, (region, t0, t1) in enumerate(constraints):
            hit = region.contains(keys) & (t >= t0) & (t <= t1)
            masked = hit if cand is None else hit & cand
            if need_first:
                first[:, c] = np.where(masked, tkey, FIRST_HIT_NONE)
            if need_last:
                last[:, c] = np.where(masked, tkey, LAST_HIT_NONE)
            if need_count:
                count[:, c] = masked.astype(np.int64)
            out &= ok_of(c, masked) if (min_counts is not None
                                        or any_dwell) else hit
        return finish(out)
    if candidates is not None:
        cand = np.asarray(candidates, dtype=bool)
        ids = np.nonzero(cand)[0]
        flat = span_indices(row_splits[ids], row_splits[ids + 1])
        lat, lng, t = lat[flat], lng[flat], t[flat]
        row_of = np.repeat(ids, np.diff(row_splits)[ids])
        out = cand.copy()
    else:
        row_of = np.repeat(np.arange(n_docs), np.diff(row_splits))
        out = np.ones(n_docs, dtype=bool)
    keys = M.latlng_to_morton(lat, lng)
    tkey = f64_sort_key(t) if (need_first or need_last) else None
    for c, (region, t0, t1) in enumerate(constraints):
        hit = region.contains(keys) & (t >= t0) & (t <= t1)
        doc_hit = np.zeros(n_docs, dtype=bool)
        if hit.size:
            np.logical_or.at(doc_hit, row_of, hit)
            if need_first:
                np.minimum.at(first[:, c], row_of,
                              np.where(hit, tkey, FIRST_HIT_NONE))
            if need_last:
                np.maximum.at(last[:, c], row_of,
                              np.where(hit, tkey, LAST_HIT_NONE))
            if need_count:
                np.add.at(count[:, c], row_of, hit)
        out &= ok_of(c, doc_hit)
    return finish(out)
