"""Pluggable execution backends for the query hot path.

The paper's time-to-first-result hinges on three per-shard primitives:

  * **bitmap intersection** — AND-reduce the index-probe postings
    (``probe_shard``),
  * **mask compaction** — positions of selected rows after the residual
    filter (``apply_filter``),
  * **group-by partial aggregation** — (count, sum, sumsq) per group code
    (``aggregate_produce``),

An :class:`ExecBackend` supplies all three behind one seam so the logical
plan stays engine- and backend-agnostic:

  * ``numpy``  — the host reference (current behavior, the parity oracle),
  * ``jax``    — dispatches through :mod:`repro.kernels.ops`, which selects
    the Pallas kernels on TPU (``pallas``), the interpreted kernel bodies
    (``interpret``), or the pure-jnp oracle (``reference``) via
    ``REPRO_KERNEL_IMPL``.

Select a backend per engine (``AdHocEngine(backend="jax")``), per session
(``Session(backend="jax")``), or globally with ``REPRO_EXEC_BACKEND``.
Bit/integer primitives are exact, so selection is byte-identical across
backends; the jax ``reference`` aggregation path runs the segment kernel
math at float64 (``enable_x64``) and accumulates in row order — bit-equal
to the numpy oracle's ``bincount`` — while ``pallas``/``interpret`` keep
the MXU's float32, the TPU deployment precision.

Future scaling PRs (sharded device meshes, async prefetch, GPU lowering)
plug in here: ``register_backend`` a new implementation and every engine
picks it up.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..fdb.index import (bitmap_stack, ids_from_bitmap, mask_from_bitmap)

__all__ = ["ExecBackend", "NumpyBackend", "JaxBackend", "register_backend",
           "backend_names", "get_backend", "as_backend"]


class ExecBackend:
    """Interface every execution backend implements.

    All methods take and return **host** numpy arrays; a device-resident
    backend owns its own transfers (and may cache device buffers keyed by
    array identity).  Contracts:

      * ``intersect_bitmaps(full, bitmaps)`` → uint32 word bitmap: AND of
        ``full`` (the shard's valid-doc mask) and every probe bitmap.
      * ``select_ids(bitmap, n)`` → ascending int64 doc ids of set bits.
      * ``compact_mask(mask)`` → ascending int64 positions of True entries.
      * ``segment_aggregate(codes, values, num_groups)`` →
        ``(count[G] int64, sum[G] float64, sumsq[G] float64)`` with rows
        whose code is negative ignored.
    """

    name: str = "abstract"

    def intersect_bitmaps(self, full: np.ndarray,
                          bitmaps: Sequence[np.ndarray]) -> np.ndarray:
        raise NotImplementedError

    def select_ids(self, bitmap: np.ndarray, n: int) -> np.ndarray:
        raise NotImplementedError

    def compact_mask(self, mask: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def segment_aggregate(self, codes: np.ndarray, values: np.ndarray,
                          num_groups: int
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        raise NotImplementedError

    def __repr__(self):
        return f"<ExecBackend {self.name}>"


# --------------------------------------------------------------------------
# numpy — host reference implementation (the oracle)
# --------------------------------------------------------------------------

class NumpyBackend(ExecBackend):
    name = "numpy"

    def intersect_bitmaps(self, full, bitmaps):
        bm = full
        for b in bitmaps:
            bm = bm & b
        return bm

    def select_ids(self, bitmap, n):
        return ids_from_bitmap(bitmap, n)

    def compact_mask(self, mask):
        return np.nonzero(mask)[0].astype(np.int64)

    def segment_aggregate(self, codes, values, num_groups):
        codes = np.asarray(codes, dtype=np.int64)
        keep = codes >= 0
        if not keep.all():
            codes, values = codes[keep], np.asarray(values)[keep]
        v = np.asarray(values, dtype=np.float64)
        cnt = np.bincount(codes, minlength=num_groups)[:num_groups]
        s = np.bincount(codes, weights=v, minlength=num_groups)[:num_groups]
        s2 = np.bincount(codes, weights=v * v,
                         minlength=num_groups)[:num_groups]
        return cnt.astype(np.int64), s, s2


# --------------------------------------------------------------------------
# jax — kernels.ops dispatch (pallas on TPU, interpret/reference elsewhere)
# --------------------------------------------------------------------------

class JaxBackend(ExecBackend):
    """Routes the hot loop through :mod:`repro.kernels.ops`.

    ``impl`` pins the kernel implementation (``pallas`` / ``interpret`` /
    ``reference``); default defers to ``ops.default_impl()`` per call, so
    ``REPRO_KERNEL_IMPL`` keeps working.
    """

    name = "jax"

    def __init__(self, impl: Optional[str] = None):
        import jax  # container ships the jax_pallas toolchain
        import jax.numpy as jnp
        from ..kernels import ops
        self._jax, self._jnp, self._ops = jax, jnp, ops
        self.impl = impl

    def _impl(self) -> str:
        return self.impl or self._ops.default_impl()

    def intersect_bitmaps(self, full, bitmaps):
        if not bitmaps:
            return full
        stack = bitmap_stack([full, *bitmaps])
        bm, _count = self._ops.bitmap_intersect(self._jnp.asarray(stack),
                                                impl=self._impl())
        return np.asarray(bm, dtype=np.uint32)

    def select_ids(self, bitmap, n):
        return self.compact_mask(mask_from_bitmap(bitmap, n))

    def compact_mask(self, mask):
        mask = np.asarray(mask, dtype=bool)
        idx, count = self._ops.compact(self._jnp.asarray(mask),
                                       impl=self._impl())
        return np.asarray(idx[: int(count)], dtype=np.int64)

    def segment_aggregate(self, codes, values, num_groups):
        impl = self._impl()
        codes32 = np.ascontiguousarray(codes, dtype=np.int32)
        if impl == "reference":
            # float64 + row-order accumulation: bit-equal to the numpy
            # oracle, and the same segment math the kernel implements.
            with self._jax.experimental.enable_x64():
                cnt, s, s2 = self._ops.segment_agg(
                    self._jnp.asarray(codes32),
                    self._jnp.asarray(np.asarray(values, dtype=np.float64)),
                    num_groups, impl=impl)
                cnt, s, s2 = (np.asarray(cnt), np.asarray(s, np.float64),
                              np.asarray(s2, np.float64))
        else:
            cnt, s, s2 = self._ops.segment_agg(
                self._jnp.asarray(codes32),
                self._jnp.asarray(np.asarray(values, dtype=np.float32)),
                num_groups, impl=impl)
            cnt, s, s2 = (np.asarray(cnt), np.asarray(s, np.float64),
                          np.asarray(s2, np.float64))
        return np.rint(cnt).astype(np.int64), s, s2


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_FACTORIES: Dict[str, Callable[[], ExecBackend]] = {}
_INSTANCES: Dict[str, ExecBackend] = {}


def register_backend(name: str, factory: Callable[[], ExecBackend]) -> None:
    """Register (or replace) a backend under ``name``."""
    _FACTORIES[name] = factory
    _INSTANCES.pop(name, None)


def backend_names() -> List[str]:
    return sorted(_FACTORIES)


register_backend("numpy", NumpyBackend)
register_backend("jax", JaxBackend)


def get_backend(spec: Optional[str] = None) -> ExecBackend:
    """Resolve a backend name (default: ``$REPRO_EXEC_BACKEND`` or numpy)."""
    name = spec or os.environ.get("REPRO_EXEC_BACKEND") or "numpy"
    if name not in _FACTORIES:
        raise ValueError(f"unknown exec backend {name!r}; "
                         f"registered: {backend_names()}")
    if name not in _INSTANCES:
        _INSTANCES[name] = _FACTORIES[name]()
    return _INSTANCES[name]


def as_backend(spec: Union[None, str, ExecBackend]) -> ExecBackend:
    """Accept None (env default), a registered name, or an instance."""
    if isinstance(spec, ExecBackend):
        return spec
    return get_backend(spec)
